"""Train a ~100M-parameter LM with QAT + weight-set restriction, with
fault-tolerant checkpointing — the framework's end-to-end LM driver.

Runs a few hundred steps on CPU (olmo-family reduced config, synthetic
bigram corpus), restricts the FFN weight sets to 16 values mid-training (the
paper's technique applied to a transformer), and shows loss keeps improving.
Demonstrates: spec-system init, train_step factory, deterministic resumable
data, CheckpointManager + resilient loop, straggler monitor.

    PYTHONPATH=src python examples/train_lm_qat.py [--steps N] [--arch olmo-1b]
"""

import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.core.lm_compress import init_lm_comp, lm_comp_layers, set_codebook
from repro.data.synthetic import SyntheticTokens
from repro.distributed.fault import StragglerMonitor, run_resilient_loop
from repro.launch.train import StepConfig, init_train_state, make_train_step
from repro.models.lm import build_lm
from repro.nn.spec import spec_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (hours on one CPU core; the "
                         "default is a ~17M quick profile of the same run)")
    args = ap.parse_args()

    if args.full:
        cfg = get_config(args.arch).scaled_down(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=2304, vocab=32768, compute_dtype="float32")
    else:
        cfg = get_config(args.arch).scaled_down(
            n_layers=4, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
            d_ff=1536, vocab=8192, compute_dtype="float32")
    model = build_lm(cfg)
    n_params = spec_count(model.spec)
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M")

    step_cfg = StepConfig(qat=True, with_comp=True, remat=False,
                          q_block=128, kv_block=128, lr=6e-4)
    state = init_train_state(model, step_cfg)
    comp = init_lm_comp(model)
    print(f"compressible units: {len(lm_comp_layers(model))}")

    train_step = jax.jit(make_train_step(model, step_cfg))
    data = SyntheticTokens(vocab=cfg.vocab, seed=0)
    batch_size, seq = 8, 128

    def data_fn(step):
        x, y = data.batch(step, batch_size, seq)
        return {"tokens": x, "labels": y}

    def step_fn(state, batch):
        return train_step(state, batch, comp)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    monitor = StragglerMonitor()

    half = args.steps // 2
    state, rep1 = run_resilient_loop(
        step_fn=step_fn, data_fn=data_fn, state=state, ckpt=ckpt,
        n_steps=half, checkpoint_every=50, monitor=monitor)
    print(f"phase 1 (unrestricted QAT): loss {rep1.losses[0]:.3f} -> "
          f"{rep1.losses[-1]:.3f}")

    # ---- apply the paper's weight-set restriction to the FFN matmuls
    restricted = [-112, -80, -56, -40, -28, -16, -8, 0,
                  8, 16, 28, 40, 56, 80, 112, 127]
    for unit in lm_comp_layers(model):
        if "/mlp/" in unit:
            comp = set_codebook(comp, unit, restricted)
    print(f"restricted every FFN matmul to {len(restricted)} weight values")

    state, rep2 = run_resilient_loop(
        step_fn=step_fn, data_fn=data_fn, state=state, ckpt=ckpt,
        n_steps=args.steps - half, start_step=half, checkpoint_every=50,
        monitor=monitor)
    print(f"phase 2 (16-value FFN):     loss {rep2.losses[0]:.3f} -> "
          f"{rep2.losses[-1]:.3f}")
    print(f"checkpoints kept: {ckpt.all_steps()}  stragglers: "
          f"{monitor.flagged}")
    assert rep2.losses[-1] < rep1.losses[0], "training must make progress"
    print("OK")


if __name__ == "__main__":
    main()
