"""End-to-end paper driver: ResNet-20 energy-aware layer-wise compression.

The full Section 5 protocol as ONE `repro.pipeline.Pipeline` run through
every stage — QAT base training, per-layer systolic-trace profiling, the
energy model, energy-prioritized layer-wise compression (pruning x weight-set
selection under the global accuracy constraint), packed 4-bit export, and the
whole-model LUT-GEMM serve check against the QAT fake-quant forward. The
resulting `CompressionPlan` can be saved (``--plan-out``) and re-served later
with ``repro serve --plan-in``.

    PYTHONPATH=src python examples/compress_resnet20.py [--steps N]
    PYTHONPATH=src python examples/compress_resnet20.py --reduced  # CPU smoke
"""

import argparse
import json

from repro.core.schedule import ScheduleConfig
from repro.core.weight_selection import SelectionConfig
from repro.pipeline import (
    Pipeline,
    PipelineConfig,
    ProfileStageConfig,
    ServeStageConfig,
    TargetConfig,
    TrainStageConfig,
)


def build_config(args) -> PipelineConfig:
    return PipelineConfig(
        target=TargetConfig(kind="cnn",
                            arch="resnet8" if args.reduced else "resnet20",
                            data_seed=7, batch_size=64, lr=2e-3),
        train=TrainStageConfig(
            qat_steps=args.steps,
            final_finetune_steps=max(args.steps // 6, 20),
            eval_batches=2 if args.reduced else 3),
        profile=ProfileStageConfig(batches=1,
                                   max_tiles=4 if args.reduced else 8),
        schedule=ScheduleConfig(prune_ratios=(0.7, 0.5), k_targets=(16,),
                                delta_acc=0.05, finetune_steps=20,
                                trial_finetune_steps=12, eval_batches=2,
                                max_layers=2 if args.reduced else 4,
                                search_mode=args.search_mode),
        selection=SelectionConfig(k_init=24, k_target=16, delta_acc=0.05,
                                  score_batches=1, accept_batches=2,
                                  max_score_candidates=4 if args.reduced
                                  else 6),
        serve=ServeStageConfig(use_ref_kernel=args.use_ref_kernel),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized run: resnet8 + a 2-layer schedule budget")
    ap.add_argument("--use-ref-kernel", action="store_true",
                    help="serve through the jnp oracle instead of the "
                         "(interpreted on CPU) Pallas kernel")
    ap.add_argument("--search-mode", choices=("batched", "serial"),
                    default="batched",
                    help="schedule candidate search: vmapped sweep of all "
                         "(prune, k) configs per layer, or the serial "
                         "trial-and-rollback reference")
    ap.add_argument("--plan-out", default=None, metavar="BASE",
                    help="save the CompressionPlan to BASE.json + BASE.npz")
    args = ap.parse_args()

    plan = Pipeline(build_config(args)).run(verbose=True)
    print(json.dumps(plan.summary(), indent=2))

    m = plan.metrics
    print(f"\nexported {m['export_layers']} compressed layers: "
          f"{m['export_weight_bytes_packed']} bytes packed "
          f"({m['export_compression_vs_int8']:.2f}x vs dense int8)")
    if not plan.artifacts:
        print("no layer accepted a <=16-value restriction; nothing to serve")
        return
    print(f"compressed serve: {m['serve_layers']} layers on the 4-bit LUT "
          f"GEMM, full-model logit rel_err={m['serve_logit_rel_err']:.2e} "
          f"vs fake-quant forward")
    print(f"compressed serve accuracy: {m['serve_accuracy']:.3f} "
          f"(schedule reported acc_final={m['acc_final']:.3f})")
    if args.plan_out:
        json_path, npz_path = plan.save(args.plan_out)
        print(f"plan saved: {json_path} + {npz_path}")


if __name__ == "__main__":
    main()
