"""End-to-end paper driver: ResNet-20 energy-aware layer-wise compression.

The full Section 5 protocol — QAT base training, per-layer systolic-trace
profiling, energy-prioritized layer-wise compression (pruning x weight-set
selection under the global accuracy constraint), final fine-tune — followed
by serving one compressed layer through the 4-bit LUT Pallas kernel and
checking it agrees with the QAT forward.

    PYTHONPATH=src python examples/compress_resnet20.py [--steps N]
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qat
from repro.core.compression import CompressionPipeline, PipelineConfig
from repro.core.runner import CnnRunner
from repro.core.schedule import ScheduleConfig
from repro.core.stats import conv_weight_matrix
from repro.core.weight_selection import SelectionConfig
from repro.data.synthetic import SyntheticImages
from repro.kernels.lut_matmul.ops import compress_layer_weights, lut_matmul
from repro.nn import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    runner = CnnRunner(cnn.resnet20(), SyntheticImages(seed=7), batch_size=64,
                       lr=2e-3)
    cfg = PipelineConfig(
        qat_steps=args.steps,
        profile_batches=1,
        profile_max_tiles=8,
        final_finetune_steps=max(args.steps // 6, 20),
        eval_batches=3,
        schedule=ScheduleConfig(prune_ratios=(0.7, 0.5), k_targets=(16,),
                                delta_acc=0.05, finetune_steps=20,
                                trial_finetune_steps=12, eval_batches=2,
                                max_layers=4),
        selection=SelectionConfig(k_init=24, k_target=16, delta_acc=0.05,
                                  score_batches=1, accept_batches=2,
                                  max_score_candidates=6),
    )
    pipe = CompressionPipeline(runner, cfg)
    result = pipe.run(verbose=True)
    print(json.dumps(result.summary(), indent=2))

    # ---- serve one compressed layer through the Pallas LUT kernel
    accepted = [d for d in result.schedule.decisions if d.accepted]
    if accepted:
        layer = accepted[0].layer
        comp = pipe.comp[layer]
        w = runner.model.get_weight(pipe.params, layer)
        cl = runner.model.comp_layer(layer)
        w_mat = conv_weight_matrix(w * comp["mask"]) if cl.kind == "conv" \
            else (w * comp["mask"])
        w_mat = w_mat.T if cl.kind == "conv" else w_mat  # (K, N)
        k_dim = w_mat.shape[0]
        pad_k = (-k_dim) % 128
        w_mat = jnp.pad(w_mat, ((0, pad_k), (0, 0)))
        cb_vals = [int(v) for v in np.asarray(
            comp["codebook"][: int(comp["codebook_k"])])]
        packed, cb, scale = compress_layer_weights(w_mat, cb_vals, block_k=128)
        x = jax.random.normal(jax.random.PRNGKey(0), (32, w_mat.shape[0]))
        y_kernel = lut_matmul(x, packed, cb, scale, interpret=True)
        w_fake = qat.fake_quant_weight(w_mat, {
            "mask": jnp.ones_like(w_mat), "codebook": comp["codebook"],
            "codebook_k": comp["codebook_k"]})
        rel = float(jnp.linalg.norm(y_kernel - x @ w_fake)
                    / jnp.linalg.norm(x @ w_fake))
        print(f"\nLUT-kernel serve check on layer '{layer}': rel_err={rel:.2e}"
              f" (codebook {len(cb_vals)} values, 4-bit weights)")


if __name__ == "__main__":
    main()
