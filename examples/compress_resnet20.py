"""End-to-end paper driver: ResNet-20 energy-aware layer-wise compression.

The full Section 5 protocol — QAT base training, per-layer systolic-trace
profiling, energy-prioritized layer-wise compression (pruning x weight-set
selection under the global accuracy constraint), final fine-tune — then the
deployment step: export every restricted layer to packed 4-bit serving
artifacts (`repro.core.export`) and run the *whole model* through the LUT
GEMM serve path, checking logits and accuracy against the QAT fake-quant
forward. Schedule -> export -> compressed inference, one invocation.

    PYTHONPATH=src python examples/compress_resnet20.py [--steps N]
    PYTHONPATH=src python examples/compress_resnet20.py --reduced  # CPU smoke
"""

import argparse
import json

import jax.numpy as jnp

from repro.core.compression import CompressionPipeline, PipelineConfig
from repro.core.export import export_model, export_summary
from repro.core.runner import CnnRunner
from repro.core.schedule import ScheduleConfig
from repro.core.weight_selection import SelectionConfig
from repro.data.synthetic import SyntheticImages
from repro.nn import cnn
from repro.nn.layers import QuantConfig


def serve_accuracy(runner, params, state, comp, arts, *, n_batches=3,
                   use_ref_kernel=False):
    """Val accuracy with every exported layer on the 4-bit LUT path."""
    qserve = QuantConfig.serve(use_ref_kernel=use_ref_kernel)
    correct = 0
    for i in range(n_batches):
        x, y = runner.dataset.batch(i, runner.batch_size, "val")
        logits, _, _ = runner.model.apply(params, state, x, train=False,
                                          qcfg=qserve, comp=comp, serve=arts)
        correct += int(jnp.sum((jnp.argmax(logits, -1) == y)))
    return correct / (n_batches * runner.batch_size)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized run: resnet8 + a 2-layer schedule budget")
    ap.add_argument("--use-ref-kernel", action="store_true",
                    help="serve through the jnp oracle instead of the "
                         "(interpreted on CPU) Pallas kernel")
    ap.add_argument("--search-mode", choices=("batched", "serial"),
                    default="batched",
                    help="schedule candidate search: vmapped sweep of all "
                         "(prune, k) configs per layer, or the serial "
                         "trial-and-rollback reference")
    args = ap.parse_args()

    model = cnn.resnet8() if args.reduced else cnn.resnet20()
    runner = CnnRunner(model, SyntheticImages(seed=7), batch_size=64, lr=2e-3)
    cfg = PipelineConfig(
        qat_steps=args.steps,
        profile_batches=1,
        profile_max_tiles=4 if args.reduced else 8,
        final_finetune_steps=max(args.steps // 6, 20),
        eval_batches=2 if args.reduced else 3,
        schedule=ScheduleConfig(prune_ratios=(0.7, 0.5), k_targets=(16,),
                                delta_acc=0.05, finetune_steps=20,
                                trial_finetune_steps=12, eval_batches=2,
                                max_layers=2 if args.reduced else 4,
                                search_mode=args.search_mode),
        selection=SelectionConfig(k_init=24, k_target=16, delta_acc=0.05,
                                  score_batches=1, accept_batches=2,
                                  max_score_candidates=4 if args.reduced
                                  else 6),
    )
    pipe = CompressionPipeline(runner, cfg)
    result = pipe.run(verbose=True)
    print(json.dumps(result.summary(), indent=2))

    # ---- export: comp tree -> packed 4-bit serving artifacts
    arts = export_model(runner.model, pipe.params, pipe.comp)
    summary = export_summary(arts)
    print(f"\nexported {summary['layers']} compressed layers: "
          f"{summary['weight_bytes_packed']} bytes packed "
          f"({summary['compression_vs_int8']:.2f}x vs dense int8)")
    if not arts:
        print("no layer accepted a <=16-value restriction; nothing to serve")
        return

    # ---- compressed inference: full model through the LUT GEMM serve path
    x, _ = runner.dataset.batch(0, runner.batch_size, "val")
    l_fake, _, _ = runner.model.apply(
        pipe.params, pipe.state, x, train=False, qcfg=QuantConfig.on(),
        comp=pipe.comp)
    l_serve, _, _ = runner.model.apply(
        pipe.params, pipe.state, x, train=False,
        qcfg=QuantConfig.serve(use_ref_kernel=args.use_ref_kernel),
        comp=pipe.comp, serve=arts)
    rel = float(jnp.linalg.norm(l_serve - l_fake)
                / jnp.maximum(jnp.linalg.norm(l_fake), 1e-9))
    acc = serve_accuracy(runner, pipe.params, pipe.state, pipe.comp, arts,
                         n_batches=cfg.eval_batches,
                         use_ref_kernel=args.use_ref_kernel)
    print(f"compressed serve: {len(arts)} layers on the 4-bit LUT GEMM, "
          f"full-model logit rel_err={rel:.2e} vs fake-quant forward")
    print(f"compressed serve accuracy: {acc:.3f} "
          f"(schedule reported acc_final={result.acc_final:.3f})")


if __name__ == "__main__":
    main()
