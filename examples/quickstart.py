"""Quickstart: the paper's pipeline in ~60 seconds on CPU.

Trains a QAT LeNet-5 on the synthetic CIFAR-10 stand-in, profiles per-layer
MAC energy on the 64x64 systolic model, runs energy-prioritized layer-wise
compression on the top layer, and reports the energy/accuracy trade-off.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core.compression import CompressionPipeline, PipelineConfig
from repro.core.runner import CnnRunner
from repro.core.schedule import ScheduleConfig
from repro.core.weight_selection import SelectionConfig
from repro.data.synthetic import SyntheticImages
from repro.nn import cnn


def main():
    print(f"devices: {jax.devices()}")
    runner = CnnRunner(cnn.lenet5(), SyntheticImages(seed=5), batch_size=64,
                       lr=2e-3)
    cfg = PipelineConfig(
        qat_steps=200,
        profile_batches=1,
        profile_max_tiles=6,
        final_finetune_steps=30,
        eval_batches=2,
        # two candidate configs per layer: the default search_mode="batched"
        # sweeps both in one vmapped trial (see docs/schedule.md)
        schedule=ScheduleConfig(prune_ratios=(0.7, 0.5), k_targets=(16,),
                                delta_acc=0.06, finetune_steps=15,
                                trial_finetune_steps=10, eval_batches=2,
                                max_layers=2),
        selection=SelectionConfig(k_init=20, k_target=16, delta_acc=0.06,
                                  score_batches=1, accept_batches=1,
                                  max_score_candidates=4),
    )
    result = CompressionPipeline(runner, cfg).run(verbose=True)
    print(f"\n== quickstart result ==")
    print(f"baseline accuracy : {result.acc_base:.3f}")
    print(f"final accuracy    : {result.acc_final:.3f} "
          f"(drop {result.accuracy_drop:.3f})")
    print(f"conv energy saving: {result.energy_saving:.1%}")
    print(f"max codebook size : {result.max_codebook}")


if __name__ == "__main__":
    main()
