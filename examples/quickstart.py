"""Quickstart: the paper's pipeline in ~60 seconds on CPU.

One `repro.pipeline.Pipeline` run: QAT LeNet-5 on the synthetic CIFAR-10
stand-in, per-layer MAC energy profiling on the 64x64 systolic model,
energy-prioritized layer-wise compression of the top layers, and the
energy/accuracy report — the same flow the `repro` CLI drives
(``python -m repro compress --reduced``).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core.schedule import ScheduleConfig
from repro.core.weight_selection import SelectionConfig
from repro.pipeline import (
    Pipeline,
    PipelineConfig,
    ProfileStageConfig,
    TargetConfig,
    TrainStageConfig,
)


def main():
    print(f"devices: {jax.devices()}")
    cfg = PipelineConfig(
        target=TargetConfig(kind="cnn", arch="lenet5", data_seed=5,
                            batch_size=64, lr=2e-3),
        train=TrainStageConfig(qat_steps=200, final_finetune_steps=30,
                               eval_batches=2),
        profile=ProfileStageConfig(batches=1, max_tiles=6),
        # two candidate configs per layer: the default search_mode="batched"
        # sweeps both in one vmapped trial (see docs/schedule.md)
        schedule=ScheduleConfig(prune_ratios=(0.7, 0.5), k_targets=(16,),
                                delta_acc=0.06, finetune_steps=15,
                                trial_finetune_steps=10, eval_batches=2,
                                max_layers=2),
        selection=SelectionConfig(k_init=20, k_target=16, delta_acc=0.06,
                                  score_batches=1, accept_batches=1,
                                  max_score_candidates=4),
    )
    plan = Pipeline(cfg).run_until("schedule", verbose=True)
    m = plan.metrics
    print("\n== quickstart result ==")
    print(f"baseline accuracy : {m['acc_base']:.3f}")
    print(f"final accuracy    : {m['acc_final']:.3f} "
          f"(drop {m['accuracy_drop']:.3f})")
    print(f"conv energy saving: {m['energy_saving']:.1%}")
    print(f"max codebook size : {m['max_codebook']}")


if __name__ == "__main__":
    main()
