"""Serve a small LM through the continuous-batching engine — pipeline-driven.

One `repro.pipeline.Pipeline` run with an LM target: a mixed-length request
trace is packed into padded shape buckets (one jit compile per bucket, never
per request), prefetched and decoded in waves, accounted per request
(latency, tokens/sec, estimated MAC energy), and cross-checked against the
``mode="oneshot"`` fallback. Runs a gemma3-family reduced config (5:1
local:global pattern with ring-buffer window caches) so both cache kinds are
exercised. The identical flow is available from the shell as
``repro serve --target lm --arch gemma3-4b --reduced``.

Requests travel as `repro.serving.ServeRequest` (tokens, max_new_tokens,
tenant, budget, seed) — the pipeline builds the trace internally; `--plans`
swaps the pinned engine for a multi-plan fleet
(`repro.serving.fleet.FleetRouter`) routing the same trace across resident
compression variants, e.g. ``--plans k4 base``.

    PYTHONPATH=src python examples/serve_lm.py [--requests 6] [--new-tokens 16]
"""

import argparse
import time

from repro.pipeline import (
    Pipeline,
    PipelineConfig,
    ServeStageConfig,
    TargetConfig,
    TrainStageConfig,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--plans", nargs="+", default=None, metavar="SPEC",
                    help="serve a multi-plan fleet instead of one pinned "
                         "engine ('base', 'k<N>[m<M>]', or saved plan paths)")
    args = ap.parse_args()

    fleet = bool(args.plans)
    cfg = PipelineConfig(
        target=TargetConfig(kind="lm", arch=args.arch, reduced=True),
        train=TrainStageConfig(qat_steps=0, final_finetune_steps=0),
        # mixed-length trace over two prompt buckets; engine output is
        # cross-checked token for token against the oneshot fallback
        # (pinned mode only: the fleet routes across variants instead)
        serve=ServeStageConfig(mode="engine", requests=args.requests,
                               prompt_len=max(args.prompt_len, 2),
                               new_tokens=args.new_tokens, mixed=True,
                               mixed_stride=9, max_batch=4, prompt_seed=1,
                               verify_oneshot=not fleet,
                               plans=tuple(args.plans or ())),
    )
    pipe = Pipeline(cfg)
    t0 = time.time()
    plan = pipe.run(verbose=True)
    dt = time.time() - t0

    m = plan.metrics
    if fleet:
        rep = pipe.target.last_fleet_report
        print(f"fleet [{m['serve_plans']}]: {m['serve_requests']} requests / "
              f"{m['serve_new_tokens']} tokens in {dt*1e3:.0f} ms, "
              f"{m['serve_recompiles_after_warmup']} recompiles after warmup")
        for pid, p in rep["plans"].items():
            print(f"  plan {pid}: {p['requests']} requests, "
                  f"{p['energy_eu']:.3g} eu")
        results = pipe.target.last_serve_results
        for rid in sorted(results)[:2]:
            print(f"request {rid}: {results[rid].tokens[:8]}...")
        print("OK (fleet)")
        return

    print(f"engine: {m['serve_requests']} requests / "
          f"{m['serve_new_tokens']} tokens in {dt*1e3:.0f} ms "
          f"({m['serve_tokens_per_s']:.0f} tok/s), "
          f"ttft p50 {m['serve_ttft_p50_s']*1e3:.0f} ms, "
          f"latency p50 {m['serve_latency_p50_s']*1e3:.0f} ms, "
          f"{m['serve_cache_buckets_compiled']} buckets / "
          f"{m['serve_cache_compile_count']} compiles, "
          f"energy {m['serve_energy_eu_per_token']:.3g} eu/token")

    assert m["serve_parity_engine_vs_oneshot"], \
        "engine vs oneshot token mismatch"
    results = pipe.target.last_serve_results
    for rid in sorted(results)[:2]:
        print(f"request {rid}: {results[rid].tokens[:8]}...")
    print("OK (engine == oneshot)")


if __name__ == "__main__":
    main()
