"""Serve a small LM with batched requests: prefill + decode loop.

Demonstrates the serving path the decode_* dry-run cells lower: batched
prefill building the per-layer KV/recurrent caches, then step-wise greedy
decoding via `decode_step`. Runs a gemma3-family reduced config (5:1
local:global pattern with ring-buffer window caches) so both cache kinds are
exercised.

    PYTHONPATH=src python examples/serve_lm.py [--batch 4] [--new-tokens 24]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params, spec_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--arch", default="gemma3-4b")
    args = ap.parse_args()

    cfg = get_config(args.arch).scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    print(f"arch={cfg.name} (reduced: {spec_count(model.spec)/1e6:.1f}M params,"
          f" pattern={cfg.pattern}, window={cfg.window})")
    params = init_params(jax.random.PRNGKey(0), model.spec)

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    max_len = args.prompt_len + args.new_tokens

    t0 = time.time()
    logits, cache = model.prefill(params, prompts, max_len=max_len,
                                  cache_dtype=jnp.float32, q_block=8,
                                  kv_block=8)
    t_prefill = time.time() - t0
    next_tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]

    decode = jax.jit(model.decode_step)
    seqs = [next_tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, next_tok)
        next_tok = jnp.argmax(logits[:, 0, :cfg.vocab], axis=-1)[:, None]
        seqs.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0

    out = jnp.concatenate(seqs, axis=1)
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.0f} ms")
    print(f"decode : {args.batch}x{args.new_tokens} tokens in "
          f"{t_decode*1e3:.0f} ms "
          f"({args.batch*args.new_tokens/max(t_decode,1e-9):.0f} tok/s batch)")
    for b in range(min(args.batch, 2)):
        print(f"request {b}: prompt tail {list(map(int, prompts[b, -4:]))} -> "
              f"generated {list(map(int, out[b, :8]))}...")
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab))
    print("OK")


if __name__ == "__main__":
    main()
