"""Serve a small LM through the continuous-batching engine.

Demonstrates the serving subsystem end to end: a mixed-length request trace
is queued into `repro.serving.ServingEngine`, packed into padded shape
buckets (one jit compile per bucket, never per request), prefetched and
decoded in waves, and accounted per request (latency, tokens/sec, estimated
MAC energy). Runs a gemma3-family reduced config (5:1 local:global pattern
with ring-buffer window caches) so both cache kinds are exercised, and
cross-checks the engine output against the ``mode="oneshot"`` fallback.

    PYTHONPATH=src python examples/serve_lm.py [--requests 6] [--new-tokens 16]
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params, spec_count
from repro.serving import EngineConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--arch", default="gemma3-4b")
    args = ap.parse_args()

    cfg = get_config(args.arch).scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    print(f"arch={cfg.name} (reduced: {spec_count(model.spec)/1e6:.1f}M params,"
          f" pattern={cfg.pattern}, window={cfg.window})")
    params = init_params(jax.random.PRNGKey(0), model.spec)

    # mixed-length trace over two prompt buckets (floors match the bucket
    # derivation so any --prompt-len >= 2 fits)
    p_max = max(args.prompt_len, 2)
    shapes = [(max(p_max - 9 * (i % 3), 2), args.new_tokens)
              for i in range(args.requests)]
    prompts = [
        jax.random.randint(jax.random.PRNGKey(1 + i), (plen,), 0, cfg.vocab)
        for i, (plen, _) in enumerate(shapes)
    ]

    ecfg = EngineConfig(max_batch=4,
                        prompt_buckets=(max(p_max // 2, 2), p_max),
                        new_token_buckets=(args.new_tokens,))
    engine = ServingEngine(model, params, mode="engine", config=ecfg)
    engine.warmup(shapes)

    t0 = time.time()
    results = engine.serve(prompts, [n for _, n in shapes])
    dt = time.time() - t0
    rep = engine.report()
    print(f"engine: {rep['requests']} requests / {rep['new_tokens']} tokens "
          f"in {dt*1e3:.0f} ms ({rep['tokens_per_s']:.0f} tok/s), "
          f"ttft p50 {rep['ttft_p50_s']*1e3:.0f} ms, "
          f"latency p50 {rep['latency_p50_s']*1e3:.0f} ms, "
          f"{rep['cache_buckets_compiled']} buckets / "
          f"{rep['cache_compile_count']} compiles, "
          f"energy {rep['energy_eu_per_token']:.3g} eu/token")

    # single-shot fallback: identical outputs, no batching
    oneshot = ServingEngine(model, params, mode="oneshot", config=ecfg)
    oneshot.warmup(shapes)
    ref = oneshot.serve(prompts, [n for _, n in shapes])
    assert all(results[r].tokens == ref[r].tokens for r in results), \
        "engine vs oneshot token mismatch"
    for rid in sorted(results)[:2]:
        print(f"request {rid}: prompt[{len(prompts[rid])}] -> "
              f"{results[rid].tokens[:8]}...")
    print("OK (engine == oneshot)")


if __name__ == "__main__":
    main()
