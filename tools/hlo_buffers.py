"""Dump the largest per-device HLO buffers for a dry-run cell (debug tool).

Usage: PYTHONPATH=src python tools/hlo_buffers.py <arch> <shape> [n]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import collections  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402

sys.path.insert(0, "src")

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch import train as TR  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.lm import build_lm  # noqa: E402

DT = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
      "f32": 4, "s64": 8, "f64": 8, "u64": 8, "s16": 2, "u16": 2}


def lower_cell(arch, shape_name, step_cfg=None, rules=None):
    from repro.distributed.sharding import DEFAULT_RULES

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    model = build_lm(cfg)
    step_cfg = step_cfg or TR.StepConfig()
    rules = rules or DEFAULT_RULES
    if shape.kind == "train":
        state = TR.abstract_train_state(model)
        state_sh = TR.train_state_shardings(model, mesh, rules)
        specs = TR.batch_specs(cfg, shape)
        specs_sh = TR.batch_shardings(specs, mesh, rules)
        comp = TR.comp_abstract(model)
        comp_sh = TR.comp_shardings(model, mesh, rules)
        step = TR.make_train_step(model, step_cfg, mesh, rules)
        jitted = jax.jit(step, in_shardings=(state_sh, specs_sh, comp_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        with mesh:
            return jitted.lower(state, specs, comp)
    if shape.kind == "prefill":
        params = TR.abstract_serve_params(model)
        params_sh = TR.make_param_shardings(model.spec, mesh, rules)
        specs = TR.batch_specs(cfg, shape)
        specs_sh = TR.batch_shardings(specs, mesh, rules)
        step = TR.make_prefill_step(model, step_cfg, mesh, rules)
        jitted = jax.jit(step, in_shardings=(params_sh, specs_sh))
        with mesh:
            return jitted.lower(params, specs)
    import jax.numpy as jnp

    params = TR.abstract_serve_params(model)
    params_sh = TR.make_param_shardings(model.spec, mesh, rules)
    cache = TR.decode_cache_specs(model, shape)
    cache_sh = TR.cache_shardings(model, shape, mesh, rules)
    tokens = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    tokens_sh = TR.batch_shardings({"tokens": tokens}, mesh, rules)["tokens"]
    step = TR.make_serve_step(model, step_cfg, mesh, rules)
    jitted = jax.jit(step, in_shardings=(params_sh, cache_sh, tokens_sh),
                     out_shardings=(None, cache_sh), donate_argnums=(1,))
    with mesh:
        return jitted.lower(params, cache, tokens)


def top_buffers(hlo: str, n: int = 15):
    sizes = collections.Counter()
    for m in re.finditer(r"= (\w+)\[([\d,]+)\]", hlo):
        dt, dims = m.group(1), m.group(2)
        if dt not in DT:
            continue
        nn = 1
        for x in dims.split(","):
            nn *= int(x)
        key = f"{dt}[{dims}]"
        sizes[key] = max(sizes[key], nn * DT[dt])
    return sizes.most_common(n)


if __name__ == "__main__":
    arch, shape_name = sys.argv[1], sys.argv[2]
    n = int(sys.argv[3]) if len(sys.argv) > 3 else 15
    lowered = lower_cell(arch, shape_name)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    print(f"temp GB: {mem.temp_size_in_bytes/2**30:.2f}")
    for shp, b in top_buffers(compiled.as_text(), n):
        print(f"{b/2**30:8.2f} GiB  {shp}")
