#!/usr/bin/env bash
# One-stop contributor check: tier-1 test suite + profiler smoke benchmark.
#
#   tools/run_checks.sh            # full tier-1 pytest + profiling smoke
#   tools/run_checks.sh --fast     # skip the slowest test files
#
# The tier-1 command mirrors ROADMAP.md; the smoke benchmark asserts the
# batched profiler still beats the per-tile loop by >= 5x tiles/sec and
# stays bin-for-bin consistent with the oracle.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-}" in
  --fast)
    echo "== tier-1 tests (fast subset) =="
    python -m pytest -x -q tests/test_kernels.py tests/test_core_energy.py \
      tests/test_profiler.py tests/test_serve_compressed.py
    ;;
  "")
    echo "== tier-1 tests =="
    python -m pytest -x -q
    ;;
  *)
    echo "usage: tools/run_checks.sh [--fast]" >&2
    exit 2
    ;;
esac

echo "== profiler smoke benchmark =="
python - <<'PY'
import json
from benchmarks import bench_kernels

bench_kernels.run()
out = json.loads(open("benchmarks/out/bench_kernels.json").read())
d = out["derived"]
speed = d["profile_speedup_batched_vs_looped"]
assert d["all_within_tolerance"], d
assert speed >= 5.0, f"batched profiler speedup regressed: {speed:.1f}x < 5x"
print(f"profiler speedup {speed:.1f}x (>= 5x), parity within tolerance")

# compressed serving gates: LUT forward must match the dense fake-quant
# forward, stay >= 3.5x smaller than int8 weights, and the CPU serve
# dispatch must not regress below 5% of dense matmul throughput
assert d["serve_forward_rel_err"] < 2e-2, d["serve_forward_rel_err"]
comp = d["serve_weight_compression_vs_bf16"]
assert comp >= 3.5, f"serve weight compression regressed: {comp:.2f}x"
ratio = d["serve_vs_dense_throughput"]
assert ratio >= 0.05, f"compressed serve dispatch regressed: {ratio:.3f}x"
print(f"compressed serve: parity ok, {comp:.1f}x weight compression vs "
      f"bf16, {ratio:.2f}x dense throughput on CPU")
PY

echo "All checks passed."
