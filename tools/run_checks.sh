#!/usr/bin/env bash
# One-stop contributor check: tier-1 test suite + profiler smoke benchmark.
#
#   tools/run_checks.sh            # full tier-1 pytest + profiling smoke
#   tools/run_checks.sh --fast     # skip the slowest test files
#
# The tier-1 command mirrors ROADMAP.md; the smoke benchmark asserts the
# batched profiler still beats the per-tile loop by >= 5x tiles/sec and
# stays bin-for-bin consistent with the oracle.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-}" in
  --fast)
    echo "== tier-1 tests (fast subset) =="
    python -m pytest -x -q tests/test_kernels.py tests/test_core_energy.py \
      tests/test_profiler.py
    ;;
  "")
    echo "== tier-1 tests =="
    python -m pytest -x -q
    ;;
  *)
    echo "usage: tools/run_checks.sh [--fast]" >&2
    exit 2
    ;;
esac

echo "== profiler smoke benchmark =="
python - <<'PY'
import json
from benchmarks import bench_kernels

bench_kernels.run()
out = json.loads(open("benchmarks/out/bench_kernels.json").read())
d = out["derived"]
speed = d["profile_speedup_batched_vs_looped"]
assert d["all_within_tolerance"], d
assert speed >= 5.0, f"batched profiler speedup regressed: {speed:.1f}x < 5x"
print(f"profiler speedup {speed:.1f}x (>= 5x), parity within tolerance")
PY

echo "All checks passed."
