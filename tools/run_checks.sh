#!/usr/bin/env bash
# One-stop contributor check: tier-1 test suite + gated benchmarks.
#
#   tools/run_checks.sh              # full tier-1 pytest + benchmark gates
#   tools/run_checks.sh --fast       # skip the slowest test files
#   tools/run_checks.sh --ci         # junit XML + machine-readable gate
#                                    # summary + GitHub error annotations +
#                                    # CI timing slack (see check_gates.py)
#   tools/run_checks.sh --fast --ci  # what .github/workflows/ci.yml runs
#
# The tier-1 command mirrors ROADMAP.md. The benchmark gates (see
# tools/check_gates.py for the full table) assert among others that the
# batched profiler stays >= 5x the per-tile loop, the compressed serve path
# keeps parity + compression, the batched candidate sweep stays >= 3x serial
# trials/sec, and the serving engine stays >= 2x the single-shot fallback
# with zero recompiles after bucket warmup. In --ci mode every gate is
# evaluated (no die-on-first), the table lands in
# benchmarks/out/gate_summary.json, benches take more best-of repeats
# (REPRO_BENCH_CI=1), and timing-ratio thresholds get the documented
# CI_SLACK factor. A final trajectory pass gates the committed BENCH_*.json
# histories (newest point vs previous, tools/check_gates.py --trajectory).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FAST=0
CI=0
for arg in "$@"; do
  case "$arg" in
    --fast) FAST=1 ;;
    --ci)   CI=1 ;;
    *)
      echo "usage: tools/run_checks.sh [--fast] [--ci]" >&2
      exit 2
      ;;
  esac
done

mkdir -p benchmarks/out
PYTEST_ARGS=(-x -q)
if [[ "$CI" == 1 ]]; then
  PYTEST_ARGS+=(--junitxml=benchmarks/out/junit.xml)
  export REPRO_BENCH_CI=1
fi

# Coverage floor on the paper-contribution packages: enabled whenever
# pytest-cov is importable (it's pinned in requirements-ci.txt; local envs
# without it just skip the floor rather than failing the run). The floor is
# a conservative ratchet — raise it as measured coverage grows, never lower.
COV_ARGS=()
if [[ "$CI" == 1 ]]; then
  if python -c "import pytest_cov" 2>/dev/null; then
    COV_ARGS=(--cov=src/repro/core --cov=src/repro/kernels
              --cov-report=term --cov-fail-under=65)
  else
    echo "pytest-cov not installed; skipping the coverage floor" >&2
  fi
fi

if [[ "$FAST" == 1 ]]; then
  echo "== tier-1 tests (fast subset) =="
  python -m pytest "${PYTEST_ARGS[@]}" ${COV_ARGS[@]+"${COV_ARGS[@]}"} \
    tests/test_kernels.py tests/test_lut_fused.py \
    tests/test_core_energy.py tests/test_profiler.py \
    tests/test_serve_compressed.py tests/test_schedule_batched.py \
    tests/test_serving_engine.py tests/test_fleet.py \
    tests/test_pipeline.py \
    tests/test_cosim_differential.py tests/test_msr_schedule.py \
    tests/test_routing_targets.py
else
  echo "== tier-1 tests =="
  python -m pytest "${PYTEST_ARGS[@]}" ${COV_ARGS[@]+"${COV_ARGS[@]}"}
fi

echo "== benchmark gates =="
GATE_ARGS=()
if [[ "$CI" == 1 ]]; then
  GATE_ARGS+=(--ci)
fi
python tools/check_gates.py ${GATE_ARGS[@]+"${GATE_ARGS[@]}"}

echo "== kernel gates =="
# re-gates the bench_kernels.json the main pass just produced (the dedicated
# CI kernels job runs the same table standalone with --kernels, no --skip)
python tools/check_gates.py --kernels --skip-bench \
  ${GATE_ARGS[@]+"${GATE_ARGS[@]}"}

echo "== bench trajectory gates =="
python tools/check_gates.py --trajectory ${GATE_ARGS[@]+"${GATE_ARGS[@]}"}

echo "All checks passed."
