#!/usr/bin/env python
"""Run the gated benchmarks and emit a machine-readable gate summary.

Replaces the bare ``assert`` gauntlet that used to live inline in
``tools/run_checks.sh``: every gate is evaluated (no die-on-first), the full
table is written to ``benchmarks/out/gate_summary.json`` as
``[{name, value, threshold, op, pass}, ...]``, and the exit code reflects
whether *all* gates passed. With ``--ci`` each failure is additionally
printed as a GitHub Actions error annotation so CI surfaces the failing gate
by name instead of a dead shell.

    PYTHONPATH=src python tools/check_gates.py [--ci] [--skip-bench]
    PYTHONPATH=src python tools/check_gates.py --trajectory [--ci]
    PYTHONPATH=src python tools/check_gates.py --plan BASE [--ci]
    PYTHONPATH=src python tools/check_gates.py --cosim [--ci] [--skip-bench]
    PYTHONPATH=src python tools/check_gates.py --kernels [--ci] [--skip-bench]

``--kernels`` runs `benchmarks/bench_kernels.py` alone and gates the fused
LUT-GEMM serve lane (the dedicated ``kernels`` CI job): oracle parity for
the bare and fused-epilogue kernels, the 4-bit weight format's >= 3.5x
compression vs bf16, the fused single-dispatch call beating the unfused
serve + eager-epilogue sequence it replaced, and the roofline block
autotuner's cache round-tripping with zero retune events. Summary:
``benchmarks/out/kernels_summary.json``.

``--cosim`` runs `benchmarks/bench_cosim.py` and gates bit-exact agreement
between the transition-energy kernel's histograms and the independent
cycle-accurate cosim (``repro.cosim``) on >= 64 sampled tiles per model,
plus MSR-axis sweep parity (serial == batched with >= 1 accepted MSR
candidate). Summary: ``benchmarks/out/cosim_summary.json``.

``--fleet`` runs `benchmarks/bench_fleet.py` and gates multi-plan fleet
serving (`repro.serving.fleet`): routed tokens-per-energy-unit >= 1.15x the
always-high-fidelity baseline on the bursty trace, p99 TTFT within 1.2x of
always-aggressive, zero post-warmup recompiles with >= 3 plans resident,
both degrade and recover transitions observed in the route log, and
routed-vs-pinned token parity per plan. Summary:
``benchmarks/out/fleet_summary.json``.

``--skip-bench`` evaluates whatever JSON is already in benchmarks/out/
(useful to re-check without re-running the benchmarks).

``--plan BASE`` validates a saved `repro.pipeline` CompressionPlan document
(``BASE.json``; schema version, stage ordering, energy-share normalization,
decision sanity — see `repro.pipeline.schema.validate_plan_doc`). Pure JSON
inspection: no jax, no arrays loaded, so CI can gate a plan right after the
fast tier. Runs no benchmarks.

CI slack: shared CI runners (2 cores, noisy neighbours) time the speedup
gates far less repeatably than the reference host, so under ``--ci`` every
*timing-ratio* gate keeps its documented local threshold but is enforced at
``threshold * CI_SLACK`` (and the benchmarks take more best-of repeats, see
``benchmarks.common.best_of``). Parity/compression gates are exact
everywhere. The slack is one global, documented constant — not per-gate
hand-tuned numbers.

``--trajectory`` gates the *trend* instead of the absolute: each repo-root
``BENCH_*.json`` keeps one history entry per PR that moved its number; the
newest point must not regress more than TRAJECTORY_TOL (20%) past the
previous point on any tracked key — below it for throughput/speedup keys,
above it for latency-style ``*_s`` keys (``ttft_p99_s`` etc.), which are
lower-is-better. Runs no benchmarks.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
for p in (ROOT, ROOT / "src"):   # standalone invocation: python tools/check_gates.py
    if str(p) not in sys.path:
        sys.path.insert(0, str(p))

OUT_DIR = ROOT / "benchmarks" / "out"

# timing-ratio gates are enforced at threshold * CI_SLACK under --ci
CI_SLACK = 0.8
# newest trajectory point must stay >= (1 - TRAJECTORY_TOL) * previous point
TRAJECTORY_TOL = 0.20

# (gate name, source benchmark, derived key, operator, threshold, timing?)
# timing=True marks wall-clock-ratio gates that get CI_SLACK under --ci.
GATES = [
    ("profiler_parity", "bench_kernels", "all_within_tolerance", "==", True,
     False),
    ("profiler_speedup_batched_vs_looped", "bench_kernels",
     "profile_speedup_batched_vs_looped", ">=", 5.0, True),
    ("serve_forward_parity", "bench_kernels", "serve_forward_rel_err",
     "<", 2e-2, False),
    ("serve_weight_compression_vs_bf16", "bench_kernels",
     "serve_weight_compression_vs_bf16", ">=", 3.5, False),
    ("serve_vs_dense_throughput", "bench_kernels",
     "serve_vs_dense_throughput", ">=", 0.05, True),
    ("serve_fused_epilogue_parity", "bench_kernels", "serve_fused_rel_err",
     "<", 2e-2, False),
    ("serve_fused_vs_unfused", "bench_kernels", "serve_fused_vs_unfused",
     ">=", 1.0, True),
    ("schedule_sweep_speedup_batched_vs_serial", "bench_schedule",
     "sweep_speedup_batched_vs_serial", ">=", 3.0, True),
    ("schedule_sweep_decisions_match", "bench_schedule", "decisions_match",
     "==", True, False),
    ("serving_speedup_engine_vs_oneshot", "bench_serving",
     "serving_speedup_engine_vs_oneshot", ">=", 2.0, True),
    ("serving_speedup_slot_vs_wave", "bench_serving",
     "serving_speedup_slot_vs_wave", ">=", 1.05, True),
    ("serving_ttft_p99_improvement_vs_wave", "bench_serving",
     "serving_ttft_p99_improvement_vs_wave", ">=", 1.3, True),
    ("serving_recompiles_after_warmup", "bench_serving",
     "recompiles_after_warmup", "==", 0, False),
    ("serving_parity_engine_vs_oneshot", "bench_serving",
     "parity_engine_vs_oneshot", "==", True, False),
    ("serving_parity_slot_vs_wave", "bench_serving",
     "parity_slot_vs_wave", "==", True, False),
]

# kernel gates for `--kernels` (the dedicated CI kernel lane): the fused
# LUT-GEMM serve path must match its oracle, keep the 4-bit weight format's
# >= 3.5x compression vs bf16, beat the unfused serve + eager-epilogue
# dispatch it replaced (timing gate, CI slack applies), and the roofline
# block autotuner's cache must round-trip with zero retune events while
# never preferring a tile its own model scores worse than the 128-cube
# default. Runs bench_kernels only; summary: benchmarks/out/
# kernels_summary.json.
KERNEL_GATES = [
    ("kernel_lut_parity", "bench_kernels", "lut_rel_err", "<", 2e-2, False),
    ("kernel_all_within_tolerance", "bench_kernels", "all_within_tolerance",
     "==", True, False),
    ("kernel_fused_epilogue_parity", "bench_kernels", "serve_fused_rel_err",
     "<", 2e-2, False),
    ("kernel_serve_parity", "bench_kernels", "serve_forward_rel_err",
     "<", 2e-2, False),
    ("kernel_weight_compression_vs_bf16", "bench_kernels",
     "serve_weight_compression_vs_bf16", ">=", 3.5, False),
    ("kernel_fused_vs_unfused", "bench_kernels", "serve_fused_vs_unfused",
     ">=", 1.0, True),
    ("kernel_autotune_roundtrip_retunes", "bench_kernels",
     "autotune_cache_roundtrip_retunes", "==", 0, False),
    ("kernel_autotune_model_sane", "bench_kernels", "autotune_model_sane",
     "==", True, False),
]

# bit-accuracy gates for `--cosim`: the transition-energy kernel's MSB-group
# histograms must match the independent cycle-accurate cosim EXACTLY on the
# sampled tiles, and the MSR candidate axis must be live (serial == batched
# decisions, >= 1 accepted MSR candidate in the seeded reduced sweep).
COSIM_GATES = [
    ("cosim_hist_match", "bench_cosim", "cosim_hist_match", "==", True,
     False),
    ("cosim_min_tiles_verified", "bench_cosim", "cosim_min_tiles_verified",
     ">=", 64, False),
    ("cosim_max_abs_diff", "bench_cosim", "cosim_max_abs_diff", "==", 0.0,
     False),
    ("cosim_f32_exactness_bound", "bench_cosim", "cosim_exactness_ok", "==",
     True, False),
    ("cosim_msr_decisions_match", "bench_cosim", "msr_decisions_match", "==",
     True, False),
    ("cosim_msr_candidate_accepted", "bench_cosim",
     "msr_candidates_accepted", ">=", 1, False),
]

# fleet-serving gates for `--fleet` (benchmarks/bench_fleet.py): the routed
# fleet must convert queue pressure into energy savings without buying them
# with latency, recompiles, or output changes. The tokens-per-energy and
# parity gates are deterministic (analytic energy charges, bit-identical
# replay); only the TTFT headroom gate is timing-sensitive.
FLEET_GATES = [
    ("fleet_tokens_per_eu_vs_highfid", "bench_fleet",
     "fleet_tokens_per_eu_vs_highfid", ">=", 1.15, False),
    ("fleet_ttft_p99_headroom_vs_aggressive", "bench_fleet",
     "fleet_ttft_p99_headroom_vs_aggressive", ">=", 1.0, True),
    ("fleet_recompiles_after_warmup", "bench_fleet",
     "fleet_recompiles_after_warmup", "==", 0, False),
    ("fleet_plans_resident", "bench_fleet", "fleet_plans_resident", ">=", 3,
     False),
    ("fleet_degrade_observed", "bench_fleet", "fleet_degrade_observed", "==",
     True, False),
    ("fleet_recover_observed", "bench_fleet", "fleet_recover_observed", "==",
     True, False),
    ("fleet_parity_routed_vs_pinned", "bench_fleet",
     "fleet_parity_routed_vs_pinned", "==", True, False),
]

# routing-aware compression-target gates for `--targets`
# (benchmarks/bench_targets.py): the routed MoE and scan pipelines must
# serve their per-expert / per-scan-unit LUT-GEMM exports at fake-quant
# parity, cut traffic-weighted per-token energy past the documented floor,
# keep the hot-gentler/cold-aggressive k assignment monotone in measured
# traffic, and export with an empty skip report. All deterministic
# (seeded calibration, analytic energy) — no CI slack.
TARGETS_GATES = [
    ("targets_moe_parity_rel_err", "bench_targets",
     "targets_moe_parity_rel_err", "<", 2e-2, False),
    ("targets_moe_energy_reduction", "bench_targets",
     "targets_moe_energy_reduction", ">=", 0.10, False),
    ("targets_moe_hotcold_monotone", "bench_targets",
     "targets_moe_hotcold_monotone", "==", True, False),
    ("targets_moe_routed_units", "bench_targets",
     "targets_moe_routed_units", ">=", 8, False),
    ("targets_moe_export_skipped", "bench_targets",
     "targets_moe_export_skipped", "==", 0, False),
    ("targets_scan_parity_rel_err", "bench_targets",
     "targets_scan_parity_rel_err", "<", 2e-2, False),
    ("targets_scan_energy_reduction", "bench_targets",
     "targets_scan_energy_reduction", ">=", 0.05, False),
    ("targets_scan_hotcold_monotone", "bench_targets",
     "targets_scan_hotcold_monotone", "==", True, False),
    ("targets_scan_export_skipped", "bench_targets",
     "targets_scan_export_skipped", "==", 0, False),
]

OPS = {
    ">=": lambda v, t: v >= t,
    "<": lambda v, t: v < t,
    "==": lambda v, t: v == t,
}


def run_benchmarks() -> None:
    from benchmarks import bench_kernels, bench_schedule, bench_serving

    print("== bench_kernels ==", flush=True)
    bench_kernels.run()
    print("== bench_schedule ==", flush=True)
    bench_schedule.run()
    print("== bench_serving ==", flush=True)
    bench_serving.run()


def evaluate(ci: bool = False, gates=None) -> list:
    derived = {}
    summary = []
    for name, bench, key, op, threshold, timing in (gates or GATES):
        if bench not in derived:
            path = OUT_DIR / f"{bench}.json"
            derived[bench] = (json.loads(path.read_text())["derived"]
                              if path.exists() else None)
        d = derived[bench]
        value = None if d is None else d.get(key)
        effective = threshold
        if ci and timing and op == ">=":
            effective = threshold * CI_SLACK
        ok = value is not None and OPS[op](value, effective)
        summary.append({
            "name": name,
            "benchmark": bench,
            "value": value,
            "op": op,
            "threshold": threshold,
            "ci_slack": CI_SLACK if (ci and timing and op == ">=") else None,
            "effective_threshold": effective,
            "pass": bool(ok),
        })
    return summary


def _fmt(value) -> str:
    if value is None:
        return "missing"
    if isinstance(value, float):
        return f"{value:.4g}"
    return str(value)


def report(summary: list, ci: bool, out_name: str) -> int:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / out_name).write_text(json.dumps(summary, indent=2))
    failed = [g for g in summary if not g["pass"]]
    for g in summary:
        status = "PASS" if g["pass"] else "FAIL"
        want = f"{g['op']} {_fmt(g['effective_threshold'])}"
        if g.get("ci_slack"):
            want += f" (= {_fmt(g['threshold'])} * ci_slack {g['ci_slack']})"
        print(f"  [{status}] {g['name']}: {_fmt(g['value'])} (want {want})")
        if not g["pass"] and ci:
            print(f"::error title=gate {g['name']} failed::"
                  f"{g['name']} = {_fmt(g['value'])}, required {want} "
                  f"(from {g['benchmark']})")
    print(f"{len(summary) - len(failed)}/{len(summary)} gates passed "
          f"(summary: benchmarks/out/{out_name})")
    return 1 if failed else 0


def _trajectory_keys(entry: dict, declared) -> list:
    if declared:
        return [k for k in declared if k in entry]
    return [k for k, v in entry.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
            and (k.endswith("_per_s") or "speedup" in k
                 or _lower_is_better(k))]


def _lower_is_better(key: str) -> bool:
    """Latency-style keys (``*_s`` but not ``*_per_s`` throughputs) regress
    by going UP, so the trajectory gate bounds them from above."""
    return key.endswith("_s") and not key.endswith("_per_s")


def check_plan(base: str, ci: bool = False) -> int:
    """Validate a saved CompressionPlan's JSON document (schema gate)."""
    from repro.pipeline.schema import validate_plan_doc  # jax-free module

    path = Path(base)
    if path.suffix in (".json", ".npz"):
        path = path.with_suffix("")
    json_path = path.with_suffix(".json")
    if not json_path.exists():
        print(f"::error title=plan missing::{json_path} does not exist"
              if ci else f"plan document {json_path} does not exist")
        return 1
    doc = json.loads(json_path.read_text())
    summary = validate_plan_doc(doc)
    npz_path = path.with_suffix(".npz")
    summary.append({
        "name": "plan_npz_present", "benchmark": "plan",
        "value": str(npz_path), "op": "==", "threshold": "exists",
        "ci_slack": None, "effective_threshold": "exists",
        "pass": npz_path.exists(),
    })
    return report(summary, ci, "plan_summary.json")


def check_kernels(ci: bool = False, skip_bench: bool = False) -> int:
    """Run the kernel microbenchmarks and gate the fused LUT-GEMM lane."""
    if not skip_bench:
        from benchmarks import bench_kernels

        print("== bench_kernels ==", flush=True)
        bench_kernels.run()
    return report(evaluate(ci=ci, gates=KERNEL_GATES), ci,
                  "kernels_summary.json")


def check_cosim(ci: bool = False, skip_bench: bool = False) -> int:
    """Run the cosim verification benchmark and gate bit-exactness + MSR."""
    if not skip_bench:
        from benchmarks import bench_cosim

        print("== bench_cosim ==", flush=True)
        bench_cosim.run()
    return report(evaluate(ci=ci, gates=COSIM_GATES), ci,
                  "cosim_summary.json")


def check_fleet(ci: bool = False, skip_bench: bool = False) -> int:
    """Run the fleet-serving benchmark and gate routing quality."""
    if not skip_bench:
        from benchmarks import bench_fleet

        print("== bench_fleet ==", flush=True)
        bench_fleet.run()
    return report(evaluate(ci=ci, gates=FLEET_GATES), ci,
                  "fleet_summary.json")


def check_targets(ci: bool = False, skip_bench: bool = False) -> int:
    """Run the routing-aware target benchmark and gate MoE/scan routing."""
    if not skip_bench:
        from benchmarks import bench_targets

        print("== bench_targets ==", flush=True)
        bench_targets.run()
    return report(evaluate(ci=ci, gates=TARGETS_GATES), ci,
                  "targets_summary.json")


def check_trajectory(ci: bool = False) -> int:
    """Compare the newest vs previous point of each repo-root BENCH_*.json."""
    summary = []
    for path in sorted(ROOT.glob("BENCH_*.json")):
        data = json.loads(path.read_text())
        hist = data.get("history", [])
        if len(hist) < 2:
            print(f"  [----] {path.name}: {len(hist)} point(s), nothing to "
                  f"compare")
            continue
        prev, cur = hist[-2], hist[-1]
        for key in _trajectory_keys(cur, data.get("trajectory_keys")):
            if not isinstance(prev.get(key), (int, float)) \
                    or isinstance(prev.get(key), bool):
                continue
            if _lower_is_better(key):
                bound = (1.0 + TRAJECTORY_TOL) * prev[key]
                op, ok = "<=", bool(cur[key] <= bound)
            else:
                bound = (1.0 - TRAJECTORY_TOL) * prev[key]
                op, ok = ">=", bool(cur[key] >= bound)
            summary.append({
                "name": f"{path.stem}:{key}",
                "benchmark": path.name,
                "value": cur[key],
                "op": op,
                "threshold": bound,
                "ci_slack": None,
                "effective_threshold": bound,
                "pass": ok,
                "previous": prev[key],
            })
    if not summary:
        print("no BENCH_*.json trajectory with >= 2 points; nothing gated")
        return 0
    return report(summary, ci, "trajectory_summary.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ci", action="store_true",
                    help="emit GitHub Actions annotations for failures and "
                         "apply CI_SLACK to timing-ratio gates")
    ap.add_argument("--skip-bench", action="store_true",
                    help="evaluate existing benchmarks/out/*.json only")
    ap.add_argument("--trajectory", action="store_true",
                    help="gate repo-root BENCH_*.json newest-vs-previous "
                         "trajectory instead of running benchmarks")
    ap.add_argument("--plan", default=None, metavar="BASE",
                    help="validate a saved CompressionPlan document "
                         "(BASE.json) instead of running benchmarks")
    ap.add_argument("--kernels", action="store_true",
                    help="run the kernel microbenchmarks only and gate the "
                         "fused LUT-GEMM serve lane: oracle parity, >= 3.5x "
                         "weight compression vs bf16, fused beats unfused, "
                         "and autotune cache round-trip with zero retunes "
                         "(writes kernels_summary.json)")
    ap.add_argument("--cosim", action="store_true",
                    help="run the bit-accurate cosim verification benchmark "
                         "and gate kernel-vs-cosim histogram exactness plus "
                         "MSR sweep parity (writes cosim_summary.json)")
    ap.add_argument("--fleet", action="store_true",
                    help="run the multi-plan fleet serving benchmark and "
                         "gate routed energy efficiency, TTFT headroom, "
                         "zero recompiles, observed degrade/recover "
                         "transitions, and routed-vs-pinned parity (writes "
                         "fleet_summary.json)")
    ap.add_argument("--targets", action="store_true",
                    help="run the routing-aware target benchmark and gate "
                         "MoE/scan routed compression: LUT-GEMM vs "
                         "fake-quant parity, traffic-weighted energy "
                         "reduction, hot-gentler/cold-aggressive "
                         "monotonicity, and an empty export skip report "
                         "(writes targets_summary.json)")
    args = ap.parse_args(argv)

    if args.plan:
        return check_plan(args.plan, ci=args.ci)
    if args.kernels:
        return check_kernels(ci=args.ci, skip_bench=args.skip_bench)
    if args.cosim:
        return check_cosim(ci=args.ci, skip_bench=args.skip_bench)
    if args.fleet:
        return check_fleet(ci=args.ci, skip_bench=args.skip_bench)
    if args.targets:
        return check_targets(ci=args.ci, skip_bench=args.skip_bench)
    if args.trajectory:
        return check_trajectory(ci=args.ci)

    if not args.skip_bench:
        run_benchmarks()
    return report(evaluate(ci=args.ci), args.ci, "gate_summary.json")


if __name__ == "__main__":
    sys.exit(main())
