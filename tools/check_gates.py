#!/usr/bin/env python
"""Run the gated benchmarks and emit a machine-readable gate summary.

Replaces the bare ``assert`` gauntlet that used to live inline in
``tools/run_checks.sh``: every gate is evaluated (no die-on-first), the full
table is written to ``benchmarks/out/gate_summary.json`` as
``[{name, value, threshold, op, pass}, ...]``, and the exit code reflects
whether *all* gates passed. With ``--ci`` each failure is additionally
printed as a GitHub Actions error annotation so CI surfaces the failing gate
by name instead of a dead shell.

    PYTHONPATH=src python tools/check_gates.py [--ci] [--skip-bench]

``--skip-bench`` evaluates whatever JSON is already in benchmarks/out/
(useful to re-check without re-running the benchmarks).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
for p in (ROOT, ROOT / "src"):   # standalone invocation: python tools/check_gates.py
    if str(p) not in sys.path:
        sys.path.insert(0, str(p))

OUT_DIR = ROOT / "benchmarks" / "out"

# (gate name, source benchmark, derived key, operator, threshold)
GATES = [
    ("profiler_parity", "bench_kernels", "all_within_tolerance", "==", True),
    ("profiler_speedup_batched_vs_looped", "bench_kernels",
     "profile_speedup_batched_vs_looped", ">=", 5.0),
    ("serve_forward_parity", "bench_kernels", "serve_forward_rel_err",
     "<", 2e-2),
    ("serve_weight_compression_vs_bf16", "bench_kernels",
     "serve_weight_compression_vs_bf16", ">=", 3.5),
    ("serve_vs_dense_throughput", "bench_kernels",
     "serve_vs_dense_throughput", ">=", 0.05),
    ("schedule_sweep_speedup_batched_vs_serial", "bench_schedule",
     "sweep_speedup_batched_vs_serial", ">=", 3.0),
    ("schedule_sweep_decisions_match", "bench_schedule", "decisions_match",
     "==", True),
]

OPS = {
    ">=": lambda v, t: v >= t,
    "<": lambda v, t: v < t,
    "==": lambda v, t: v == t,
}


def run_benchmarks() -> None:
    from benchmarks import bench_kernels, bench_schedule

    print("== bench_kernels ==", flush=True)
    bench_kernels.run()
    print("== bench_schedule ==", flush=True)
    bench_schedule.run()


def evaluate() -> list:
    derived = {}
    summary = []
    for name, bench, key, op, threshold in GATES:
        if bench not in derived:
            path = OUT_DIR / f"{bench}.json"
            derived[bench] = (json.loads(path.read_text())["derived"]
                              if path.exists() else None)
        d = derived[bench]
        value = None if d is None else d.get(key)
        ok = value is not None and OPS[op](value, threshold)
        summary.append({
            "name": name,
            "benchmark": bench,
            "value": value,
            "op": op,
            "threshold": threshold,
            "pass": bool(ok),
        })
    return summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ci", action="store_true",
                    help="emit GitHub Actions annotations for failures")
    ap.add_argument("--skip-bench", action="store_true",
                    help="evaluate existing benchmarks/out/*.json only")
    args = ap.parse_args(argv)

    if not args.skip_bench:
        run_benchmarks()

    summary = evaluate()
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / "gate_summary.json").write_text(json.dumps(summary, indent=2))

    failed = [g for g in summary if not g["pass"]]
    for g in summary:
        status = "PASS" if g["pass"] else "FAIL"
        val = "missing" if g["value"] is None else f"{g['value']:.4g}" \
            if isinstance(g["value"], float) else g["value"]
        print(f"  [{status}] {g['name']}: {val} (want {g['op']} "
              f"{g['threshold']})")
        if not g["pass"] and args.ci:
            print(f"::error title=gate {g['name']} failed::"
                  f"{g['name']} = {val}, required {g['op']} {g['threshold']} "
                  f"(from benchmarks/out/{g['benchmark']}.json)")
    print(f"{len(summary) - len(failed)}/{len(summary)} gates passed "
          f"(summary: benchmarks/out/gate_summary.json)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
