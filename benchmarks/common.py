"""Shared helpers for the benchmark harness.

Budgets: BENCH_BUDGET=fast (default) runs every paper artifact at reduced
training budgets suitable for a single CPU core; BENCH_BUDGET=full raises
step counts ~4x. The *pipeline* is the paper's end-to-end regardless of
budget; EXPERIMENTS.md records the scaled protocol next to the paper's
numbers.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Dict

from repro.core.runner import CnnRunner
from repro.data.synthetic import SyntheticImages
from repro.nn import cnn

OUT_DIR = Path(__file__).resolve().parent / "out"
OUT_DIR.mkdir(parents=True, exist_ok=True)

BUDGET = os.environ.get("BENCH_BUDGET", "fast")
_SCALE = {"fast": 1, "full": 4}[BUDGET]


def steps(n: int) -> int:
    return n * _SCALE


_MODELS = {
    "lenet5": lambda: (cnn.lenet5(10), SyntheticImages(num_classes=10, seed=11)),
    "resnet20": lambda: (cnn.resnet20(10), SyntheticImages(num_classes=10, seed=12)),
    # reduced same-family stand-in for ResNet-50/CIFAR-100 (see EXPERIMENTS.md)
    "resnet8_c100": lambda: (cnn.resnet8(100),
                             SyntheticImages(num_classes=100, seed=13)),
}

_CACHE: Dict[tuple, dict] = {}


def trained(model_key: str, *, qat_steps: int | None = None) -> dict:
    """QAT-train a model once per (model, budget) per process and profile it."""
    n = qat_steps if qat_steps is not None else steps(250)
    key = (model_key, n)
    if key in _CACHE:
        return _CACHE[key]
    model, data = _MODELS[model_key]()
    runner = CnnRunner(model, data, batch_size=64, lr=2e-3, seed=0)
    params, state, opt_state, comp = runner.init()
    params, state, opt_state, loss = runner.train(params, state, opt_state,
                                                  comp, n)
    acc0 = runner.accuracy(params, state, comp, n_batches=4)
    stats = runner.profile(params, state, comp, n_batches=1, max_tiles=8)
    _CACHE[key] = dict(runner=runner, params=params, state=state,
                       opt_state=opt_state, comp=comp, stats=stats,
                       acc0=acc0, loss=loss)
    return _CACHE[key]


def fresh_copy(bundle: dict) -> dict:
    """Independent comp/opt copies so benchmarks don't contaminate the cache."""
    import jax

    out = dict(bundle)
    out["comp"] = {k: dict(v) for k, v in bundle["comp"].items()}
    out["params"] = jax.tree.map(lambda x: x, bundle["params"])
    out["state"] = jax.tree.map(lambda x: x, bundle["state"])
    out["opt_state"] = jax.tree.map(lambda x: x, bundle["opt_state"])
    return out


DEFAULT_BEST_OF = 3
CI_BEST_OF = 5


def bench_ci() -> bool:
    """True when running under CI (tools/run_checks.sh --ci exports
    REPRO_BENCH_CI=1). Timing-sensitive benches take more repeats and the
    gate thresholds get a documented slack factor (tools/check_gates.py)
    instead of hard-coded CI-tuned numbers."""
    return os.environ.get("REPRO_BENCH_CI", "") == "1"


def best_of(fn, *args, n: int | None = None) -> float:
    """Min wall time of ``fn(*args)`` over n runs — one scheduler hiccup on a
    loaded host must not fail the speedup gates in tools/run_checks.sh.

    ``n=None`` resolves to DEFAULT_BEST_OF locally and CI_BEST_OF under
    ``--ci`` (shared 2-core runners schedule far noisier than the reference
    host); explicit n is bumped to CI_BEST_OF in CI too."""
    if n is None:
        n = CI_BEST_OF if bench_ci() else DEFAULT_BEST_OF
    elif bench_ci():
        n = max(n, CI_BEST_OF)
    best = float("inf")
    for _ in range(n):
        t = time.time()
        fn(*args)
        best = min(best, time.time() - t)
    return best


def emit(name: str, t0: float, rows, derived: dict):
    """Template-conformant CSV line + JSON sidecar."""
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{json.dumps(derived, default=float)}")
    (OUT_DIR / f"{name}.json").write_text(
        json.dumps({"rows": rows, "derived": derived}, indent=2, default=float))
    return rows
