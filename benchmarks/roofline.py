"""Roofline analysis from the dry-run manifests (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod 16x16 mesh:

    compute_s    = HLO_flops_per_device / 197e12           (bf16 peak / chip)
    memory_s     = HLO_bytes_per_device / 819e9            (HBM bw / chip)
    collective_s = sum_kind transfer_bytes(kind) / 50e9    (per-link ICI)

HLO flops/bytes come from compiled.cost_analysis() of the *partitioned*
per-device module. Collective transfer volumes apply ring multipliers to the
result-shape bytes parsed from the optimized HLO:
    all-gather: 1x, reduce-scatter: 1x, all-reduce: 2x, all-to-all: 1x,
    collective-permute: 1x.

MODEL_FLOPS uses 6*N*D (train), 2*N*D (prefill), 2*N*B (decode) with
N = active params (MoE counts routed+shared experts only) and D = global
tokens — divided by 256 chips to compare against the per-device HLO flops.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.common import OUT_DIR, emit
from repro.configs import ALL_ARCHS, SHAPES, cell_is_runnable, get_config
# machine balance is single-sourced with the LUT-GEMM block autotuner
from repro.kernels.lut_matmul.autotune import (  # noqa: F401
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
)
from repro.launch.train import WHISPER_DECODER_LEN
from repro.models.config import active_param_count

_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}

DRYRUN_DIR = Path(__file__).resolve().parent / "out" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    """Useful (model) FLOPs per device for the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = active_param_count(cfg)
    if cfg.encoder_decoder:
        tokens = shape.batch * (shape.seq + min(shape.seq, WHISPER_DECODER_LEN))
    else:
        tokens = shape.batch * shape.seq
    if shape.kind == "train":
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.batch
    return total / 256.0


def _decode_min_bytes(arch: str, shape_name: str) -> float:
    """Per-device lower bound on decode HBM traffic: every active parameter
    (bf16) and every live cache byte is read once per generated token."""
    import math

    from repro.launch.train import decode_cache_specs
    from repro.models.lm import build_lm

    cfg = get_config(arch)
    model = build_lm(cfg)
    spec = decode_cache_specs(model, SHAPES[shape_name])
    cache_bytes = 0
    import jax

    for leaf in jax.tree.leaves(spec):
        cache_bytes += math.prod(leaf.shape) * leaf.dtype.itemsize
    param_bytes = 2 * active_param_count(cfg)
    return (param_bytes + cache_bytes) / 256.0


def analyze_cell(manifest: dict) -> dict:
    arch, shape = manifest["arch"], manifest["shape"]
    raw_flops = manifest["cost_analysis"].get("flops", 0.0)
    raw_bytes = manifest["cost_analysis"].get("bytes accessed", 0.0)
    corr = manifest.get("corrected_cost", {})
    if "flops" in corr:
        # loop-corrected flops (HLO walker, exact on scan microbenches) and
        # collectives (result bytes x trip counts). Bytes: the walker's
        # operand accounting over-counts sliced stacks, so scale XLA's own
        # fusion-convention count by the same loop multiplicity as the flops
        # (weights/activations stream once per iteration, like the flops).
        flops = max(corr["flops"], raw_flops)
        loop_mult = flops / max(raw_flops, 1.0)
        bytes_acc = raw_bytes * loop_mult
        coll_bytes = sum(_MULT[k] * corr["collectives"].get(k, 0.0)
                         for k in _MULT)
    else:
        flops, bytes_acc = raw_flops, raw_bytes
        coll = manifest["collectives"]
        coll_bytes = sum(_MULT[k] * coll[k]["bytes"] for k in _MULT)

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    mf = model_flops(arch, shape)
    useful_ratio = mf / max(flops, 1.0)
    # roofline fraction: decode is legitimately memory-bound, so its ideal is
    # the minimum HBM traffic (params + cache read once per token); train and
    # prefill are compute-ideal (useful model FLOPs at MXU peak).
    if SHAPES[shape].kind == "decode":
        ideal_time = _decode_min_bytes(arch, shape) / HBM_BW
    else:
        ideal_time = mf / PEAK_FLOPS
    roofline_frac = ideal_time / max(bound, 1e-12)

    advice = {
        "compute_s": "raise MXU utilization: larger matmul tiles / fuse "
                     "fake-quant chains / drop redundant recompute",
        "memory_s": "cut HBM traffic: fuse elementwise chains, keep attention "
                    "tiles resident (flash-style custom VJP), bf16 residuals",
        "collective_s": "reshard or overlap: move FSDP gathers off the hot "
                        "path, reduce-scatter grads, async collectives",
    }[dominant]

    return {
        "arch": arch, "shape": shape, "mesh": manifest["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant.replace("_s", ""),
        "model_flops_per_dev": mf, "hlo_flops_per_dev": flops,
        "raw_hlo_flops_per_dev": raw_flops,
        "loop_corrected": "flops" in corr,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": roofline_frac,
        "temp_bytes": manifest.get("memory_analysis", {}).get(
            "temp_size_in_bytes", 0),
        "advice": advice,
    }


def run(*, tag: str = "", mesh: str = "16x16", quiet: bool = False):
    t0 = time.time()
    rows = []
    missing = []
    for arch in ALL_ARCHS:
        for shape in SHAPES:
            if not cell_is_runnable(arch, shape):
                rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                             "status": "skipped (see DESIGN.md)"})
                continue
            suffix = f"__{tag}" if tag else ""
            path = DRYRUN_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"
            if not path.exists():
                missing.append(path.name)
                continue
            manifest = json.loads(path.read_text())
            if manifest["status"] != "ok":
                missing.append(path.name)
                continue
            rows.append(analyze_cell(manifest))

    analyzed = [r for r in rows if "dominant" in r]
    derived = {
        "cells_analyzed": len(analyzed),
        "cells_skipped": len(rows) - len(analyzed),
        "cells_missing": missing,
        "dominant_histogram": {
            k: sum(1 for r in analyzed if r["dominant"] == k)
            for k in ("compute", "memory", "collective")},
        "median_roofline_fraction": sorted(
            r["roofline_fraction"] for r in analyzed
        )[len(analyzed) // 2] if analyzed else 0.0,
        "worst_cells": sorted(
            ((r["arch"], r["shape"], round(r["roofline_fraction"], 4))
             for r in analyzed), key=lambda x: x[2])[:3],
    }

    # markdown table for EXPERIMENTS.md
    md = ["| arch | shape | compute_s | memory_s | collective_s | dominant | "
          "useful/HLO | roofline frac |",
          "|---|---|---|---|---|---|---|---|"]
    for r in analyzed:
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.3f} |")
    (OUT_DIR / f"roofline_{mesh}{('__' + tag) if tag else ''}.md").write_text(
        "\n".join(md))
    if not quiet:
        return emit("roofline", t0, rows, derived)
    return rows, derived


if __name__ == "__main__":
    run()
