"""Paper Table 2: layer-wise energy decisions on ResNet-20 — per-layer prune
ratio, selected weights, energy saving, and energy share, in the
energy-prioritized processing order."""

from __future__ import annotations

import time

from benchmarks.common import emit, fresh_copy, steps, trained
from repro.core.schedule import ScheduleConfig, energy_prioritized_compression
from repro.core.weight_selection import SelectionConfig


def run():
    t0 = time.time()
    b = fresh_copy(trained("resnet20"))
    cfg = ScheduleConfig(
        prune_ratios=(0.7, 0.5), k_targets=(16,), delta_acc=0.05,
        finetune_steps=steps(15), trial_finetune_steps=steps(10),
        eval_batches=2, max_layers=6, min_energy_share=0.0)
    sel = SelectionConfig(k_init=24, k_target=16, delta_acc=0.05,
                          score_batches=1, accept_batches=2,
                          max_score_candidates=5)
    _, _, _, _, result = energy_prioritized_compression(
        b["runner"], b["params"], b["state"], b["opt_state"], b["comp"],
        b["stats"], cfg, sel)

    rows = [{
        "layer": d.layer, "share": round(d.share, 4),
        "prune_ratio": d.prune_ratio, "selected_weights": d.k,
        "energy_saving": round(d.saving, 4), "accepted": d.accepted,
    } for d in result.decisions]

    accepted = [d for d in result.decisions if d.accepted]
    shares = [d.share for d in result.decisions]
    derived = {
        "processed_in_descending_share": shares == sorted(shares, reverse=True),
        "n_accepted": len(accepted),
        "total_saving": result.energy_saving,
        "acc0": result.acc0, "acc_final": result.acc_final,
        "top_layer": result.decisions[0].layer if result.decisions else None,
        "top_layer_saving": accepted[0].saving if accepted else 0.0,
    }
    return emit("table2_layerwise_resnet20", t0, rows, derived)


if __name__ == "__main__":
    run()
