"""Paper Table 4: naive lowest-energy top-K selection vs the co-optimized
greedy elimination. The naive arm must show the catastrophic accuracy
collapse at K=16 that motivates Section 4.2."""

from __future__ import annotations

import time

from benchmarks.common import emit, fresh_copy, steps, trained
from repro.core import baselines
from repro.core.schedule import ScheduleConfig, energy_prioritized_compression
from repro.core.weight_selection import SelectionConfig


def run():
    t0 = time.time()
    bundle = trained("resnet20")
    rows = []
    for k in (16, 20):
        b = fresh_copy(bundle)
        _, _, _, _, res = baselines.naive_topk(
            b["runner"], b["params"], b["state"], b["opt_state"], b["comp"],
            b["stats"], k=k, finetune_steps=steps(25), eval_batches=2)
        rows.append({"method": f"naive top-{k}", "k": k,
                     "energy_saving": res.energy_saving,
                     "accuracy": res.acc_after})

    b = fresh_copy(bundle)
    cfg = ScheduleConfig(prune_ratios=(0.5,), k_targets=(16,), delta_acc=0.08,
                         finetune_steps=steps(15),
                         trial_finetune_steps=steps(10), eval_batches=2,
                         max_layers=3, min_energy_share=0.0)
    sel = SelectionConfig(k_init=24, k_target=16, delta_acc=0.08,
                          score_batches=1, accept_batches=2,
                          max_score_candidates=5)
    _, _, _, _, r = energy_prioritized_compression(
        b["runner"], b["params"], b["state"], b["opt_state"], b["comp"],
        b["stats"], cfg, sel)
    rows.append({"method": "optimized selected-16", "k": 16,
                 "energy_saving": r.energy_saving, "accuracy": r.acc_final})

    naive16 = rows[0]["accuracy"]
    opt16 = rows[-1]["accuracy"]
    derived = {
        "acc0": bundle["acc0"],
        "naive16_acc": naive16,
        "optimized16_acc": opt16,
        "optimized_advantage": opt16 - naive16,
        "naive16_collapses": naive16 < bundle["acc0"] - 0.10,
        "optimized_holds": opt16 > bundle["acc0"] - 0.08,
    }
    return emit("table4_weight_selection", t0, rows, derived)


if __name__ == "__main__":
    run()
