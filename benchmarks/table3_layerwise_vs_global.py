"""Paper Table 3: layer-wise vs global strategies at matched (prune, K) on
ResNet-20. The global arm restricts every layer to one network-wide codebook;
the layer-wise arm runs the energy-prioritized schedule."""

from __future__ import annotations

import time

from benchmarks.common import emit, fresh_copy, steps, trained
from repro.core import baselines
from repro.core.schedule import ScheduleConfig, energy_prioritized_compression
from repro.core.weight_selection import SelectionConfig


def _layerwise(bundle, prune, k):
    b = fresh_copy(bundle)
    cfg = ScheduleConfig(prune_ratios=(prune,), k_targets=(k,), delta_acc=0.08,
                         finetune_steps=steps(15), trial_finetune_steps=steps(10),
                         eval_batches=2, max_layers=3, min_energy_share=0.0)
    sel = SelectionConfig(k_init=max(24, k), k_target=k, delta_acc=0.08,
                          score_batches=1, accept_batches=2,
                          max_score_candidates=5)
    _, _, _, _, r = energy_prioritized_compression(
        b["runner"], b["params"], b["state"], b["opt_state"], b["comp"],
        b["stats"], cfg, sel)
    return {"method": f"layerwise p{prune} k{k}", "prune": prune, "k": k,
            "energy_saving": r.energy_saving, "accuracy": r.acc_final}


def _global(bundle, prune, k):
    b = fresh_copy(bundle)
    sel = SelectionConfig(k_init=max(24, k), k_target=k, delta_acc=0.5,
                          score_batches=1, accept_batches=1,
                          max_score_candidates=5)
    _, _, _, _, res = baselines.global_strategy(
        b["runner"], b["params"], b["state"], b["opt_state"], b["comp"],
        b["stats"], prune_ratio=prune, k_target=k,
        finetune_steps=steps(30), eval_batches=2, sel_cfg=sel)
    return {"method": f"global p{prune} k{k}", "prune": prune, "k": k,
            "energy_saving": res.energy_saving, "accuracy": res.acc_after}


def run():
    t0 = time.time()
    bundle = trained("resnet20")
    rows = []
    for prune, k in ((0.5, 32), (0.5, 16)):
        rows.append(_global(bundle, prune, k))
        rows.append(_layerwise(bundle, prune, k))

    def pair(prune, k):
        g = next(r for r in rows if r["method"] == f"global p{prune} k{k}")
        l = next(r for r in rows if r["method"] == f"layerwise p{prune} k{k}")
        return g, l

    g16, l16 = pair(0.5, 16)
    g32, l32 = pair(0.5, 32)
    derived = {
        "k16_layerwise_acc_advantage": l16["accuracy"] - g16["accuracy"],
        "k32_layerwise_acc_advantage": l32["accuracy"] - g32["accuracy"],
        "layerwise_acc_wins_at_16": l16["accuracy"] >= g16["accuracy"],
        "global_degrades_more_at_16": (g32["accuracy"] - g16["accuracy"])
                                      >= (l32["accuracy"] - l16["accuracy"]),
    }
    return emit("table3_layerwise_vs_global", t0, rows, derived)


if __name__ == "__main__":
    run()
