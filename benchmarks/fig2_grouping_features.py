"""Paper Figure 2: (a) power vs transition Hamming distance; (b) power vs
(MSB_prev, MSB_cur) pair — validates the two grouping features, plus the
stability-ratio comparison against random grouping."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import grouping
from repro.core.mac_model import mac_transition_energy


def run():
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    n = 1 << 15
    k1, k2, k3 = jax.random.split(key, 3)
    width1 = jax.random.randint(k1, (n,), 1, 23)
    width2 = jax.random.randint(k2, (n,), 1, 23)
    raw = jax.random.randint(k3, (n, 2), 0, 1 << 22)
    p_prev = raw[:, 0] & ((1 << width1) - 1)
    p_cur = raw[:, 1] & ((1 << width2) - 1)
    e = mac_transition_energy(11, 5, 5, p_prev, p_cur)

    # (a) power vs HD
    hd = jax.lax.population_count((p_prev ^ p_cur) & 0x3FFFFF)
    hd_rows = []
    for h in range(0, 22, 2):
        m = (hd >= h) & (hd < h + 2)
        if bool(jnp.any(m)):
            hd_rows.append({"hd_bucket": h,
                            "mean_power": float(jnp.mean(e[m]))})
    hd_monotone = all(a["mean_power"] < b["mean_power"]
                      for a, b in zip(hd_rows, hd_rows[1:]))

    # (b) power vs MSB pair (5x5 coarse buckets)
    mg_prev = grouping.msb_group(p_prev) // 2
    mg_cur = grouping.msb_group(p_cur) // 2
    msb_rows = []
    for i in range(5):
        for j in range(5):
            m = (mg_prev == i) & (mg_cur == j)
            if bool(jnp.any(m)):
                msb_rows.append({"msb_prev": i, "msb_cur": j,
                                 "mean_power": float(jnp.mean(e[m]))})
    diag = [r["mean_power"] for r in msb_rows if r["msb_prev"] == r["msb_cur"]]
    offd = [r["mean_power"] for r in msb_rows if
            abs(r["msb_prev"] - r["msb_cur"]) >= 2]

    # stability ratio: model grouping vs random
    gid = (grouping.group_id(p_prev) * grouping.N_GROUPS
           + grouping.group_id(p_cur))
    sr_model = float(grouping.stability_ratio(e, gid, grouping.N_GROUPS ** 2))
    g_rand = jax.random.randint(jax.random.fold_in(key, 9), (n,), 0,
                                grouping.N_GROUPS ** 2)
    sr_rand = float(grouping.stability_ratio(e, g_rand, grouping.N_GROUPS ** 2))

    derived = {
        "hd_monotone": hd_monotone,
        "diag_mean": sum(diag) / len(diag),
        "offdiag_mean": sum(offd) / len(offd),
        "offdiag_over_diag": (sum(offd) / len(offd)) / (sum(diag) / len(diag)),
        "stability_ratio_msb_hd": sr_model,
        "stability_ratio_random": sr_rand,
        "stability_gain": sr_model / max(sr_rand, 1e-9),
    }
    return emit("fig2_grouping_features", t0,
                {"hd": hd_rows, "msb": msb_rows}, derived)


if __name__ == "__main__":
    run()
