"""Serving benchmark: slot-level continuous batching vs wave-lockstep vs
single-shot.

Two fixed request traces through `repro.serving.ServingEngine` on a reduced
olmo-1b:

* ``TRACE`` (16 requests, two prompt buckets, per-request ``new_tokens``)
  drains through ``mode="engine"`` (slot-level) and ``mode="oneshot"`` —
  the historical engine-vs-fallback comparison whose throughput trajectory
  `BENCH_serving.json` tracks across PRs.
* ``BURSTY`` (24 requests, queue depth > slot count, new-token budgets
  varying 4..16 under a single 16-token decode bucket) drains through
  ``mode="engine"`` and the legacy ``mode="wave"`` lockstep baseline. The
  trace is built to stall a lockstep scheduler: early finishers idle until
  their wave drains, and deep-queue requests wait for a whole wave to form.
  Slot-level refill + chunked prefill is gated to beat the wave baseline on
  both tokens/sec and p99 time-to-first-token by >= 30%.

All modes implement the same pad-to-bucket contract and the same AOT
compile-cache discipline (each mode warms its own executables and is timed
only after warmup), so the ratios isolate scheduling, not compile-time
accounting tricks. Gated in tools/check_gates.py:

* ``serving_speedup_engine_vs_oneshot`` >= 2.0 — the batching win;
* ``serving_speedup_slot_vs_wave`` >= 1.05 — slot refill + chunked prefill
  must beat wave lockstep outright on the bursty trace (measured ~1.2-1.3x;
  the modest floor absorbs scheduler-noise variance on shared hosts);
* ``serving_ttft_p99_improvement_vs_wave`` >= 1.3 — tail TTFT must improve
  >= 30% on the same trace (measured ~3x: freed slots refill immediately
  instead of queueing behind a draining wave);
* ``recompiles_after_warmup`` == 0 — serving both traces in all modes must
  not build a single new executable (the AOT cache raises on a shape miss,
  so this both measures and enforces);
* ``parity_engine_vs_oneshot`` / ``parity_slot_vs_wave`` — greedy outputs
  identical per request across modes.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import best_of, emit
from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params
from repro.serving import EngineConfig, ServeRequest, ServingEngine

ARCH = "olmo-1b"
# (prompt_len, new_tokens) per request: 16 requests over two prompt buckets
# (16, 32) and one new-token bucket, mixed so waves pack partially and the
# admission loop has to interleave buckets.
TRACE = [
    (12, 16), (16, 12), (30, 16), (9, 16),
    (16, 16), (25, 10), (32, 16), (14, 8),
    (31, 16), (16, 16), (10, 12), (28, 16),
    (16, 10), (24, 16), (13, 16), (32, 12),
]
# Bursty trace: 24 requests against 16 slots, long and short prompts
# interleaved, decode budgets spread over 4..32 under one 32-token bucket —
# a lockstep wave pads every request's decode to 32 steps and idles the
# early finishers until the wave drains, and the queue depth makes admission
# latency visible in the TTFT tail.
BURSTY = [
    (32, 32), (30, 4), (16, 16), (12, 6), (32, 28), (9, 8), (28, 12), (16, 20),
    (31, 32), (14, 4), (25, 24), (16, 10), (10, 6), (32, 32), (13, 16), (24, 8),
    (29, 28), (16, 4), (27, 12), (11, 32), (32, 6), (15, 20), (26, 24), (16, 10),
]
ENGINE_CFG = EngineConfig(max_batch=8, prompt_buckets=(16, 32),
                          new_token_buckets=(16,), max_waves=2,
                          chunk_buckets=(16,), chunk_rows=8)
BURSTY_CFG = EngineConfig(max_batch=8, prompt_buckets=(16, 32),
                          new_token_buckets=(32,), max_waves=2,
                          chunk_buckets=(16,), chunk_rows=8)


def _build():
    cfg = get_config(ARCH).scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), model.spec)
    rng = np.random.default_rng(7)
    traces = {}
    for name, trace in (("trace", TRACE), ("bursty", BURSTY)):
        prompts = [rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
                   for plen, _ in trace]
        traces[name] = (prompts, [n for _, n in trace])
    return model, params, traces


def _drain(engine, prompts, news):
    for p, n in zip(prompts, news):
        engine.submit(p, n)
    engine.run()


def _measure(model, params, mode, trace_name, prompts, news):
    cfg = ENGINE_CFG if trace_name == "trace" else BURSTY_CFG
    eng = ServingEngine(model, params, mode=mode, config=cfg)
    eng.warmup(list(zip((len(p) for p in prompts), news)))
    _drain(eng, prompts, news)          # warm run: process-level jax caches
    warm_compiles = eng.cache.compile_count
    wall = best_of(lambda: _drain(eng, prompts, news))
    recompiles = eng.cache.compile_count - warm_compiles
    # untimed verification pass: per-request tokens in trace order
    res = eng.serve([ServeRequest(tokens=p, max_new_tokens=n)
                     for p, n in zip(prompts, news)])
    tokens = [r.tokens for r in res]
    rep = eng.report()
    new_tokens = sum(news)
    row = {
        "mode": mode,
        "trace": trace_name,
        "requests": len(prompts),
        "new_tokens": new_tokens,
        "wall_s": wall,
        "tokens_per_s": new_tokens / wall,
        "buckets_compiled": rep["cache_buckets_compiled"],
        "compile_count": rep["cache_compile_count"],
        "recompiles_after_warmup": recompiles,
        "energy_eu_per_token": rep["energy_eu_per_token"],
        "energy_eu_overhead": rep["energy_eu_overhead"],
        "slot_utilization": rep["slot_utilization"],
        "latency_p50_s": rep["latency_p50_s"],
        "ttft_p50_s": rep["ttft_p50_s"],
        "ttft_p99_s": rep["ttft_p99_s"],
    }
    return row, tokens, recompiles


def run():
    t0 = time.time()
    model, params, traces = _build()

    rows, tokens, recompiles = {}, {}, 0
    for mode, trace_name in (("engine", "trace"), ("oneshot", "trace"),
                             ("engine", "bursty"), ("wave", "bursty")):
        prompts, news = traces[trace_name]
        row, toks, rc = _measure(model, params, mode, trace_name, prompts,
                                 news)
        rows[(mode, trace_name)] = row
        tokens[(mode, trace_name)] = toks
        recompiles += rc

    parity = tokens[("engine", "trace")] == tokens[("oneshot", "trace")]
    lengths_ok = all(
        len(t) == n
        for t, n in zip(tokens[("engine", "trace")], traces["trace"][1]))
    parity_burst = tokens[("engine", "bursty")] == tokens[("wave", "bursty")]

    eng_t, one_t = rows[("engine", "trace")], rows[("oneshot", "trace")]
    slot_b, wave_b = rows[("engine", "bursty")], rows[("wave", "bursty")]
    derived = {
        "requests": len(TRACE),
        "new_tokens": sum(traces["trace"][1]),
        "engine_wall_s": eng_t["wall_s"],
        "oneshot_wall_s": one_t["wall_s"],
        "engine_tokens_per_s": eng_t["tokens_per_s"],
        "oneshot_tokens_per_s": one_t["tokens_per_s"],
        "serving_speedup_engine_vs_oneshot":
            one_t["wall_s"] / eng_t["wall_s"],
        "recompiles_after_warmup": recompiles,
        "parity_engine_vs_oneshot": bool(parity and lengths_ok),
        # bursty trace: slot-level engine vs the wave-lockstep baseline
        "bursty_requests": len(BURSTY),
        "slot_tokens_per_s": slot_b["tokens_per_s"],
        "wave_tokens_per_s": wave_b["tokens_per_s"],
        "serving_speedup_slot_vs_wave":
            wave_b["wall_s"] / slot_b["wall_s"],
        "ttft_p99_s": slot_b["ttft_p99_s"],
        "wave_ttft_p99_s": wave_b["ttft_p99_s"],
        "serving_ttft_p99_improvement_vs_wave":
            wave_b["ttft_p99_s"] / slot_b["ttft_p99_s"],
        "slot_utilization": slot_b["slot_utilization"],
        "wave_slot_utilization": wave_b["slot_utilization"],
        "parity_slot_vs_wave": bool(parity_burst),
    }
    return emit("bench_serving", t0, list(rows.values()), derived)


if __name__ == "__main__":
    run()
