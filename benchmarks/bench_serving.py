"""Serving benchmark: continuous-batching engine vs single-shot fallback.

Drains a fixed mixed-length request trace (two prompt buckets, per-request
``new_tokens``) through `repro.serving.ServingEngine` in both modes on a
reduced olmo-1b and reports tokens/sec. Both modes implement the same
pad-to-bucket contract and the same AOT compile-cache discipline (each mode
warms its own cache — their bucket widths differ — and both are timed only
after warmup), so the ratio isolates exactly what the engine adds — wave
batching plus admission/decode interleaving — not compile-time accounting
tricks.

Gated in tools/check_gates.py:

* ``serving_speedup_engine_vs_oneshot`` >= 2.0 — the batching win;
* ``recompiles_after_warmup`` == 0 — after bucket warmup, serving the whole
  trace must not build a single new executable (the AOT cache would raise
  on a shape miss, so this both measures and enforces);
* ``parity_engine_vs_oneshot`` — greedy outputs identical per request.

`BENCH_serving.json` at the repo root tracks the throughput trajectory
across PRs (tools/check_gates.py --trajectory gates on it).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import best_of, emit
from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params
from repro.serving import EngineConfig, ServingEngine

ARCH = "olmo-1b"
# (prompt_len, new_tokens) per request: 16 requests over two prompt buckets
# (16, 32) and one new-token bucket, mixed so waves pack partially and the
# admission loop has to interleave buckets.
TRACE = [
    (12, 16), (16, 12), (30, 16), (9, 16),
    (16, 16), (25, 10), (32, 16), (14, 8),
    (31, 16), (16, 16), (10, 12), (28, 16),
    (16, 10), (24, 16), (13, 16), (32, 12),
]
ENGINE_CFG = EngineConfig(max_batch=8, prompt_buckets=(16, 32),
                          new_token_buckets=(16,), max_waves=2)


def _build():
    cfg = get_config(ARCH).scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), model.spec)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
               for plen, _ in TRACE]
    news = [n for _, n in TRACE]
    return model, params, prompts, news


def _drain(engine, prompts, news):
    for p, n in zip(prompts, news):
        engine.submit(p, n)
    engine.run()


def run():
    t0 = time.time()
    model, params, prompts, news = _build()
    new_tokens = sum(news)

    rows = []
    walls = {}
    compiles = {}
    tokens = {}
    for mode in ("engine", "oneshot"):
        eng = ServingEngine(model, params, mode=mode, config=ENGINE_CFG)
        eng.warmup(TRACE)
        _drain(eng, prompts, news)      # warm run: process-level jax caches
        warm_compiles = eng.cache.compile_count
        walls[mode] = best_of(lambda e=eng: _drain(e, prompts, news))
        compiles[mode] = eng.cache.compile_count - warm_compiles
        # untimed verification pass: per-request tokens in trace order
        res = eng.serve(prompts, news)
        tokens[mode] = [res[r].tokens for r in sorted(res)]
        rep = eng.report()
        rows.append({
            "mode": mode,
            "requests": len(TRACE),
            "new_tokens": new_tokens,
            "wall_s": walls[mode],
            "tokens_per_s": new_tokens / walls[mode],
            "buckets_compiled": rep["cache_buckets_compiled"],
            "compile_count": rep["cache_compile_count"],
            "recompiles_after_warmup": compiles[mode],
            "energy_eu_per_token": rep["energy_eu_per_token"],
            "latency_p50_s": rep["latency_p50_s"],
            "ttft_p50_s": rep["ttft_p50_s"],
        })

    parity = tokens["engine"] == tokens["oneshot"]
    lengths_ok = all(len(t) == n for t, n in zip(tokens["engine"], news))
    derived = {
        "requests": len(TRACE),
        "new_tokens": new_tokens,
        "engine_wall_s": walls["engine"],
        "oneshot_wall_s": walls["oneshot"],
        "engine_tokens_per_s": new_tokens / walls["engine"],
        "oneshot_tokens_per_s": new_tokens / walls["oneshot"],
        "serving_speedup_engine_vs_oneshot": walls["oneshot"] / walls["engine"],
        "recompiles_after_warmup": compiles["engine"] + compiles["oneshot"],
        "parity_engine_vs_oneshot": bool(parity and lengths_ok),
    }
    return emit("bench_serving", t0, rows, derived)


if __name__ == "__main__":
    run()
