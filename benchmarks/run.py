"""Benchmark driver: one entry per paper table/figure + roofline + kernels.

Prints ``name,us_per_call,derived`` CSV per benchmark (derived is a JSON
blob of the headline numbers) and writes full rows to benchmarks/out/*.json.
BENCH_BUDGET=fast|full scales training budgets (default fast; see common.py).
BENCH_ONLY=<name[,name]> restricts the run.
"""

from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        bench_kernels,
        bench_schedule,
        bench_serving,
        fig1_weight_power,
        fig2_grouping_features,
        fig3_activation_heatmaps,
        fig4_components,
        roofline,
        table1_energy_savings,
        table2_layerwise_resnet20,
        table3_layerwise_vs_global,
        table4_weight_selection,
    )

    benches = [
        ("fig1_weight_power", fig1_weight_power.run),
        ("fig2_grouping_features", fig2_grouping_features.run),
        ("fig3_activation_heatmaps", fig3_activation_heatmaps.run),
        ("table1_energy_savings", table1_energy_savings.run),
        ("table2_layerwise_resnet20", table2_layerwise_resnet20.run),
        ("table3_layerwise_vs_global", table3_layerwise_vs_global.run),
        ("table4_weight_selection", table4_weight_selection.run),
        ("fig4_components", fig4_components.run),
        ("bench_kernels", bench_kernels.run),
        ("bench_schedule", bench_schedule.run),
        ("bench_serving", bench_serving.run),
        ("roofline", roofline.run),
    ]
    only = os.environ.get("BENCH_ONLY")
    if only:
        allow = set(only.split(","))
        benches = [(n, f) for n, f in benches if n in allow]

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for name, fn in benches:
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"{name},0,{{\"status\": \"FAILED\"}}")
    print(f"# total wall: {time.time() - t0:.1f}s budget="
          f"{os.environ.get('BENCH_BUDGET', 'fast')}")
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
