"""Routing-aware compression target benchmark: MoE + scan smoke gate.

Runs the two routed reduced pipelines end-to-end through export —
``repro.pipeline.targets.MoETarget`` on the reduced phi3.5-MoE config and
``ScanTarget`` on the reduced mamba2 config — and derives the keys gated by
``tools/check_gates.py --targets``:

* ``targets_{moe,scan}_parity_rel_err`` — the exported per-expert /
  per-scan-unit LUT-GEMM artifacts must match the fake-quant matmul on
  random activations (`repro.core.lm_compress.lut_parity_report` inside the
  export stage). This is the compressed-vs-dense serving parity: the same
  artifacts the serve stage dispatches on.
* ``targets_{moe,scan}_energy_reduction`` — traffic-weighted per-token
  energy must drop by the documented floor once the k-ladder assignment is
  applied over the uniform codebook floor.
* ``targets_{moe,scan}_hotcold_monotone`` — within every routed group
  (experts of one MoE layer; layers of one scan unit) a higher measured
  traffic share must never get a smaller codebook than a lower one.
* ``targets_{moe,scan}_routed_units`` / ``targets_{moe,scan}_export_skipped``
  — the routed slice count matches the architecture and nothing silently
  drops out of the export (the skip report must be empty).

Deterministic: the calibration trace, routing counts and energy model are
all seeded; no timing-sensitive keys, so no CI slack applies.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

from benchmarks.common import emit


def _monotone(pairs: List[Tuple[float, int]]) -> bool:
    """share_i > share_j must imply k_i >= k_j within one routed group."""
    for s1, k1 in pairs:
        for s2, k2 in pairs:
            if s1 > s2 and k1 < k2:
                return False
    return True


def _run_target(tag: str, make_cfg) -> Dict:
    from repro.pipeline.pipeline import Pipeline
    from repro.pipeline.targets import _slice_key

    pipe = Pipeline(make_cfg())
    plan = pipe.run_until("export", verbose=False)
    m = plan.metrics

    routed = [d for d in plan.decisions if "traffic_share" in d]
    groups: Dict[Tuple, List[Tuple[float, int]]] = {}
    for d in routed:
        path, li, ei = _slice_key(d["layer"])
        key = (path, li) if ei is not None else (path,)
        groups.setdefault(key, []).append(
            (float(d["traffic_share"]), int(d["k"])))
    e_before = float(m["energy_before"])
    e_after = float(m["energy_after"])
    return {
        f"targets_{tag}_parity_rel_err": float(m["export_parity_max_rel_err"]),
        f"targets_{tag}_energy_reduction":
            1.0 - e_after / max(e_before, 1e-12),
        f"targets_{tag}_hotcold_monotone":
            bool(groups) and all(_monotone(g) for g in groups.values()),
        f"targets_{tag}_routed_units": len(routed),
        f"targets_{tag}_export_skipped": int(m["export_skipped"]),
        f"targets_{tag}_routing_tokens": int(m["routing_tokens"]),
    }


def run():
    from repro.pipeline.config import reduced_moe_config, reduced_scan_config

    t0 = time.time()
    rows = []
    derived: Dict = {}
    for tag, make_cfg in (("moe", reduced_moe_config),
                          ("scan", reduced_scan_config)):
        res = _run_target(tag, make_cfg)
        derived.update(res)
        rows.append({"bench": "targets", "target": tag, **res})
        print(f"  targets {tag}: parity="
              f"{res[f'targets_{tag}_parity_rel_err']:.2e} "
              f"energy_reduction="
              f"{res[f'targets_{tag}_energy_reduction']:.3f} "
              f"monotone={res[f'targets_{tag}_hotcold_monotone']} "
              f"routed={res[f'targets_{tag}_routed_units']}", flush=True)
    return emit("bench_targets", t0, rows, derived)


if __name__ == "__main__":
    run()
