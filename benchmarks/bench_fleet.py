"""Fleet serving benchmark: SLO-aware routing across compression levels.

Three resident plans on a reduced olmo-1b — ``base`` (uncompressed), ``k8``
and ``k4`` codebook restrictions — behind one `repro.serving.fleet
.FleetRouter`, against two pinned single-plan baselines on the identical
trace:

* ``BURST`` (24 requests against 8 slots, submitted before any scheduler
  step runs) drives queue pressure through the router's high watermark so
  it degrades to aggressive compression, then ``TRICKLE`` (one request per
  drain) lets pressure collapse so it recovers to high fidelity — both
  transitions must appear in the route log.
* **always-high-fidelity** pins every request to ``base``: the energy
  baseline. Routed tokens-per-energy-unit must beat it by >= 1.15x — the
  fleet's reason to exist is serving the same trace for less energy.
* **always-aggressive** pins every request to ``k4``: the latency baseline.
  Routed p99 time-to-first-token must stay within 1.2x of it — degrading
  *fidelity* under load must not be bought with a latency regression.

Per-request energy charges are analytic (`repro.serving.metrics
.per_token_energy` x positions), so the tokens-per-energy ratio is
deterministic given the route decisions; only the TTFT gate is
timing-sensitive. Gated in tools/check_gates.py (``--fleet``):

* ``fleet_tokens_per_eu_vs_highfid`` >= 1.15;
* ``fleet_ttft_p99_headroom_vs_aggressive`` >= 1.0 (aggressive p99 x 1.2
  over routed p99; timing gate, CI slack applies);
* ``fleet_recompiles_after_warmup`` == 0 with >= 3 plans resident — every
  variant's executables are AOT-warmed, routing never compiles;
* ``fleet_degrade_observed`` / ``fleet_recover_observed`` — the route log
  must show both transitions;
* ``fleet_parity_routed_vs_pinned`` — every routed request's tokens match
  a pinned engine of the plan that served it, *replaying that plan's routed
  workload* (routing changes which variant runs, never what that variant
  outputs). The replay matters: queue composition decides which executable
  prefills a request (chunked vs whole-bucket), and on a reduced
  random-weight model greedy argmax near-ties flip under the ~1e-6 float
  differences between those paths — pre-existing engine behavior, observed
  identically at the seed commit. Same plan + same workload pattern -> same
  executables -> bit-identical tokens, which is the invariant the fleet
  layer must preserve.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import CI_BEST_OF, DEFAULT_BEST_OF, bench_ci, emit
from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params
from repro.serving import (
    EngineConfig,
    FleetRouter,
    PlanHandle,
    RequestBudget,
    RouterConfig,
    ServeRequest,
    ServingEngine,
)

ARCH = "olmo-1b"
# 24 requests against 8 slots (max_batch=4 x max_waves=2): queue pressure at
# submit time ramps past the high watermark with no drain in between, so the
# router must degrade base -> k8 -> k4 mid-burst.
BURST = [
    (32, 16), (30, 4), (16, 16), (12, 6), (32, 12), (9, 8),
    (28, 12), (16, 16), (31, 16), (14, 4), (25, 12), (16, 10),
    (10, 6), (32, 16), (13, 16), (24, 8), (29, 12), (16, 4),
    (27, 12), (11, 16), (32, 6), (15, 16), (26, 12), (16, 10),
]
# One request per full drain: pressure is ~0 at every submit, so the router
# must walk back to high fidelity. The last request carries an energy budget
# that only the aggressive plans satisfy — routed by SLO, not pressure.
TRICKLE = [(16, 8), (24, 8), (12, 8), (30, 8), (16, 8), (20, 8)]
CFG = EngineConfig(max_batch=4, prompt_buckets=(16, 32),
                   new_token_buckets=(16,), max_waves=2,
                   chunk_buckets=(16,), chunk_rows=4)
# Capacity is 8 slots: half-full already means a deep queue relative to one
# wave, so the degrade watermark sits at 0.5 rather than the library default.
ROUTER = RouterConfig(high_watermark=0.5, low_watermark=0.25, hysteresis=2)
# Energy cap for the budgeted TRICKLE request: above k8/k4 (~5.8-6.5e8 eu per
# token on this config), below base (~8.3e8) — satisfiable, but not at the
# high-fidelity level the idle router would otherwise pick.
BUDGET_EU_PER_TOKEN = 7.0e8


def _build():
    cfg = get_config(ARCH).scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), model.spec)
    rng = np.random.default_rng(7)

    def reqs(trace, tenant_base):
        out = []
        for i, (plen, ntok) in enumerate(trace):
            prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
            out.append(ServeRequest(tokens=prompt, max_new_tokens=ntok,
                                    tenant=f"tenant{(tenant_base + i) % 2}"))
        return out

    burst = reqs(BURST, 0)
    trickle = reqs(TRICKLE, 1)
    trickle[-1] = ServeRequest(
        tokens=trickle[-1].tokens, max_new_tokens=trickle[-1].max_new_tokens,
        tenant=trickle[-1].tenant,
        budget=RequestBudget(energy_eu_per_token=BUDGET_EU_PER_TOKEN))
    return model, params, burst, trickle


def _drive(submit, run, burst, trickle):
    """Burst phase (submit all, then drain) + trickle phase (drain between
    submits); returns per-request results in submit order."""
    rids = [submit(r) for r in burst]
    out = dict(run())
    for r in trickle:
        rids.append(submit(r))
        out.update(run())
    return [out[rid] for rid in rids]


def _drive_engine(eng, burst, trickle):
    """Same burst + trickle pattern against one pinned engine."""
    rids = [eng.submit_request(r) for r in burst]
    eng.run()
    for r in trickle:
        rids.append(eng.submit_request(r))
        eng.run()
    return [eng.result(rid) for rid in rids]


def _ttft_p99(results) -> float:
    from repro.serving.metrics import percentile

    return percentile([r.stats.ttft_s for r in results], 99)


def run():
    t0 = time.time()
    model, params, burst, trickle = _build()
    shapes = [(len(r.tokens), r.max_new_tokens) for r in burst + trickle]
    # the TTFT gate is the one timing-sensitive number: like best_of(), take
    # the best pass so one scheduler hiccup on a loaded host cannot fail it
    # (router state recovers to level 0 between passes, so every pass routes
    # identically and the energy/parity numbers come from the first)
    passes = CI_BEST_OF if bench_ci() else DEFAULT_BEST_OF

    handles = [PlanHandle.uncompressed(),
               PlanHandle.from_compress_k(model, 8),
               PlanHandle.from_compress_k(model, 4)]

    fleet = FleetRouter(model, params, handles, config=CFG, router=ROUTER)
    fleet.warmup(shapes)
    n_req = len(burst) + len(trickle)
    fleet_ttft = float("inf")
    for p in range(passes):
        pass_routed = _drive(fleet.submit, fleet.run, burst, trickle)
        fleet_ttft = min(fleet_ttft, _ttft_p99(pass_routed))
        if p == 0:
            routed = pass_routed
    rep = fleet.report()
    route_plan = [e["plan_id"] for e in fleet.route_log[:n_req]]

    # pinned baselines on the identical full trace: base = energy reference,
    # k4 = latency reference (same best-of-passes treatment)
    pinned_reports = {}
    for h, n in ((handles[0], 1), (handles[2], passes)):
        eng = ServingEngine(model, params, config=CFG, plan=h)
        eng.warmup(shapes)
        warm = eng.cache.compile_count
        ttft = min(_ttft_p99(_drive_engine(eng, burst, trickle))
                   for _ in range(n))
        pinned_reports[h.plan_id] = dict(eng.report(),
                                         ttft_best_p99_s=ttft,
                                         recompiles=eng.cache.compile_count
                                         - warm)

    # parity: replay each plan's routed workload on a pinned engine of that
    # plan — same submit pattern, so the same executables fire
    requests = burst + trickle
    replayed = {}
    for h in handles:
        eng = ServingEngine(model, params, config=CFG, plan=h)
        eng.warmup(shapes)
        rids = {i: eng.submit_request(requests[i])
                for i in range(len(burst)) if route_plan[i] == h.plan_id}
        eng.run()
        for i in range(len(burst), len(requests)):
            if route_plan[i] == h.plan_id:
                rids[i] = eng.submit_request(requests[i])
            eng.run()  # the fleet drained after every trickle submit
        replayed.update({i: eng.result(rid) for i, rid in rids.items()})
    parity = all(
        r.tokens == replayed[i].tokens for i, r in enumerate(routed))

    hf, ag = pinned_reports["base"], pinned_reports["k4"]
    # energy is analytic per request, so the pass-0 sums (one full trace on
    # each side) give a deterministic tokens-per-energy-unit ratio
    fleet_energy = sum(r.stats.energy_eu for r in routed)
    fleet_tokens = sum(r.stats.new_tokens for r in routed)
    fleet_tpe = fleet_tokens / fleet_energy
    hf_tpe = hf["new_tokens"] / hf["energy_eu_total"]
    rows = [dict(system="fleet", **{k: v for k, v in rep.items()
                                    if not isinstance(v, dict)})]
    rows += [dict(system=f"pinned_{pid}", **r)
             for pid, r in pinned_reports.items()]
    derived = {
        "fleet_requests": len(routed),
        "fleet_new_tokens": fleet_tokens,
        "fleet_plans_resident": rep["plans_resident"],
        "fleet_tokens_per_s": rep["tokens_per_s"],
        "highfid_tokens_per_s": hf["tokens_per_s"],
        "aggressive_tokens_per_s": ag["tokens_per_s"],
        "fleet_energy_eu_total": fleet_energy,
        "highfid_energy_eu_total": hf["energy_eu_total"],
        "fleet_tokens_per_eu_vs_highfid": fleet_tpe / hf_tpe,
        "fleet_ttft_p99_s": fleet_ttft,
        "aggressive_ttft_p99_s": ag["ttft_best_p99_s"],
        "fleet_ttft_p99_headroom_vs_aggressive":
            ag["ttft_best_p99_s"] * 1.2 / fleet_ttft,
        "fleet_recompiles_after_warmup": rep["recompiles_after_warmup"],
        "fleet_level_degrades": rep["level_degrades"],
        "fleet_level_recovers": rep["level_recovers"],
        "fleet_degrade_observed": bool(rep["level_degrades"] > 0),
        "fleet_recover_observed": bool(rep["level_recovers"] > 0),
        "fleet_parity_routed_vs_pinned": bool(parity),
        "fleet_slo_total": rep["slo_total"],
        "fleet_slo_hits": rep["slo_hits"],
        "fleet_requests_per_plan": {
            pid: route_plan.count(pid) for pid in sorted(set(route_plan))},
    }
    return emit("bench_fleet", t0, rows, derived)


if __name__ == "__main__":
    run()
