"""Paper Figure 3: activation transition heatmaps for LeNet-5 conv1/conv2 —
shows layer-to-layer variation that global models miss (plus the grouped
energy-model fidelity per layer)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, trained
from repro.core.energy_lut import model_fidelity


def _summarize(act_hist) -> dict:
    h = np.asarray(act_hist)
    total = h.sum() or 1.0
    p = h / total
    # sparsity proxy: mass at a==0 transitions (row/col 128)
    zero_mass = float(p[128, :].sum() + p[:, 128].sum() - p[128, 128])
    # spread: entropy of the transition distribution
    nz = p[p > 0]
    entropy = float(-(nz * np.log(nz)).sum())
    diag_mass = float(np.trace(p))
    return {"zero_mass": zero_mass, "entropy": entropy, "diag_mass": diag_mass}


def run():
    t0 = time.time()
    b = trained("lenet5")
    stats = b["stats"]
    rows = {}
    for layer in ("conv1", "conv2"):
        s = stats[layer]
        rows[layer] = _summarize(s.act_hist)
        rows[layer]["model_fidelity"] = model_fidelity(s, n_mc=2048)
        # coarse 8x8 heatmap for the record
        h = np.asarray(s.act_hist).reshape(8, 32, 8, 32).sum((1, 3))
        rows[layer]["heatmap_8x8"] = (h / max(h.sum(), 1)).round(4).tolist()

    d1, d2 = rows["conv1"], rows["conv2"]
    derived = {
        "conv1_entropy": d1["entropy"],
        "conv2_entropy": d2["entropy"],
        "entropy_gap": abs(d1["entropy"] - d2["entropy"]),
        "conv1_zero_mass": d1["zero_mass"],
        "conv2_zero_mass": d2["zero_mass"],
        "layers_differ": abs(d1["entropy"] - d2["entropy"]) > 0.05
                         or abs(d1["zero_mass"] - d2["zero_mass"]) > 0.02,
        "conv1_lut_spearman": d1["model_fidelity"]["spearman"],
        "conv2_lut_spearman": d2["model_fidelity"]["spearman"],
    }
    return emit("fig3_activation_heatmaps", t0, rows, derived)


if __name__ == "__main__":
    run()
