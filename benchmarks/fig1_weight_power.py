"""Paper Figure 1: average MAC power across weight values."""

from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.mac_model import weight_static_energy_profile


def run():
    t0 = time.time()
    prof = weight_static_energy_profile(n_samples=4096)
    w = jnp.arange(-128, 128)
    rows = [{"w": int(wi), "power_eu": float(p)} for wi, p in zip(w, prof)]
    derived = {
        "min_power": float(jnp.min(prof)),
        "max_power": float(jnp.max(prof)),
        "spread_ratio": float(jnp.max(prof) / jnp.min(prof)),
        "argmin_w": int(w[int(jnp.argmin(prof))]),
        "zero_weight_power": float(prof[128]),
    }
    # ASCII sketch of the profile (16 buckets)
    buckets = prof.reshape(16, 16).mean(axis=1)
    lo, hi = float(buckets.min()), float(buckets.max())
    bars = "".join("▁▂▃▄▅▆▇█"[min(7, int(8 * (float(b) - lo) / (hi - lo + 1e-9)))]
                   for b in buckets)
    print(f"# fig1 weight-power profile (w=-128..127): {bars}")
    return emit("fig1_weight_power", t0, rows, derived)


if __name__ == "__main__":
    run()
