"""Kernel microbenchmarks: correctness deltas + structural stats.

Wall times on this CPU-only host come from interpret mode and are NOT TPU
projections; the meaningful derived quantities are correctness vs oracle and
the compression ratio of the LUT weight format (4x byte reduction vs bf16,
with a 16-entry codebook + per-channel scales as the only overhead).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.mac_model import DEFAULT_COEFFS
from repro.core.stats import TILE, tile_transition_stats as stats_oracle
from repro.kernels.lut_matmul.ops import compress_layer_weights, lut_matmul
from repro.kernels.lut_matmul.ref import lut_matmul_ref
from repro.kernels.transition_energy.ops import tile_transition_stats


def run():
    t0 = time.time()
    rows = []
    key = jax.random.PRNGKey(0)

    # --- LUT matmul
    m, k, n = 256, 512, 256
    w = jax.random.normal(key, (k, n)) * 0.04
    values = [-112, -80, -56, -40, -28, -16, -8, 0, 8, 16, 28, 40, 56, 80,
              112, 127]
    packed, cb, scale = compress_layer_weights(w, values, block_k=128)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k), jnp.bfloat16)

    t = time.time()
    y = lut_matmul(x, packed, cb, scale, interpret=True)
    y.block_until_ready()
    t_kernel = time.time() - t
    y_ref = lut_matmul_ref(x, packed, cb, scale, block_k=128)
    rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    dense_bytes = k * n * 2  # bf16
    lut_bytes = packed.size * 1 + cb.size + scale.size * 4
    rows.append({
        "kernel": "lut_matmul", "shape": f"{m}x{k}x{n}",
        "interpret_s": t_kernel, "rel_err_vs_ref": rel,
        "weight_bytes_dense_bf16": dense_bytes,
        "weight_bytes_lut4": int(lut_bytes),
        "weight_compression": dense_bytes / lut_bytes,
    })

    # --- transition energy
    wt = jax.random.randint(key, (TILE, TILE), -128, 128, dtype=jnp.int32)
    ab = jax.random.randint(jax.random.fold_in(key, 2), (TILE, TILE), -128,
                            128, dtype=jnp.int32)
    t = time.time()
    got = tile_transition_stats(wt, ab, DEFAULT_COEFFS, interpret=True)
    jax.block_until_ready(got)
    t_kernel = time.time() - t
    want = stats_oracle(wt, ab, DEFAULT_COEFFS)
    rel = float(jnp.max(jnp.abs(got[0] - want[0]))
                / jnp.maximum(jnp.max(want[0]), 1e-9))
    rows.append({
        "kernel": "transition_energy", "shape": "64x64x64",
        "interpret_s": t_kernel, "rel_err_vs_ref": rel,
        "transitions_per_call": TILE * TILE * (TILE - 1),
    })

    derived = {
        "lut_rel_err": rows[0]["rel_err_vs_ref"],
        "lut_weight_compression": rows[0]["weight_compression"],
        "te_rel_err": rows[1]["rel_err_vs_ref"],
        "all_within_tolerance": all(r["rel_err_vs_ref"] < 2e-2 for r in rows),
    }
    return emit("bench_kernels", t0, rows, derived)


if __name__ == "__main__":
    run()
