"""Kernel microbenchmarks: correctness deltas + structural stats.

Wall times on this CPU-only host come from interpret mode and are NOT TPU
projections; the meaningful derived quantities are correctness vs oracle and
the compression ratio of the LUT weight format (4x byte reduction vs bf16,
with a 16-entry codebook + per-channel scales as the only overhead).

The profiling section IS a real wall-clock comparison: the seed's per-tile
Python dispatch loop vs the batched whole-layer profiler
(`repro.core.profiler`), both running the same pure-jnp trace math on this
host. ``profile_speedup_batched_vs_looped`` is the tiles/sec ratio the
tentpole claims (>= 5x).

The compressed-serving section (``serve_*`` derived keys) compares the
exported 4-bit LUT forward (`repro.core.export.serve_dense`, CPU jnp
dispatch) against the dense fake-quant matmul it replaces: parity, weight
compression vs bf16, and the dispatch-throughput ratio gated in
tools/run_checks.sh.

The fused-epilogue section (``serve_fused_*``) times the whole serve matmul
contract — bias + activation + residual folded into the single
`serve_dense` dispatch — against the unfused form that call replaced (serve
matmul, then an eager epilogue op per term). One dispatch must not lose to
four: ``serve_fused_vs_unfused`` is gated >= 1.0 by
``tools/check_gates.py --kernels``.

The autotune section exercises the roofline block autotuner
(`repro.kernels.lut_matmul.autotune`) over decode/prefill/FFN shapes and
round-trips its cache file: a reloaded cache must resolve every shape with
zero retune events (``autotune_cache_roundtrip_retunes``), and the model
must never prefer a tile that the roofline scores worse than the default
128-cube (``autotune_model_sane``). Honors ``REPRO_LUT_AUTOTUNE_CACHE`` as
the cache path so CI can persist winners across runs.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

import functools

from benchmarks.common import best_of, emit
from repro.core import qat
from repro.core.export import export_layer, serve_dense
from repro.core.grouping import N_GROUPS, group_id
from repro.core.mac_model import DEFAULT_COEFFS, mac_transition_energy
from repro.core.profiler import (
    batched_stats_oracle,
    gather_layer_tiles,
    sharded_layer_stats,
)
from repro.core.stats import (
    N_WVALS,
    TILE,
    pad_to_tiles,
    tile_psum_trace,
    tile_transition_stats as stats_oracle,
)
from repro.kernels.lut_matmul.ops import compress_layer_weights, lut_matmul
from repro.kernels.lut_matmul.ref import lut_matmul_ref
from repro.kernels.transition_energy.ops import (
    batched_transition_stats,
    tile_transition_stats,
)


@functools.partial(jax.jit, static_argnames=("coeffs",))
def _seed_tile_stats(w_tile, a_block, coeffs=DEFAULT_COEFFS):
    """FROZEN seed-era per-tile trace — the benchmark baseline.

    `repro.core.stats.tile_transition_stats` now delegates to the batched
    oracle (one stats implementation behind the profile stage), so the
    original per-tile body is preserved here verbatim as the thing the
    ``profile_speedup_batched_vs_looped`` gate measures against: per-element
    scatters, no pre-reduction over the streaming axis, no optimization
    barrier. Do not "improve" it — it IS the baseline.
    """
    w_tile = jnp.asarray(w_tile, jnp.int32)
    a_block = jnp.asarray(a_block, jnp.int32)
    psums = tile_psum_trace(w_tile, a_block)  # (K, M, T)
    p_prev, p_cur = psums[:, :, :-1], psums[:, :, 1:]
    a_prev, a_cur = a_block[:, None, :-1], a_block[:, None, 1:]
    w = w_tile[:, :, None]

    energy = mac_transition_energy(w, a_prev, a_cur, p_prev, p_cur, coeffs)
    w_bins = jnp.broadcast_to(w + 128, energy.shape).reshape(-1)
    energy_flat = energy.reshape(-1)
    energy_sum = jax.ops.segment_sum(energy_flat, w_bins,
                                     num_segments=N_WVALS)
    count = jax.ops.segment_sum(jnp.ones_like(energy_flat), w_bins,
                                num_segments=N_WVALS)

    g_bins = (group_id(p_prev).reshape(-1) * N_GROUPS
              + group_id(p_cur).reshape(-1))
    group_hist = jax.ops.segment_sum(
        jnp.ones_like(g_bins, jnp.float32), g_bins,
        num_segments=N_GROUPS * N_GROUPS).reshape(N_GROUPS, N_GROUPS)

    a_bins = ((a_block[:, :-1] + 128).reshape(-1) * N_WVALS
              + (a_block[:, 1:] + 128).reshape(-1))
    act_hist = jax.ops.segment_sum(
        jnp.ones_like(a_bins, jnp.float32), a_bins,
        num_segments=N_WVALS * N_WVALS).reshape(N_WVALS, N_WVALS)
    return energy_sum, count, group_hist, act_hist


def run():
    t0 = time.time()
    rows = []
    key = jax.random.PRNGKey(0)

    # --- LUT matmul
    m, k, n = 256, 512, 256
    w = jax.random.normal(key, (k, n)) * 0.04
    values = [-112, -80, -56, -40, -28, -16, -8, 0, 8, 16, 28, 40, 56, 80,
              112, 127]
    packed, cb, scale = compress_layer_weights(w, values, block_k=128)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k), jnp.bfloat16)

    t = time.time()
    y = lut_matmul(x, packed, cb, scale, interpret=True)
    y.block_until_ready()
    t_kernel = time.time() - t
    y_ref = lut_matmul_ref(x, packed, cb, scale, block_k=128)
    rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    dense_bytes = k * n * 2  # bf16
    lut_bytes = packed.size * 1 + cb.size + scale.size * 4
    rows.append({
        "kernel": "lut_matmul", "shape": f"{m}x{k}x{n}",
        "interpret_s": t_kernel, "rel_err_vs_ref": rel,
        "weight_bytes_dense_bf16": dense_bytes,
        "weight_bytes_lut4": int(lut_bytes),
        "weight_compression": dense_bytes / lut_bytes,
    })

    # --- transition energy
    wt = jax.random.randint(key, (TILE, TILE), -128, 128, dtype=jnp.int32)
    ab = jax.random.randint(jax.random.fold_in(key, 2), (TILE, TILE), -128,
                            128, dtype=jnp.int32)
    t = time.time()
    got = tile_transition_stats(wt, ab, DEFAULT_COEFFS, interpret=True)
    jax.block_until_ready(got)
    t_kernel = time.time() - t
    want = stats_oracle(wt, ab, DEFAULT_COEFFS)
    rel = float(jnp.max(jnp.abs(got[0] - want[0]))
                / jnp.maximum(jnp.max(want[0]), 1e-9))
    rows.append({
        "kernel": "transition_energy", "shape": "64x64x64",
        "interpret_s": t_kernel, "rel_err_vs_ref": rel,
        "transitions_per_call": TILE * TILE * (TILE - 1),
    })

    # --- batched layer profiling: seed per-tile loop vs batched profiler
    m2, k2, n2 = 256, 192, 512
    n_tiles = 32
    wl = jax.random.randint(jax.random.fold_in(key, 3), (m2, k2), -128, 128,
                            dtype=jnp.int32)
    xl = jax.random.randint(jax.random.fold_in(key, 4), (k2, n2), -128, 128,
                            dtype=jnp.int32)
    w_pad, x_pad = pad_to_tiles(wl, xl)
    mt = w_pad.shape[0] // TILE
    kt = w_pad.shape[1] // TILE
    nt = x_pad.shape[1] // TILE
    choice = jax.random.choice(key, mt * kt * nt, (n_tiles,), replace=False)
    choice_host = jax.device_get(choice)

    def looped_seed():
        """The seed `collect_layer_stats` body: one dispatch per tile."""
        acc = None
        for idx in choice_host:
            idx = int(idx)
            mi, rest = divmod(idx, kt * nt)
            ki, ni = divmod(rest, nt)
            w_t = w_pad[mi * TILE:(mi + 1) * TILE, ki * TILE:(ki + 1) * TILE].T
            a_b = x_pad[ki * TILE:(ki + 1) * TILE, ni * TILE:(ni + 1) * TILE]
            o = _seed_tile_stats(w_t, a_b, DEFAULT_COEFFS)
            acc = o if acc is None else [x + y for x, y in zip(acc, o)]
        jax.block_until_ready(acc)
        return acc

    mask = jnp.ones((n_tiles,), jnp.float32)

    def batched():
        w_tiles, a_blocks = gather_layer_tiles(w_pad, x_pad, choice)
        o = batched_stats_oracle(w_tiles, a_blocks, mask, DEFAULT_COEFFS)
        jax.block_until_ready(o)
        return o

    def sharded():
        w_tiles, a_blocks = gather_layer_tiles(w_pad, x_pad, choice)
        o = sharded_layer_stats(w_tiles, a_blocks, DEFAULT_COEFFS)
        jax.block_until_ready(o)
        return o

    ref_loop = looped_seed()   # warmup + reference values
    got_batch = batched()
    got_shard = sharded()      # warmup (trivial 1-device mesh on this host)

    def rel_err(got):
        return float(jnp.max(jnp.abs(got[0] - ref_loop[0]))
                     / jnp.maximum(jnp.max(ref_loop[0]), 1e-9))

    batch_err = rel_err(got_batch)
    shard_err = rel_err(got_shard)

    t_loop = best_of(looped_seed, n=2)  # slowest variant: 2 repeats suffice
    t_batch = best_of(batched)
    t_shard = best_of(sharded)

    for label, secs, err in (("profile_looped_seed", t_loop, 0.0),
                             ("profile_batched", t_batch, batch_err),
                             ("profile_sharded", t_shard, shard_err)):
        rows.append({
            "kernel": label, "shape": f"{m2}x{k2}x{n2}/{n_tiles}tiles",
            "wall_s": secs, "tiles_per_s": n_tiles / secs,
            "rel_err_vs_ref": err,
            "devices": jax.device_count(),
        })

    # batched Pallas kernel (interpret): correctness on a small batch only —
    # interpret-mode wall time is not a speed claim
    nb, tb = 2, 12
    w_b = jax.random.randint(jax.random.fold_in(key, 5), (nb, TILE, TILE),
                             -128, 128, dtype=jnp.int32)
    a_b = jax.random.randint(jax.random.fold_in(key, 6), (nb, TILE, tb),
                             -128, 128, dtype=jnp.int32)
    t = time.time()
    got_k = batched_transition_stats(w_b, a_b, DEFAULT_COEFFS, interpret=True)
    jax.block_until_ready(got_k)
    t_kernel = time.time() - t
    want_k = [jnp.zeros_like(g) for g in got_k]
    for i in range(nb):
        o = stats_oracle(w_b[i], a_b[i], DEFAULT_COEFFS)
        want_k = [x + y for x, y in zip(want_k, o)]
    kernel_err = float(jnp.max(jnp.abs(got_k[0] - want_k[0]))
                       / jnp.maximum(jnp.max(want_k[0]), 1e-9))
    rows.append({
        "kernel": "transition_energy_batched", "shape": f"{nb}x64x64x{tb}",
        "interpret_s": t_kernel, "rel_err_vs_ref": kernel_err,
        "transitions_per_call": nb * TILE * TILE * (tb - 1),
    })

    # --- compressed-vs-dense forward throughput (serve path)
    # Wall clock on this host compares the jnp serve oracle (the CPU dispatch
    # of the backend-aware serve path) against the dense fake-quant matmul it
    # replaces; on TPU the same serve_dense call runs the compiled Pallas
    # kernel. Correctness is the primary gate; the throughput ratio is a
    # regression canary for the serve dispatch overhead (unpack + LUT gather
    # in pure jnp), not a TPU speed projection.
    ms, ks, ns = 512, 1024, 512
    ws = jax.random.normal(jax.random.fold_in(key, 7), (ks, ns)) * 0.04
    comp_s = qat.identity_comp(ws.shape)
    comp_s["codebook"], comp_s["codebook_k"] = qat.make_codebook(values)
    art = export_layer(ws, comp_s, kind="dense")
    xs = jax.random.normal(jax.random.fold_in(key, 8), (ms, ks))
    w_fake = qat.fake_quant_weight(ws, comp_s)

    dense_fwd = jax.jit(lambda a, wq: a @ wq)
    serve_fwd = jax.jit(lambda a: serve_dense(a, art, use_ref=True))
    y_dense = dense_fwd(xs, w_fake).block_until_ready()   # warmup + reference
    y_serve = serve_fwd(xs).block_until_ready()
    serve_err = float(jnp.linalg.norm(y_serve - y_dense)
                      / jnp.linalg.norm(y_dense))

    t_dense = best_of(lambda: jax.block_until_ready(dense_fwd(xs, w_fake)),
                      n=5)
    t_serve = best_of(lambda: jax.block_until_ready(serve_fwd(xs)), n=5)
    for label, secs in (("serve_forward_dense_fakequant", t_dense),
                        ("serve_forward_compressed_lut", t_serve)):
        rows.append({
            "kernel": label, "shape": f"{ms}x{ks}x{ns}",
            "wall_s": secs, "rows_per_s": ms / secs,
            "rel_err_vs_ref": serve_err if label.endswith("lut") else 0.0,
        })

    # --- fused epilogue vs unfused serve + eager epilogue (decode shape)
    # The fused call folds bias + relu + residual into the one serve
    # dispatch; the unfused baseline is the pre-fusion serve contract: the
    # bare LUT matmul dispatch followed by one eager op per epilogue term.
    # Measured at the decode shape (M = a batch of 8 rows), where per-token
    # latency is dispatch-dominated and the three extra epilogue dispatches
    # are exactly the cost fusion removes.
    md = 8
    xd = jax.random.normal(jax.random.fold_in(key, 9), (md, ks))
    bias_s = jax.random.normal(jax.random.fold_in(key, 10), (ns,)) * 0.1
    res_s = jax.random.normal(jax.random.fold_in(key, 11), (md, ns))

    def fused_fwd(a):
        return serve_dense(a, art, bias=bias_s, residual=res_s,
                           activation="relu", use_ref=True)

    def unfused_fwd(a):
        y = serve_dense(a, art, use_ref=True)
        return jax.nn.relu(y + bias_s) + res_s

    y_fused = fused_fwd(xd).block_until_ready()     # warmup + reference
    y_unfused = unfused_fwd(xd).block_until_ready()
    y_epi_ref = jax.nn.relu(dense_fwd(xd, w_fake) + bias_s) + res_s
    fused_err = float(jnp.linalg.norm(y_fused - y_epi_ref)
                      / jnp.linalg.norm(y_epi_ref))
    fused_vs_unfused_err = float(jnp.max(jnp.abs(y_fused - y_unfused)))
    t_fused = best_of(lambda: jax.block_until_ready(fused_fwd(xd)), n=5)
    t_unfused = best_of(lambda: jax.block_until_ready(unfused_fwd(xd)), n=5)
    for label, secs, err in (
            ("serve_fused_epilogue", t_fused, fused_err),
            ("serve_unfused_epilogue", t_unfused, 0.0)):
        rows.append({
            "kernel": label, "shape": f"{md}x{ks}x{ns}+bias+relu+residual",
            "wall_s": secs, "rows_per_s": md / secs,
            "rel_err_vs_ref": err,
        })

    # --- roofline block autotuner: tuning sweep + cache round-trip
    from repro.kernels.lut_matmul.autotune import (
        BlockAutotuner,
        roofline_time,
    )

    cache_path = os.environ.get("REPRO_LUT_AUTOTUNE_CACHE",
                                "benchmarks/out/autotune_cache.json")
    tuner = BlockAutotuner(path=cache_path)   # loads existing winners if any
    pre_entries = tuner.stats()["entries"]
    # decode (skinny M), prefill (square-ish), FFN (fat N)
    tune_shapes = [(8, 1024, 512), (256, 1024, 1024), (128, 1024, 4096)]
    t = time.time()
    winners = {s: tuner.best(*s, backend="bench") for s in tune_shapes}
    t_tune = time.time() - t
    s_tune = tuner.stats()
    tuner.save(cache_path)

    # round trip: a fresh tuner fed only the saved file must resolve every
    # shape as a cache hit — zero retune events
    tuner2 = BlockAutotuner(path=cache_path)
    for s in tune_shapes:
        tuner2.best(*s, backend="bench")
    s_round = tuner2.stats()

    # model sanity: the chosen tile must never score worse than the
    # hand-picked 128-cube default under the same roofline model
    model_sane = all(
        roofline_time(*s, winners[s]) <= roofline_time(*s, (128, 128, 128))
        for s in tune_shapes)
    rows.append({
        "kernel": "lut_autotune", "shape": f"{len(tune_shapes)} shapes",
        "wall_s": t_tune, "rel_err_vs_ref": 0.0,
        "cache_entries": s_tune["entries"],
        "cache_hits": s_tune["hits"], "cache_misses": s_tune["misses"],
        "roundtrip_retunes": s_round["retune_events"],
    })
    print(f"  autotune cache {cache_path}: {pre_entries} entries loaded, "
          f"{s_tune['hits']} hits / {s_tune['misses']} misses this run, "
          f"{s_tune['entries']} saved", flush=True)

    derived = {
        "lut_rel_err": rows[0]["rel_err_vs_ref"],
        "lut_weight_compression": rows[0]["weight_compression"],
        "te_rel_err": rows[1]["rel_err_vs_ref"],
        "profile_tiles_per_s_looped": n_tiles / t_loop,
        "profile_tiles_per_s_batched": n_tiles / t_batch,
        "profile_tiles_per_s_sharded": n_tiles / t_shard,
        "profile_speedup_batched_vs_looped": t_loop / t_batch,
        "profile_batched_rel_err": batch_err,
        "profile_sharded_rel_err": shard_err,
        "te_batched_rel_err": kernel_err,
        "serve_forward_rel_err": serve_err,
        "serve_rows_per_s_dense": ms / t_dense,
        "serve_rows_per_s_compressed": ms / t_serve,
        "serve_vs_dense_throughput": t_dense / t_serve,
        "serve_weight_compression_vs_bf16": (art.dense_bytes_int8 * 2
                                             / art.weight_bytes),
        "serve_fused_rel_err": fused_err,
        "serve_fused_vs_unfused_max_abs": fused_vs_unfused_err,
        "serve_fused_rows_per_s": md / t_fused,
        "serve_unfused_rows_per_s": md / t_unfused,
        "serve_fused_vs_unfused": t_unfused / t_fused,
        "autotune_entries": s_tune["entries"],
        "autotune_cache_roundtrip_retunes": s_round["retune_events"],
        "autotune_model_sane": model_sane,
        "all_within_tolerance": all(r["rel_err_vs_ref"] < 2e-2 for r in rows),
    }
    return emit("bench_kernels", t0, rows, derived)


if __name__ == "__main__":
    run()
