"""Paper Table 1: energy saving + accuracy vs PowerPruning baseline.

Rows per network: origin (QAT 256 values), PowerPruning-style global
selection (32 values), Ours (energy-prioritized layer-wise, 16 values).
Networks: LeNet-5/c10 and ResNet-20/c10 as in the paper; ResNet-8/c100 as
the reduced same-family stand-in for ResNet-50/CIFAR-100 (single-CPU budget;
see EXPERIMENTS.md for the scaling note).
"""

from __future__ import annotations

import time

from benchmarks.common import emit, fresh_copy, steps, trained
from repro.core import baselines
from repro.core.schedule import ScheduleConfig, energy_prioritized_compression
from repro.core.weight_selection import SelectionConfig


def ours(bundle, *, delta=0.05, max_layers=4):
    b = fresh_copy(bundle)
    cfg = ScheduleConfig(
        prune_ratios=(0.7, 0.5), k_targets=(16,), delta_acc=delta,
        finetune_steps=steps(20), trial_finetune_steps=steps(12),
        eval_batches=2, max_layers=max_layers, min_energy_share=0.0)
    sel = SelectionConfig(k_init=24, k_target=16, delta_acc=delta,
                          score_batches=1, accept_batches=2,
                          max_score_candidates=6)
    p, s, o, c, result = energy_prioritized_compression(
        b["runner"], b["params"], b["state"], b["opt_state"], b["comp"],
        b["stats"], cfg, sel)
    return {
        "method": "ours(16)",
        "accuracy": result.acc_final,
        "energy_saving": result.energy_saving,
        "selected_weights": 16,
        "accepted_layers": sum(d.accepted for d in result.decisions),
        "_schedule": result,
    }


def powerpruning(bundle):
    b = fresh_copy(bundle)
    _, _, _, _, res = baselines.powerpruning_global(
        b["runner"], b["params"], b["state"], b["opt_state"], b["comp"],
        b["stats"], k=32, prune_ratio=0.5, finetune_steps=steps(40),
        eval_batches=2)
    return {"method": "powerpruning[15](32)", "accuracy": res.acc_after,
            "energy_saving": res.energy_saving, "selected_weights": 32}


def run():
    t0 = time.time()
    rows = []
    nets = [("LeNet-5-c10", "lenet5"), ("ResNet-20-c10", "resnet20"),
            ("ResNet-8-c100 (stand-in for ResNet-50-c100)", "resnet8_c100")]
    for label, key in nets:
        bundle = trained(key)
        rows.append({"network": label, "method": "origin",
                     "accuracy": bundle["acc0"], "energy_saving": 0.0,
                     "selected_weights": 256})
        pp = powerpruning(bundle)
        pp["network"] = label
        rows.append(pp)
        us = ours(bundle)
        us.pop("_schedule")
        us["network"] = label
        rows.append(us)

    derived = {}
    for label, _ in nets:
        sub = {r["method"].split("(")[0]: r for r in rows
               if r["network"] == label}
        derived[label] = {
            "ours_saving": sub["ours"]["energy_saving"],
            "pp_saving": sub["powerpruning[15]"]["energy_saving"],
            "ours_beats_pp": sub["ours"]["energy_saving"]
                             > sub["powerpruning[15]"]["energy_saving"],
            "ours_acc_drop": sub["origin"]["accuracy"] - sub["ours"]["accuracy"],
        }
    return emit("table1_energy_savings", t0, rows, derived)


if __name__ == "__main__":
    run()
