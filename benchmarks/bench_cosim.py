"""Cosim verification benchmark: kernel-vs-bit-accurate-reference gate.

Two parts, both consumed by ``tools/check_gates.py --cosim``:

1. **Histogram verification sweep** — QAT-train the benchmark models, then
   replay the profiler's exact tile sampling per layer and require the
   `transition_energy` kernel's (50, 50) MSB-group transition histogram to
   match the cycle-accurate `repro.cosim` reference EXACTLY (integer
   equality, >= 64 sampled tiles per model).

2. **MSR schedule sweep** — run the reduced seeded candidate sweep with the
   MSR-truncation axis enabled (``msr_bits=(2, 0)``) in both search modes
   and require (a) serial == batched decisions including the msr component
   and (b) at least one layer accepting an MSR candidate, i.e. the third
   axis is actually live, priced by the cosim-validated energy model.

Derived keys gated: ``cosim_hist_match``, ``cosim_min_tiles_verified``,
``cosim_max_abs_diff``, ``msr_decisions_match``, ``msr_candidates_accepted``.
"""

from __future__ import annotations

import time

from benchmarks.common import emit, steps, trained
from repro.core import schedule as sched
from repro.core.schedule import ScheduleConfig
from repro.core.weight_selection import SelectionConfig
from repro.cosim import verify_runner_profile

# >= 64 gated tiles per model: LeNet-5's four compressible layers at 24
# tiles each give 96 when every layer has that many tiles to sample
VERIFY_MODELS = ("lenet5", "resnet8_c100")
VERIFY_TILES = 24
TRAIN_STEPS = 40

MSR_SWEEP = dict(
    prune_ratios=(0.5,), k_targets=(8,), msr_bits=(2, 0),
    delta_acc=0.2,             # generous floor: the aggressive MSR-on
    finetune_steps=4,          # candidate passes on the seeded run
    trial_finetune_steps=4,
    eval_batches=2,
    min_energy_share=0.0,
    max_layers=2,
)
MSR_SEL = SelectionConfig(k_init=10, k_target=8, delta_acc=0.2,
                          score_batches=1, accept_batches=1,
                          max_score_candidates=3)


def _decision_key(decisions):
    return [(d.layer, d.prune_ratio, d.k, d.msr, d.accepted,
             tuple(tuple(t) for t in d.tried)) for d in decisions]


def run():
    t0 = time.time()
    rows = []

    # ---- part 1: bit-accurate histogram verification, per model
    verify = {}
    for model_key in VERIFY_MODELS:
        bundle = trained(model_key, qat_steps=steps(TRAIN_STEPS))
        res = verify_runner_profile(
            bundle["runner"], bundle["params"], bundle["state"],
            bundle["comp"], n_batches=1, max_tiles=VERIFY_TILES,
            use_kernel=True)
        verify[model_key] = res
        rows.append({"bench": "cosim_verify", "model": model_key,
                     "tiles": res["n_tiles"], "match": res["match"],
                     "max_abs_diff": res["max_abs_diff"],
                     "toggles": res["toggles"],
                     "exactness_ok": res["exactness_ok"]})
        print(f"  cosim verify {model_key}: tiles={res['n_tiles']} "
              f"match={res['match']} max_abs_diff={res['max_abs_diff']}",
              flush=True)

    # ---- part 2: seeded reduced sweep with the MSR axis enabled
    bundle = trained("lenet5", qat_steps=steps(TRAIN_STEPS))
    runner = bundle["runner"]
    acc0 = runner.accuracy(bundle["params"], bundle["state"], bundle["comp"],
                           n_batches=2)
    decisions = {}
    for mode in ("serial", "batched"):
        cfg = ScheduleConfig(search_mode=mode, **MSR_SWEEP)
        _, _, _, _, res = sched.energy_prioritized_compression(
            runner, bundle["params"], bundle["state"], bundle["opt_state"],
            {k: dict(v) for k, v in bundle["comp"].items()},
            bundle["stats"], cfg, MSR_SEL)
        decisions[mode] = res.decisions
        rows.append({"bench": "msr_sweep", "mode": mode,
                     "decisions": [[d.layer, d.prune_ratio, d.k, d.msr,
                                    d.accepted] for d in res.decisions]})
        print(f"  msr sweep [{mode}]: "
              f"{[(d.layer, d.prune_ratio, d.k, d.msr, d.accepted) for d in res.decisions]}",
              flush=True)

    msr_match = _decision_key(decisions["serial"]) \
        == _decision_key(decisions["batched"])
    msr_accepted = sum(1 for d in decisions["batched"]
                       if d.accepted and (d.msr or 0) > 0)

    derived = {
        "cosim_hist_match": all(r["match"] for r in verify.values()),
        "cosim_min_tiles_verified": min(r["n_tiles"]
                                        for r in verify.values()),
        "cosim_max_abs_diff": max(r["max_abs_diff"]
                                  for r in verify.values()),
        "cosim_exactness_ok": all(r["exactness_ok"]
                                  for r in verify.values()),
        "cosim_toggles_total": sum(r["toggles"] for r in verify.values()),
        "msr_decisions_match": msr_match,
        "msr_candidates_accepted": msr_accepted,
        "msr_sweep_acc0": float(acc0),
    }
    return emit("bench_cosim", t0, rows, derived)


if __name__ == "__main__":
    run()
