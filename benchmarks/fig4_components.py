"""Paper Figure 4: pruning-only vs weight-restriction-only vs combined on
ResNet-20 — the two mechanisms must compose."""

from __future__ import annotations

import time


from benchmarks.common import emit, fresh_copy, steps, trained
from repro.core import baselines


def _energy_and_acc(b, comp, params, state):
    runner = b["runner"]
    models = runner.refresh_counts(
        params, comp, runner.energy_models(params, comp, b["stats"]))
    e = sum(m.energy for m in models.values())
    acc = runner.accuracy(params, state, comp, n_batches=2)
    return float(e), acc


def run():
    t0 = time.time()
    bundle = trained("resnet20")
    runner = bundle["runner"]
    rows = []

    # baseline energy
    e0, acc0 = _energy_and_acc(bundle, bundle["comp"], bundle["params"],
                               bundle["state"])

    # pruning only (uniform 0.5 + finetune)
    b = fresh_copy(bundle)
    comp = baselines._apply_uniform_prune(runner, b["params"], b["comp"], 0.5)
    p, s, o, _ = runner.train(b["params"], b["state"], b["opt_state"], comp,
                              steps(30))
    e_p, acc_p = _energy_and_acc(b, comp, p, s)
    rows.append({"method": "pruning-only(0.5)", "energy_saving": 1 - e_p / e0,
                 "accuracy": acc_p})

    # restriction only (global 16-value codebook from joint score, finetune)
    b = fresh_copy(bundle)
    models = runner.energy_models(b["params"], b["comp"], b["stats"])
    lut, counts = baselines._global_lut_counts(models)
    from repro.core.weight_selection import SelectionConfig, initial_candidate_set

    values = initial_candidate_set(counts, lut, SelectionConfig(k_init=16))
    comp = baselines._apply_global_codebook(runner, b["comp"], values)
    p, s, o, _ = runner.train(b["params"], b["state"], b["opt_state"], comp,
                              steps(30))
    e_r, acc_r = _energy_and_acc(b, comp, p, s)
    rows.append({"method": "restriction-only(16)",
                 "energy_saving": 1 - e_r / e0, "accuracy": acc_r})

    # combined
    b = fresh_copy(bundle)
    comp = baselines._apply_uniform_prune(runner, b["params"], b["comp"], 0.5)
    comp = baselines._apply_global_codebook(runner, comp, values)
    p, s, o, _ = runner.train(b["params"], b["state"], b["opt_state"], comp,
                              steps(40))
    e_c, acc_c = _energy_and_acc(b, comp, p, s)
    rows.append({"method": "combined(0.5+16)", "energy_saving": 1 - e_c / e0,
                 "accuracy": acc_c})

    derived = {
        "acc0": acc0,
        "prune_saving": rows[0]["energy_saving"],
        "restrict_saving": rows[1]["energy_saving"],
        "combined_saving": rows[2]["energy_saving"],
        "combined_beats_each": rows[2]["energy_saving"] > max(
            rows[0]["energy_saving"], rows[1]["energy_saving"]),
    }
    return emit("fig4_components", t0, rows, derived)


if __name__ == "__main__":
    run()
