"""Schedule-search benchmark: serial vs batched candidate sweep.

Times the energy-prioritized schedule's per-layer candidate sweep — the
paper's §4.3 search, the slowest stage of the pipeline — in both
``search_mode`` implementations on a QAT-trained LeNet-5 and reports
trials/sec (one *trial* = one ``(prune_ratio, k_target)`` candidate taken
through trial fine-tune → greedy weight selection → fine-tune → accept eval).

To make the two paths do *identical logical work*, the schedule accuracy
floor is set unreachable (``delta_acc = -1``): the serial walk then tries
every candidate instead of stopping at its first accept, and the batched
sweep evaluates the same full candidate set, so the wall-clock ratio
measures sweep machinery (dispatch count, batch generation, vectorization) —
not early-exit luck. Selection uses its own permissive ``delta_acc`` so
greedy elimination descends k_init -> k_target deterministically in both
modes.

``sweep_speedup_batched_vs_serial`` is the trials/sec ratio gated (>= 3x) in
tools/run_checks.sh; `BENCH_schedule.json` at the repo root tracks its
trajectory across PRs.
"""

from __future__ import annotations

import time

from benchmarks.common import best_of, emit, steps, trained
from repro.core import schedule as sched
from repro.core.schedule import ScheduleConfig
from repro.core.weight_selection import SelectionConfig

# Small-batch sweep config: candidate search throughput is dominated by
# per-trial dispatch + batch generation, which is exactly what the batched
# sweep amortizes. The full 3x3 paper grid keeps the candidate set realistic.
SWEEP_CFG = dict(
    prune_ratios=(0.9, 0.7, 0.5, 0.3),
    k_targets=(8, 10, 12),
    delta_acc=-1.0,            # unreachable floor: every candidate is tried
    finetune_steps=2,
    trial_finetune_steps=2,
    eval_batches=2,
    min_energy_share=0.0,
)
SEL_CFG = SelectionConfig(k_init=20, delta_acc=1.0,  # permissive: fast descent
                          score_batches=1, accept_batches=1,
                          max_score_candidates=4)
BATCH_SIZE = 8


def _sweep_once(mode, runner, bundle, layer, models, cfg, acc0):
    fn = sched._SEARCH_MODES[mode]
    return fn(runner, bundle["params"], bundle["state"], bundle["opt_state"],
              {k: dict(v) for k, v in bundle["comp"].items()},
              dict(models), layer, 1.0, acc0, cfg, SEL_CFG, False)


def run():
    t0 = time.time()
    bundle = trained("lenet5", qat_steps=steps(120))
    runner = bundle["runner"]
    # candidate-search throughput is dispatch-bound at small batch; restore
    # the training batch size afterwards so other benchmarks see the cache
    # unchanged
    old_bs = runner.batch_size
    runner.batch_size = BATCH_SIZE
    try:
        models = runner.energy_models(bundle["params"], bundle["comp"],
                                      bundle["stats"])
        layer = max(models, key=lambda n: models[n].energy)
        acc0 = runner.accuracy(bundle["params"], bundle["state"],
                               bundle["comp"], n_batches=2)
        cfg = ScheduleConfig(search_mode="batched", **SWEEP_CFG)
        n_cand = len(sched._config_order(cfg))

        results = {}
        times = {}
        for mode in ("serial", "batched"):
            _sweep_once(mode, runner, bundle, layer, models, cfg, acc0)  # warmup
            last = {}

            def timed(mode=mode, last=last):
                last["out"] = _sweep_once(mode, runner, bundle, layer, models,
                                          cfg, acc0)

            # best-of-2 locally (CI bumps repeats): shield the gate from
            # scheduler noise
            times[mode] = best_of(timed, n=2)
            results[mode] = last["out"][5]  # LayerDecision

        decision_tuple = lambda d: (d.layer, d.prune_ratio, d.k, d.accepted)  # noqa: E731

        # decision-parity gate, accepting configuration: with a reachable
        # floor both modes must accept the SAME most-aggressive candidate —
        # this is the non-vacuous half of the parity gate (the δ=-1 timing
        # runs above only prove all-reject parity) and catches accept-index
        # regressions in the batched sweep
        accept_cfg = ScheduleConfig(search_mode="batched",
                                    **{**SWEEP_CFG, "delta_acc": 0.5})
        accepts = {mode: _sweep_once(mode, runner, bundle, layer, models,
                                     accept_cfg, acc0)[5]
                   for mode in ("serial", "batched")}
        reject_match = decision_tuple(results["serial"]) \
            == decision_tuple(results["batched"])
        accept_match = decision_tuple(accepts["serial"]) \
            == decision_tuple(accepts["batched"])

        rows = [
            {
                "mode": mode,
                "layer": layer,
                "n_candidates": n_cand,
                "wall_s": times[mode],
                "trials_per_s": n_cand / times[mode],
                "decision": list(decision_tuple(results[mode])),
                "accept_decision": list(decision_tuple(accepts[mode])),
            }
            for mode in ("serial", "batched")
        ]
        derived = {
            "n_candidates": n_cand,
            "serial_wall_s": times["serial"],
            "batched_wall_s": times["batched"],
            "serial_trials_per_s": n_cand / times["serial"],
            "batched_trials_per_s": n_cand / times["batched"],
            "sweep_speedup_batched_vs_serial": times["serial"] / times["batched"],
            "decisions_match_reject": reject_match,
            "decisions_match_accept": accept_match,
            "decisions_match": reject_match and accept_match,
        }
        return emit("bench_schedule", t0, rows, derived)
    finally:
        runner.batch_size = old_bs


if __name__ == "__main__":
    run()
