"""``python -m repro`` — the `repro` pipeline CLI (see repro.pipeline.cli)."""

import sys

from repro.pipeline.cli import main

if __name__ == "__main__":
    sys.exit(main())
