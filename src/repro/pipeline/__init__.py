"""Unified compression-pipeline API: one `CompressionPlan` from profile to
serve (see docs/pipeline.md).

Attribute access is lazy (PEP 562) so that importing `repro.pipeline` — as
the `repro` CLI does before argument parsing — does not pull jax or any
stage module. ``repro.pipeline.schema`` stays import-light by construction.
"""

from __future__ import annotations

_EXPORTS = {
    # config namespace
    "PipelineConfig": "repro.pipeline.config",
    "TargetConfig": "repro.pipeline.config",
    "TrainStageConfig": "repro.pipeline.config",
    "ProfileStageConfig": "repro.pipeline.config",
    "RoutingStageConfig": "repro.pipeline.config",
    "ExportStageConfig": "repro.pipeline.config",
    "ServeStageConfig": "repro.pipeline.config",
    "reduced_cnn_config": "repro.pipeline.config",
    "reduced_lm_config": "repro.pipeline.config",
    "reduced_moe_config": "repro.pipeline.config",
    "reduced_scan_config": "repro.pipeline.config",
    # plan artifact
    "CompressionPlan": "repro.pipeline.plan",
    # targets
    "CnnTarget": "repro.pipeline.targets",
    "LMTarget": "repro.pipeline.targets",
    "MoETarget": "repro.pipeline.targets",
    "ScanTarget": "repro.pipeline.targets",
    "resolve_target": "repro.pipeline.targets",
    # driver
    "Pipeline": "repro.pipeline.pipeline",
    # jax-free schema constants
    "STAGES": "repro.pipeline.schema",
    "PLAN_SCHEMA_VERSION": "repro.pipeline.schema",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module_name = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.pipeline' has no attribute {name!r}") from None
    import importlib

    return getattr(importlib.import_module(module_name), name)


def __dir__():
    return __all__
