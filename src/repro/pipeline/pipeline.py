"""Staged compression pipeline: one API from profile to serve.

`Pipeline` drives a `Target` (CNN or LM, see `repro.pipeline.targets`)
through the stage registry

    profile -> energy_model -> schedule -> export -> serve

with every stage reading and writing the shared `CompressionPlan`. The
registry is data, not control flow: ``run_until("schedule")`` executes the
prefix, a saved plan records which stages already ran, and
``Pipeline.from_plan(plan)`` rebuilds the target from the plan's embedded
config and continues from the first incomplete stage — re-running nothing.

Per-stage overrides compose functionally::

    Pipeline(cfg).run(overrides={"schedule": {"max_layers": 1}})

Typical flows::

    plan = Pipeline(cfg).run()                     # everything
    plan = Pipeline(cfg).run_until("schedule")     # stop after the sweep
    plan.save("plan")                              # plan.json + plan.npz
    plan2 = CompressionPlan.load("plan")
    Pipeline.from_plan(plan2).run()                # resume: export + serve

The `repro` CLI (``python -m repro``) is a thin shell over exactly this
object; `repro.core.compression.CompressionPipeline` survives as a
deprecated delegate.
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional

from repro.pipeline.config import PipelineConfig
from repro.pipeline.plan import CompressionPlan
from repro.pipeline.schema import STAGES, stage_index
from repro.pipeline.targets import resolve_target


class Pipeline:
    """Stage driver bound to one target and one validated config."""

    STAGES = STAGES

    def __init__(self, cfg_or_target, cfg: Optional[PipelineConfig] = None,
                 *, plan: Optional[CompressionPlan] = None):
        if isinstance(cfg_or_target, PipelineConfig):
            if cfg is not None:
                raise TypeError("pass either Pipeline(cfg) or "
                                "Pipeline(target, cfg), not both configs")
            cfg = cfg_or_target
            target = None
        else:
            target = cfg_or_target
            if cfg is None:
                cfg = PipelineConfig()
        cfg.validate()
        self.cfg = cfg
        self.target = target if target is not None else resolve_target(cfg)
        if plan is None:
            plan = CompressionPlan(
                config=cfg.to_dict(),
                target={"kind": self.target.kind, "arch": cfg.target.arch,
                        "name": getattr(self.target, "name",
                                        cfg.target.arch)},
            )
        self.plan = plan

    # ----------------------------------------------------------------- runs

    def run(self, *, verbose: bool = False,
            overrides: Optional[Dict[str, Dict[str, Any]]] = None
            ) -> CompressionPlan:
        return self.run_until(STAGES[-1], verbose=verbose,
                              overrides=overrides)

    def run_until(self, stage: str, *, verbose: bool = False,
                  overrides: Optional[Dict[str, Dict[str, Any]]] = None
                  ) -> CompressionPlan:
        """Run every not-yet-completed stage up to and including ``stage``.

        The plan's embedded config is kept in sync with the *effective*
        config (base + overrides) so that a saved plan always describes the
        settings its remaining stages will resume under."""
        cfg = self.cfg.with_overrides(overrides)
        self.plan.config = cfg.to_dict()
        last = stage_index(stage)
        for name in STAGES[: last + 1]:
            if self.plan.is_done(name):
                continue
            t0 = time.time()
            getattr(self.target, f"stage_{name}")(self.plan, cfg,
                                                  verbose=verbose)
            self.plan.mark_done(name)
            self.plan.metrics[f"wall_s_{name}"] = round(time.time() - t0, 3)
            if verbose:
                print(f"[pipeline] stage {name} done "
                      f"({self.plan.metrics[f'wall_s_{name}']:.1f}s)")
        return self.plan

    # --------------------------------------------------------------- resume

    @classmethod
    def from_plan(cls, plan: CompressionPlan, *, target=None,
                  cfg: Optional[PipelineConfig] = None) -> "Pipeline":
        """Rebuild a pipeline around a saved plan; subsequent ``run*`` calls
        skip every stage the plan already completed."""
        if cfg is None:
            cfg = PipelineConfig.from_dict(plan.config)
        if target is None:
            return cls(cfg, plan=plan)
        return cls(target, cfg, plan=plan)

    # ------------------------------------------------------------ shortcuts

    @property
    def params(self):
        return self.plan.params

    @property
    def state(self):
        return self.plan.state

    @property
    def comp(self):
        return self.plan.comp
