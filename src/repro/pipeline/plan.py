"""`CompressionPlan`: the one artifact every pipeline stage reads and writes.

The paper's stages share one logical object — per-layer trace statistics,
energy LUTs and shares, the schedule's accepted (prune, k) decisions, the
restricted codebooks, and the packed serving artifacts. This module makes
that object first-class:

  * a registered **pytree** (array sections are children, bookkeeping is
    aux data) so a plan passes through `jax.tree` utilities and device
    placement like any other state tree;
  * **serializable**: ``save(base)`` writes ``<base>.json`` (structure +
    static fields, see `repro.pipeline.schema`) and ``<base>.npz`` (the
    array payload); ``CompressionPlan.load(base)`` round-trips bit-exactly
    (bf16 leaves are stored widened to f32 with a dtype tag and cast back);
  * **resumable**: ``completed`` records which stages already ran, so
    `Pipeline.from_plan` continues exactly where a saved plan stopped.

Array sections and what stage fills them:

  section     stage          contents
  ---------   ------------   -------------------------------------------
  params      profile        model parameters after QAT base training
  state       profile        non-trainable state (CNN batch stats)
  opt_state   profile        optimizer moments (resume-exact schedules)
  comp        profile        per-layer CompState {mask, codebook, codebook_k}
  stats       profile        {layer: LayerStats} systolic trace statistics
  luts        energy_model   {layer: (256,) blended per-weight-value LUT}
  artifacts   export         {layer/unit: ServeArtifact} packed 4-bit form
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.export import ServeArtifact
from repro.core.stats import LayerStats
from repro.pipeline.schema import PLAN_FORMAT, PLAN_SCHEMA_VERSION, STAGES

ARRAY_SECTIONS = ("params", "state", "opt_state", "comp", "stats", "luts",
                  "artifacts")


@dataclasses.dataclass
class CompressionPlan:
    """Everything the pipeline has learned about one model so far."""

    schema_version: int = PLAN_SCHEMA_VERSION
    config: Dict[str, Any] = dataclasses.field(default_factory=dict)
    target: Dict[str, Any] = dataclasses.field(default_factory=dict)
    completed: Tuple[str, ...] = ()
    decisions: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)
    shares: Dict[str, float] = dataclasses.field(default_factory=dict)

    params: Any = None
    state: Any = None
    opt_state: Any = None
    comp: Any = None
    stats: Any = None
    luts: Any = None
    artifacts: Any = None

    # ---------------------------------------------------------------- stages

    def is_done(self, stage: str) -> bool:
        return stage in self.completed

    def mark_done(self, stage: str) -> None:
        if stage not in STAGES:
            raise ValueError(f"unknown stage {stage!r}")
        if stage not in self.completed:
            self.completed = tuple(s for s in STAGES
                                   if s in self.completed or s == stage)

    # ------------------------------------------------------------ fingerprint

    def fingerprint(self) -> str:
        """Content identity of the plan's *serving-relevant* state: the comp
        tree (codebook values, masks, ``msr_bits``) plus the schedule's
        decision set. This is what `repro.serving.ServeCompileCache` keys
        executables and exported artifacts on — two plans with the same
        ``compress_k`` but different codebooks or MSR settings get distinct
        fingerprints and never share compiled state."""
        from repro.serving.fleet import comp_fingerprint

        extra = json.dumps(self.decisions, sort_keys=True) \
            if self.decisions else None
        return comp_fingerprint(self.comp, extra=extra)

    # --------------------------------------------------------------- summary

    def summary(self) -> Dict[str, Any]:
        out = {
            "target": dict(self.target),
            "completed": list(self.completed),
            "metrics": {k: (round(v, 4) if isinstance(v, float) else v)
                        for k, v in self.metrics.items()},
        }
        if self.decisions:
            out["layers"] = [
                {"layer": d["layer"], "share": round(d["share"], 4),
                 "prune": d["prune_ratio"], "k": d["k"],
                 "msr": d.get("msr"), "accepted": d["accepted"]}
                for d in self.decisions
            ]
        if self.artifacts:
            out["exported_units"] = len(self.artifacts)
        return out

    # ------------------------------------------------------------- save/load

    def save(self, base) -> Tuple[Path, Path]:
        """Write ``<base>.json`` + ``<base>.npz``; returns both paths."""
        base = _strip_ext(base)
        arrays: Dict[str, np.ndarray] = {}
        tree = {s: _encode(getattr(self, s), arrays)
                for s in ARRAY_SECTIONS if getattr(self, s) is not None}
        doc = {
            "format": PLAN_FORMAT,
            "schema_version": self.schema_version,
            "config": self.config,
            "target": self.target,
            "completed": list(self.completed),
            "decisions": self.decisions,
            "metrics": self.metrics,
            "shares": self.shares,
            "tree": tree,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in arrays.items()},
        }
        json_path = base.with_suffix(".json")
        npz_path = base.with_suffix(".npz")
        json_path.parent.mkdir(parents=True, exist_ok=True)
        json_path.write_text(json.dumps(doc, indent=1, sort_keys=False))
        np.savez(npz_path, **arrays)
        return json_path, npz_path

    @classmethod
    def load(cls, base) -> "CompressionPlan":
        base = _strip_ext(base)
        doc = json.loads(base.with_suffix(".json").read_text())
        if doc.get("format") != PLAN_FORMAT:
            raise ValueError(f"{base}: not a {PLAN_FORMAT} document")
        if doc.get("schema_version") != PLAN_SCHEMA_VERSION:
            raise ValueError(
                f"{base}: plan schema v{doc.get('schema_version')} != "
                f"supported v{PLAN_SCHEMA_VERSION}")
        with np.load(base.with_suffix(".npz")) as npz:
            arrays = {k: npz[k] for k in npz.files}
        plan = cls(
            schema_version=doc["schema_version"],
            config=doc.get("config", {}),
            target=doc.get("target", {}),
            completed=tuple(doc.get("completed", [])),
            decisions=list(doc.get("decisions", [])),
            metrics=dict(doc.get("metrics", {})),
            shares=dict(doc.get("shares", {})),
        )
        for section, node in doc.get("tree", {}).items():
            setattr(plan, section, _decode(node, arrays))
        return plan

    # ------------------------------------------------------------ validation

    def validate(self) -> "CompressionPlan":
        from repro.pipeline.schema import validate_plan_doc

        doc = {
            "format": PLAN_FORMAT, "schema_version": self.schema_version,
            "completed": list(self.completed), "decisions": self.decisions,
            "metrics": self.metrics, "shares": self.shares,
            "arrays": {"live": True},
        }
        failed = [g for g in validate_plan_doc(doc) if not g["pass"]]
        if failed:
            raise ValueError(
                "invalid plan: " + "; ".join(
                    f"{g['name']}={g['value']!r} (want {g['op']} "
                    f"{g['threshold']!r})" for g in failed))
        return self


# --------------------------------------------------------------- pytree reg


def _plan_flatten(plan: CompressionPlan):
    children = tuple(getattr(plan, s) for s in ARRAY_SECTIONS)
    aux = json.dumps({
        "schema_version": plan.schema_version,
        "config": plan.config,
        "target": plan.target,
        "completed": list(plan.completed),
        "decisions": plan.decisions,
        "metrics": plan.metrics,
        "shares": plan.shares,
    }, sort_keys=True)
    return children, aux


def _plan_unflatten(aux, children):
    static = json.loads(aux)
    plan = CompressionPlan(
        schema_version=static["schema_version"],
        config=static["config"],
        target=static["target"],
        completed=tuple(static["completed"]),
        decisions=static["decisions"],
        metrics=static["metrics"],
        shares=static["shares"],
    )
    for section, child in zip(ARRAY_SECTIONS, children):
        setattr(plan, section, child)
    return plan


jax.tree_util.register_pytree_node(
    CompressionPlan, _plan_flatten, _plan_unflatten)


# ------------------------------------------------------- structure encoding


def _strip_ext(base) -> Path:
    base = Path(base)
    if base.suffix in (".json", ".npz"):
        base = base.with_suffix("")
    return base


def _is_array(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray)) or (
        isinstance(x, np.generic))


def _encode(obj, arrays: Dict[str, np.ndarray]):
    """Structure -> JSON-serializable node; arrays land in ``arrays``."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if _is_array(obj):
        a = np.asarray(obj)
        dtype = str(a.dtype)
        if dtype == "bfloat16":  # np.savez can't store ml_dtypes natively
            a = a.astype(np.float32)
        key = f"a{len(arrays):05d}"
        arrays[key] = a
        return {"__array__": key, "dtype": dtype}
    if isinstance(obj, LayerStats):
        return {"__layerstats__": {
            "act_hist": _encode(obj.act_hist, arrays),
            "group_hist": _encode(obj.group_hist, arrays),
            "energy_sum": _encode(obj.energy_sum, arrays),
            "count": _encode(obj.count, arrays),
            "n_transitions": int(obj.n_transitions),
        }}
    if isinstance(obj, ServeArtifact):
        return {"__artifact__": {
            "packed": _encode(obj.packed, arrays),
            "codebook": _encode(obj.codebook, arrays),
            "scale": _encode(obj.scale, arrays),
            "k_dim": int(obj.k_dim), "n_dim": int(obj.n_dim),
            "block_k": int(obj.block_k), "kind": obj.kind,
            "kernel": int(obj.kernel),
        }}
    if isinstance(obj, dict):
        return {"__dict__": {str(k): _encode(v, arrays)
                             for k, v in obj.items()}}
    if isinstance(obj, tuple):
        return {"__tuple__": [_encode(v, arrays) for v in obj]}
    if isinstance(obj, list):
        return [_encode(v, arrays) for v in obj]
    raise TypeError(
        f"CompressionPlan cannot serialize {type(obj).__name__}; supported "
        f"node types are dict/list/tuple/array/scalar/LayerStats/"
        f"ServeArtifact")


def _decode(node, arrays: Dict[str, np.ndarray]):
    if node is None or isinstance(node, (bool, int, float, str)):
        return node
    if isinstance(node, list):
        return [_decode(v, arrays) for v in node]
    if "__array__" in node:
        a = arrays[node["__array__"]]
        return jnp.asarray(a, dtype=node["dtype"])
    if "__layerstats__" in node:
        d = node["__layerstats__"]
        return LayerStats(
            act_hist=_decode(d["act_hist"], arrays),
            group_hist=_decode(d["group_hist"], arrays),
            energy_sum=_decode(d["energy_sum"], arrays),
            count=_decode(d["count"], arrays),
            n_transitions=int(d["n_transitions"]),
        )
    if "__artifact__" in node:
        d = node["__artifact__"]
        return ServeArtifact(
            packed=_decode(d["packed"], arrays),
            codebook=_decode(d["codebook"], arrays),
            scale=_decode(d["scale"], arrays),
            k_dim=d["k_dim"], n_dim=d["n_dim"], block_k=d["block_k"],
            kind=d["kind"], kernel=d["kernel"],
        )
    if "__dict__" in node:
        return {k: _decode(v, arrays) for k, v in node["__dict__"].items()}
    if "__tuple__" in node:
        return tuple(_decode(v, arrays) for v in node["__tuple__"])
    raise ValueError(f"unrecognized plan node: {list(node)[:3]}")


def decision_dict(d) -> Dict[str, Any]:
    """`repro.core.schedule.LayerDecision` -> plain serializable dict."""
    return {
        "layer": d.layer,
        "share": float(d.share),
        "prune_ratio": None if d.prune_ratio is None else float(d.prune_ratio),
        "k": None if d.k is None else int(d.k),
        "energy_before": float(d.energy_before),
        "energy_after": float(d.energy_after),
        "accuracy": float(d.accuracy),
        "accepted": bool(d.accepted),
        "msr": None if getattr(d, "msr", None) is None else int(d.msr),
        # (prune, k) pairs from pre-MSR plans and (prune, k, msr) triples
        # both round-trip — old documents stay loadable
        "tried": [[float(t[0]), int(t[1])] +
                  ([int(t[2])] if len(t) > 2 else [])
                  for t in d.tried],
    }
