"""`Target` protocol: CNN and LM models behind one pipeline stage interface.

A target owns the model runtime (a `CnnRunner`, or an `LMModel` + serving
engine) and implements one method per pipeline stage. Every method takes the
shared `CompressionPlan` and the `PipelineConfig` and mutates only the plan —
the plan is the *only* object that travels between stages, which is what
makes `run_until` + save + `Pipeline.from_plan` resume exact.

  stage          CnnTarget                        LMTarget
  ------------   ------------------------------   ---------------------------
  profile        QAT base train + systolic trace  param init/restore
                 stats per layer                  (+ optional LM QAT steps)
  energy_model   blended per-layer LUTs + shares  uniform-trace LUT per-unit
                                                  energies + shares
  schedule       energy-prioritized layer sweep   uniform k-value codebook
                 (prune x k, accuracy floor)      restriction per unit
  export         packed 4-bit ServeArtifacts      packed 4-bit ServeArtifacts
                 (repro.core.export)              (repro.core.lm_compress)
  serve          full-model LUT-GEMM forward,     continuous-batching engine
                 parity + accuracy vs fake-quant  over a deterministic trace

The CNN stages reproduce the pre-refactor `CompressionPipeline.run()` wiring
operation for operation (same seeds, same batch streams, same eval order),
so schedule decisions and exported artifacts are bit-identical to the old
path — gated by tests/test_pipeline.py.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.pipeline.config import PipelineConfig
from repro.pipeline.plan import CompressionPlan, decision_dict


def resolve_target(cfg: PipelineConfig):
    if cfg.target.kind == "cnn":
        return CnnTarget(cfg)
    if cfg.target.kind == "lm":
        return LMTarget(cfg)
    if cfg.target.kind == "moe":
        return MoETarget(cfg)
    if cfg.target.kind == "scan":
        return ScanTarget(cfg)
    raise ValueError(f"unknown target kind {cfg.target.kind!r}")


def lm_trace_shapes(n_requests: int, prompt_len: int, new_tokens: int,
                    mixed: bool, *, stride: int = 7) -> List[Tuple[int, int]]:
    """Deterministic (prompt_len, new_tokens) trace; ``mixed`` varies lengths
    so several buckets are exercised."""
    if not mixed:
        return [(prompt_len, new_tokens)] * n_requests
    lens = [max(2, prompt_len - stride * (i % 3)) for i in range(n_requests)]
    news = [max(2, new_tokens - 3 * (i % 2)) for i in range(n_requests)]
    return list(zip(lens, news))


# ===================================================================== CNN


class CnnTarget:
    """CNN compression through a `repro.core.runner.CnnRunner`."""

    kind = "cnn"

    def __init__(self, cfg: PipelineConfig, runner=None):
        if runner is None:
            from repro.core.runner import CnnRunner
            from repro.data.synthetic import SyntheticImages
            from repro.nn import cnn

            factories = {"lenet5": cnn.lenet5, "resnet8": cnn.resnet8,
                         "resnet20": cnn.resnet20, "resnet50": cnn.resnet50}
            t = cfg.target
            runner = CnnRunner(factories[t.arch](),
                               SyntheticImages(seed=t.data_seed),
                               batch_size=t.batch_size, lr=t.lr, seed=t.seed)
        self.runner = runner
        # an injected runner's model name wins over the config arch so the
        # plan's target identity stays truthful for custom models
        self.name = getattr(runner.model, "name", cfg.target.arch)
        self.last_schedule_result = None  # transient, for the legacy shim

    # ------------------------------------------------------------- stages

    def stage_profile(self, plan: CompressionPlan, cfg: PipelineConfig,
                      verbose: bool = False) -> None:
        runner = self.runner
        params, state, opt_state, comp = runner.init()
        loss = float("nan")
        if cfg.train.qat_steps:
            params, state, opt_state, loss = runner.train(
                params, state, opt_state, comp, cfg.train.qat_steps)
        acc_base = runner.accuracy(params, state, comp,
                                   n_batches=cfg.train.eval_batches)
        if verbose:
            print(f"[pipeline] QAT base: loss={loss:.4f} acc={acc_base:.3f}")
        stats = runner.profile(params, state, comp,
                               n_batches=cfg.profile.batches,
                               max_tiles=cfg.profile.max_tiles)
        plan.params, plan.state = params, state
        plan.opt_state, plan.comp = opt_state, comp
        plan.stats = stats
        plan.metrics["acc_base"] = float(acc_base)
        plan.metrics["qat_loss"] = float(loss)
        if cfg.profile.verify_cosim:
            from repro.cosim import verify_runner_profile

            res = verify_runner_profile(
                runner, params, state, comp,
                n_batches=cfg.profile.batches,
                max_tiles=cfg.profile.max_tiles)
            plan.metrics["cosim_match"] = bool(res["match"])
            plan.metrics["cosim_tiles"] = int(res["n_tiles"])
            plan.metrics["cosim_max_abs_diff"] = float(res["max_abs_diff"])
            plan.metrics["cosim_toggles"] = int(res["toggles"])
            if verbose:
                print(f"[pipeline] cosim verify: match={res['match']} "
                      f"tiles={res['n_tiles']} "
                      f"max_abs_diff={res['max_abs_diff']}")
            if not res["match"]:
                bad = {n: r["max_abs_diff"] for n, r in res["layers"].items()
                       if not r["match"]}
                raise RuntimeError(
                    "transition-energy kernel disagrees with the "
                    f"bit-accurate cosim on layers {bad} — see docs/cosim.md")

    def stage_energy_model(self, plan: CompressionPlan, cfg: PipelineConfig,
                           verbose: bool = False) -> None:
        runner = self.runner
        models = runner.energy_models(plan.params, plan.comp, plan.stats)
        e_total = sum(m.energy for m in models.values())
        plan.shares = {n: m.energy / max(e_total, 1e-12)
                       for n, m in models.items()}
        plan.luts = {n: m.lut for n, m in models.items()}
        plan.metrics["energy_profile_total"] = float(e_total)
        if verbose:
            for n, s in sorted(plan.shares.items(), key=lambda kv: -kv[1]):
                print(f"[pipeline] energy share {n}: {s:.3f}")

    def stage_schedule(self, plan: CompressionPlan, cfg: PipelineConfig,
                       verbose: bool = False) -> None:
        from repro.core.schedule import energy_prioritized_compression

        runner = self.runner
        params, state, opt_state, comp, sched = energy_prioritized_compression(
            runner, plan.params, plan.state, plan.opt_state, plan.comp,
            plan.stats, cfg.schedule, cfg.selection, verbose=verbose)
        if cfg.train.final_finetune_steps:
            params, state, opt_state, _ = runner.train(
                params, state, opt_state, comp,
                cfg.train.final_finetune_steps)
        acc_final = runner.accuracy(params, state, comp,
                                    n_batches=cfg.train.eval_batches)
        models = runner.refresh_counts(
            params, comp, runner.energy_models(params, comp, plan.stats))
        e_after = sum(m.energy for m in models.values())

        plan.params, plan.state = params, state
        plan.opt_state, plan.comp = opt_state, comp
        plan.decisions = [decision_dict(d) for d in sched.decisions]
        ks = [int(d.k) for d in sched.decisions if d.k is not None]
        plan.metrics.update({
            "acc0": float(sched.acc0),
            "acc_final": float(acc_final),
            "accuracy_drop": float(plan.metrics.get("acc_base", sched.acc0)
                                   - acc_final),
            "energy_before": float(sched.energy_before),
            "energy_after": float(e_after),
            "energy_saving": 1.0 - float(e_after)
            / max(float(sched.energy_before), 1e-12),
            "max_codebook": max(ks) if ks else 256,
        })
        self.last_schedule_result = sched

    def stage_export(self, plan: CompressionPlan, cfg: PipelineConfig,
                     verbose: bool = False) -> None:
        from repro.core.export import export_model, export_summary

        arts = export_model(self.runner.model, plan.params, plan.comp,
                            block_k=cfg.export.block_k)
        plan.artifacts = arts
        plan.metrics.update(
            {f"export_{k}": v for k, v in export_summary(arts).items()})
        if verbose:
            print(f"[pipeline] exported {len(arts)} compressed layers")

    def stage_serve(self, plan: CompressionPlan, cfg: PipelineConfig,
                    verbose: bool = False) -> None:
        """Full-model forward through the packed LUT GEMM: logit parity vs
        the QAT fake-quant reference + served accuracy."""
        import jax.numpy as jnp

        from repro.nn.layers import QuantConfig

        runner = self.runner
        arts = plan.artifacts or {}
        plan.metrics["serve_layers"] = len(arts)
        if not arts:
            if verbose:
                print("[pipeline] no layer is servable; nothing to serve")
            return
        qserve = QuantConfig.serve(use_ref_kernel=cfg.serve.use_ref_kernel)
        x, _ = runner.dataset.batch(0, runner.batch_size, "val")
        l_fake, _, _ = runner.model.apply(
            plan.params, plan.state, x, train=False, qcfg=QuantConfig.on(),
            comp=plan.comp)
        l_serve, _, _ = runner.model.apply(
            plan.params, plan.state, x, train=False, qcfg=qserve,
            comp=plan.comp, serve=arts)
        rel = float(jnp.linalg.norm(l_serve - l_fake)
                    / jnp.maximum(jnp.linalg.norm(l_fake), 1e-9))
        correct = 0
        n_batches = max(cfg.train.eval_batches, 1)
        for i in range(n_batches):
            xb, yb = runner.dataset.batch(i, runner.batch_size, "val")
            logits, _, _ = runner.model.apply(
                plan.params, plan.state, xb, train=False, qcfg=qserve,
                comp=plan.comp, serve=arts)
            correct += int(jnp.sum(jnp.argmax(logits, -1) == yb))
        plan.metrics["serve_logit_rel_err"] = rel
        plan.metrics["serve_accuracy"] = correct / (n_batches
                                                    * runner.batch_size)
        if verbose:
            print(f"[pipeline] serve: {len(arts)} layers on the LUT GEMM, "
                  f"rel_err={rel:.2e}, "
                  f"acc={plan.metrics['serve_accuracy']:.3f}")


# ====================================================================== LM


class LMTarget:
    """LM compression + serving through `repro.serving.ServingEngine`."""

    kind = "lm"

    def __init__(self, cfg: PipelineConfig):
        from repro.configs import get_config
        from repro.models.lm import build_lm

        acfg = get_config(cfg.target.arch)
        if cfg.target.reduced:
            acfg = acfg.scaled_down(compute_dtype="float32")
        self.acfg = acfg
        self.model = build_lm(acfg)
        self.name = acfg.name
        self.last_schedule_result = None

    # ----------------------------------------------------------- helpers

    def _unit_energies(self, params, comp) -> Dict[str, float]:
        """Per-unit one-token MAC energy on the 64x64 array (uniform-trace
        LUT — no profiled activations exist at LM scale); the summed total
        is `repro.serving.metrics.per_token_energy`."""
        from repro.core import qat
        from repro.core.energy_lut import uniform_trace_lut
        from repro.core.layer_energy import (
            dense_matmul_dims,
            layer_energy_from_counts,
            weight_value_counts,
        )
        from repro.core.lm_compress import iter_eligible_units

        lut = uniform_trace_lut()
        out: Dict[str, float] = {}
        for name, w, c, layout in iter_eligible_units(self.model, params,
                                                      comp):
            w_int = qat.quantize_weight_int(w, c)
            mat = (w_int.reshape(w_int.shape[0], -1) if layout == "in_first"
                   else w_int.reshape(-1, w_int.shape[-1]))
            dims = dense_matmul_dims(fan_in=mat.shape[0], fan_out=mat.shape[1],
                                     n_tokens=1)
            counts = weight_value_counts(mat.T, dims)
            out[name] = float(layer_energy_from_counts(counts, lut, dims))
        return out

    # ------------------------------------------------------------- stages

    def stage_profile(self, plan: CompressionPlan, cfg: PipelineConfig,
                      verbose: bool = False) -> None:
        import jax

        from repro.core.lm_compress import init_lm_comp, lm_comp_layers
        from repro.nn.spec import init_params, spec_count

        if cfg.target.ckpt_dir:
            from repro.checkpoint.manager import CheckpointManager

            step, state = CheckpointManager(cfg.target.ckpt_dir).restore()
            params = state["params"] if "params" in state else state
            if verbose:
                print(f"[pipeline] restored checkpoint step {step}")
        else:
            params = init_params(jax.random.PRNGKey(cfg.target.seed),
                                 self.model.spec)
        comp = init_lm_comp(self.model)
        if cfg.train.qat_steps:
            params = self._qat_train(params, comp, cfg, verbose)
        plan.params, plan.comp = params, comp
        plan.metrics["n_params"] = int(spec_count(self.model.spec))
        plan.metrics["n_units"] = len(lm_comp_layers(self.model))
        if verbose:
            print(f"[pipeline] {self.name}: "
                  f"{plan.metrics['n_params'] / 1e6:.1f}M params, "
                  f"{plan.metrics['n_units']} compressible units")

    def _qat_train(self, params, comp, cfg: PipelineConfig, verbose: bool):
        """Optional LM QAT through the `repro.launch.train` step factories."""
        import jax

        from repro.data.synthetic import SyntheticTokens
        from repro.launch.train import StepConfig, make_optimizer, make_train_step

        step_cfg = StepConfig(qat=True, with_comp=True, remat=False,
                              q_block=128, kv_block=128, lr=cfg.target.lr)
        train_step = jax.jit(make_train_step(self.model, step_cfg))
        state = {"params": params,
                 "opt": make_optimizer(step_cfg).init(params)}
        data = SyntheticTokens(vocab=self.acfg.vocab, seed=cfg.target.data_seed)
        loss = float("nan")
        for i in range(cfg.train.qat_steps):
            x, y = data.batch(i, cfg.target.batch_size, 64)
            state, metrics = train_step(state, {"tokens": x, "labels": y},
                                        comp)
            loss = float(metrics["loss"])
        if verbose:
            print(f"[pipeline] LM QAT: {cfg.train.qat_steps} steps, "
                  f"final loss={loss:.3f}")
        return state["params"]

    def stage_energy_model(self, plan: CompressionPlan, cfg: PipelineConfig,
                           verbose: bool = False) -> None:
        from repro.core.energy_lut import uniform_trace_lut

        energies = self._unit_energies(plan.params, plan.comp)
        total = sum(energies.values())
        plan.shares = {n: e / max(total, 1e-12) for n, e in energies.items()}
        plan.luts = {"uniform": uniform_trace_lut()}
        plan.metrics["energy_per_token"] = float(total)
        self._unit_energy_cache = energies

    def stage_schedule(self, plan: CompressionPlan, cfg: PipelineConfig,
                       verbose: bool = False) -> None:
        from repro.core.lm_compress import (
            restrict_all_codebooks,
            symmetric_codebook_values,
        )

        k = cfg.serve.compress_k
        e_before = getattr(self, "_unit_energy_cache", None)
        if e_before is None:
            e_before = self._unit_energies(plan.params, plan.comp)
        total_before = sum(e_before.values())
        if not k:
            plan.metrics["energy_before"] = float(total_before)
            plan.metrics["energy_after"] = float(total_before)
            return
        values = symmetric_codebook_values(k)
        plan.comp = restrict_all_codebooks(self.model, plan.comp, values)
        e_after = self._unit_energies(plan.params, plan.comp)
        plan.decisions = [
            {"layer": name, "share": e_before[name] / max(total_before, 1e-12),
             "prune_ratio": None, "k": k,
             "energy_before": e_before[name], "energy_after": e_after[name],
             "accuracy": None, "accepted": True, "tried": [[0.0, k]]}
            for name in e_before
        ]
        plan.metrics["energy_before"] = float(total_before)
        plan.metrics["energy_after"] = float(sum(e_after.values()))
        plan.metrics["compress_k"] = k
        if verbose:
            print(f"[pipeline] restricted {len(e_before)} units to "
                  f"{k}-value codebooks "
                  f"(per-token energy {total_before:.3g} -> "
                  f"{plan.metrics['energy_after']:.3g} eu)")

    def stage_export(self, plan: CompressionPlan, cfg: PipelineConfig,
                     verbose: bool = False) -> None:
        from repro.core.export import export_summary
        from repro.core.lm_compress import export_lm_matmuls, lut_parity_report

        arts, skips = export_lm_matmuls(self.model, plan.params, plan.comp,
                                        block_k=cfg.export.block_k)
        plan.artifacts = arts
        summary = export_summary(arts)
        checked = lut_parity_report(self.model, plan.params, plan.comp, arts)
        summary["parity_max_rel_err"] = max(checked.values()) if checked else 0.0
        summary["skipped"] = len(skips)
        plan.metrics.update({f"export_{k}": v for k, v in summary.items()
                             if k != "skipped_units"})
        if plan.stats is None:
            plan.stats = {}
        plan.stats.setdefault("export", {})["skip_report"] = skips
        if verbose and arts:
            print(f"[pipeline] exported {summary['layers']} matmuls, "
                  f"{summary['weight_bytes_packed'] / 1e6:.2f} MB packed "
                  f"({summary['compression_vs_int8']:.2f}x vs int8), "
                  f"LUT parity max rel err "
                  f"{summary['parity_max_rel_err']:.2e}")
        if verbose and skips:
            print(f"[pipeline] export skipped {len(skips)} units:")
            for s in skips:
                print(f"  - {s['unit']}: {s['reason']} ({s['detail']})")

    def _serve_handle(self, plan: CompressionPlan, k: int):
        """The single-variant `PlanHandle` the pinned serve stage uses."""
        from repro.serving import PlanHandle

        if k and plan.comp is not None:
            return PlanHandle.from_comp(plan.comp, compress_k=k,
                                        plan_id=f"k{k}")
        if k:
            return PlanHandle.from_compress_k(self.model, k)
        return PlanHandle.uncompressed()

    def _fleet_handles(self, plan: CompressionPlan, cfg: PipelineConfig):
        """Resolve `serve.plans` specs + `serve.plans_dir` into handles."""
        from repro.pipeline.config import parse_plan_spec
        from repro.serving import PlanHandle, PlanRegistry

        registry = PlanRegistry()
        if cfg.serve.plans_dir:
            for h in PlanRegistry.from_dir(cfg.serve.plans_dir):
                registry.register(h)
        for spec in cfg.serve.plans:
            k, msr = parse_plan_spec(spec)
            if k is None:
                loaded = CompressionPlan.load(spec)
                registry.register(PlanHandle.from_compression_plan(loaded))
            elif k == 0:
                registry.register(PlanHandle.uncompressed())
            else:
                registry.register(PlanHandle.from_compress_k(
                    self.model, k, msr_bits=msr))
        return registry

    def stage_serve(self, plan: CompressionPlan, cfg: PipelineConfig,
                    verbose: bool = False) -> None:
        import jax

        from repro.serving import EngineConfig, ServeRequest, ServingEngine

        s = cfg.serve
        k = s.compress_k
        shapes = lm_trace_shapes(s.requests, s.prompt_len, s.new_tokens,
                                 s.mixed, stride=s.mixed_stride)
        p_bucket = max(sh[0] for sh in shapes)
        n_bucket = max(sh[1] for sh in shapes)
        # dedupe and sort: EngineConfig rejects duplicate buckets, and a
        # tiny p_bucket makes the half-size bucket collide with it
        p_buckets = tuple(sorted({max(p_bucket // 2, 2), p_bucket}))
        ecfg = EngineConfig(max_batch=s.max_batch,
                            prompt_buckets=p_buckets,
                            new_token_buckets=(n_bucket,))
        prompts = [
            jax.random.randint(jax.random.PRNGKey(s.prompt_seed + i),
                               (plen,), 0, self.acfg.vocab)
            for i, (plen, _) in enumerate(shapes)
        ]
        requests = [
            ServeRequest(tokens=prompt, max_new_tokens=ntok,
                         temperature=s.temperature,
                         tenant=f"tenant{i % 2}")
            for i, (prompt, (_, ntok)) in enumerate(zip(prompts, shapes))
        ]

        if s.plans or s.plans_dir:
            self._serve_fleet(plan, cfg, ecfg, shapes, requests, verbose)
            return

        handle = self._serve_handle(plan, k)

        def drain(mode):
            engine = ServingEngine(self.model, plan.params, mode=mode,
                                   config=ecfg, plan=handle)
            engine.warmup(shapes)
            warm_compiles = engine.cache.compile_count
            results = engine.serve(requests)
            rep = engine.report()
            rep["recompiles_after_warmup"] = (engine.cache.compile_count
                                              - warm_compiles)
            return {r.rid: r for r in results}, rep

        results, rep = drain(s.mode)
        plan.metrics.update({f"serve_{key}": val for key, val in rep.items()
                             if isinstance(val, (int, float, bool))})
        plan.metrics["serve_mode"] = s.mode
        parity: Optional[bool] = None
        if s.verify_oneshot and s.mode == "engine":
            ref, _ = drain("oneshot")
            parity = all(results[r].tokens == ref[r].tokens for r in results)
            plan.metrics["serve_parity_engine_vs_oneshot"] = bool(parity)
        self.last_serve_results = results
        if verbose:
            line = (f"[pipeline] {s.mode}: {rep['requests']} requests, "
                    f"{rep['new_tokens']} tokens "
                    f"({rep['tokens_per_s']:.1f} tok/s), "
                    f"{rep['recompiles_after_warmup']} recompiles after "
                    f"warmup")
            if parity is not None:
                line += f", engine==oneshot: {parity}"
            print(line)

    def _serve_fleet(self, plan: CompressionPlan, cfg: PipelineConfig, ecfg,
                     shapes, requests, verbose: bool) -> None:
        """Fleet path: route the trace across every resident plan."""
        from repro.serving import FleetRouter

        s = cfg.serve
        registry = self._fleet_handles(plan, cfg)
        fleet = FleetRouter(self.model, plan.params, registry,
                            mode=s.mode if s.mode != "oneshot" else "engine",
                            config=ecfg)
        fleet.warmup(shapes)
        results = fleet.serve(requests)
        rep = fleet.report()
        plan.metrics.update({f"serve_{key}": val for key, val in rep.items()
                             if isinstance(val, (int, float, bool))})
        plan.metrics["serve_mode"] = "fleet"
        plan.metrics["serve_plans"] = ",".join(h.plan_id
                                               for h in fleet.levels)
        # engine-local rids repeat across the fleet; key on trace order
        self.last_serve_results = dict(enumerate(results))
        self.last_fleet_report = rep
        if verbose:
            routed = {pid: p["requests"] for pid, p in rep["plans"].items()}
            print(f"[pipeline] fleet: {rep['requests']} requests over "
                  f"{rep['plans_resident']} plans {routed}, "
                  f"{rep['new_tokens']} tokens "
                  f"({rep['tokens_per_s']:.1f} tok/s), "
                  f"{rep['recompiles_after_warmup']} recompiles after "
                  f"warmup")


# ==================================================== routing-aware targets


# per-(layer, expert) slice names from LMTarget._unit_energies /
# iter_eligible_units: "blocks/g0/moe/w_gate[1][e2]", "tail/t0/moe/w_up[e0]",
# "blocks/g0/ssm/in_proj[1]", "tail/t0/mlp/w_down"
_EXPERT_SLICE_RE = re.compile(
    r"^(?P<base>.+)/(?P<key>[^/\[]+)(?:\[(?P<li>\d+)\])?\[e(?P<ei>\d+)\]$")
_LAYER_SLICE_RE = re.compile(
    r"^(?P<base>.+)/(?P<key>[^/\[]+)(?:\[(?P<li>\d+)\])?$")


def _slice_key(name: str) -> Tuple[str, int, Optional[int]]:
    """(unit path, layer index, expert index|None) of one energy-slice name."""
    m = _EXPERT_SLICE_RE.match(name)
    if m:
        return (f"{m.group('base')}/{m.group('key')}",
                int(m.group("li") or 0), int(m.group("ei")))
    m = _LAYER_SLICE_RE.match(name)
    if m:
        return (f"{m.group('base')}/{m.group('key')}",
                int(m.group("li") or 0), None)
    return (name, 0, None)


def traffic_weighted_unit_energies(energies: Dict[str, float],
                                   stats) -> Dict[str, float]:
    """Scale per-slice tile energies by measured routing traffic.

    ``stats`` is a `repro.core.routing_stats.RoutingStats`. Expert slices
    are charged ``energy * share * E`` (uniform traffic changes nothing,
    hot experts weigh more); scan-layer slices likewise against the
    activity share. Slices without routing statistics pass through.
    """
    from repro.core import routing_stats as rs

    moe = {u: rs.traffic_shares(c) for u, c in stats.moe_counts.items()}
    scan = {u: rs.activity_shares(a) for u, a in stats.scan_activity.items()}
    out: Dict[str, float] = {}
    for name, e in energies.items():
        path, li, ei = _slice_key(name)
        base = path.rsplit("/", 1)[0]
        if ei is not None and base in moe:
            shares = moe[base]
            out[name] = float(e * shares[li, ei] * shares.shape[-1])
        elif ei is None and base in scan:
            shares = scan[base]
            out[name] = float(e * shares[li] * shares.size)
        else:
            out[name] = float(e)
    return out


class _RoutedTarget(LMTarget):
    """LM target with traffic-weighted per-unit compression.

    Extends the uniform LM schedule with a calibration pass
    (`repro.core.routing_stats.collect_lm_routing_stats`): the profile
    stage measures how traffic distributes over routed units, the energy
    model scales each unit's tile energy by its measured share, and the
    schedule stage assigns per-unit codebook sizes from the config's k
    ladder by traffic rank — hot units keep gentler (larger-k) codebooks,
    cold units compress aggressively. Subclasses define which units are
    routed and how assignments map onto comp entries."""

    def _collect_routing(self, plan: CompressionPlan, cfg: PipelineConfig,
                         verbose: bool = False):
        from repro.core import routing_stats as rs

        r = cfg.routing
        stats = rs.collect_lm_routing_stats(
            self.model, plan.params, comp=plan.comp,
            batches=r.calib_batches, batch_size=r.calib_batch_size,
            seq_len=r.calib_seq_len, seed=r.calib_seed)
        if plan.stats is None:
            plan.stats = {}
        plan.stats["routing"] = stats.as_arrays()
        self._routing_cache = stats
        if verbose:
            units = len(stats.moe_counts) + len(stats.scan_activity)
            print(f"[pipeline] routing calibration: {stats.tokens} tokens "
                  f"over {units} routed units")
        return stats

    def _routing_stats(self, plan: CompressionPlan, cfg: PipelineConfig):
        """Cached -> plan-recorded -> freshly collected, in that order."""
        stats = getattr(self, "_routing_cache", None)
        if stats is not None:
            return stats
        arrays = (plan.stats or {}).get("routing")
        if arrays:
            from repro.core.routing_stats import RoutingStats

            self._routing_cache = RoutingStats.from_arrays(
                {k: v for k, v in arrays.items()})
            return self._routing_cache
        return self._collect_routing(plan, cfg)

    def _unit_energies(self, params, comp) -> Dict[str, float]:
        energies = super()._unit_energies(params, comp)
        stats = getattr(self, "_routing_cache", None)
        if stats is None:
            return energies
        return traffic_weighted_unit_energies(energies, stats)

    def _routed_assignments(self, stats, cfg: PipelineConfig) -> List[Tuple]:
        """(path, layer, expert|None, k, traffic_share) per routed slice."""
        raise NotImplementedError

    # ------------------------------------------------------------- stages

    def stage_profile(self, plan: CompressionPlan, cfg: PipelineConfig,
                      verbose: bool = False) -> None:
        super().stage_profile(plan, cfg, verbose)
        self._collect_routing(plan, cfg, verbose)

    def stage_energy_model(self, plan: CompressionPlan, cfg: PipelineConfig,
                           verbose: bool = False) -> None:
        self._routing_stats(plan, cfg)   # ensure the traffic prior is live
        super().stage_energy_model(plan, cfg, verbose)

    def stage_schedule(self, plan: CompressionPlan, cfg: PipelineConfig,
                       verbose: bool = False) -> None:
        from repro.core.lm_compress import (
            restrict_all_codebooks,
            set_codebook,
            symmetric_codebook_values,
        )

        k = cfg.serve.compress_k
        e_before = getattr(self, "_unit_energy_cache", None)
        if e_before is None:
            e_before = self._unit_energies(plan.params, plan.comp)
        total_before = sum(e_before.values())
        plan.metrics["energy_before"] = float(total_before)
        if not k:
            plan.metrics["energy_after"] = float(total_before)
            return

        # uniform floor first (every eligible unit gets the serve codebook),
        # then traffic-ranked per-unit overrides from the k ladder
        plan.comp = restrict_all_codebooks(self.model, plan.comp,
                                           symmetric_codebook_values(k))
        stats = self._routing_stats(plan, cfg)
        routed = self._routed_assignments(stats, cfg)
        for path, li, ei, kk, _share in routed:
            plan.comp = set_codebook(plan.comp, path,
                                     symmetric_codebook_values(int(kk)),
                                     layer=li, expert=ei)
        e_after = self._unit_energies(plan.params, plan.comp)

        assign = {(p, li, ei): (kk, share)
                  for p, li, ei, kk, share in routed}
        plan.decisions = []
        for name in e_before:
            kk, tshare = assign.get(_slice_key(name), (k, None))
            d = {"layer": name,
                 "share": e_before[name] / max(total_before, 1e-12),
                 "prune_ratio": None, "k": int(kk),
                 "energy_before": e_before[name],
                 "energy_after": e_after[name],
                 "accuracy": None, "accepted": True,
                 "tried": [[0.0, int(kk)]]}
            if tshare is not None:
                d["traffic_share"] = float(tshare)
            plan.decisions.append(d)

        plan.metrics["energy_after"] = float(sum(e_after.values()))
        plan.metrics["compress_k"] = k
        plan.metrics["routed_units"] = len(routed)
        plan.metrics["routing_tokens"] = int(stats.tokens)
        if verbose:
            ks = sorted({int(kk) for _, _, _, kk, _ in routed})
            print(f"[pipeline] routed {len(routed)} unit slices onto "
                  f"k ladder {ks} (uniform floor k={k}; per-token energy "
                  f"{total_before:.3g} -> "
                  f"{plan.metrics['energy_after']:.3g} eu)")


class MoETarget(_RoutedTarget):
    """MoE LM: per-expert codebooks sized by measured dispatch frequency."""

    kind = "moe"

    def _routed_assignments(self, stats, cfg: PipelineConfig) -> List[Tuple]:
        from repro.core import routing_stats as rs
        from repro.core.lm_compress import MOE_EXPERT_KEYS

        ladder = tuple(cfg.routing.k_ladder)
        out: List[Tuple] = []
        for base, counts in sorted(stats.moe_counts.items()):
            shares = rs.traffic_shares(counts)
            for li in range(shares.shape[0]):
                ks = rs.assign_rank_k(shares[li], ladder)
                for key in MOE_EXPERT_KEYS:
                    for ei in range(shares.shape[1]):
                        out.append((f"{base}/{key}", li, ei, int(ks[ei]),
                                    float(shares[li, ei])))
        return out


class ScanTarget(_RoutedTarget):
    """SSM/RG-LRU LM: per-scan-unit codebooks sized by measured activity."""

    kind = "scan"

    def _routed_assignments(self, stats, cfg: PipelineConfig) -> List[Tuple]:
        from repro.core import routing_stats as rs
        from repro.core.lm_compress import lm_comp_layers

        ladder = tuple(cfg.routing.k_ladder)
        by_base: Dict[str, List[str]] = {}
        for path in lm_comp_layers(self.model):
            by_base.setdefault(path.rsplit("/", 1)[0], []).append(path)
        out: List[Tuple] = []
        for base, act in sorted(stats.scan_activity.items()):
            shares = rs.activity_shares(act)
            ks = rs.assign_rank_k(shares, ladder)
            for li in range(shares.size):
                for path in by_base.get(base, ()):
                    out.append((path, li, None, int(ks[li]),
                                float(shares[li])))
        return out
