"""Plan/stage schema constants and pure-python plan-document validation.

This module is deliberately **jax-free**: it is imported by the `repro` CLI
before any stage module loads (so ``repro --help`` costs nothing) and by
``tools/check_gates.py --plan`` inside CI (which validates a saved plan's
JSON document without building a device runtime).

The on-disk `CompressionPlan` format is a pair of files sharing a base path:

  * ``<base>.json`` — everything static: schema version, the originating
    `PipelineConfig` dict, target identity, completed stages, schedule
    decisions, metrics, energy shares, and the encoded *structure* of every
    array-bearing section (arrays appear as ``{"__array__": key}`` refs);
  * ``<base>.npz``  — the array payload, keyed by the refs above.

`validate_plan_doc` checks the JSON half only — enough for the CI gate
(schema version, stage ordering, share normalization, decision sanity)
without touching the arrays.
"""

from __future__ import annotations

from typing import Dict, List

PLAN_SCHEMA_VERSION = 1
PLAN_FORMAT = "repro.pipeline.plan"

# canonical stage order; `Pipeline` executes a prefix of this tuple
STAGES = ("profile", "energy_model", "schedule", "export", "serve")

# mirrors repro.core.qat.K_MAX without importing jax
K_MAX = 32

# relative slack on "shares sum to 1" and energy monotonicity checks
_SHARE_TOL = 0.01


def stage_index(name: str) -> int:
    try:
        return STAGES.index(name)
    except ValueError:
        raise ValueError(
            f"unknown stage {name!r}; stages are {', '.join(STAGES)}"
        ) from None


def validate_plan_doc(doc: dict) -> List[Dict]:
    """Gate table for a saved plan's JSON document.

    Returns ``[{name, value, op, threshold, pass}, ...]`` in the shape
    ``tools/check_gates.py`` reports, so the CI step can reuse its printer.
    Purely structural — no arrays are loaded.
    """
    gates: List[Dict] = []

    def gate(name, value, op, threshold, ok):
        gates.append({
            "name": name, "benchmark": "plan", "value": value, "op": op,
            "threshold": threshold, "ci_slack": None,
            "effective_threshold": threshold, "pass": bool(ok),
        })

    version = doc.get("schema_version")
    gate("plan_schema_version", version, "==", PLAN_SCHEMA_VERSION,
         version == PLAN_SCHEMA_VERSION)
    fmt = doc.get("format")
    gate("plan_format", fmt, "==", PLAN_FORMAT, fmt == PLAN_FORMAT)

    completed = doc.get("completed") or []
    known = all(s in STAGES for s in completed)
    ordered = known and [s for s in STAGES if s in completed] == list(completed)
    gate("plan_stages_ordered", ",".join(completed) or "(none)", "==",
         "prefix-ordered subset of " + "->".join(STAGES),
         bool(completed) and ordered)

    shares = doc.get("shares") or {}
    if "energy_model" in completed:
        total = sum(float(v) for v in shares.values())
        gate("plan_energy_shares_sum", round(total, 6), "~=", 1.0,
             bool(shares) and abs(total - 1.0) <= _SHARE_TOL)

    decisions = doc.get("decisions") or []
    if "schedule" in completed:
        sane = True
        for d in decisions:
            if not d.get("accepted"):
                continue
            k = d.get("k")
            if k is None or not (1 <= int(k) <= K_MAX):
                sane = False
            msr = d.get("msr")   # absent in pre-MSR documents
            if msr is not None and not (0 <= int(msr) <= 8):
                sane = False
            # routed (moe/scan) decisions carry the measured traffic share
            ts = d.get("traffic_share")
            if ts is not None and not (0.0 <= float(ts) <= 1.0):
                sane = False
            eb, ea = d.get("energy_before"), d.get("energy_after")
            if eb is None or ea is None or ea > eb * (1.0 + _SHARE_TOL):
                sane = False
        gate("plan_decisions_sane", len(decisions), "==",
             f"accepted k in [1, {K_MAX}], msr in [0, 8], "
             f"traffic share in [0, 1], energy non-increasing", sane)

        metrics = doc.get("metrics") or {}
        eb = metrics.get("energy_before")
        ea = metrics.get("energy_after")
        if any(d.get("accepted") for d in decisions):
            gate("plan_total_energy_non_increasing", ea, "<=", eb,
                 eb is not None and ea is not None
                 and ea <= eb * (1.0 + _SHARE_TOL))

    arrays = doc.get("arrays")
    gate("plan_array_manifest_present", None if arrays is None else len(arrays),
         ">=", 1, bool(arrays))
    return gates
