"""One validated configuration namespace for the whole compression pipeline.

`PipelineConfig` composes the per-stage configs that used to be wired by hand
in every example/launcher — `ScheduleConfig` and `SelectionConfig` are the
*existing* dataclasses from `repro.core`, embedded unchanged — plus target
selection (CNN vs LM behind one `Target` protocol, see
`repro.pipeline.targets`) and the train/profile/export/serve knobs that were
previously loose function arguments.

The whole tree round-trips through plain dicts / JSON (``to_dict`` /
``from_dict`` / ``save`` / ``load``): tuples become lists on the way out and
are restored by field type on the way in, unknown keys are rejected (typos in
a config file fail loudly), and ``validate()`` checks cross-field invariants
once instead of five call sites doing it differently.
"""

from __future__ import annotations

import dataclasses
import json
import typing
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

from repro.core.schedule import ScheduleConfig
from repro.core.weight_selection import SelectionConfig

CNN_ARCHS = ("lenet5", "resnet8", "resnet20", "resnet50")


@dataclasses.dataclass
class TargetConfig:
    """What model the pipeline compresses and how its runtime is built."""

    kind: str = "cnn"            # "cnn" | "lm"
    arch: str = "lenet5"         # cnn: CNN_ARCHS; lm: repro.configs ids
    reduced: bool = False        # lm: scaled_down CPU config of the family
    seed: int = 0                # param init seed
    data_seed: int = 7           # synthetic dataset seed (cnn)
    batch_size: int = 64         # train/eval batch (cnn)
    lr: float = 2e-3             # QAT learning rate (cnn)
    ckpt_dir: Optional[str] = None  # lm: restore params instead of init


@dataclasses.dataclass
class TrainStageConfig:
    """QAT base training before profiling + the post-schedule fine-tune."""

    qat_steps: int = 300
    final_finetune_steps: int = 100
    eval_batches: int = 4


@dataclasses.dataclass
class ProfileStageConfig:
    """Systolic-trace profiling budget (see repro.core.profiler).

    ``verify_cosim`` runs the bit-accurate co-simulation gate
    (`repro.cosim.verify_runner_profile`) on the profiled tiles right
    after the stage: the kernel's transition histograms must match the
    independent PE-level reference exactly, or the stage fails."""

    batches: int = 1
    max_tiles: int = 16
    verify_cosim: bool = False


@dataclasses.dataclass
class RoutingStageConfig:
    """Routing/activity calibration for traffic-weighted compression.

    Used by the "moe" and "scan" targets (`repro.pipeline.targets.MoETarget`
    / `ScanTarget`): a deterministic synthetic calibration trace measures
    per-expert dispatch frequency and per-scan-layer activity
    (`repro.core.routing_stats`), and routed units are bucketed onto
    ``k_ladder`` by traffic rank — hottest units get the largest (gentlest)
    codebook, coldest the smallest.
    """

    calib_batches: int = 2       # calibration prefill batches
    calib_batch_size: int = 2
    calib_seq_len: int = 32
    calib_seed: int = 0          # PRNG chain seed of the token trace
    # codebook sizes routed units are assigned by traffic rank
    # (order-insensitive; entries must stay LUT-servable, i.e. <= N_CODES)
    k_ladder: Tuple[int, ...] = (4, 8, 16)


@dataclasses.dataclass
class ExportStageConfig:
    """Packed 4-bit artifact export (see repro.core.export)."""

    block_k: int = 128


@dataclasses.dataclass
class ServeStageConfig:
    """Serve-stage behaviour.

    CNN targets run the full-model LUT-GEMM forward and report parity vs the
    fake-quant reference; LM targets drive `repro.serving.ServingEngine`
    over a deterministic mixed-length trace.
    """

    mode: str = "engine"         # "engine" | "wave" | "oneshot" (lm)
    compress_k: int = 0          # lm: uniform k-value codebook restriction
    # multi-plan fleet serving (lm): resident variant specs routed across by
    # repro.serving.fleet.FleetRouter. Each entry is either a saved
    # CompressionPlan base path or a shorthand spec: "base" (uncompressed),
    # "k4", "k8m2" (k-value codebook + MSR bits). plans_dir loads every
    # saved plan in a directory instead.
    plans: Tuple[str, ...] = ()
    plans_dir: Optional[str] = None
    requests: int = 4
    prompt_len: int = 32
    new_tokens: int = 16
    mixed: bool = False          # vary request lengths across buckets
    mixed_stride: int = 7        # prompt-length decrement for mixed traces
    max_batch: int = 8           # engine wave width
    temperature: float = 0.0
    prompt_seed: int = 100       # base seed of the synthetic prompt trace
    verify_oneshot: bool = False  # lm: cross-check engine vs oneshot tokens
    use_ref_kernel: bool = False  # cnn: serve via the jnp oracle


@dataclasses.dataclass
class PipelineConfig:
    target: TargetConfig = dataclasses.field(default_factory=TargetConfig)
    train: TrainStageConfig = dataclasses.field(
        default_factory=TrainStageConfig)
    profile: ProfileStageConfig = dataclasses.field(
        default_factory=ProfileStageConfig)
    schedule: ScheduleConfig = dataclasses.field(
        default_factory=ScheduleConfig)
    selection: SelectionConfig = dataclasses.field(
        default_factory=SelectionConfig)
    routing: RoutingStageConfig = dataclasses.field(
        default_factory=RoutingStageConfig)
    export: ExportStageConfig = dataclasses.field(
        default_factory=ExportStageConfig)
    serve: ServeStageConfig = dataclasses.field(
        default_factory=ServeStageConfig)

    # ------------------------------------------------------------ round-trip

    def to_dict(self) -> Dict[str, Any]:
        return _asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PipelineConfig":
        cfg = _build(cls, d, path="config")
        cfg.validate()
        return cfg

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, text: str) -> "PipelineConfig":
        return cls.from_dict(json.loads(text))

    def save(self, path) -> None:
        Path(path).write_text(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "PipelineConfig":
        return cls.from_json(Path(path).read_text())

    # ------------------------------------------------------------ validation

    def validate(self) -> "PipelineConfig":
        from repro.core.qat import K_MAX
        from repro.core.schedule import _SEARCH_MODES

        t = self.target
        if t.kind not in ("cnn", "lm", "moe", "scan"):
            raise ValueError(f"target.kind must be one of 'cnn', 'lm', "
                             f"'moe', 'scan', got {t.kind!r}")
        if t.kind == "cnn" and t.arch not in CNN_ARCHS:
            raise ValueError(
                f"target.arch {t.arch!r} is not a CNN arch {CNN_ARCHS}")
        if self.schedule.search_mode not in _SEARCH_MODES:
            raise ValueError(
                f"schedule.search_mode must be one of "
                f"{sorted(_SEARCH_MODES)}, got {self.schedule.search_mode!r}")
        for p in self.schedule.prune_ratios:
            if not 0.0 <= p < 1.0:
                raise ValueError(f"schedule.prune_ratios entry {p} not in [0, 1)")
        for k in self.schedule.k_targets:
            if not 1 <= k <= K_MAX:
                raise ValueError(f"schedule.k_targets entry {k} not in [1, {K_MAX}]")
        for m in getattr(self.schedule, "msr_bits", (0,)):
            if not 0 <= m <= 8:
                raise ValueError(
                    f"schedule.msr_bits entry {m} not in [0, 8] "
                    f"(0 disables MSR truncation; int8 weights have at "
                    f"most 8 magnitude bits)")
        if not 1 <= self.selection.k_target <= self.selection.k_init <= 256:
            raise ValueError(
                f"selection needs 1 <= k_target <= k_init, got "
                f"{self.selection.k_target} / {self.selection.k_init}")
        if self.serve.mode not in ("engine", "oneshot"):
            raise ValueError(
                f"serve.mode must be 'engine' or 'oneshot', got {self.serve.mode!r}")
        if not 0 <= self.serve.compress_k <= K_MAX:
            raise ValueError(
                f"serve.compress_k must be in [0, {K_MAX}], got "
                f"{self.serve.compress_k}")
        if (self.serve.plans or self.serve.plans_dir) \
                and self.target.kind == "cnn":
            raise ValueError("serve.plans / serve.plans_dir (fleet serving) "
                             "need an LM-family target")
        if not self.routing.k_ladder:
            raise ValueError("routing.k_ladder must not be empty")
        for k in self.routing.k_ladder:
            if not 1 <= k <= K_MAX:
                raise ValueError(
                    f"routing.k_ladder entry {k} not in [1, {K_MAX}]")
        for name in ("calib_batches", "calib_batch_size", "calib_seq_len"):
            if getattr(self.routing, name) < 1:
                raise ValueError(f"routing.{name} must be >= 1")
        for spec in self.serve.plans:
            k, msr = parse_plan_spec(spec)
            if k is None:
                continue  # a saved-plan path; existence checked at load
            if not 0 <= k <= K_MAX:
                raise ValueError(
                    f"serve.plans entry {spec!r}: k must be in [0, {K_MAX}]")
            if not 0 <= msr <= 8:
                raise ValueError(
                    f"serve.plans entry {spec!r}: msr bits must be in [0, 8]")
        for name in ("qat_steps", "final_finetune_steps", "eval_batches"):
            if getattr(self.train, name) < 0:
                raise ValueError(f"train.{name} must be >= 0")
        return self

    # ------------------------------------------------------------- overrides

    def with_overrides(
        self, overrides: Optional[Dict[str, Dict[str, Any]]]
    ) -> "PipelineConfig":
        """Functional per-section overrides: ``{"schedule": {"max_layers": 1}}``.

        Sections are the field names of this dataclass; unknown sections or
        fields raise (same strictness as `from_dict`)."""
        if not overrides:
            return self
        sections = {f.name: f for f in dataclasses.fields(self)}
        out = self
        for section, fields in overrides.items():
            if section not in sections:
                raise ValueError(
                    f"unknown config section {section!r}; have "
                    f"{sorted(sections)}")
            cur = getattr(out, section)
            valid = {f.name for f in dataclasses.fields(cur)}
            bad = set(fields) - valid
            if bad:
                raise ValueError(
                    f"unknown field(s) {sorted(bad)} for section {section!r}")
            out = dataclasses.replace(
                out, **{section: dataclasses.replace(cur, **fields)})
        out.validate()
        return out


def parse_plan_spec(spec: str) -> Tuple[Optional[int], int]:
    """Parse a fleet plan shorthand: ``"base"`` -> (0, 0), ``"k4"`` ->
    (4, 0), ``"k8m2"`` -> (8, 2). Anything else is a saved-plan path and
    returns (None, 0). jax-free, shared by validation and the serve stage."""
    import re

    if spec == "base":
        return 0, 0
    m = re.fullmatch(r"k(\d+)(?:m(\d+))?", spec)
    if m:
        return int(m.group(1)), int(m.group(2) or 0)
    return None, 0


# ------------------------------------------------------------------ presets


def reduced_cnn_config(**target_kw) -> PipelineConfig:
    """CPU-smoke preset: a LeNet-5 micro-run of the full pipeline (~1 min).

    This is what ``repro compress --reduced`` executes and what the CI plan
    gate builds its plan from — small enough for a 2-core runner, big enough
    that a layer actually accepts a restriction.
    """
    target = TargetConfig(kind="cnn", arch="lenet5", data_seed=5,
                          batch_size=64, lr=2e-3, **target_kw)
    return PipelineConfig(
        target=target,
        train=TrainStageConfig(qat_steps=60, final_finetune_steps=15,
                               eval_batches=2),
        profile=ProfileStageConfig(batches=1, max_tiles=4),
        schedule=ScheduleConfig(prune_ratios=(0.5,), k_targets=(16,),
                                delta_acc=0.08, finetune_steps=10,
                                trial_finetune_steps=8, eval_batches=1,
                                max_layers=1),
        selection=SelectionConfig(k_init=20, k_target=16, delta_acc=0.08,
                                  score_batches=1, accept_batches=1,
                                  max_score_candidates=3),
    )


def reduced_lm_config(arch: str = "olmo-1b", *, compress_k: int = 4,
                      **serve_kw) -> PipelineConfig:
    """CPU-smoke preset for an LM target: reduced config, uniform k-value
    restriction, short mixed trace through the serving engine."""
    serve = ServeStageConfig(compress_k=compress_k, requests=2, prompt_len=12,
                             new_tokens=6, mixed=True, max_batch=4)
    serve = dataclasses.replace(serve, **serve_kw)
    return PipelineConfig(
        target=TargetConfig(kind="lm", arch=arch, reduced=True),
        train=TrainStageConfig(qat_steps=0, final_finetune_steps=0),
        serve=serve,
    )


def reduced_moe_config(arch: str = "phi3.5-moe-42b-a6.6b", *,
                       compress_k: int = 4, **serve_kw) -> PipelineConfig:
    """CPU-smoke preset for a routed MoE target: reduced config, uniform
    codebook floor plus per-expert k sized by measured dispatch traffic."""
    cfg = reduced_lm_config(arch, compress_k=compress_k, **serve_kw)
    return dataclasses.replace(
        cfg, target=dataclasses.replace(cfg.target, kind="moe"))


def reduced_scan_config(arch: str = "mamba2-1.3b", *, compress_k: int = 4,
                        **serve_kw) -> PipelineConfig:
    """CPU-smoke preset for a routed SSM/RG-LRU target: per-scan-unit k
    sized by measured activation activity."""
    cfg = reduced_lm_config(arch, compress_k=compress_k, **serve_kw)
    return dataclasses.replace(
        cfg, target=dataclasses.replace(cfg.target, kind="scan"))


def from_legacy(core_cfg, *, arch: Optional[str] = None) -> PipelineConfig:
    """Map the deprecated `repro.core.compression.PipelineConfig` onto the
    unified namespace (the runner itself is injected by the caller).

    ``arch`` records the injected runner's model name when it is a registry
    arch, so plans saved through the legacy shim resume against the right
    model; custom models keep the default and are only identified by the
    plan's ``target.name``."""
    target = (TargetConfig(kind="cnn", arch=arch) if arch in CNN_ARCHS
              else TargetConfig())
    return PipelineConfig(
        target=target,
        train=TrainStageConfig(
            qat_steps=core_cfg.qat_steps,
            final_finetune_steps=core_cfg.final_finetune_steps,
            eval_batches=core_cfg.eval_batches),
        profile=ProfileStageConfig(batches=core_cfg.profile_batches,
                                   max_tiles=core_cfg.profile_max_tiles),
        schedule=core_cfg.schedule,
        selection=core_cfg.selection,
    )


# ----------------------------------------------------- dict <-> dataclasses


def _asdict(obj) -> Any:
    if dataclasses.is_dataclass(obj):
        return {f.name: _asdict(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, (list, tuple)):
        return [_asdict(v) for v in obj]
    if isinstance(obj, dict):
        return {k: _asdict(v) for k, v in obj.items()}
    return obj


def _build(dc_cls, d: Dict[str, Any], *, path: str):
    if not isinstance(d, dict):
        raise ValueError(f"{path}: expected a dict for {dc_cls.__name__}, "
                         f"got {type(d).__name__}")
    fields = {f.name: f for f in dataclasses.fields(dc_cls)}
    unknown = set(d) - set(fields)
    if unknown:
        raise ValueError(f"{path}: unknown field(s) {sorted(unknown)} for "
                         f"{dc_cls.__name__}")
    kwargs = {}
    hints = typing.get_type_hints(dc_cls)
    for name, value in d.items():
        hint = hints.get(name, Any)
        kwargs[name] = _coerce(hint, value, path=f"{path}.{name}")
    return dc_cls(**kwargs)


def _coerce(hint, value, *, path: str):
    origin = typing.get_origin(hint)
    if dataclasses.is_dataclass(hint) and isinstance(hint, type):
        return _build(hint, value, path=path)
    if origin in (tuple, Tuple) and isinstance(value, (list, tuple)):
        args = typing.get_args(hint)
        inner = args[0] if args else Any
        return tuple(_coerce(inner, v, path=path) for v in value)
    if origin is typing.Union:  # Optional[...]
        if value is None:
            return None
        for arg in typing.get_args(hint):
            if arg is type(None):
                continue
            return _coerce(arg, value, path=path)
    return value
