"""`repro` command-line entry point: drive the Pipeline from a shell.

    repro profile  [--config cfg.json | presets] [--plan-out BASE]
    repro compress [--config cfg.json | presets] [--plan-out BASE]
    repro export   [--plan-in BASE | presets]    [--plan-out BASE]
    repro serve    [--plan-in BASE | presets]    [--mode engine|oneshot]

Each subcommand runs the same `repro.pipeline.Pipeline` up to a stage:
``profile`` stops after ``energy_model`` (per-layer stats + energy shares —
a profiling report), ``compress`` after ``schedule``, ``export`` after
``export``, and ``serve`` runs everything. ``--plan-in`` resumes a saved
`CompressionPlan` (completed stages are skipped); ``--plan-out`` saves the
resulting plan as ``BASE.json`` + ``BASE.npz``.

This module imports **no stage code at parse time** — ``repro --help`` (and
the argparse error paths) never touch jax. Stage modules load lazily inside
`_execute` once a subcommand actually runs.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

# subcommand -> last pipeline stage it runs (see repro.pipeline.schema.STAGES)
COMMAND_STAGE = {
    "profile": "energy_model",
    "compress": "schedule",
    "export": "export",
    "serve": "serve",
}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro",
        description="Energy-aware layer-wise compression pipeline "
                    "(profile -> energy_model -> schedule -> export -> "
                    "serve) over one CompressionPlan artifact.")
    sub = ap.add_subparsers(dest="command", required=True)
    for command, stage in COMMAND_STAGE.items():
        p = sub.add_parser(
            command,
            help=f"run the pipeline through its '{stage}' stage")
        p.add_argument("--config", default=None, metavar="JSON",
                       help="PipelineConfig JSON file (see docs/pipeline.md)")
        p.add_argument("--target", choices=("cnn", "lm", "moe", "scan"),
                       default=None,
                       help="target kind when building a config from flags "
                            "(moe/scan: routing-aware LM targets)")
        p.add_argument("--arch", default=None,
                       help="cnn: lenet5|resnet8|resnet20|resnet50; "
                            "lm/moe/scan: repro.configs arch id "
                            "(e.g. olmo-1b, phi3.5-moe-42b-a6.6b, "
                            "mamba2-1.3b)")
        p.add_argument("--reduced", action="store_true",
                       help="CPU-smoke preset (tiny budgets; lm: scaled-down "
                            "config)")
        p.add_argument("--steps", type=int, default=None,
                       help="override train.qat_steps")
        p.add_argument("--search-mode", choices=("batched", "serial"),
                       default=None, help="override schedule.search_mode")
        p.add_argument("--compress-k", type=int, default=None,
                       help="lm: restrict every eligible matmul to a "
                            "k-value codebook")
        p.add_argument("--seed", type=int, default=None,
                       help="override target.seed")
        p.add_argument("--plan-in", default=None, metavar="BASE",
                       help="resume from a saved plan (BASE.json + BASE.npz)")
        p.add_argument("--plan-out", default=None, metavar="BASE",
                       help="save the resulting plan to BASE.json + BASE.npz")
        p.add_argument("--verify-cosim", action="store_true",
                       help="gate the profiler's transition histograms "
                            "against the bit-accurate systolic cosim "
                            "(repro.cosim) on the sampled tiles")
        p.add_argument("--quiet", action="store_true",
                       help="suppress per-stage progress output")
        if command == "serve":
            p.add_argument("--mode", choices=("engine", "oneshot"),
                           default=None, help="override serve.mode")
            p.add_argument("--requests", type=int, default=None)
            p.add_argument("--prompt-len", type=int, default=None)
            p.add_argument("--new-tokens", type=int, default=None)
            p.add_argument("--mixed", action=argparse.BooleanOptionalAction,
                           default=None,
                           help="vary request lengths across buckets")
            p.add_argument("--max-batch", type=int, default=None,
                           help="engine wave width")
            p.add_argument("--temperature", type=float, default=None)
            p.add_argument("--verify-oneshot", action="store_true",
                           default=None,
                           help="cross-check engine tokens vs the oneshot "
                                "fallback")
            p.add_argument("--plans", nargs="+", default=None,
                           metavar="SPEC",
                           help="fleet serving: resident plan variants "
                                "routed across by load/budget. Each SPEC is "
                                "'base', 'k<N>[m<M>]' (k-value codebook + "
                                "MSR bits), or a saved CompressionPlan "
                                "base path")
            p.add_argument("--plans-dir", default=None, metavar="DIR",
                           help="fleet serving: load every saved "
                                "CompressionPlan under DIR as a resident "
                                "variant")
    return ap


def _serve_overrides(args) -> dict:
    fields = {
        "mode": getattr(args, "mode", None),
        "compress_k": args.compress_k,
        "requests": getattr(args, "requests", None),
        "prompt_len": getattr(args, "prompt_len", None),
        "new_tokens": getattr(args, "new_tokens", None),
        "mixed": getattr(args, "mixed", None),
        "max_batch": getattr(args, "max_batch", None),
        "temperature": getattr(args, "temperature", None),
        "verify_oneshot": getattr(args, "verify_oneshot", None),
        "plans": (tuple(args.plans)
                  if getattr(args, "plans", None) else None),
        "plans_dir": getattr(args, "plans_dir", None),
    }
    return {k: v for k, v in fields.items() if v is not None}


def _build_config(args):
    """Resolve the PipelineConfig from --config / presets / flag overrides.

    Imported lazily: this is the first point that touches jax."""
    from repro.pipeline.config import (
        PipelineConfig,
        reduced_cnn_config,
        reduced_lm_config,
        reduced_moe_config,
        reduced_scan_config,
    )

    kind = args.target
    if kind is None and (args.compress_k or getattr(args, "plans", None)
                         or getattr(args, "plans_dir", None)):
        kind = "lm"  # codebook restriction / fleet serving are LM schedules
    if args.config:
        cfg = PipelineConfig.load(args.config)
    elif args.reduced:
        if kind == "moe":
            cfg = reduced_moe_config(args.arch or "phi3.5-moe-42b-a6.6b")
        elif kind == "scan":
            cfg = reduced_scan_config(args.arch or "mamba2-1.3b")
        elif kind == "lm":
            cfg = reduced_lm_config(args.arch or "olmo-1b")
        else:
            cfg = reduced_cnn_config()
    else:
        cfg = PipelineConfig()

    overrides: dict = {}
    target_over = {}
    if kind:
        target_over["kind"] = kind
    if args.arch:
        target_over["arch"] = args.arch
    if args.seed is not None:
        target_over["seed"] = args.seed
    if target_over:
        overrides["target"] = target_over
    if args.steps is not None:
        overrides["train"] = {"qat_steps": args.steps}
    if args.search_mode is not None:
        overrides["schedule"] = {"search_mode": args.search_mode}
    if getattr(args, "verify_cosim", False):
        overrides["profile"] = {"verify_cosim": True}
    serve_over = _serve_overrides(args)
    if serve_over:
        overrides["serve"] = serve_over
    return cfg.with_overrides(overrides)


def _execute(args) -> int:
    from repro.pipeline.pipeline import Pipeline
    from repro.pipeline.plan import CompressionPlan

    verbose = not args.quiet
    if args.plan_in:
        plan = CompressionPlan.load(args.plan_in)
        pipe = Pipeline.from_plan(plan)
        # CLI flags still override the embedded config for the stages that
        # remain to run (e.g. `repro serve --plan-in p --mode oneshot`);
        # target identity is fixed by the plan and cannot be overridden.
        over: dict = {}
        if args.steps is not None:
            over["train"] = {"qat_steps": args.steps}
        if args.search_mode is not None:
            over["schedule"] = {"search_mode": args.search_mode}
        if getattr(args, "verify_cosim", False):
            over["profile"] = {"verify_cosim": True}
        serve_over = _serve_overrides(args)
        if serve_over:
            over["serve"] = serve_over
        if over:
            pipe.cfg = pipe.cfg.with_overrides(over)
    else:
        pipe = Pipeline(_build_config(args))

    plan = pipe.run_until(COMMAND_STAGE[args.command], verbose=verbose)
    print(json.dumps(plan.summary(), indent=2))
    if args.plan_out:
        json_path, npz_path = plan.save(args.plan_out)
        print(f"plan saved: {json_path} + {npz_path}")
    return 0


def main(argv: Optional[list] = None) -> int:
    args = build_parser().parse_args(argv)
    return _execute(args)


if __name__ == "__main__":
    sys.exit(main())
