"""Bit-level primitives for the processing-element co-simulator.

Everything here is deliberately *independent* of the `transition_energy`
kernel and of `core.bitops`: no `jax.lax.clz`, no
`jax.lax.population_count`, no shared helpers. Popcount and MSB position
are computed as explicit 22-term bit sums, so a bug in the XLA intrinsic
lowering (or in our use of it) cannot cancel out between the kernel and
this reference. The only shared artifacts are the published constants of
the grouping spec (22-bit accumulator, 10 MSB groups, 5 Hamming
subgroups) from the paper's Sec. 3.1.1.
"""

from __future__ import annotations

import jax.numpy as jnp

PSUM_BITS = 22
MASK22 = (1 << PSUM_BITS) - 1
N_MSB_GROUPS = 10
N_HD_SUBGROUPS = 5
N_GROUPS = N_MSB_GROUPS * N_HD_SUBGROUPS


def bits22(x):
    """The 22-bit accumulator view of an int32 partial sum (two's complement
    truncation, always non-negative)."""
    return jnp.asarray(x, jnp.int32) & MASK22


def ref_popcount22(x):
    """Hamming weight of the 22-bit view, as a sum of 22 single-bit tests."""
    v = bits22(x)
    total = jnp.zeros_like(v)
    for b in range(PSUM_BITS):
        total = total + ((v >> b) & 1)
    return total


def ref_msb_val22(x):
    """1-based index of the highest set bit of the 22-bit view; 0 when the
    masked value is zero.  Computed as ``sum_b [v >= 2^b]`` — a monotone
    threshold count, no count-leading-zeros anywhere."""
    v = bits22(x)
    total = jnp.zeros_like(v)
    for b in range(PSUM_BITS):
        total = total + (v >= (1 << b)).astype(jnp.int32)
    return total


def ref_group_id(p):
    """Energy-group id (0..49) of one partial-sum value: coarse MSB group
    times 5 plus Hamming-weight subgroup.  Mirrors the spec in
    docs/energy_model.md; shares no code with the kernel's `_group_id`."""
    msb_val = ref_msb_val22(p)                       # 0..22
    mg = jnp.minimum(msb_val * N_MSB_GROUPS // (PSUM_BITS + 1),
                     N_MSB_GROUPS - 1)
    hw = ref_popcount22(p)                           # 0..22
    hg = jnp.minimum(hw * N_HD_SUBGROUPS // (PSUM_BITS + 1),
                     N_HD_SUBGROUPS - 1)
    return mg * N_HD_SUBGROUPS + hg
