"""Differential verification: transition-energy kernel vs. the cosim.

`verify_tiles` gates one tile batch; `verify_runner_profile` replays the
profiler's exact per-layer tile sampling (same crc32-derived PRNG keys,
same `pad_to_tiles`/`gather_layer_tiles` path) on a trained runner and
gates every layer. Both return plain-dict machine-readable summaries —
the shape `tools/check_gates.py --cosim` and the pipeline's
``--verify-cosim`` pass consume.

Exactness: the kernel accumulates its (50, 50) group histogram in float32
(one-hot matmuls). float32 holds integers exactly below 2**24, so the
comparison against the cosim's integer histogram is exact as long as no
single bin exceeds 16.7M counts. A per-layer batch at the profiler's
defaults (<= 48 tiles x 64*64 MACs x 63 transitions ~= 12.4M transitions
TOTAL) stays under that bound even if every transition landed in one bin;
`verify_tiles` checks the bound and reports ``exactness_ok`` rather than
silently comparing rounded floats.
"""

from __future__ import annotations

import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mac_model import DEFAULT_COEFFS, MacEnergyCoeffs
from repro.core.stats import TILE, pad_to_tiles
from repro.cosim.systolic import cosim_batched_stats

_F32_EXACT = 2 ** 24

__all__ = ["verify_tiles", "verify_runner_profile"]


def verify_tiles(
    w_tiles: jax.Array,
    a_blocks: jax.Array,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    *,
    mask: Optional[jax.Array] = None,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
    chunk: int = 8,
) -> dict:
    """Compare the kernel's transition histogram with the cosim's, exactly.

    ``use_kernel=True`` gates the Pallas kernel (interpret mode off-TPU);
    ``use_kernel=False`` gates the vectorized jnp oracle instead — both
    must reproduce the cosim's integer counts bin for bin.
    """
    from repro.core.profiler import batched_layer_stats

    n_tiles = int(w_tiles.shape[0])
    t_len = int(a_blocks.shape[2])
    _, _, kernel_hist, _ = batched_layer_stats(
        w_tiles, a_blocks, coeffs, mask=mask, use_kernel=use_kernel,
        interpret=interpret)
    cosim_hist, toggles = cosim_batched_stats(
        w_tiles, a_blocks, mask=mask, chunk=chunk)

    kh = np.asarray(kernel_hist, np.float64)
    diff = np.abs(kh - cosim_hist.astype(np.float64))
    n_masked = n_tiles if mask is None else int(np.sum(np.asarray(mask) != 0))
    total = n_masked * int(w_tiles.shape[1]) * int(w_tiles.shape[2]) \
        * (t_len - 1)
    return {
        "n_tiles": n_masked,
        "n_transitions": total,
        "match": bool(diff.max() == 0.0) if diff.size else True,
        "max_abs_diff": float(diff.max()),
        "kernel_total": float(kh.sum()),
        "cosim_total": int(cosim_hist.sum()),
        "toggles": toggles,
        "exactness_ok": bool(total < _F32_EXACT),
    }


def verify_runner_profile(
    runner,
    params,
    state,
    comp,
    *,
    n_batches: int = 1,
    max_tiles: int = 16,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
    chunk: int = 8,
) -> dict:
    """Replay `CnnRunner.profile`'s sampling and cosim-gate every layer.

    Uses the identical per-layer PRNG key (`crc32(name)`), padding, and
    tile gather as the profiler, so the gated tiles are exactly the tiles
    the production statistics came from.
    """
    from repro.core.profiler import gather_layer_tiles

    taps = runner.capture_taps(params, state, comp, n_batches)
    layers = {}
    for cl in runner.model.comp_layers:
        w_mat, x_col = runner.layer_trace_inputs(cl, taps[cl.name])
        w_pad, x_pad = pad_to_tiles(jnp.asarray(w_mat, jnp.int32),
                                    jnp.asarray(x_col, jnp.int32))
        total_tiles = (w_pad.shape[0] // TILE) * (w_pad.shape[1] // TILE) \
            * (x_pad.shape[1] // TILE)
        n_sample = min(max_tiles, total_tiles)
        key = jax.random.PRNGKey(zlib.crc32(cl.name.encode()) % (2 ** 31))
        choice = jax.random.choice(key, total_tiles, (n_sample,),
                                   replace=False)
        w_tiles, a_blocks = gather_layer_tiles(w_pad, x_pad, choice)
        layers[cl.name] = verify_tiles(
            w_tiles, a_blocks, coeffs, use_kernel=use_kernel,
            interpret=interpret, chunk=chunk)

    return {
        "layers": layers,
        "n_layers": len(layers),
        "n_tiles": sum(r["n_tiles"] for r in layers.values()),
        "match": all(r["match"] for r in layers.values()),
        "max_abs_diff": max((r["max_abs_diff"] for r in layers.values()),
                            default=0.0),
        "toggles": sum(r["toggles"] for r in layers.values()),
        "exactness_ok": all(r["exactness_ok"] for r in layers.values()),
    }
