"""Cycle-accurate weight-stationary systolic-array co-simulator.

This is the independent reference the `transition_energy` kernel is gated
against (tools/check_gates.py --cosim, `repro profile --verify-cosim`).
It models the paper's Sec. 3.1.1 array PE by PE and cycle by cycle:

  * weights are stationary: PE(r, c) holds ``w[r, c]``;
  * activations stream in skewed by ``r + c`` cycles, so at cycle ``u``
    PE(r, c) consumes ``a[r, u - r - c]`` (zero outside the stream);
  * each cycle a PE adds its product to the partial sum arriving from the
    PE above and latches the result:
    ``reg[r, c](u + 1) = reg[r - 1, c](u) + w[r, c] * a[r, u - r - c]``.

By induction PE(r, c)'s register holds the exact prefix sum
``S[r, c, t] = sum_{r' <= r} w[r', c] * a[r', t]`` at cycle
``r + c + t + 1``, i.e. the skewed cycle trace visits exactly the T values
of the unskewed prefix-sum trace, in t-order, per PE. The statistics are
therefore comparable 1:1 with the kernel's (which computes the unskewed
trace directly): per PE there are ``T - 1`` accumulator-register
transitions, each classified into one of the 50x50 (MSB group, Hamming
subgroup) transition pairs.

Everything downstream of the trace uses the independent bit primitives of
`repro.cosim.pe` (explicit 22-term bit sums, integer scatter-add
histograms) — no code shared with the kernel, the oracle, or
`core.bitops`/`core.grouping`.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cosim.pe import MASK22, N_GROUPS, bits22, ref_group_id, \
    ref_popcount22

__all__ = [
    "pe_array_trace",
    "tile_cosim_stats",
    "cosim_batched_stats",
]


def pe_array_trace(w_tile: jax.Array, a_block: jax.Array) -> jax.Array:
    """Run the array cycle by cycle; return per-PE partial-sum sequences.

    Args:
      w_tile: (K, M) int weights, stationary (row r feeds activation r).
      a_block: (K, T) int activation stream, T output elements.

    Returns:
      (K, M, T) int32 — the exact accumulator value PE(r, c) latches for
      output element t (extracted from the cycle-indexed register history
      at cycle ``r + c + t + 1``). Apply ``pe.bits22`` for the 22-bit
      hardware register view.
    """
    w = jnp.asarray(w_tile, jnp.int32)
    a = jnp.asarray(a_block, jnp.int32)
    k_dim, m_dim = w.shape
    k2, t_len = a.shape
    assert k_dim == k2, (w.shape, a.shape)

    rows = jnp.arange(k_dim)[:, None]                      # (K, 1)
    cols = jnp.arange(m_dim)[None, :]                      # (1, M)
    n_cycles = k_dim + m_dim + t_len - 2

    def step(reg, u):
        # activation entering PE(r, c) this cycle (skew r + c)
        t_idx = u - rows - cols                            # (K, M)
        valid = (t_idx >= 0) & (t_idx < t_len)
        a_in = jnp.where(
            valid,
            a[jnp.broadcast_to(rows, (k_dim, m_dim)),
              jnp.clip(t_idx, 0, t_len - 1)],
            0)
        # partial sum handed down from the PE above (row 0 receives 0)
        from_above = jnp.concatenate(
            [jnp.zeros((1, m_dim), jnp.int32), reg[:-1]], axis=0)
        new = from_above + w * a_in
        return new, new

    _, reg_hist = jax.lax.scan(step, jnp.zeros((k_dim, m_dim), jnp.int32),
                               jnp.arange(n_cycles))
    # reg_hist[u] = register state after cycle u; PE(r, c) holds S[r, c, t]
    # at cycle r + c + t + 1, i.e. reg_hist[r + c + t].
    r_i = jnp.arange(k_dim)[:, None, None]
    c_i = jnp.arange(m_dim)[None, :, None]
    t_i = jnp.arange(t_len)[None, None, :]
    return reg_hist[r_i + c_i + t_i, r_i, c_i]             # (K, M, T)


def tile_cosim_stats(
    w_tile: jax.Array, a_block: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Bit-accurate per-tile statistics from the cycle trace.

    Returns:
      group_hist: (50, 50) int32 — count of accumulator transitions from
        group ``g_prev`` to ``g_cur`` (integer scatter-add, exact).
      toggles: () int32 — total bit flips of the 22-bit accumulator
        registers across all transitions (sum of XOR popcounts).
    """
    psums = pe_array_trace(w_tile, a_block)
    g = ref_group_id(psums)                                # (K, M, T)
    codes = (g[..., :-1] * N_GROUPS + g[..., 1:]).reshape(-1)
    group_hist = jnp.zeros((N_GROUPS * N_GROUPS,), jnp.int32
                           ).at[codes].add(1).reshape(N_GROUPS, N_GROUPS)
    flipped = bits22(psums[..., :-1]) ^ bits22(psums[..., 1:])
    toggles = jnp.sum(ref_popcount22(flipped))
    return group_hist, toggles


@jax.jit
def _chunk_stats(w_tiles, a_blocks, mask):
    hists, toggles = jax.vmap(tile_cosim_stats)(w_tiles, a_blocks)
    m = jnp.asarray(mask != 0, jnp.int32)
    return (jnp.sum(hists * m[:, None, None], axis=0),
            jnp.sum(toggles * m))


def cosim_batched_stats(
    w_tiles: jax.Array,
    a_blocks: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    chunk: int = 8,
) -> Tuple[np.ndarray, int]:
    """Co-simulate a tile batch; sum masked per-tile statistics.

    Mirrors `profiler.batched_layer_stats` semantics: zero-padded MACs
    inside a tile count (the padded PE still clocks), tiles with
    ``mask == 0`` contribute nothing. The batch is traced in chunks of
    ``chunk`` tiles to bound the live register-history buffer
    (one (K+M+T-2, K, M) int32 array per in-flight tile, ~3 MiB at 64^3),
    and accumulated on the host in int64 — no float anywhere.

    Returns ``(group_hist (50, 50) np.int64, toggles int)``.
    """
    n = w_tiles.shape[0]
    if mask is None:
        mask = jnp.ones((n,), jnp.int32)
    hist = np.zeros((N_GROUPS, N_GROUPS), np.int64)
    toggles = 0
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        h, t = _chunk_stats(w_tiles[lo:hi], a_blocks[lo:hi], mask[lo:hi])
        hist += np.asarray(h, np.int64)
        toggles += int(t)
    return hist, toggles
