"""Bit-accurate systolic-array co-simulation oracle.

An independent reference implementation of the paper's Sec. 3.1.1
weight-stationary PE array: cycle-accurate partial-sum register traces,
integer-only transition histograms and toggle counts, built on bit
primitives that share no code with the `transition_energy` kernel or the
jnp oracle. See docs/cosim.md.
"""

from repro.cosim.pe import (
    MASK22,
    N_GROUPS,
    N_HD_SUBGROUPS,
    N_MSB_GROUPS,
    PSUM_BITS,
    bits22,
    ref_group_id,
    ref_msb_val22,
    ref_popcount22,
)
from repro.cosim.systolic import (
    cosim_batched_stats,
    pe_array_trace,
    tile_cosim_stats,
)
from repro.cosim.verify import verify_runner_profile, verify_tiles

__all__ = [
    "MASK22",
    "N_GROUPS",
    "N_HD_SUBGROUPS",
    "N_MSB_GROUPS",
    "PSUM_BITS",
    "bits22",
    "ref_group_id",
    "ref_msb_val22",
    "ref_popcount22",
    "pe_array_trace",
    "tile_cosim_stats",
    "cosim_batched_stats",
    "verify_tiles",
    "verify_runner_profile",
]
