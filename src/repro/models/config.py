"""ArchConfig: a single declarative description covering all assigned archs.

Frozen/hashable so it can ride through jit static args. The `pattern` tuple
is cycled over layers: e.g. gemma3's 5:1 local:global is
``("local",)*5 + ("attn",)``; Griffin's 2:1 recurrent:attention is
``("rglru", "rglru", "local")``; Mamba-2 is ``("ssm",)``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.nn.attention import AttnDims
from repro.nn.moe import MoEDims
from repro.nn.rglru import RGLRUDims
from repro.nn.ssm import SSMDims

VOCAB_PAD = 256  # pad vocab to a multiple (shardable over the model axis)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                  # for "local" blocks
    qkv_bias: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm | nonparam_ln
    ffn: str = "swiglu"              # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    rope_theta_local: Optional[float] = None
    embed_scale: bool = False        # multiply embeddings by sqrt(d_model)
    tie_embeddings: bool = True
    attn_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_d_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # RG-LRU
    rnn_width: int = 0
    # enc-dec (whisper)
    encoder_decoder: bool = False
    n_enc_layers: int = 0
    # multimodal stub prefix (internvl2 patches / whisper frames are inputs)
    prefix_len: int = 0
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # ------------------------------------------------------------ derived

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // VOCAB_PAD) * VOCAB_PAD

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_types(self) -> Tuple[str, ...]:
        """Block type of every layer (pattern cycled)."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def attn_dims(self, local: bool) -> AttnDims:
        theta = self.rope_theta
        if local and self.rope_theta_local is not None:
            theta = self.rope_theta_local
        return AttnDims(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.resolved_head_dim,
            qkv_bias=self.qkv_bias,
            rope_theta=theta,
            window=self.window if local else 0,
            causal=True,
            softcap=self.attn_softcap,
        )

    def enc_attn_dims(self) -> AttnDims:
        d = self.attn_dims(local=False)
        return dataclasses.replace(d, causal=False, rope_theta=0.0)

    def moe_dims(self) -> MoEDims:
        return MoEDims(
            d_model=self.d_model,
            n_experts=self.n_experts,
            top_k=self.moe_top_k,
            d_ff=self.moe_d_ff,
            n_shared=self.n_shared_experts,
            capacity_factor=self.capacity_factor,
            ffn=self.ffn,
        )

    def ssm_dims(self) -> SSMDims:
        return SSMDims(
            d_model=self.d_model,
            d_state=self.ssm_d_state,
            head_dim=self.ssm_head_dim,
            expand=self.ssm_expand,
            chunk=self.ssm_chunk,
        )

    def rglru_dims(self) -> RGLRUDims:
        return RGLRUDims(d_model=self.d_model, d_rnn=self.rnn_width or self.d_model)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def scaled_down(self, **overrides) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        scale = {
            "n_layers": min(self.n_layers, 2 * max(1, len(self.pattern))),
            "d_model": 128,
            "n_heads": max(2, min(self.n_heads, 4)),
            "n_kv_heads": max(1, min(self.n_kv_heads, 2)),
            "head_dim": 32,
            "d_ff": 256,
            "vocab": 512,
            "window": min(self.window, 64) if self.window else 0,
            "rnn_width": 128 if self.rnn_width else 0,
            "ssm_d_state": 32 if self.ssm_d_state else 0,
            "ssm_head_dim": 32,
            "ssm_chunk": 32,
            "n_experts": min(self.n_experts, 4),
            "moe_top_k": min(self.moe_top_k, 2),
            "moe_d_ff": 64 if self.moe_d_ff else 0,
            "n_shared_experts": min(self.n_shared_experts, 1),
            "n_enc_layers": min(self.n_enc_layers, 2),
            "prefix_len": min(self.prefix_len, 8),
            "compute_dtype": "float32",
        }
        scale.update(overrides)
        return dataclasses.replace(self, **scale)


def model_param_count(cfg: ArchConfig) -> int:
    """Approximate parameter count (used for MODEL_FLOPS roofline term)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    dense_ffn = d * cfg.d_ff * (3 if cfg.ffn in ("swiglu", "geglu") else 2)
    moe_ffn = cfg.n_experts * d * cfg.moe_d_ff * 3 + d * cfg.n_experts
    moe_ffn += cfg.n_shared_experts * d * cfg.moe_d_ff * 3
    ssm = 0
    if cfg.ssm_d_state:
        sd = cfg.ssm_dims()
        ssm = d * (2 * sd.d_inner + 2 * sd.n_groups * sd.d_state + sd.n_heads)
        ssm += sd.d_inner * d
    rglru = 0
    if cfg.rnn_width:
        r = cfg.rnn_width
        rglru = 2 * d * r + 2 * r * r + r * d

    total = 0
    for lt in cfg.layer_types():
        if lt in ("attn", "local"):
            total += attn + (moe_ffn if cfg.is_moe else dense_ffn)
        elif lt == "rglru":
            total += rglru + dense_ffn
        elif lt == "ssm":
            total += ssm
    if cfg.encoder_decoder:
        # encoder layers: attn + ffn; decoder cross-attn extra
        total += cfg.n_enc_layers * (attn + dense_ffn)
        total += cfg.n_layers * attn  # cross attention
    total += cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    return total


def active_param_count(cfg: ArchConfig) -> int:
    """Active (per-token) parameters — MoE counts top_k + shared experts."""
    if not cfg.is_moe:
        return model_param_count(cfg)
    d = cfg.d_model
    full = model_param_count(cfg)
    moe_total = cfg.n_layers * cfg.n_experts * d * cfg.moe_d_ff * 3
    moe_active = cfg.n_layers * cfg.moe_top_k * d * cfg.moe_d_ff * 3
    return full - moe_total + moe_active
