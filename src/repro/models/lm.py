"""LM assembly: ArchConfig -> spec tree + forward / prefill / decode.

Layers are grouped by the config's repeating block `pattern`; each pattern
position's parameters are stacked over the repeat count and iterated with
`jax.lax.scan` (keeps HLO size O(pattern) instead of O(n_layers), which is
what makes 512-device SPMD lowering of 26-48 layer models tractable).
Remainder layers (n_layers % len(pattern)) are unrolled as "tail" blocks.

Whisper-style encoder-decoder stacks an extra (non-causal, no-RoPE) encoder
scan and gives decoder blocks cross-attention; VLM (internvl2) prepends stub
patch embeddings to the token embeddings (the frontend is an input, per the
assignment).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.nn import transformer as T
from repro.nn.layers import QuantConfig
from repro.nn.spec import ParamSpec, normal_init, stack_specs
from repro.nn.transformer import (
    apply_block,
    apply_block_chunk,
    apply_block_decode,
    block_cache_spec,
    make_block_spec,
)

NEG_INF = -1e30


@jax.custom_vjp
def _carry_barrier(h):
    """`optimization_barrier` with a differentiation rule.

    `jax.lax.optimization_barrier` has no VJP, so placing it on the scan
    carry broke every grad step. The barrier semantics (don't let XLA hoist
    dtype converts of the remat-saved carry stack out of the backward loop)
    matter in both directions, so forward and cotangent each get their own
    barrier while the math stays identity."""
    return jax.lax.optimization_barrier(h)


def _carry_barrier_fwd(h):
    return jax.lax.optimization_barrier(h), None


def _carry_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_carry_barrier.defvjp(_carry_barrier_fwd, _carry_barrier_bwd)


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """(B, S) int positions -> (B, S, d) sinusoidal embeddings (whisper)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


@dataclasses.dataclass
class LMModel:
    cfg: ArchConfig
    spec: dict

    # ------------------------------------------------------------ structure

    @property
    def n_pattern(self) -> int:
        return len(self.cfg.pattern)

    @property
    def n_rep(self) -> int:
        return self.cfg.n_layers // self.n_pattern

    @property
    def n_tail(self) -> int:
        return self.cfg.n_layers % self.n_pattern

    # ------------------------------------------------------------- encoder

    def _encode(self, params, enc_embeds, *, qcfg, comp, remat, q_block, kv_block,
                shard=None):
        cfg = self.cfg
        x = enc_embeds.astype(cfg.cdtype)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
        if shard is not None:
            x = shard(x)
        enc_comp = None if comp is None else comp.get("enc_blocks")

        def body(carry, xs):
            layer_params, layer_comp = xs if enc_comp is not None else (xs, None)
            h, _ = apply_block(layer_params, carry, cfg, "attn", positions=pos,
                               qcfg=qcfg, comp=layer_comp, q_block=q_block,
                               kv_block=kv_block, encoder=True)
            if shard is not None:
                h = shard(h)
            return h, None

        if remat:
            body = jax.checkpoint(body)
        xs = (params["enc_blocks"], enc_comp) if enc_comp is not None \
            else params["enc_blocks"]
        x, _ = jax.lax.scan(body, x, xs)
        return T.apply_norm(params["enc_norm"], x, cfg)

    # ------------------------------------------------------------- forward

    def forward(
        self,
        params,
        tokens: jax.Array,                      # (B, S) int32
        *,
        prefix_embeds: Optional[jax.Array] = None,   # (B, P, d) stub frontend
        enc_embeds: Optional[jax.Array] = None,      # (B, S_enc, d) whisper frames
        qcfg: QuantConfig = QuantConfig.off(),
        comp=None,
        remat: bool = False,
        q_block: int = 512,
        kv_block: int = 512,
        shard: Optional[Callable] = None,
        shard_logits: Optional[Callable] = None,
        use_flash: bool = False,
        remat_policy: Optional[str] = None,   # None | "save_qat"
    ) -> Tuple[jax.Array, dict]:
        """Returns (logits (B, S_total, padded_vocab), aux)."""
        cfg = self.cfg
        b, s_tok = tokens.shape
        x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.cdtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x], axis=1)
        if cfg.encoder_decoder:
            pos_ids = jnp.broadcast_to(
                jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])
            x = x + _sinusoid(pos_ids, cfg.d_model).astype(x.dtype)
        if shard is not None:
            x = shard(x)
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        enc_out = None
        if cfg.encoder_decoder:
            assert enc_embeds is not None
            enc_out = self._encode(params, enc_embeds, qcfg=qcfg, comp=comp,
                                   remat=remat, q_block=q_block,
                                   kv_block=kv_block, shard=shard)

        aux0 = {"lb_loss": jnp.zeros((), jnp.float32),
                "z_loss": jnp.zeros((), jnp.float32)}
        blocks_comp = None if comp is None else comp.get("blocks")
        tail_comp = None if comp is None else comp.get("tail")

        def macro_body(carry, xs):
            layer_params, layer_comp = xs if blocks_comp is not None else (xs, None)
            h, aux_c = carry
            # Barrier: stops XLA from hoisting the bf16->f32 convert of the
            # rematerialization-saved carry *stack* out of the backward loop
            # (which would materialize an O(L*B*S*D) f32 buffer).
            h = _carry_barrier(h)
            aux_new = dict(aux_c)
            for i, bt in enumerate(cfg.pattern):
                ci = None if layer_comp is None else layer_comp.get(f"g{i}")
                h, aux = apply_block(
                    layer_params[f"g{i}"], h, cfg, bt, positions=positions,
                    qcfg=qcfg, comp=ci, enc_out=enc_out,
                    q_block=q_block, kv_block=kv_block, use_flash=use_flash)
                aux_new = {k: aux_new[k] + aux[k] for k in aux_new}
            if shard is not None:
                h = shard(h)
            return (h, aux_new), None

        if remat and remat_policy == "save_qat":
            policy = jax.checkpoint_policies.save_only_these_names("qat_weights")
            body = jax.checkpoint(macro_body, policy=policy)
        elif remat:
            body = jax.checkpoint(macro_body)
        else:
            body = macro_body
        if self.n_rep > 0:
            xs = (params["blocks"], blocks_comp) if blocks_comp is not None \
                else params["blocks"]
            (x, aux), _ = jax.lax.scan(body, (x, aux0), xs)
        else:
            aux = aux0
        for j in range(self.n_tail):
            bt = cfg.pattern[j]
            cj = None if tail_comp is None else tail_comp.get(f"t{j}")
            x, a = apply_block(params["tail"][f"t{j}"], x, cfg, bt,
                               positions=positions, qcfg=qcfg, comp=cj,
                               enc_out=enc_out, q_block=q_block,
                               kv_block=kv_block, use_flash=use_flash)
            aux = {k: aux[k] + a[k] for k in aux}

        x = T.apply_norm(params["final_norm"], x, cfg)
        logits = self._unembed(params, x, shard_logits)
        return logits, aux

    def _unembed(self, params, x, shard_logits=None):
        cfg = self.cfg
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x,
                                params["embed"]["table"].astype(x.dtype))
        else:
            logits = jnp.einsum("bsd,dv->bsv", x,
                                params["lm_head"]["w"].astype(x.dtype))
        # mask the vocab padding
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, NEG_INF, logits.astype(jnp.float32))
        if shard_logits is not None:
            logits = shard_logits(logits)
        return logits

    # ---------------------------------------------------------------- loss

    def loss(self, params, batch: Dict[str, jax.Array], **fwd_kwargs):
        """Causal LM loss. batch: tokens, labels (+prefix/enc embeds)."""
        logits, aux = self.forward(
            params, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            **fwd_kwargs)
        labels = batch["labels"]
        # with a prefix, loss applies to the trailing token positions only
        logits_tok = logits[:, logits.shape[1] - labels.shape[1]:]
        logp = jax.nn.log_softmax(logits_tok, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is not None:
            loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        else:
            loss = jnp.mean(nll)
        total = loss + 0.01 * aux["lb_loss"] + 1e-3 * aux["z_loss"]
        metrics = {"ce": loss, "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"]}
        return total, metrics

    # --------------------------------------------------------------- caches

    def cache_spec(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   cross_len: int = 0) -> dict:
        cfg = self.cfg
        spec: Dict[str, Any] = {"groups": {}, "tail": {}}
        for i, bt in enumerate(cfg.pattern):
            one = block_cache_spec(cfg, bt, batch, max_len, dtype,
                                   cross_len=cross_len)
            spec["groups"][f"g{i}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((self.n_rep, *s.shape), s.dtype),
                one)
        for j in range(self.n_tail):
            bt = cfg.pattern[j]
            spec["tail"][f"t{j}"] = block_cache_spec(
                cfg, bt, batch, max_len, dtype, cross_len=cross_len)
        # per-sequence positions: rows of one batch may sit at different
        # depths (slot-level continuous batching refills rows mid-flight)
        spec["pos"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
        return spec

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   cross_len: int = 0) -> dict:
        spec = self.cache_spec(batch, max_len, dtype, cross_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)

    # --------------------------------------------------------------- decode

    def decode_step(
        self,
        params,
        cache: dict,
        tokens: jax.Array,          # (B, 1) int32
        *,
        qcfg: QuantConfig = QuantConfig.off(),
        comp=None,
        shard: Optional[Callable] = None,
        shard_logits: Optional[Callable] = None,
        active: Optional[jax.Array] = None,   # (B,) bool; None = all rows
    ) -> Tuple[jax.Array, dict]:
        """One token for every sequence in the batch. Returns (logits, cache).

        ``cache["pos"]`` is per-sequence (B,). With ``active`` given, rows
        where it is False keep their cache and position untouched (their
        logits are garbage and must be ignored) — this is what lets a slot
        group decode while some slots are empty or mid-prefill.
        """
        cfg = self.cfg
        pos = cache["pos"]
        x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.cdtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)
        if cfg.encoder_decoder:
            pos_ids = (pos.astype(jnp.int32)[:, None] if jnp.ndim(pos)
                       else jnp.broadcast_to(pos.astype(jnp.int32), x.shape[:2]))
            x = x + _sinusoid(pos_ids, cfg.d_model).astype(x.dtype)
        if shard is not None:
            x = shard(x)

        blocks_comp = None if comp is None else comp.get("blocks")
        tail_comp = None if comp is None else comp.get("tail")

        def macro_body(carry, xs):
            h = carry
            if blocks_comp is not None:
                layer_params, layer_cache, layer_comp = xs
            else:
                (layer_params, layer_cache), layer_comp = xs, None
            new_caches = {}
            for i, bt in enumerate(cfg.pattern):
                ci = None if layer_comp is None else layer_comp.get(f"g{i}")
                h, c_new = apply_block_decode(
                    layer_params[f"g{i}"], h, layer_cache[f"g{i}"], pos, cfg,
                    bt, qcfg=qcfg, comp=ci)
                new_caches[f"g{i}"] = c_new
            return h, new_caches

        new_cache = {"groups": cache["groups"], "tail": {}, "pos": pos + 1}
        if self.n_rep > 0:
            xs = (params["blocks"], cache["groups"])
            if blocks_comp is not None:
                xs = (params["blocks"], cache["groups"], blocks_comp)
            x, group_caches = jax.lax.scan(macro_body, x, xs)
            new_cache["groups"] = group_caches
        for j in range(self.n_tail):
            bt = cfg.pattern[j]
            cj = None if tail_comp is None else tail_comp.get(f"t{j}")
            x, c_new = apply_block_decode(
                params["tail"][f"t{j}"], x, cache["tail"][f"t{j}"], pos, cfg,
                bt, qcfg=qcfg, comp=cj)
            new_cache["tail"][f"t{j}"] = c_new

        if active is not None:
            new_cache = self._merge_active(cache, new_cache, active)

        x = T.apply_norm(params["final_norm"], x, cfg)
        logits = self._unembed(params, x, shard_logits)
        return logits, new_cache

    @staticmethod
    def _merge_active(old_cache: dict, new_cache: dict, active) -> dict:
        """Keep inactive rows' cache untouched. Requires per-row pos (B,).

        `groups` leaves carry a leading layer-stack axis (batch is axis 1);
        `tail` and `pos` leaves have batch leading.
        """
        act = active.astype(bool)

        def merge(axis):
            def f(new, old):
                shape = [1] * new.ndim
                shape[axis] = act.shape[0]
                return jnp.where(act.reshape(shape), new, old)
            return f

        return {
            "groups": jax.tree.map(merge(1), new_cache["groups"],
                                   old_cache["groups"]),
            "tail": jax.tree.map(merge(0), new_cache["tail"],
                                 old_cache["tail"]),
            "pos": jnp.where(act, new_cache["pos"], old_cache["pos"]),
        }

    # --------------------------------------------------------------- prefill

    def prefill(
        self,
        params,
        tokens: jax.Array,
        max_len: int,
        *,
        prefix_embeds: Optional[jax.Array] = None,
        enc_embeds: Optional[jax.Array] = None,
        qcfg: QuantConfig = QuantConfig.off(),
        comp=None,
        cache_dtype=jnp.bfloat16,
        q_block: int = 512,
        kv_block: int = 512,
    ) -> Tuple[jax.Array, dict]:
        """Forward over the prompt, capturing per-layer state into a decode
        cache. Returns (logits (B, S, V), cache ready at pos = S)."""
        cfg = self.cfg
        b, s_tok = tokens.shape
        x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.cdtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x], axis=1)
        if cfg.encoder_decoder:
            pos_ids = jnp.broadcast_to(
                jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])
            x = x + _sinusoid(pos_ids, cfg.d_model).astype(x.dtype)
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        enc_out = None
        if cfg.encoder_decoder:
            assert enc_embeds is not None
            enc_out = self._encode(params, enc_embeds, qcfg=qcfg, comp=comp,
                                   remat=False, q_block=q_block,
                                   kv_block=kv_block)
        cross_len = enc_out.shape[1] if enc_out is not None else 0

        # Blocks run unrolled for prefill (state capture per layer); prefill
        # happens once per request and serve-time models ship a fixed cfg, so
        # the larger HLO is acceptable. (The dry-run decode path uses the
        # scanned decode_step.)
        cache = {"groups": {}, "tail": {},
                 "pos": jnp.full((b,), s, jnp.int32)}
        group_states: Dict[str, list] = {f"g{i}": [] for i in range(self.n_pattern)}
        blocks_comp = None if comp is None else comp.get("blocks")
        tail_comp = None if comp is None else comp.get("tail")

        def run_block(block_params, h, bt, block_comp):
            return apply_block(block_params, h, cfg, bt, positions=positions,
                               qcfg=qcfg, comp=block_comp, enc_out=enc_out,
                               q_block=q_block, kv_block=kv_block,
                               return_state=True)

        for r in range(self.n_rep):
            layer_params = jax.tree.map(lambda p: p[r], params["blocks"])
            layer_comp = None if blocks_comp is None else jax.tree.map(
                lambda c: c[r], blocks_comp)
            for i, bt in enumerate(cfg.pattern):
                ci = None if layer_comp is None else layer_comp.get(f"g{i}")
                (x, _), st = run_block(layer_params[f"g{i}"], x, bt, ci)
                group_states[f"g{i}"].append(
                    self._state_to_cache(st, bt, max_len, cache_dtype, enc_out,
                                         layer_params[f"g{i}"], qcfg, ci))
        for key, sts in group_states.items():
            if sts:
                cache["groups"][key] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *sts)
        for j in range(self.n_tail):
            bt = cfg.pattern[j]
            cj = None if tail_comp is None else tail_comp.get(f"t{j}")
            (x, _), st = run_block(params["tail"][f"t{j}"], x, bt, cj)
            cache["tail"][f"t{j}"] = self._state_to_cache(
                st, bt, max_len, cache_dtype, enc_out,
                params["tail"][f"t{j}"], qcfg, cj)

        x = T.apply_norm(params["final_norm"], x, cfg)
        logits = self._unembed(params, x)
        return logits, cache

    # ------------------------------------------------------- chunked prefill

    def prefill_chunk(
        self,
        params,
        cache: dict,
        tokens: jax.Array,          # (B, C) int32 — one prompt chunk per row
        *,
        start: jax.Array,           # (B,) int32 — first absolute position
        qcfg: QuantConfig = QuantConfig.off(),
        comp=None,
        q_block: int = 8,
        kv_block: int = 8,
        shard: Optional[Callable] = None,
        shard_logits: Optional[Callable] = None,
    ) -> Tuple[jax.Array, dict]:
        """Run one prefill chunk per row against an existing decode cache.

        Row r processes positions ``start[r] .. start[r]+C-1``; the cache
        comes back with ``pos = start + C``. Logits are (B, C, V) — the last
        chunk's final real position seeds the first sampled token. Recurrent
        mixers only support a single chunk from position 0 (their state
        restarts from zero each call); encoder-decoder models have no chunk
        path at all.
        """
        cfg = self.cfg
        if cfg.encoder_decoder:
            raise ValueError("chunked prefill does not support "
                             "encoder-decoder models; use the oneshot path")
        b, c = tokens.shape
        start = jnp.asarray(start, jnp.int32)
        positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.cdtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)
        if shard is not None:
            x = shard(x)

        blocks_comp = None if comp is None else comp.get("blocks")
        tail_comp = None if comp is None else comp.get("tail")

        def macro_body(carry, xs):
            h = carry
            if blocks_comp is not None:
                layer_params, layer_cache, layer_comp = xs
            else:
                (layer_params, layer_cache), layer_comp = xs, None
            new_caches = {}
            for i, bt in enumerate(cfg.pattern):
                ci = None if layer_comp is None else layer_comp.get(f"g{i}")
                h, c_new = apply_block_chunk(
                    layer_params[f"g{i}"], h, layer_cache[f"g{i}"], positions,
                    cfg, bt, qcfg=qcfg, comp=ci, q_block=q_block,
                    kv_block=kv_block)
                new_caches[f"g{i}"] = c_new
            return h, new_caches

        new_cache = {"groups": cache["groups"], "tail": {}, "pos": start + c}
        if self.n_rep > 0:
            xs = (params["blocks"], cache["groups"])
            if blocks_comp is not None:
                xs = (params["blocks"], cache["groups"], blocks_comp)
            x, group_caches = jax.lax.scan(macro_body, x, xs)
            new_cache["groups"] = group_caches
        for j in range(self.n_tail):
            bt = cfg.pattern[j]
            cj = None if tail_comp is None else tail_comp.get(f"t{j}")
            x, c_new = apply_block_chunk(
                params["tail"][f"t{j}"], x, cache["tail"][f"t{j}"], positions,
                cfg, bt, qcfg=qcfg, comp=cj, q_block=q_block,
                kv_block=kv_block)
            new_cache["tail"][f"t{j}"] = c_new

        x = T.apply_norm(params["final_norm"], x, cfg)
        logits = self._unembed(params, x, shard_logits)
        return logits, new_cache

    # ---------------------------------------------------- cache row shuffles

    def gather_cache_rows(self, cache: dict, rows: jax.Array) -> dict:
        """Extract rows (int32 (Bc,)) of a decode cache as a smaller cache.

        `groups` leaves carry a leading layer-stack axis (batch is axis 1);
        `tail` and `pos` leaves have batch leading.
        """
        return {
            "groups": jax.tree.map(lambda a: jnp.take(a, rows, axis=1),
                                   cache["groups"]),
            "tail": jax.tree.map(lambda a: jnp.take(a, rows, axis=0),
                                 cache["tail"]),
            "pos": jnp.take(cache["pos"], rows, axis=0),
        }

    def scatter_cache_rows(self, cache: dict, rows: jax.Array,
                           row_cache: dict, active: jax.Array) -> dict:
        """Write `row_cache` (batch Bc) back into `cache` at `rows`.

        `active` (Bc,) bool masks padding rows; active entries of `rows`
        must be distinct. Inactive/unlisted rows keep their old state.
        """
        b = cache["pos"].shape[0]
        sel = (jnp.arange(b, dtype=jnp.int32)[:, None] == rows[None, :]) \
            & active.astype(bool)[None, :]
        hit = jnp.any(sel, axis=1)                       # (B,)
        src = jnp.argmax(sel, axis=1).astype(jnp.int32)  # (B,) source column

        def put(axis):
            def f(old, new):
                gathered = jnp.take(new, src, axis=axis)
                shape = [1] * old.ndim
                shape[axis] = b
                return jnp.where(hit.reshape(shape), gathered, old)
            return f

        return {
            "groups": jax.tree.map(put(1), cache["groups"],
                                   row_cache["groups"]),
            "tail": jax.tree.map(put(0), cache["tail"], row_cache["tail"]),
            "pos": put(0)(cache["pos"], row_cache["pos"]),
        }

    def _state_to_cache(self, st, bt, max_len, dtype, enc_out, block_params,
                        qcfg, comp):
        cfg = self.cfg
        if bt in ("attn", "local"):
            dims = cfg.attn_dims(bt == "local")
            cache_len = min(max_len, dims.window) if dims.window else max_len
            k, v = st["k"], st["v"]
            s = k.shape[1]
            take = min(s, cache_len)
            pos_range = jnp.arange(s - take, s, dtype=jnp.int32)
            slots = jnp.mod(pos_range, cache_len)
            b = k.shape[0]
            kc = jnp.zeros((b, cache_len, *k.shape[2:]), dtype)
            vc = jnp.zeros((b, cache_len, *v.shape[2:]), dtype)
            kc = kc.at[:, slots].set(k[:, s - take:].astype(dtype))
            vc = vc.at[:, slots].set(v[:, s - take:].astype(dtype))
            out = {"k": kc, "v": vc}
            if enc_out is not None and "xattn" in block_params:
                xk, xv = T._cross_kv(block_params["xattn"], enc_out, cfg, qcfg,
                                     comp)
                out["xk"] = xk.astype(dtype)
                out["xv"] = xv.astype(dtype)
            return out
        return st  # rglru / ssm states already in cache layout


def build_lm(cfg: ArchConfig) -> LMModel:
    spec: Dict[str, Any] = {
        "embed": {
            "table": ParamSpec((cfg.padded_vocab, cfg.d_model), cfg.pdtype,
                               ("vocab", "embed"), normal_init(0.02)),
        },
        "final_norm": T.make_norm_spec(cfg),
    }
    n_pat = len(cfg.pattern)
    n_rep = cfg.n_layers // n_pat
    n_tail = cfg.n_layers % n_pat
    cross = cfg.encoder_decoder

    if n_rep > 0:
        group = {
            f"g{i}": make_block_spec(cfg, bt, cross_attn=cross)
            for i, bt in enumerate(cfg.pattern)
        }
        spec["blocks"] = stack_specs(group, n_rep, "layers")
    if n_tail:
        spec["tail"] = {
            f"t{j}": make_block_spec(cfg, cfg.pattern[j], cross_attn=cross)
            for j in range(n_tail)
        }
    if cfg.encoder_decoder:
        enc_block = make_block_spec(cfg, "attn", cross_attn=False)
        spec["enc_blocks"] = stack_specs(enc_block, cfg.n_enc_layers, "layers")
        spec["enc_norm"] = T.make_norm_spec(cfg)
    if not cfg.tie_embeddings:
        spec["lm_head"] = {
            "w": ParamSpec((cfg.d_model, cfg.padded_vocab), cfg.pdtype,
                           ("embed", "vocab"), normal_init(0.02)),
        }
    return LMModel(cfg, spec)
