"""Fault-tolerant checkpointing: atomic, async, mesh-elastic.

Design (orbax-free, no external deps):

  * a checkpoint is a directory ``step_<N>/`` holding npz shards (leaves are
    gathered to host numpy) + ``manifest.json`` (flat name -> shard, shape,
    dtype) — host arrays make restores *elastic*: any future mesh/device
    count can consume them;
  * writes go to ``step_<N>.tmp`` and are atomically renamed, then the
    ``latest`` pointer file is atomically replaced — a crash mid-save never
    corrupts the restore path;
  * saves run on a background thread (training continues; ``wait()`` joins);
  * ``keep`` bounds retained checkpoints (oldest pruned after a successful
    save).

Restore targets a sharding tree: leaves are ``jax.device_put`` onto the
*current* mesh, so restarting on 2x fewer or more chips only changes the
shardings passed in (see repro.distributed.elastic).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.nn.spec import flatten_with_names

_SHARD_BYTES = 512 * 1024 * 1024  # max npz shard size


def _unflatten(flat: Dict[str, Any]) -> Any:
    tree: Dict[str, Any] = {}
    for name, leaf in flat.items():
        node = tree
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---------------------------------------------------------------- save

    def save(self, step: int, state: Any, *, block: bool = False) -> None:
        """Snapshot `state` at `step`. Values are fetched to host *before*
        the background write starts, so training may mutate them freely."""
        self.wait()
        flat = flatten_with_names(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def _write():
            try:
                self._write_sync(step, host)
            except BaseException as e:  # surfaced on next wait()/save()
                self._error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _write_sync(self, step: int, host: Dict[str, np.ndarray]) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        manifest = {"step": step, "created": time.time(), "leaves": {}}
        shard_idx, shard_bytes, shard_items = 0, 0, {}

        def flush():
            nonlocal shard_idx, shard_bytes, shard_items
            if shard_items:
                np.savez(tmp / f"shard_{shard_idx:04d}.npz", **shard_items)
                shard_idx += 1
                shard_bytes, shard_items = 0, {}

        for name, arr in sorted(host.items()):
            key = name.replace("/", "::")
            if shard_bytes + arr.nbytes > _SHARD_BYTES and shard_items:
                flush()
            shard_items[key] = arr
            shard_bytes += arr.nbytes
            manifest["leaves"][name] = {
                "shard": shard_idx,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        flush()
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)

        # atomic latest pointer
        ptr_tmp = self.dir / "latest.tmp"
        ptr_tmp.write_text(final.name)
        os.replace(ptr_tmp, self.dir / "latest")
        self._prune()

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err!r}")

    # -------------------------------------------------------------- restore

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "latest"
        if ptr.exists():
            name = ptr.read_text().strip()
            if (self.dir / name / "manifest.json").exists():
                return int(name.split("_")[1])
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, shardings: Any = None
                ) -> tuple[int, Any]:
        """Returns (step, state). With `shardings` (a pytree of NamedSharding
        matching the saved structure) every leaf is placed onto the current
        mesh — the elastic-restart path."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())

        shards: Dict[int, Any] = {}

        def shard(i: int):
            if i not in shards:
                shards[i] = np.load(d / f"shard_{i:04d}.npz")
            return shards[i]

        flat = {}
        for name, info in manifest["leaves"].items():
            arr = shard(info["shard"])[name.replace("/", "::")]
            flat[name] = arr
        state = _unflatten(flat)

        if shardings is not None:
            flat_sh = flatten_with_names(shardings)
            placed = {
                name: jax.device_put(flat[name], flat_sh[name])
                for name in flat
            }
            state = _unflatten(placed)
        return step, state
