"""moonshot-v1-16b-a3b [moe] — kimi/moonlight style, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840, MoE 64e top-6,
plus 2 shared (always-on) experts (DeepSeek-V3/Moonlight style).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    pattern=("attn",),
    norm="rmsnorm",
    ffn="swiglu",
    rope_theta=50_000.0,
    tie_embeddings=False,
    n_experts=64,
    moe_top_k=6,
    moe_d_ff=1408,
    n_shared_experts=2,
    capacity_factor=1.25,
)
