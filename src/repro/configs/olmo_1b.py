"""olmo-1b [dense] — non-parametric LayerNorm [arXiv:2402.00838; hf].

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304. SwiGLU, no biases,
tied embeddings, non-parametric LN (no scale/bias).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab=50304,
    pattern=("attn",),
    norm="nonparam_ln",
    ffn="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
