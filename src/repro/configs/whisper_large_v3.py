"""whisper-large-v3 [audio] — encoder-decoder [arXiv:2212.04356; unverified].

32L (enc) + 32L (dec), d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
The conv frontend is a stub per the assignment: `input_specs()` provides
precomputed frame embeddings at d_model. Sinusoidal positions, LayerNorm,
GELU FFN, no RoPE. Decoder has cross-attention over the encoder output.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    pattern=("attn",),
    norm="layernorm",
    ffn="gelu",
    rope_theta=0.0,          # sinusoidal absolute positions instead
    tie_embeddings=True,
    encoder_decoder=True,
    n_enc_layers=32,
)
