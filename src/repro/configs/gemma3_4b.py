"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]. Local window 1024 with rope theta 10k;
global layers rope theta 1M. GeGLU FFN, embeddings scaled by sqrt(d).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    norm="rmsnorm",
    ffn="geglu",
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    embed_scale=True,
    tie_embeddings=True,
)
