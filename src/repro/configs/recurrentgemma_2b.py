"""recurrentgemma-2b [hybrid] — Griffin RG-LRU + local attention, 2:1 pattern.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000 [arXiv:2402.19427; hf].
Pattern: (rglru, rglru, local-attn) repeating; local window 2048; GeGLU FFN;
RG-LRU width = d_model. Long-context capable (bounded state + window).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    norm="rmsnorm",
    ffn="geglu",
    rope_theta=10_000.0,
    embed_scale=True,
    tie_embeddings=True,
    rnn_width=2560,
)
