"""phi3-mini-3.8b [dense] — RoPE SwiGLU MHA [arXiv:2404.14219; unverified].

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32064,
    pattern=("attn",),
    norm="rmsnorm",
    ffn="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)
