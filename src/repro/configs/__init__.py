"""Assigned architecture configs (--arch <id>) + input-shape registry."""

from repro.configs.base import (  # noqa: F401
    ALL_ARCHS,
    SHAPES,
    Shape,
    cell_is_runnable,
    get_config,
    skip_reason,
)
