"""internvl2-26b [vlm] — InternViT frontend (stub) + InternLM2-20B backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 [arXiv:2404.16821; hf].
The ViT frontend is an input stub per the assignment: `input_specs()` feeds
precomputed patch embeddings (prefix_len=256 patches) at d_model.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    pattern=("attn",),
    norm="rmsnorm",
    ffn="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    prefix_len=256,
)
