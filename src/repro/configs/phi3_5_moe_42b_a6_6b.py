"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) expert d_ff=6400 vocab=32064, MoE 16e top-2.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,               # nominal (experts hold the FFN capacity)
    vocab=32064,
    pattern=("attn",),
    norm="rmsnorm",
    ffn="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    n_experts=16,
    moe_top_k=2,
    moe_d_ff=6400,
    capacity_factor=1.25,
)
