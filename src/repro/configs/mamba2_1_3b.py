"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

48L d_model=2048 (attention-free) vocab=50280, ssm_state=128, expand=2,
head_dim=64 (=> 64 heads). Long-context capable (constant-size state).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,               # attention-free; SSM heads derive from expand
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    pattern=("ssm",),
    norm="rmsnorm",
    rope_theta=0.0,
    tie_embeddings=True,
    ssm_d_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
)
