"""Shape registry + --arch config lookup.

The four assigned input shapes (same set for every LM arch):

  train_4k     seq=4096,   global_batch=256   -> lowers train_step
  prefill_32k  seq=32768,  global_batch=32    -> lowers prefill_step
  decode_32k   seq=32768,  global_batch=128   -> lowers serve_step (1 new
                                                token, KV cache of seq len)
  long_500k    seq=524288, global_batch=1     -> serve_step; requires
                                                sub-quadratic sequence mixing
                                                (SSM / hybrid only — see
                                                DESIGN.md for the 8 skips)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}

ALL_ARCHS = [
    "internvl2-26b",
    "recurrentgemma-2b",
    "gemma3-4b",
    "olmo-1b",
    "phi3-mini-3.8b",
    "qwen2.5-14b",
    "whisper-large-v3",
    "phi3.5-moe-42b-a6.6b",
    "moonshot-v1-16b-a3b",
    "mamba2-1.3b",
    # paper's own CNNs are configured via repro.nn.cnn builders
]

_MODULE_OF = {name: name.replace("-", "_").replace(".", "_") for name in ALL_ARCHS}


def get_config(name: str) -> ArchConfig:
    if name not in _MODULE_OF:
        raise KeyError(f"unknown arch {name!r}; choose from {ALL_ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[name]}")
    return mod.CONFIG


# long-context decode needs a bounded cache: SSM state or recurrent state +
# windowed local attention. Pure full-attention archs keep a full 500k KV and
# are skipped per the assignment (documented in DESIGN.md).
_LONG_OK_FAMILIES = {"ssm", "hybrid"}


def cell_is_runnable(arch: str, shape: str) -> bool:
    cfg = get_config(arch)
    if shape == "long_500k":
        return cfg.family in _LONG_OK_FAMILIES
    return True


def skip_reason(arch: str, shape: str) -> Optional[str]:
    if cell_is_runnable(arch, shape):
        return None
    return ("full-attention KV cache at 500k context (global layers keep the "
            "entire KV); long_500k runs only for SSM/hybrid archs per the "
            "assignment")
