"""Deterministic, resumable, shardable synthetic data pipelines."""

from repro.data.synthetic import SyntheticImages, SyntheticTokens  # noqa: F401
