"""Synthetic-but-learnable datasets (offline container: no CIFAR downloads).

Design goals:

  * **Deterministic & step-indexed**: ``batch(step)`` is a pure function of
    (seed, split, step) — a restarted job resumes mid-epoch with zero drift,
    which is the data-side half of fault-tolerant training.
  * **Shardable**: ``host_batch`` carves the global batch by (host, n_hosts)
    so every host materializes only its slice; the same API drives the
    multi-pod launcher.
  * **Learnable**: labels are deterministic functions of the inputs with
    class structure (images = class template + noise; tokens = noisy affine
    bigram process), so accuracy-driven experiments (QAT, weight selection,
    layer-wise scheduling) behave like they do on CIFAR: more capacity /
    gentler compression => higher accuracy.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

_SPLIT_SALT = {"train": 0, "val": 1, "test": 2}


@dataclasses.dataclass(frozen=True)
class SyntheticImages:
    """CIFAR-like image classification stream."""

    num_classes: int = 10
    image_hw: Tuple[int, int] = (32, 32)
    channels: int = 3
    noise: float = 0.45
    seed: int = 0

    def _templates(self) -> jax.Array:
        key = jax.random.PRNGKey(self.seed)
        h, w = self.image_hw
        # smooth class templates: low-frequency random fields
        base = jax.random.normal(key, (self.num_classes, h // 4, w // 4, self.channels))
        up = jax.image.resize(base, (self.num_classes, h, w, self.channels), "bilinear")
        return up / jnp.maximum(jnp.std(up), 1e-6)

    def batch(self, step: int, batch_size: int, split: str = "train"):
        """Returns (images (B,H,W,C) float32, labels (B,) int32)."""
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.seed + 1000 * _SPLIT_SALT[split]), step
        )
        k_y, k_n, k_s = jax.random.split(key, 3)
        y = jax.random.randint(k_y, (batch_size,), 0, self.num_classes)
        templates = self._templates()
        x = templates[y]
        # per-sample brightness/contrast jitter + pixel noise
        scale = 1.0 + 0.2 * jax.random.normal(k_s, (batch_size, 1, 1, 1))
        x = x * scale + self.noise * jax.random.normal(k_n, x.shape)
        return x.astype(jnp.float32), y.astype(jnp.int32)

    def host_batch(self, step: int, global_batch: int, host: int, n_hosts: int,
                   split: str = "train"):
        x, y = self.batch(step, global_batch, split)
        shard = global_batch // n_hosts
        return x[host * shard:(host + 1) * shard], y[host * shard:(host + 1) * shard]


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    """LM token stream: noisy affine bigram process over the vocab.

    next = (a * cur + b) % vocab  with prob 1-eps, else uniform noise.
    A transformer learns the bigram map quickly — loss decreases measurably
    within a few hundred steps at ~100M params.
    """

    vocab: int = 32000
    eps: float = 0.15
    seed: int = 0

    @property
    def _a(self) -> int:
        return 31337 % self.vocab or 7

    @property
    def _b(self) -> int:
        return (self.seed * 2654435761 + 12345) % self.vocab

    def batch(self, step: int, batch_size: int, seq_len: int, split: str = "train"):
        """Returns (tokens (B, S) int32, labels (B, S) int32)."""
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.seed + 7000 * _SPLIT_SALT[split]), step
        )
        k0, kn, ku = jax.random.split(key, 3)
        cur = jax.random.randint(k0, (batch_size,), 0, self.vocab, dtype=jnp.int32)

        def scan_fn(cur, ks):
            k_noise, k_unif = ks
            nxt = (cur * self._a + self._b) % self.vocab
            noise = jax.random.uniform(k_noise, cur.shape) < self.eps
            rand_tok = jax.random.randint(k_unif, cur.shape, 0, self.vocab, dtype=jnp.int32)
            nxt = jnp.where(noise, rand_tok, nxt).astype(jnp.int32)
            return nxt, nxt

        keys = (jax.random.split(kn, seq_len), jax.random.split(ku, seq_len))
        _, seq = jax.lax.scan(scan_fn, cur, keys)
        seq = jnp.concatenate([cur[None], seq], axis=0).T  # (B, S+1)
        return seq[:, :-1], seq[:, 1:]

    def host_batch(self, step: int, global_batch: int, seq_len: int, host: int,
                   n_hosts: int, split: str = "train"):
        x, y = self.batch(step, global_batch, seq_len, split)
        shard = global_batch // n_hosts
        return x[host * shard:(host + 1) * shard], y[host * shard:(host + 1) * shard]
