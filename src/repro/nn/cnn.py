"""CNN zoo for the paper's experiments: LeNet-5, ResNet-20, ResNet-50 (CIFAR).

Each model is a `CNNModel` bundling the param/state spec trees, a pure apply
function, and the list of compressible layers with their systolic matmul
dimensions (used by the energy model / scheduler). Conv layers are mapped to
matmuls with im2col dims per paper 3.2.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.core.layer_energy import MatmulDims, conv_matmul_dims, dense_matmul_dims
from repro.nn import layers as L
from repro.nn.layers import QuantConfig


@dataclasses.dataclass(frozen=True)
class CompLayer:
    """A compressible (weight-bearing matmul) layer."""

    name: str
    kind: str                      # "conv" | "dense"
    c_in: int
    c_out: int
    kernel: int = 1                # conv kernel size (1 for dense)
    stride: int = 1
    out_hw: Tuple[int, int] = (1, 1)  # spatial dims of the *output* map
    padding: str = "SAME"

    def matmul_dims(self, batch: int = 1) -> MatmulDims:
        if self.kind == "conv":
            return conv_matmul_dims(
                self.c_in, self.c_out, (self.kernel, self.kernel), self.out_hw, batch
            )
        return dense_matmul_dims(self.c_in, self.c_out, batch)


@dataclasses.dataclass
class CNNModel:
    name: str
    num_classes: int
    spec: dict
    state_spec: dict
    apply: Callable  # (params, state, x, *, train, qcfg, comp, serve, capture_taps) -> (logits, state, taps)
    comp_layers: List[CompLayer]

    def comp_layer(self, name: str) -> CompLayer:
        for cl in self.comp_layers:
            if cl.name == name:
                return cl
        raise KeyError(name)

    def weight_path(self, name: str) -> Tuple[str, ...]:
        return tuple(name.split("/")) + ("w",)

    def get_weight(self, params, name: str):
        node = params
        for k in self.weight_path(name):
            node = node[k]
        return node


def _maybe(comp: Optional[Dict], name: str):
    return None if comp is None else comp.get(name)


# ===================================================================== LeNet-5


def lenet5(num_classes: int = 10, in_channels: int = 3) -> CNNModel:
    """LeNet-5 for 32x32 inputs (paper: LeNet-5 / CIFAR-10)."""
    spec = {
        "conv1": L.make_conv_spec(in_channels, 6, 5),
        "conv2": L.make_conv_spec(6, 16, 5),
        "fc1": L.make_dense_spec(16 * 5 * 5, 120),
        "fc2": L.make_dense_spec(120, 84),
        "fc3": L.make_dense_spec(84, num_classes),
    }
    comp_layers = [
        CompLayer("conv1", "conv", in_channels, 6, 5, 1, (28, 28), "VALID"),
        CompLayer("conv2", "conv", 6, 16, 5, 1, (10, 10), "VALID"),
        CompLayer("fc1", "dense", 400, 120),
        CompLayer("fc2", "dense", 120, 84),
        CompLayer("fc3", "dense", 84, num_classes),
    ]

    def apply(params, state, x, *, train=False, qcfg=QuantConfig.off(),
              comp=None, serve=None, capture_taps=False):
        tap = {} if capture_taps else None
        # relu rides the layer epilogue: fused into the LUT-GEMM kernel on
        # the serve path, applied eagerly on the fake-quant/dense path
        h = L.apply_conv(params["conv1"], x, padding="VALID", qcfg=qcfg,
                         comp=_maybe(comp, "conv1"), activation="relu",
                         serve_art=_maybe(serve, "conv1"), tap=tap, tap_name="conv1")
        h = L.max_pool(h)
        h = L.apply_conv(params["conv2"], h, padding="VALID", qcfg=qcfg,
                         comp=_maybe(comp, "conv2"), activation="relu",
                         serve_art=_maybe(serve, "conv2"), tap=tap, tap_name="conv2")
        h = L.max_pool(h)
        h = h.reshape(h.shape[0], -1)
        h = L.apply_dense(params["fc1"], h, qcfg=qcfg, activation="relu",
                          comp=_maybe(comp, "fc1"),
                          serve_art=_maybe(serve, "fc1"), tap=tap, tap_name="fc1")
        h = L.apply_dense(params["fc2"], h, qcfg=qcfg, activation="relu",
                          comp=_maybe(comp, "fc2"),
                          serve_art=_maybe(serve, "fc2"), tap=tap, tap_name="fc2")
        logits = L.apply_dense(params["fc3"], h, qcfg=qcfg,
                               comp=_maybe(comp, "fc3"),
                         serve_art=_maybe(serve, "fc3"), tap=tap, tap_name="fc3")
        return logits, state, (tap or {})

    return CNNModel("lenet5", num_classes, spec, {}, apply, comp_layers)


# ===================================================================== ResNets


def _basic_block_spec(c_in: int, c_out: int, stride: int):
    spec = {
        "conv1": L.make_conv_spec(c_in, c_out, 3, use_bias=False),
        "bn1": L.make_batchnorm_spec(c_out),
        "conv2": L.make_conv_spec(c_out, c_out, 3, use_bias=False),
        "bn2": L.make_batchnorm_spec(c_out),
    }
    state = {
        "bn1": L.make_batchnorm_state(c_out),
        "bn2": L.make_batchnorm_state(c_out),
    }
    if stride != 1 or c_in != c_out:
        spec["down"] = L.make_conv_spec(c_in, c_out, 1, use_bias=False)
        spec["down_bn"] = L.make_batchnorm_spec(c_out)
        state["down_bn"] = L.make_batchnorm_state(c_out)
    return spec, state


def _apply_basic_block(params, state, x, *, prefix, stride, train, qcfg, comp,
                       serve, tap):
    h = L.apply_conv(params["conv1"], x, stride=stride, qcfg=qcfg,
                     comp=_maybe(comp, f"{prefix}/conv1"),
                         serve_art=_maybe(serve, f"{prefix}/conv1"), tap=tap,
                     tap_name=f"{prefix}/conv1")
    h, s1 = L.apply_batchnorm(params["bn1"], state["bn1"], h, train=train)
    h = jax.nn.relu(h)
    h = L.apply_conv(params["conv2"], h, qcfg=qcfg,
                     comp=_maybe(comp, f"{prefix}/conv2"),
                         serve_art=_maybe(serve, f"{prefix}/conv2"), tap=tap,
                     tap_name=f"{prefix}/conv2")
    h, s2 = L.apply_batchnorm(params["bn2"], state["bn2"], h, train=train)
    new_state = {"bn1": s1, "bn2": s2}
    if "down" in params:
        skip = L.apply_conv(params["down"], x, stride=stride, qcfg=qcfg,
                            comp=_maybe(comp, f"{prefix}/down"),
                         serve_art=_maybe(serve, f"{prefix}/down"), tap=tap,
                            tap_name=f"{prefix}/down")
        skip, s3 = L.apply_batchnorm(params["down_bn"], state["down_bn"], skip,
                                     train=train)
        new_state["down_bn"] = s3
    else:
        skip = x
    return jax.nn.relu(h + skip), new_state


def resnet20(num_classes: int = 10, in_channels: int = 3) -> CNNModel:
    """CIFAR ResNet-20: 3 stages x 3 BasicBlocks, widths 16/32/64."""
    widths = [16, 32, 64]
    blocks_per_stage = 3
    spec = {
        "conv1": L.make_conv_spec(in_channels, 16, 3, use_bias=False),
        "bn1": L.make_batchnorm_spec(16),
        "fc": L.make_dense_spec(64, num_classes),
    }
    state_spec = {"bn1": L.make_batchnorm_state(16)}
    comp_layers = [CompLayer("conv1", "conv", in_channels, 16, 3, 1, (32, 32))]

    hw = 32
    c_in = 16
    strides = {}
    for si, width in enumerate(widths, start=1):
        for bi in range(1, blocks_per_stage + 1):
            stride = 2 if (si > 1 and bi == 1) else 1
            if stride == 2:
                hw //= 2
            name = f"s{si}b{bi}"
            bspec, bstate = _basic_block_spec(c_in, width, stride)
            spec[name] = bspec
            state_spec[name] = bstate
            strides[name] = stride
            comp_layers.append(
                CompLayer(f"{name}/conv1", "conv", c_in, width, 3, stride, (hw, hw)))
            comp_layers.append(
                CompLayer(f"{name}/conv2", "conv", width, width, 3, 1, (hw, hw)))
            if stride != 1 or c_in != width:
                comp_layers.append(
                    CompLayer(f"{name}/down", "conv", c_in, width, 1, stride, (hw, hw)))
            c_in = width
    comp_layers.append(CompLayer("fc", "dense", 64, num_classes))

    def apply(params, state, x, *, train=False, qcfg=QuantConfig.off(),
              comp=None, serve=None, capture_taps=False):
        tap = {} if capture_taps else None
        h = L.apply_conv(params["conv1"], x, qcfg=qcfg,
                         comp=_maybe(comp, "conv1"),
                         serve_art=_maybe(serve, "conv1"), tap=tap, tap_name="conv1")
        h, s0 = L.apply_batchnorm(params["bn1"], state["bn1"], h, train=train)
        h = jax.nn.relu(h)
        new_state = {"bn1": s0}
        for si in range(1, 4):
            for bi in range(1, blocks_per_stage + 1):
                name = f"s{si}b{bi}"
                h, bs = _apply_basic_block(
                    params[name], state[name], h, prefix=name,
                    stride=strides[name], train=train, qcfg=qcfg, comp=comp, serve=serve, tap=tap)
                new_state[name] = bs
        h = L.avg_pool_global(h)
        logits = L.apply_dense(params["fc"], h, qcfg=qcfg,
                               comp=_maybe(comp, "fc"),
                         serve_art=_maybe(serve, "fc"), tap=tap, tap_name="fc")
        return logits, new_state, (tap or {})

    return CNNModel("resnet20", num_classes, spec, state_spec, apply, comp_layers)


def _bottleneck_spec(c_in: int, width: int, stride: int):
    c_out = width * 4
    spec = {
        "conv1": L.make_conv_spec(c_in, width, 1, use_bias=False),
        "bn1": L.make_batchnorm_spec(width),
        "conv2": L.make_conv_spec(width, width, 3, use_bias=False),
        "bn2": L.make_batchnorm_spec(width),
        "conv3": L.make_conv_spec(width, c_out, 1, use_bias=False),
        "bn3": L.make_batchnorm_spec(c_out),
    }
    state = {
        "bn1": L.make_batchnorm_state(width),
        "bn2": L.make_batchnorm_state(width),
        "bn3": L.make_batchnorm_state(c_out),
    }
    if stride != 1 or c_in != c_out:
        spec["down"] = L.make_conv_spec(c_in, c_out, 1, use_bias=False)
        spec["down_bn"] = L.make_batchnorm_spec(c_out)
        state["down_bn"] = L.make_batchnorm_state(c_out)
    return spec, state


def _apply_bottleneck(params, state, x, *, prefix, stride, train, qcfg, comp,
                      serve, tap):
    h = L.apply_conv(params["conv1"], x, qcfg=qcfg,
                     comp=_maybe(comp, f"{prefix}/conv1"),
                         serve_art=_maybe(serve, f"{prefix}/conv1"), tap=tap,
                     tap_name=f"{prefix}/conv1")
    h, s1 = L.apply_batchnorm(params["bn1"], state["bn1"], h, train=train)
    h = jax.nn.relu(h)
    h = L.apply_conv(params["conv2"], h, stride=stride, qcfg=qcfg,
                     comp=_maybe(comp, f"{prefix}/conv2"),
                         serve_art=_maybe(serve, f"{prefix}/conv2"), tap=tap,
                     tap_name=f"{prefix}/conv2")
    h, s2 = L.apply_batchnorm(params["bn2"], state["bn2"], h, train=train)
    h = jax.nn.relu(h)
    h = L.apply_conv(params["conv3"], h, qcfg=qcfg,
                     comp=_maybe(comp, f"{prefix}/conv3"),
                         serve_art=_maybe(serve, f"{prefix}/conv3"), tap=tap,
                     tap_name=f"{prefix}/conv3")
    h, s3 = L.apply_batchnorm(params["bn3"], state["bn3"], h, train=train)
    new_state = {"bn1": s1, "bn2": s2, "bn3": s3}
    if "down" in params:
        skip = L.apply_conv(params["down"], x, stride=stride, qcfg=qcfg,
                            comp=_maybe(comp, f"{prefix}/down"),
                         serve_art=_maybe(serve, f"{prefix}/down"), tap=tap,
                            tap_name=f"{prefix}/down")
        skip, s4 = L.apply_batchnorm(params["down_bn"], state["down_bn"], skip,
                                     train=train)
        new_state["down_bn"] = s4
    else:
        skip = x
    return jax.nn.relu(h + skip), new_state


def resnet50(num_classes: int = 100, in_channels: int = 3) -> CNNModel:
    """ResNet-50 adapted to CIFAR (3x3 stem, no max-pool), 4 bottleneck stages."""
    stage_blocks = [3, 4, 6, 3]
    stage_widths = [64, 128, 256, 512]
    spec = {
        "conv1": L.make_conv_spec(in_channels, 64, 3, use_bias=False),
        "bn1": L.make_batchnorm_spec(64),
        "fc": L.make_dense_spec(2048, num_classes),
    }
    state_spec = {"bn1": L.make_batchnorm_state(64)}
    comp_layers = [CompLayer("conv1", "conv", in_channels, 64, 3, 1, (32, 32))]

    hw = 32
    c_in = 64
    strides = {}
    for si, (n_blocks, width) in enumerate(zip(stage_blocks, stage_widths), start=1):
        for bi in range(1, n_blocks + 1):
            stride = 2 if (si > 1 and bi == 1) else 1
            if stride == 2:
                hw //= 2
            name = f"s{si}b{bi}"
            bspec, bstate = _bottleneck_spec(c_in, width, stride)
            spec[name] = bspec
            state_spec[name] = bstate
            strides[name] = stride
            in_hw = hw * stride if stride == 2 else hw
            comp_layers.append(
                CompLayer(f"{name}/conv1", "conv", c_in, width, 1, 1, (in_hw, in_hw)))
            comp_layers.append(
                CompLayer(f"{name}/conv2", "conv", width, width, 3, stride, (hw, hw)))
            comp_layers.append(
                CompLayer(f"{name}/conv3", "conv", width, width * 4, 1, 1, (hw, hw)))
            if stride != 1 or c_in != width * 4:
                comp_layers.append(
                    CompLayer(f"{name}/down", "conv", c_in, width * 4, 1, stride, (hw, hw)))
            c_in = width * 4
    comp_layers.append(CompLayer("fc", "dense", 2048, num_classes))

    def apply(params, state, x, *, train=False, qcfg=QuantConfig.off(),
              comp=None, serve=None, capture_taps=False):
        tap = {} if capture_taps else None
        h = L.apply_conv(params["conv1"], x, qcfg=qcfg,
                         comp=_maybe(comp, "conv1"),
                         serve_art=_maybe(serve, "conv1"), tap=tap, tap_name="conv1")
        h, s0 = L.apply_batchnorm(params["bn1"], state["bn1"], h, train=train)
        h = jax.nn.relu(h)
        new_state = {"bn1": s0}
        for si, n_blocks in enumerate(stage_blocks, start=1):
            for bi in range(1, n_blocks + 1):
                name = f"s{si}b{bi}"
                h, bs = _apply_bottleneck(
                    params[name], state[name], h, prefix=name,
                    stride=strides[name], train=train, qcfg=qcfg, comp=comp, serve=serve, tap=tap)
                new_state[name] = bs
        h = L.avg_pool_global(h)
        logits = L.apply_dense(params["fc"], h, qcfg=qcfg,
                               comp=_maybe(comp, "fc"),
                         serve_art=_maybe(serve, "fc"), tap=tap, tap_name="fc")
        return logits, new_state, (tap or {})

    return CNNModel("resnet50", num_classes, spec, state_spec, apply, comp_layers)


# small reduced variants for smoke tests / fast pipeline runs


def resnet8(num_classes: int = 10, in_channels: int = 3) -> CNNModel:
    """3-stage x 1-block reduced ResNet (same family as resnet20)."""
    model = resnet20(num_classes, in_channels)
    # rebuild with 1 block per stage by filtering
    widths = [16, 32, 64]
    spec = {
        "conv1": model.spec["conv1"],
        "bn1": model.spec["bn1"],
        "fc": model.spec["fc"],
    }
    state_spec = {"bn1": model.state_spec["bn1"]}
    comp_layers = [model.comp_layers[0]]
    strides = {}
    hw = 32
    c_in = 16
    for si, width in enumerate(widths, start=1):
        stride = 2 if si > 1 else 1
        if stride == 2:
            hw //= 2
        name = f"s{si}b1"
        bspec, bstate = _basic_block_spec(c_in, width, stride)
        spec[name] = bspec
        state_spec[name] = bstate
        strides[name] = stride
        comp_layers.append(CompLayer(f"{name}/conv1", "conv", c_in, width, 3, stride, (hw, hw)))
        comp_layers.append(CompLayer(f"{name}/conv2", "conv", width, width, 3, 1, (hw, hw)))
        if stride != 1 or c_in != width:
            comp_layers.append(CompLayer(f"{name}/down", "conv", c_in, width, 1, stride, (hw, hw)))
        c_in = width
    comp_layers.append(CompLayer("fc", "dense", 64, num_classes))

    def apply(params, state, x, *, train=False, qcfg=QuantConfig.off(),
              comp=None, serve=None, capture_taps=False):
        tap = {} if capture_taps else None
        h = L.apply_conv(params["conv1"], x, qcfg=qcfg,
                         comp=_maybe(comp, "conv1"),
                         serve_art=_maybe(serve, "conv1"), tap=tap, tap_name="conv1")
        h, s0 = L.apply_batchnorm(params["bn1"], state["bn1"], h, train=train)
        h = jax.nn.relu(h)
        new_state = {"bn1": s0}
        for si in range(1, 4):
            name = f"s{si}b1"
            h, bs = _apply_basic_block(
                params[name], state[name], h, prefix=name,
                stride=strides[name], train=train, qcfg=qcfg, comp=comp, serve=serve, tap=tap)
            new_state[name] = bs
        h = L.avg_pool_global(h)
        logits = L.apply_dense(params["fc"], h, qcfg=qcfg,
                               comp=_maybe(comp, "fc"),
                         serve_art=_maybe(serve, "fc"), tap=tap, tap_name="fc")
        return logits, new_state, (tap or {})

    return CNNModel("resnet8", num_classes, spec, state_spec, apply, comp_layers)
