"""Attention substrate: GQA/MQA/MHA + RoPE + sliding window + KV cache.

Training/prefill uses a double-blocked, online-softmax attention (pure-JAX
flash-attention schedule: outer scan over query blocks, inner scan over
key/value blocks) so activation memory is O(B * qblk * H * kblk) regardless
of sequence length — this is what lets 32k prefill lower/compile within HBM
on the production mesh. Decode is a single-query gather over the cache.

All attention projections are *compressible units*: they accept the same
optional (qcfg, comp) pair as Dense layers (see `repro.core.qat`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qat
from repro.nn.layers import QuantConfig
from repro.nn.spec import ParamSpec, fan_in_init, zeros_init

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0          # 0 => full attention; > 0 => sliding window
    causal: bool = True
    softcap: float = 0.0     # attention logit softcap (gemma-style), 0 = off


def make_attention_spec(dims: AttnDims, dtype=jnp.float32) -> dict:
    d, hq, hkv, hd = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.head_dim
    spec = {
        "wq": ParamSpec((d, hq, hd), dtype, ("embed", "heads", None), fan_in_init(in_axis=0)),
        "wk": ParamSpec((d, hkv, hd), dtype, ("embed", "kv_heads", None), fan_in_init(in_axis=0)),
        "wv": ParamSpec((d, hkv, hd), dtype, ("embed", "kv_heads", None), fan_in_init(in_axis=0)),
        "wo": ParamSpec((hq, hd, d), dtype, ("heads", None, "embed"), fan_in_init(in_axis=0)),
    }
    if dims.qkv_bias:
        spec["bq"] = ParamSpec((hq, hd), dtype, ("heads", None), zeros_init)
        spec["bk"] = ParamSpec((hkv, hd), dtype, ("kv_heads", None), zeros_init)
        spec["bv"] = ParamSpec((hkv, hd), dtype, ("kv_heads", None), zeros_init)
    return spec


# ----------------------------------------------------------------------- rope


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D), positions: (B, S) int32. Rotates first/second half pairs."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf1 * sin + xf2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ projections


def _project(params, x, qcfg: QuantConfig, comp, name: str, key: str,
             bias_key: Optional[str] = None):
    w = params[key]  # (d, H, hd) or (H, hd, d)
    c = None if comp is None else comp.get(f"{name}/{key}")
    if qcfg.enabled and qcfg.act_quant:
        x = qat.fake_quant_act(x)
    art = None if c is None else c.get("serve")
    if qcfg.enabled and qcfg.comp_mode == "serve" and art is not None:
        # packed 4-bit LUT path (bias fused into the kernel epilogue):
        # wq/wk/wv are exported in_first as (d, H*hd), wo out_last as (H*hd, d)
        from repro.core.export import serve_dense

        if key == "wo":
            xin = x.reshape(*x.shape[:-2], x.shape[-2] * x.shape[-1])
            return serve_dense(xin, art, use_ref=qcfg.use_ref_kernel)
        bias = params[bias_key] if bias_key and bias_key in params else None
        y = serve_dense(x, art,
                        bias=None if bias is None else bias.reshape(-1),
                        use_ref=qcfg.use_ref_kernel)
        return y.reshape(*x.shape[:-1], w.shape[1], w.shape[2])
    if qcfg.enabled:
        w = qat.fake_quant_weight(w, c)
    if key == "wo":
        y = jnp.einsum("bshd,hdm->bsm", x, w.astype(x.dtype))
    else:
        y = jnp.einsum("bsm,mhd->bshd", x, w.astype(x.dtype))
    if bias_key and bias_key in params:
        y = y + params[bias_key].astype(y.dtype)
    return y


# ------------------------------------------------------------ blocked attention


def _block_mask(q_pos, k_pos, dims: AttnDims):
    """Boolean mask for one (q-block, k-block) pair.

    Positions are ``(Sq,)``/``(Sk,)`` (shared across the batch) or
    ``(B, Sq)``/``(B, Sk)`` (per-sequence, e.g. chunked prefill rows at
    different offsets); the mask is ``(Sq, Sk)`` or ``(B, Sq, Sk)``.
    """
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if dims.causal:
        m &= kp <= qp
    if dims.window > 0:
        m &= kp > qp - dims.window
    return m


def blocked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, dims: AttnDims, *,
    q_offset: int = 0, q_block: int = 512, kv_block: int = 512,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    use_flash: bool = False,
) -> jax.Array:
    """Online-softmax attention. q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D).

    GQA handled by reshaping queries to (B, S, Hkv, G, D). Memory per step is
    one (B, q_block, Hkv, G, kv_block) score tile. Works for any Sq/Sk that
    are multiples of the block sizes (callers pad).

    ``q_positions``/``kv_positions`` may be per-sequence (``(B, S)``), which
    is what lets chunked-prefill rows sit at independent offsets in one
    fixed-shape call; fully masked key blocks contribute exactly zero to the
    online softmax, so adding padded/invalid keys never changes the result.
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    assert sq % q_block == 0 and sk % kv_block == 0, (sq, sk, q_block, kv_block)
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(b, sq, hkv, g, hd)
    nq, nk = sq // q_block, sk // kv_block
    if q_positions is None:
        q_positions = q_offset + jnp.arange(sq, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = jnp.arange(sk, dtype=jnp.int32)
    batched_pos = q_positions.ndim > 1 or kv_positions.ndim > 1
    if use_flash and batched_pos:
        raise ValueError("flash attention does not support per-sequence "
                         "positions; use the blocked path")

    if use_flash and dims.softcap == 0:
        # FlashAttention-style custom VJP: O(S) residuals instead of the
        # O(S^2/blk) probability stacks autodiff saves (see nn/flash.py)
        from repro.nn.flash import flash_attention

        out = flash_attention(qg, k, v, q_positions, kv_positions,
                              dims.causal, dims.window, q_block, kv_block)
        return out.reshape(b, sq, hq, hd)

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_block, q_block, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_positions, qi * q_block, q_block,
                                          axis=-1)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_block, kv_block, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_block, kv_block, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kv_positions, ki * kv_block,
                                              kv_block, axis=-1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk).astype(jnp.float32)
            s = s * scale
            if dims.softcap > 0:
                s = dims.softcap * jnp.tanh(s / dims.softcap)
            mask = _block_mask(qp, kp, dims)  # (qblk, kblk) or (b, qblk, kblk)
            if mask.ndim == 2:
                mask = mask[None, None, None]
            else:
                mask = mask[:, None, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nk, dtype=jnp.int32))
        out = acc / jnp.maximum(l_f[..., None], 1e-20)  # (b, hkv, g, qblk, hd)
        out = jnp.transpose(out, (0, 3, 1, 2, 4))       # (b, qblk, hkv, g, hd)
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq, dtype=jnp.int32))
    # blocks: (nq, b, q_block, hkv, g, hd)
    out = jnp.transpose(blocks, (1, 0, 2, 3, 4, 5)).reshape(b, sq, hq, hd)
    return out


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, dims: AttnDims, *,
    cur_pos: jax.Array, cache_positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-step attention over a cache.

    q: (B, 1, Hq, D); k_cache/v_cache: (B, Smax, Hkv, D); cur_pos: () or (B,)
    is the position of the new token. Cache entries at slot i hold position
    ``cache_positions[..., i]`` (default: identity, i.e. contiguous cache);
    ``cache_positions`` may be per-sequence (B, Smax) when rows sit at
    independent offsets (slot-level continuous batching).
    """
    b, _, hq, hd = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, hkv, g, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32) * scale
    if dims.softcap > 0:
        s = dims.softcap * jnp.tanh(s / dims.softcap)
    pos = cache_positions if cache_positions is not None else jnp.arange(smax)
    if pos.ndim == 1:
        pos = pos[None, :]                    # (1, Smax) -> broadcast over B
    cur = jnp.asarray(cur_pos)
    cur = cur[..., None] if cur.ndim else cur
    # slots that were never written carry negative positions -> invalid
    valid = (pos <= cur) & (pos >= 0)         # (B or 1, Smax)
    if dims.window > 0:
        valid &= pos > cur - dims.window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, hq, hd)


# ----------------------------------------------------------------- full layer


def apply_attention(
    params,
    x: jax.Array,
    dims: AttnDims,
    *,
    positions: Optional[jax.Array] = None,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
    name: str = "attn",
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,   # cross-attention K/V source
    q_block: int = 512,
    kv_block: int = 512,
    return_kv: bool = False,
    use_flash: bool = False,
):
    """Training/prefill attention over (B, S, d_model).

    Returns the block output, or (output, (k, v)) with post-RoPE K/V when
    ``return_kv`` (prefill cache capture).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q = _project(params, x, qcfg, comp, name, "wq", "bq")
    if kv is None:
        k = _project(params, x, qcfg, comp, name, "wk", "bk")
        v = _project(params, x, qcfg, comp, name, "wv", "bv")
        kv_positions = None
        if dims.rope_theta > 0:
            q = apply_rope(q, positions, dims.rope_theta)
            k = apply_rope(k, positions, dims.rope_theta)
    else:
        k, v = kv
        kv_positions = jnp.arange(k.shape[1], dtype=jnp.int32)
    k_ret, v_ret = k, v

    # pad S to block multiples
    pad_q = (-s) % q_block
    pad_k = (-k.shape[1]) % kv_block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        if kv_positions is not None:
            kv_positions = jnp.pad(kv_positions, (0, pad_k),
                                   constant_values=jnp.int32(1 << 30))
    out = blocked_attention(q, k, v, dims, q_block=q_block, kv_block=kv_block,
                            kv_positions=kv_positions, use_flash=use_flash)
    if pad_q:
        out = out[:, :s]
    out = _project(params, out, qcfg, comp, name, "wo")
    if return_kv:
        return out, (k_ret, v_ret)
    return out


def init_kv_cache(batch: int, max_len: int, dims: AttnDims, dtype=jnp.bfloat16):
    shape = (batch, max_len, dims.n_kv_heads, dims.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def kv_cache_spec(batch: int, max_len: int, dims: AttnDims, dtype=jnp.bfloat16):
    shape = (batch, max_len, dims.n_kv_heads, dims.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def apply_attention_decode(
    params,
    x: jax.Array,              # (B, 1, d_model)
    cache: dict,               # {"k": (B, Smax, Hkv, D), "v": ...}
    pos: jax.Array,            # () or (B,) int32 current position(s)
    dims: AttnDims,
    *,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
    name: str = "attn",
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, dict]:
    """One decode step; returns (output (B, 1, d), updated cache).

    ``pos`` may be per-sequence (B,): each row writes its own ring slot and
    masks against its own position, which is what slot-level continuous
    batching needs when rows of one batch sit at different depths.
    """
    b = x.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos_b[:, None]  # (B, 1)
    q = _project(params, x, qcfg, comp, name, "wq", "bq")

    if cross_kv is not None:
        out = decode_attention(
            q, cross_kv[0], cross_kv[1],
            dataclasses.replace(dims, causal=False, window=0),
            cur_pos=jnp.int32(1 << 30))
        return _project(params, out, qcfg, comp, name, "wo"), cache

    k_new = _project(params, x, qcfg, comp, name, "wk", "bk")
    v_new = _project(params, x, qcfg, comp, name, "wv", "bv")
    if dims.rope_theta > 0:
        q = apply_rope(q, positions, dims.rope_theta)
        k_new = apply_rope(k_new, positions, dims.rope_theta)

    smax = cache["k"].shape[1]
    idx = jnp.arange(smax, dtype=jnp.int32)
    # ring-buffer write for windowed layers, linear write otherwise; a pure
    # select (not dynamic_update_slice) so each row can hit its own slot.
    write = idx[None, :] == jnp.mod(pos_b, smax)[:, None]  # (B, Smax)
    k_cache = jnp.where(write[..., None, None],
                        k_new.astype(cache["k"].dtype), cache["k"])
    v_cache = jnp.where(write[..., None, None],
                        v_new.astype(cache["v"].dtype), cache["v"])
    # slot i holds the largest position congruent to i (mod smax) that is
    # <= pos; slots never written yet resolve to negative positions, which
    # the validity mask in decode_attention rejects.
    cache_positions = idx[None, :] + (
        (pos_b[:, None] - idx[None, :]) // smax) * smax  # (B, Smax)
    out = decode_attention(q, k_cache, v_cache, dims, cur_pos=pos_b,
                           cache_positions=cache_positions)
    out = _project(params, out, qcfg, comp, name, "wo")
    return out, {"k": k_cache, "v": v_cache}


def apply_attention_chunk(
    params,
    x: jax.Array,              # (B, C, d_model) one prefill chunk per row
    cache: dict,               # {"k": (B, Smax, Hkv, D), "v": ...}
    positions: jax.Array,      # (B, C) int32 absolute positions of the chunk
    dims: AttnDims,
    *,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
    name: str = "attn",
    q_block: int = 8,
    kv_block: int = 8,
) -> Tuple[jax.Array, dict]:
    """Chunked-prefill attention step; returns (output (B, C, d), new cache).

    Writes the chunk's post-RoPE K/V into each row's cache, then runs blocked
    online-softmax attention over the *whole* cache with per-row positions.
    Slots the row has not reached yet are masked via the same
    largest-position-congruent-to-slot formula as decode, so stale entries
    from a previous occupant of the slot are invisible. Masked key blocks
    contribute exactly zero, so with a float32 cache the chunked pass is
    bit-identical to one full prefill over the same tokens.

    Ring caches (windowed layers with Smax < total length) are not supported:
    a chunk write could evict keys still inside an earlier query's window.
    Callers gate on ``Smax >= max positions`` before using the chunk path.
    """
    b, c, _ = x.shape
    smax = cache["k"].shape[1]
    positions = positions.astype(jnp.int32)
    q = _project(params, x, qcfg, comp, name, "wq", "bq")
    k_new = _project(params, x, qcfg, comp, name, "wk", "bk")
    v_new = _project(params, x, qcfg, comp, name, "wv", "bv")
    if dims.rope_theta > 0:
        q = apply_rope(q, positions, dims.rope_theta)
        k_new = apply_rope(k_new, positions, dims.rope_theta)

    # Scatter the chunk into the cache, last-write-wins per slot (a chunk
    # never wraps — see the ring note above — so "last" is just in-order).
    idx = jnp.arange(smax, dtype=jnp.int32)
    hits = jnp.mod(positions, smax)[:, :, None] == idx[None, None, :]  # (B,C,S)
    order = jnp.where(hits, jnp.arange(c, dtype=jnp.int32)[None, :, None], -1)
    src = jnp.max(order, axis=1)          # (B, Smax); -1 = slot untouched
    written = (src >= 0)[..., None, None]

    def scatter(old, new):
        gathered = jnp.take_along_axis(
            new, jnp.maximum(src, 0)[..., None, None], axis=1)
        return jnp.where(written, gathered.astype(old.dtype), old)

    k_cache = scatter(cache["k"], k_new)
    v_cache = scatter(cache["v"], v_new)

    cur = positions[:, -1]                # (B,) last position in the chunk
    cache_positions = idx[None, :] + ((cur[:, None] - idx[None, :]) // smax) * smax
    kv_positions = jnp.where(cache_positions >= 0, cache_positions,
                             jnp.int32(1 << 30))  # unwritten -> fails causal

    pad_q = (-c) % q_block
    pad_k = (-smax) % kv_block
    q_pos = positions
    kf, vf = k_cache.astype(q.dtype), v_cache.astype(q.dtype)
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), mode="edge")
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad_k)),
                               constant_values=jnp.int32(1 << 30))
    out = blocked_attention(q, kf, vf, dims, q_block=q_block,
                            kv_block=kv_block, q_positions=q_pos,
                            kv_positions=kv_positions)
    if pad_q:
        out = out[:, :c]
    out = _project(params, out, qcfg, comp, name, "wo")
    return out, {"k": k_cache, "v": v_cache}
