"""Model substrate: spec-first parameter system + layers + architectures."""

from repro.nn.spec import (  # noqa: F401
    ParamSpec,
    abstract_params,
    init_params,
    param_axes,
    spec_bytes,
)
