"""Transformer block assembly: norms + mixer (attn/local/rglru/ssm) + FFN/MoE.

A *block* is one residual layer of the network. `make_block_spec` /
`apply_block` / `apply_block_decode` dispatch on the block type string; the
LM assembler (repro.models.lm) stacks same-typed blocks and scans over them.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qat
from repro.models.config import ArchConfig
from repro.nn import attention as A
from repro.nn import moe as MOE
from repro.nn import rglru as RG
from repro.nn import ssm as SSM
from repro.nn.layers import ACTIVATIONS, QuantConfig, apply_layernorm, apply_rmsnorm
from repro.nn.spec import ParamSpec, fan_in_init

# ------------------------------------------------------------------- norms


def make_norm_spec(cfg: ArchConfig):
    if cfg.norm == "rmsnorm":
        return {"scale": ParamSpec((cfg.d_model,), cfg.pdtype, (None,),
                                   lambda k, s, t: jnp.ones(s, t))}
    if cfg.norm == "layernorm":
        return {
            "scale": ParamSpec((cfg.d_model,), cfg.pdtype, (None,),
                               lambda k, s, t: jnp.ones(s, t)),
            "bias": ParamSpec((cfg.d_model,), cfg.pdtype, (None,),
                              lambda k, s, t: jnp.zeros(s, t)),
        }
    if cfg.norm == "nonparam_ln":
        return {}
    raise ValueError(cfg.norm)


def apply_norm(params, x, cfg: ArchConfig):
    if cfg.norm == "rmsnorm":
        return apply_rmsnorm(params, x)
    return apply_layernorm(params, x)  # parametric or non-parametric LN


# ------------------------------------------------------------------- ffn


def make_ffn_spec(cfg: ArchConfig):
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.pdtype
    if cfg.ffn in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((d, f), dt, ("embed", "mlp"), fan_in_init(in_axis=0)),
            "w_up": ParamSpec((d, f), dt, ("embed", "mlp"), fan_in_init(in_axis=0)),
            "w_down": ParamSpec((f, d), dt, ("mlp", "embed"), fan_in_init(in_axis=0)),
        }
    return {
        "w_up": ParamSpec((d, f), dt, ("embed", "mlp"), fan_in_init(in_axis=0)),
        "w_down": ParamSpec((f, d), dt, ("mlp", "embed"), fan_in_init(in_axis=0)),
    }


def apply_ffn(params, x, cfg: ArchConfig, *, qcfg=QuantConfig.off(), comp=None,
              name: str = "mlp"):
    def mm(key, xin, activation="none"):
        """act(xin @ w[key]) — on the serve path the matmul runs on the
        packed LUT GEMM with the activation fused into the kernel epilogue."""
        c = None if comp is None else comp.get(f"{name}/{key}")
        art = None if c is None else c.get("serve")
        if qcfg.enabled and qcfg.comp_mode == "serve" and art is not None:
            from repro.core.export import serve_dense

            return serve_dense(xin, art, activation=activation,
                               use_ref=qcfg.use_ref_kernel)
        w = params[key]
        w = qat.fake_quant_weight(w, c) if qcfg.enabled else w
        y = jnp.einsum("...k,kn->...n", xin, w.astype(x.dtype))
        return ACTIVATIONS[activation](y)

    xin = qat.fake_quant_act(x) if (qcfg.enabled and qcfg.act_quant) else x
    if cfg.ffn in ("swiglu", "geglu"):
        act = "silu" if cfg.ffn == "swiglu" else "gelu"
        h = mm("w_gate", xin, act) * mm("w_up", xin)
    else:
        h = mm("w_up", xin, "gelu")
    if qcfg.enabled and qcfg.act_quant:
        h = qat.fake_quant_act(h)
    return mm("w_down", h)


# ------------------------------------------------------------------- blocks


def make_block_spec(cfg: ArchConfig, block_type: str, *, cross_attn: bool = False):
    spec = {"ln1": make_norm_spec(cfg)}
    if block_type in ("attn", "local"):
        spec["attn"] = A.make_attention_spec(
            cfg.attn_dims(block_type == "local"), cfg.pdtype)
        spec["ln2"] = make_norm_spec(cfg)
        if cfg.is_moe:
            spec["moe"] = MOE.make_moe_spec(cfg.moe_dims(), cfg.pdtype)
        else:
            spec["mlp"] = make_ffn_spec(cfg)
    elif block_type == "rglru":
        spec["rglru"] = RG.make_rglru_spec(cfg.rglru_dims(), cfg.pdtype)
        spec["ln2"] = make_norm_spec(cfg)
        spec["mlp"] = make_ffn_spec(cfg)
    elif block_type == "ssm":
        spec["ssm"] = SSM.make_ssm_spec(cfg.ssm_dims(), cfg.pdtype)
    else:
        raise ValueError(block_type)
    if cross_attn:
        spec["ln_x"] = make_norm_spec(cfg)
        spec["xattn"] = A.make_attention_spec(cfg.enc_attn_dims(), cfg.pdtype)
    return spec


def apply_block(
    params,
    x: jax.Array,
    cfg: ArchConfig,
    block_type: str,
    *,
    positions: Optional[jax.Array] = None,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
    enc_out: Optional[jax.Array] = None,
    q_block: int = 512,
    kv_block: int = 512,
    encoder: bool = False,
    return_state: bool = False,
    use_flash: bool = False,
):
    """One residual block (train/prefill).

    Returns (x, aux), or ((x, aux), state) when ``return_state`` — the state
    is the mixer's contribution to a decode cache (K/V after RoPE, or the
    recurrent/SSM final state).
    """
    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32)}
    state = None
    h = apply_norm(params["ln1"], x, cfg)
    if block_type in ("attn", "local"):
        dims = cfg.enc_attn_dims() if encoder else cfg.attn_dims(block_type == "local")
        mix = A.apply_attention(params["attn"], h, dims, positions=positions,
                                qcfg=qcfg, comp=comp, name="attn",
                                q_block=q_block, kv_block=kv_block,
                                return_kv=return_state, use_flash=use_flash)
        if return_state:
            mix, (k_st, v_st) = mix
            state = {"k": k_st, "v": v_st}
    elif block_type == "rglru":
        mix = RG.apply_rglru(params["rglru"], h, cfg.rglru_dims(),
                             qcfg=qcfg, comp=comp, name="rglru",
                             return_state=return_state)
        if return_state:
            mix, state = mix
    elif block_type == "ssm":
        mix = SSM.apply_ssm(params["ssm"], h, cfg.ssm_dims(),
                            qcfg=qcfg, comp=comp, name="ssm",
                            return_state=return_state)
        if return_state:
            mix, state = mix
    else:
        raise ValueError(block_type)
    x = x + mix

    if "xattn" in params:
        h = apply_norm(params["ln_x"], x, cfg)
        assert enc_out is not None, "cross-attention block needs encoder output"
        xa = A.apply_attention(
            params["xattn"], h, cfg.enc_attn_dims(), qcfg=qcfg, comp=comp,
            name="xattn", kv=_cross_kv(params["xattn"], enc_out, cfg, qcfg, comp),
            q_block=q_block, kv_block=kv_block)
        x = x + xa

    if block_type == "ssm":
        return ((x, aux), state) if return_state else (x, aux)

    h = apply_norm(params["ln2"], x, cfg)
    if cfg.is_moe and block_type in ("attn", "local"):
        y, moe_aux = MOE.apply_moe(params["moe"], h, cfg.moe_dims(),
                                   qcfg=qcfg, comp=comp, name="moe")
        aux = {"lb_loss": moe_aux["lb_loss"], "z_loss": moe_aux["z_loss"]}
    else:
        y = apply_ffn(params["mlp"], h, cfg, qcfg=qcfg, comp=comp, name="mlp")
    x = x + y
    return ((x, aux), state) if return_state else (x, aux)


def _cross_kv(attn_params, enc_out, cfg: ArchConfig, qcfg, comp):
    """K/V from encoder output for cross-attention (no RoPE)."""
    from repro.nn.attention import _project

    k = _project(attn_params, enc_out, qcfg, comp, "xattn", "wk", "bk")
    v = _project(attn_params, enc_out, qcfg, comp, "xattn", "wv", "bv")
    return k, v


# ------------------------------------------------------------------- decode


def block_cache_spec(cfg: ArchConfig, block_type: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16, *, cross_len: int = 0):
    if block_type in ("attn", "local"):
        dims = cfg.attn_dims(block_type == "local")
        cache_len = min(max_len, dims.window) if dims.window else max_len
        spec = A.kv_cache_spec(batch, cache_len, dims, dtype)
        if cross_len:
            xdims = cfg.enc_attn_dims()
            spec["xk"] = jax.ShapeDtypeStruct(
                (batch, cross_len, xdims.n_kv_heads, xdims.head_dim), dtype)
            spec["xv"] = jax.ShapeDtypeStruct(
                (batch, cross_len, xdims.n_kv_heads, xdims.head_dim), dtype)
        return spec
    if block_type == "rglru":
        return RG.rglru_cache_spec(batch, cfg.rglru_dims(), jnp.float32)
    if block_type == "ssm":
        return SSM.ssm_cache_spec(batch, cfg.ssm_dims(), jnp.float32)
    raise ValueError(block_type)


def init_block_cache(cfg: ArchConfig, block_type: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16, *, cross_len: int = 0):
    spec = block_cache_spec(cfg, block_type, batch, max_len, dtype,
                            cross_len=cross_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def apply_block_decode(
    params,
    x: jax.Array,            # (B, 1, d)
    cache: dict,
    pos: jax.Array,          # () or (B,) int32
    cfg: ArchConfig,
    block_type: str,
    *,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
) -> Tuple[jax.Array, dict]:
    h = apply_norm(params["ln1"], x, cfg)
    new_cache = dict(cache)
    if block_type in ("attn", "local"):
        dims = cfg.attn_dims(block_type == "local")
        kv_cache = {"k": cache["k"], "v": cache["v"]}
        mix, kv_new = A.apply_attention_decode(
            params["attn"], h, kv_cache, pos, dims, qcfg=qcfg, comp=comp,
            name="attn")
        new_cache.update(kv_new)
    elif block_type == "rglru":
        mix, rg_new = RG.apply_rglru_decode(
            params["rglru"], h, cache, cfg.rglru_dims(), qcfg=qcfg, comp=comp,
            name="rglru")
        new_cache = rg_new
    elif block_type == "ssm":
        mix, ssm_new = SSM.apply_ssm_decode(
            params["ssm"], h, cache, cfg.ssm_dims(), qcfg=qcfg, comp=comp,
            name="ssm")
        new_cache = ssm_new
    else:
        raise ValueError(block_type)
    x = x + mix

    if "xattn" in params:
        h = apply_norm(params["ln_x"], x, cfg)
        xa, _ = A.apply_attention_decode(
            params["xattn"], h, {}, pos, cfg.enc_attn_dims(), qcfg=qcfg,
            comp=comp, name="xattn", cross_kv=(cache["xk"], cache["xv"]))
        x = x + xa

    if block_type == "ssm":
        return x, new_cache

    h = apply_norm(params["ln2"], x, cfg)
    if cfg.is_moe and block_type in ("attn", "local"):
        y, _ = MOE.apply_moe(params["moe"], h, cfg.moe_dims(), qcfg=qcfg,
                             comp=comp, name="moe")
    else:
        y = apply_ffn(params["mlp"], h, cfg, qcfg=qcfg, comp=comp, name="mlp")
    return x + y, new_cache


def apply_block_chunk(
    params,
    x: jax.Array,            # (B, C, d) one prefill chunk per row
    cache: dict,
    positions: jax.Array,    # (B, C) int32 absolute positions
    cfg: ArchConfig,
    block_type: str,
    *,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
    q_block: int = 8,
    kv_block: int = 8,
) -> Tuple[jax.Array, dict]:
    """One chunked-prefill step through a block; returns (x, updated cache).

    Attention blocks scatter the chunk's K/V into the row's cache and attend
    over the whole cache with per-row positions (see
    `attention.apply_attention_chunk`). Recurrent mixers (rglru/ssm) have no
    mid-sequence state injection, so they only support a single chunk that
    covers the whole prompt from position 0 — the engine enforces this
    statically by giving recurrent archs chunk buckets equal to the prompt
    buckets. Cross-attention (encoder/decoder) has no chunk path.
    """
    if "xattn" in params:
        raise ValueError("chunked prefill does not support cross-attention "
                         "blocks; use the oneshot/wave path")
    h = apply_norm(params["ln1"], x, cfg)
    new_cache = dict(cache)
    if block_type in ("attn", "local"):
        dims = cfg.attn_dims(block_type == "local")
        kv_cache = {"k": cache["k"], "v": cache["v"]}
        mix, kv_new = A.apply_attention_chunk(
            params["attn"], h, kv_cache, positions, dims, qcfg=qcfg,
            comp=comp, name="attn", q_block=q_block, kv_block=kv_block)
        new_cache.update(kv_new)
    elif block_type == "rglru":
        # chunk == whole prompt: the recurrence runs from its zero state
        mix, state = RG.apply_rglru(params["rglru"], h, cfg.rglru_dims(),
                                    qcfg=qcfg, comp=comp, name="rglru",
                                    return_state=True)
        new_cache = state
    elif block_type == "ssm":
        mix, state = SSM.apply_ssm(params["ssm"], h, cfg.ssm_dims(),
                                   qcfg=qcfg, comp=comp, name="ssm",
                                   return_state=True)
        new_cache = state
    else:
        raise ValueError(block_type)
    x = x + mix

    if block_type == "ssm":
        return x, new_cache

    h = apply_norm(params["ln2"], x, cfg)
    if cfg.is_moe and block_type in ("attn", "local"):
        y, _ = MOE.apply_moe(params["moe"], h, cfg.moe_dims(), qcfg=qcfg,
                             comp=comp, name="moe")
    else:
        y = apply_ffn(params["mlp"], h, cfg, qcfg=qcfg, comp=comp, name="mlp")
    return x + y, new_cache
