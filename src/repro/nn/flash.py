"""Flash-attention-style custom VJP for the blocked attention path.

jax.autodiff of the double-blocked online-softmax forward saves every
(q-block, kv-block) probability tile for the backward pass — an
O(nq * nk * B * H * qblk * kblk) f32 stack *per layer* that dominates train
memory (observed: 8-17 GiB/layer at 4k context on the production mesh).

This module implements the standard FlashAttention backward instead: the
forward saves only (q, k, v, out, lse); the backward recomputes each score
tile from q/k and the saved log-sum-exp, accumulating dq in the outer
q-block scan and dk/dv into a full-size f32 carry via dynamic-update-slice.
Peak attention memory drops from O(S^2 / blocks) stacks to O(S) residuals.

Semantics identical to `blocked_attention` (GQA grouping, causal + window
masks, softcap UNSUPPORTED here — callers with softcap fall back to the
autodiff path); gradients validated against jax.autodiff in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, k_pos, causal: bool, window: int):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def _forward(q, k, v, q_positions, kv_positions, causal, window,
             q_block, kv_block):
    b, sq, hkv, g, hd = q.shape
    sk = k.shape[1]
    nq, nk = sq // q_block, sk // kv_block
    scale = 1.0 / (hd ** 0.5)

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * q_block, q_block, 1)
        qp = jax.lax.dynamic_slice_in_dim(q_positions, qi * q_block, q_block)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_block, kv_block, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_block, kv_block, 1)
            kp = jax.lax.dynamic_slice_in_dim(kv_positions, ki * kv_block,
                                              kv_block)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk
                           ).astype(jnp.float32) * scale
            s = jnp.where(_mask(qp, kp, causal, window)[None, None, None],
                          s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk, dtype=jnp.int32))
        l_safe = jnp.maximum(l_f, 1e-20)
        out = (acc / l_safe[..., None]).astype(q.dtype)   # (b,hkv,g,qblk,hd)
        lse = m_f + jnp.log(l_safe)                        # (b,hkv,g,qblk)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, jnp.arange(nq, dtype=jnp.int32))
    # outs: (nq, b, hkv, g, qblk, hd) -> (b, sq, hkv, g, hd)
    out = jnp.transpose(outs, (1, 0, 4, 2, 3, 5)).reshape(b, sq, hkv, g, hd)
    lse = jnp.transpose(lses, (1, 0, 4, 2, 3)).reshape(b, sq, hkv, g)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def flash_attention(q, k, v, q_positions, kv_positions, causal: bool,
                    window: int, q_block: int, kv_block: int):
    """q: (B, Sq, Hkv, G, D); k, v: (B, Sk, Hkv, D); positions int32.

    Returns (B, Sq, Hkv, G, D). Sq/Sk must be block multiples (callers pad).
    """
    out, _ = _forward(q, k, v, q_positions, kv_positions, causal, window,
                      q_block, kv_block)
    return out


def _fwd(q, k, v, q_positions, kv_positions, causal, window, q_block,
         kv_block):
    out, lse = _forward(q, k, v, q_positions, kv_positions, causal, window,
                        q_block, kv_block)
    return out, (q, k, v, out, lse, q_positions, kv_positions)


def _bwd(causal, window, q_block, kv_block, res, dout):
    q, k, v, out, lse, q_positions, kv_positions = res
    b, sq, hkv, g, hd = q.shape
    sk = k.shape[1]
    nq, nk = sq // q_block, sk // kv_block
    scale = 1.0 / (hd ** 0.5)

    # delta = rowsum(dout * out) per query row
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                  # (b,sq,hkv,g)

    def q_step(carry, qi):
        dk_acc, dv_acc = carry
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * q_block, q_block, 1)
        do_blk = jax.lax.dynamic_slice_in_dim(dout, qi * q_block, q_block, 1)
        lse_blk = jax.lax.dynamic_slice_in_dim(lse, qi * q_block, q_block, 1)
        dl_blk = jax.lax.dynamic_slice_in_dim(delta, qi * q_block, q_block, 1)
        qp = jax.lax.dynamic_slice_in_dim(q_positions, qi * q_block, q_block)
        # to (b,hkv,g,qblk,*)
        q_t = jnp.transpose(q_blk, (0, 2, 3, 1, 4))
        do_t = jnp.transpose(do_blk, (0, 2, 3, 1, 4)).astype(jnp.float32)
        lse_t = jnp.transpose(lse_blk, (0, 2, 3, 1))
        dl_t = jnp.transpose(dl_blk, (0, 2, 3, 1))

        def kv_step(inner, ki):
            dq_blk, dk_acc, dv_acc = inner
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_block, kv_block, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_block, kv_block, 1)
            kp = jax.lax.dynamic_slice_in_dim(kv_positions, ki * kv_block,
                                              kv_block)
            s = jnp.einsum("bhgqd,bkhd->bhgqk", q_t, k_blk
                           ).astype(jnp.float32) * scale
            s = jnp.where(_mask(qp, kp, causal, window)[None, None, None],
                          s, NEG_INF)
            p = jnp.exp(s - lse_t[..., None])                 # (b,hkv,g,q,k)
            dv_tile = jnp.einsum("bhgqk,bhgqd->bkhd", p, do_t)
            dp = jnp.einsum("bhgqd,bkhd->bhgqk", do_t,
                            v_blk.astype(jnp.float32))
            ds = p * (dp - dl_t[..., None]) * scale
            dq_blk = dq_blk + jnp.einsum("bhgqk,bkhd->bhgqd", ds,
                                         k_blk.astype(jnp.float32))
            dk_tile = jnp.einsum("bhgqk,bhgqd->bkhd", ds,
                                 q_t.astype(jnp.float32))
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc,
                jax.lax.dynamic_slice_in_dim(dk_acc, ki * kv_block, kv_block, 1)
                + dk_tile, ki * kv_block, 1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc,
                jax.lax.dynamic_slice_in_dim(dv_acc, ki * kv_block, kv_block, 1)
                + dv_tile, ki * kv_block, 1)
            return (dq_blk, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, hkv, g, q_block, hd), jnp.float32)
        (dq_blk, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk, dtype=jnp.int32))
        dq_out = jnp.transpose(dq_blk, (0, 3, 1, 2, 4)).astype(q.dtype)
        return (dk_acc, dv_acc), dq_out

    dk0 = jnp.zeros((b, sk, hkv, hd), jnp.float32)
    dv0 = jnp.zeros((b, sk, hkv, hd), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(q_step, (dk0, dv0),
                                 jnp.arange(nq, dtype=jnp.int32))
    dq = jnp.transpose(dqs, (1, 0, 2, 3, 4, 5)).reshape(b, sq, hkv, g, hd)
    return (dq, dk.astype(k.dtype), dv.astype(v.dtype), None, None)


flash_attention.defvjp(_fwd, _bwd)
