"""Griffin/RecurrentGemma RG-LRU recurrent block [arXiv:2402.19427].

Block structure (the "recurrent" temporal mixer of Griffin):

    x -> linear (d_model -> d_rnn)  -> causal depthwise conv1d -> RG-LRU -> *
    x -> linear (d_model -> d_rnn)  -> GeLU gate -------------------------^
    * -> out projection (d_rnn -> d_model)

RG-LRU recurrence (elementwise over the d_rnn channels):

    r_t = sigmoid(W_a x_t + b_a)                 (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                 (input gate)
    log a_t = -c * softplus(Lambda) * r_t        (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses `jax.lax.associative_scan`; decode is the single-step
update. Gate matrices in the reference model are block-diagonal; we use full
dense gates (a documented simplification — same logical axes, strictly more
general). The recurrence parameters Lambda are not systolic weight-register
operands and are excluded from weight-value restriction (DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import qat, routing_stats
from repro.nn.layers import QuantConfig, quantized_mm
from repro.nn.spec import ParamSpec, fan_in_init, normal_init, zeros_init

_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUDims:
    d_model: int
    d_rnn: int
    conv_width: int = 4


def make_rglru_spec(dims: RGLRUDims, dtype=jnp.float32) -> dict:
    d, r = dims.d_model, dims.d_rnn

    def lambda_init(key, shape, dtype_):
        # sigma(Lambda) in ~(0.9, 0.999): softplus(Lambda) small positive
        u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
        # want exp(-c*softplus(L)) = u^c ... solve softplus(L) = -log(u)
        sp = -jnp.log(u)
        return jnp.log(jnp.expm1(sp)).astype(dtype_)

    return {
        "in_proj": ParamSpec((d, r), dtype, ("embed", "inner"), fan_in_init(in_axis=0)),
        "gate_proj": ParamSpec((d, r), dtype, ("embed", "inner"), fan_in_init(in_axis=0)),
        "conv_w": ParamSpec((dims.conv_width, r), dtype, (None, "inner"), normal_init(0.1)),
        "conv_b": ParamSpec((r,), dtype, ("inner",), zeros_init),
        "w_a": ParamSpec((r, r), dtype, ("inner", None), fan_in_init(in_axis=0)),
        "b_a": ParamSpec((r,), dtype, (None,), zeros_init),
        "w_x": ParamSpec((r, r), dtype, ("inner", None), fan_in_init(in_axis=0)),
        "b_x": ParamSpec((r,), dtype, (None,), zeros_init),
        "lam": ParamSpec((r,), jnp.float32, (None,), lambda_init),
        "out_proj": ParamSpec((r, d), dtype, ("inner", "embed"), fan_in_init(in_axis=0)),
    }


def _causal_depthwise_conv(x, w, b):
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def _rglru_coeffs(params, xc, qcfg, comp, name):
    """Per-step (log_a, beta*i*x) terms from conv output xc (B, S, r)."""

    def mm(key, xin):
        return quantized_mm(params, key, xin, qcfg=qcfg, comp=comp,
                            name=name, dtype=xc.dtype)

    r_gate = jax.nn.sigmoid(mm("w_a", xc) + params["b_a"].astype(xc.dtype))
    i_gate = jax.nn.sigmoid(mm("w_x", xc) + params["b_x"].astype(xc.dtype))
    log_a = (-_C * jax.nn.softplus(params["lam"]) *
             r_gate.astype(jnp.float32))                      # (B, S, r)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    bx = beta * (i_gate.astype(jnp.float32) * xc.astype(jnp.float32))
    return a, bx


def apply_rglru(
    params,
    x: jax.Array,                 # (B, S, d_model)
    dims: RGLRUDims,
    *,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
    name: str = "rglru",
    return_state: bool = False,
):
    collector = routing_stats.get_collector()
    if collector is not None:
        collector("rglru", name, jnp.mean(jnp.square(x.astype(jnp.float32))))

    def mm(key, xin):
        return quantized_mm(params, key, xin, qcfg=qcfg, comp=comp,
                            name=name, dtype=x.dtype)

    xin = qat.fake_quant_act(x) if (qcfg.enabled and qcfg.act_quant) else x
    branch = mm("in_proj", xin)
    gate = mm("gate_proj", xin)

    xc = _causal_depthwise_conv(branch, params["conv_w"].astype(x.dtype),
                                params["conv_b"].astype(x.dtype))
    a, bx = _rglru_coeffs(params, xc, qcfg, comp, name)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    out = h.astype(x.dtype) * jax.nn.gelu(gate, approximate=True)
    if qcfg.enabled and qcfg.act_quant:
        out = qat.fake_quant_act(out)
    out = mm("out_proj", out)
    if return_state:
        w = dims.conv_width
        tail = branch[:, -(w - 1):]
        pad = (w - 1) - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        state = {"h": h[:, -1].astype(jnp.float32), "conv": tail}
        return out, state
    return out


def init_rglru_cache(batch: int, dims: RGLRUDims, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, dims.d_rnn), dtype),
        "conv": jnp.zeros((batch, dims.conv_width - 1, dims.d_rnn), dtype),
    }


def rglru_cache_spec(batch: int, dims: RGLRUDims, dtype=jnp.float32) -> dict:
    return {
        "h": jax.ShapeDtypeStruct((batch, dims.d_rnn), dtype),
        "conv": jax.ShapeDtypeStruct((batch, dims.conv_width - 1, dims.d_rnn), dtype),
    }


def apply_rglru_decode(
    params,
    x: jax.Array,                 # (B, 1, d_model)
    cache: dict,
    dims: RGLRUDims,
    *,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
    name: str = "rglru",
) -> Tuple[jax.Array, dict]:
    def mm(key, xin):
        return quantized_mm(params, key, xin, qcfg=qcfg, comp=comp,
                            name=name, dtype=x.dtype)

    xin = qat.fake_quant_act(x) if (qcfg.enabled and qcfg.act_quant) else x
    branch = mm("in_proj", xin)
    gate = mm("gate_proj", xin)

    hist = jnp.concatenate([cache["conv"], branch], axis=1)  # (B, W, r)
    w = params["conv_w"].astype(x.dtype)
    xc = jnp.einsum("bwr,wr->br", hist, w) + params["conv_b"].astype(x.dtype)
    new_conv = hist[:, 1:]

    a, bx = _rglru_coeffs(params, xc[:, None], qcfg, comp, name)
    h_new = a[:, 0] * cache["h"].astype(jnp.float32) + bx[:, 0]
    out = h_new.astype(x.dtype)[:, None] * jax.nn.gelu(gate, approximate=True)
    if qcfg.enabled and qcfg.act_quant:
        out = qat.fake_quant_act(out)
    out = mm("out_proj", out)
    return out, {"h": h_new.astype(cache["h"].dtype), "conv": new_conv}
