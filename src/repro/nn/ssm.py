"""Mamba-2 SSD (state-space duality) mixer — chunked train/prefill + decode.

Implements the SSD algorithm of Mamba-2 [arXiv:2405.21060]: the sequence is
split into chunks; diagonal (intra-chunk) blocks are computed as masked
attention-like einsums, inter-chunk information flows through a scan over
per-chunk states. Decode is the O(1) recurrent state update.

Projections (in/out) are compressible units like every other matmul; the
per-head A/dt/D scalars are *not* (they never occupy a systolic weight
register — see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qat, routing_stats
from repro.nn.layers import QuantConfig, apply_rmsnorm, quantized_mm
from repro.nn.spec import ParamSpec, fan_in_init, normal_init, zeros_init


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def make_ssm_spec(dims: SSMDims, dtype=jnp.float32) -> dict:
    d, di, h = dims.d_model, dims.d_inner, dims.n_heads
    gn = dims.n_groups * dims.d_state
    in_out = 2 * di + 2 * gn + h  # z, x, B, C, dt

    def a_init(key, shape, dtype_):
        del key
        # A in [-16, -1): log-uniform-ish init as in mamba2
        return jnp.log(jnp.linspace(1.0, 16.0, shape[0])).astype(dtype_)

    def dt_bias_init(key, shape, dtype_):
        del key
        dt = jnp.exp(jnp.linspace(math.log(1e-3), math.log(0.1), shape[0]))
        # inverse softplus
        return jnp.log(jnp.expm1(dt)).astype(dtype_)

    return {
        "in_proj": ParamSpec((d, in_out), dtype, ("embed", "inner"), fan_in_init(in_axis=0)),
        "conv_w": ParamSpec((dims.conv_width, dims.conv_dim), dtype, (None, "inner"), normal_init(0.1)),
        "conv_b": ParamSpec((dims.conv_dim,), dtype, ("inner",), zeros_init),
        "a_log": ParamSpec((h,), jnp.float32, ("inner",), a_init),
        "dt_bias": ParamSpec((h,), jnp.float32, ("inner",), dt_bias_init),
        "d_skip": ParamSpec((h,), jnp.float32, ("inner",), lambda k, s, t: jnp.ones(s, t)),
        "norm_scale": ParamSpec((di,), dtype, ("inner",), lambda k, s, t: jnp.ones(s, t)),
        "out_proj": ParamSpec((di, d), dtype, ("inner", "embed"), fan_in_init(in_axis=0)),
    }


# ------------------------------------------------------------------ SSD core


def _segsum(a: jax.Array) -> jax.Array:
    """(..., T) -> (..., T, T) lower-triangular pairwise cumulative sums:
    out[..., i, j] = sum(a[..., j+1:i+1]) for j <= i, -inf above diagonal."""
    t = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,        # (B, S, H, P)
    a: jax.Array,        # (B, S, H) = dt * A  (negative)
    b_mat: jax.Array,    # (B, S, G, N)
    c_mat: jax.Array,    # (B, S, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,   # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B, S, H, P), final_state (B, H, P, N))."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc, l = s // chunk, chunk
    rep = h // g

    xc = x.reshape(bsz, nc, l, h, p)
    ac = a.reshape(bsz, nc, l, h).transpose(0, 3, 1, 2)          # (B, H, nc, l)
    bc = jnp.repeat(b_mat.reshape(bsz, nc, l, g, n), rep, axis=3)  # (B,nc,l,H,N)
    cc = jnp.repeat(c_mat.reshape(bsz, nc, l, g, n), rep, axis=3)

    a_cum = jnp.cumsum(ac, axis=-1)                              # (B, H, nc, l)

    # 1. intra-chunk (diagonal blocks)
    lmat = jnp.exp(_segsum(ac))                                  # (B, H, nc, l, l)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", cc, bc, lmat, xc)

    # 2. per-chunk input states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)              # (B, H, nc, l)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", bc, decay_states, xc)

    # 3. inter-chunk recurrence (state kept in f32 for stability; the decay
    # factors are f32 exps, so the carry must be f32 regardless of x dtype)
    chunk_decay = jnp.exp(a_cum[..., -1])                        # (B, H, nc)

    def scan_fn(h_prev, inp):
        st, dec = inp  # (B, H, P, N) f32, (B, H) f32
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    init = (h0.astype(jnp.float32) if h0 is not None
            else jnp.zeros((bsz, h, p, n), jnp.float32))
    final, h_prevs = jax.lax.scan(
        scan_fn, init,
        (states.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(2, 0, 1)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                   # (B, nc, H, P, N)

    # 4. state contribution to outputs
    state_decay = jnp.exp(a_cum)                                 # (B, H, nc, l)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", cc, h_prevs, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final


# ------------------------------------------------------------------ full layer


def _split_proj(z: jax.Array, dims: SSMDims):
    di, gn, h = dims.d_inner, dims.n_groups * dims.d_state, dims.n_heads
    zg = z[..., :di]
    xin = z[..., di:2 * di]
    b_mat = z[..., 2 * di:2 * di + gn]
    c_mat = z[..., 2 * di + gn:2 * di + 2 * gn]
    dt = z[..., 2 * di + 2 * gn:]
    return zg, xin, b_mat, c_mat, dt


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, C), w: (W, C) depthwise causal conv."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def apply_ssm(
    params,
    x: jax.Array,                  # (B, S, d_model)
    dims: SSMDims,
    *,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
    name: str = "ssm",
    return_state: bool = False,
):
    """Training/prefill path. With ``return_state`` also returns the decode
    cache ({"state", "conv"}) at the end of the sequence."""
    bsz, s, _ = x.shape

    collector = routing_stats.get_collector()
    if collector is not None:
        collector("ssm", name, jnp.mean(jnp.square(x.astype(jnp.float32))))

    def mm(key, xin):
        return quantized_mm(params, key, xin, qcfg=qcfg, comp=comp,
                            name=name, dtype=x.dtype)

    xin_q = qat.fake_quant_act(x) if (qcfg.enabled and qcfg.act_quant) else x
    z = mm("in_proj", xin_q)
    zg, xi, b_mat, c_mat, dt_raw = _split_proj(z, dims)

    conv_in = jnp.concatenate([xi, b_mat, c_mat], axis=-1)
    conv_out = jax.nn.silu(_causal_depthwise_conv(
        conv_in, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype)))
    xi = conv_out[..., :dims.d_inner]
    b_mat = conv_out[..., dims.d_inner:dims.d_inner + dims.n_groups * dims.d_state]
    c_mat = conv_out[..., dims.d_inner + dims.n_groups * dims.d_state:]

    h = dims.n_heads
    xh = xi.reshape(bsz, s, h, dims.head_dim)
    bg = b_mat.reshape(bsz, s, dims.n_groups, dims.d_state)
    cg = c_mat.reshape(bsz, s, dims.n_groups, dims.d_state)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a_neg = -jnp.exp(params["a_log"])                                     # (H,)
    a_dt = dt * a_neg                                                     # (B,S,H)
    x_dt = xh * dt[..., None].astype(xh.dtype)

    pad = (-s) % dims.chunk
    if pad:
        x_dt = jnp.pad(x_dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_dt = jnp.pad(a_dt, ((0, 0), (0, pad), (0, 0)))
        bg = jnp.pad(bg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cg = jnp.pad(cg, ((0, 0), (0, pad), (0, 0), (0, 0)))

    y, final_state = ssd_chunked(x_dt, a_dt, bg, cg, dims.chunk)
    if pad:
        y = y[:, :s]
    y = y.astype(xh.dtype)  # SSD internals accumulate f32; back to stream dtype
    y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(bsz, s, dims.d_inner)

    # gated RMSNorm (mamba2) then out projection
    y = apply_rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(zg))
    if qcfg.enabled and qcfg.act_quant:
        y = qat.fake_quant_act(y)
    out = mm("out_proj", y)
    if return_state:
        w = dims.conv_width
        tail = conv_in[:, -(w - 1):]
        p2 = (w - 1) - tail.shape[1]
        if p2 > 0:
            tail = jnp.pad(tail, ((0, 0), (p2, 0), (0, 0)))
        state = {"state": final_state.astype(jnp.float32), "conv": tail}
        return out, state
    return out


def init_ssm_cache(batch: int, dims: SSMDims, dtype=jnp.float32) -> dict:
    return {
        "state": jnp.zeros((batch, dims.n_heads, dims.head_dim, dims.d_state), dtype),
        "conv": jnp.zeros((batch, dims.conv_width - 1, dims.conv_dim), dtype),
    }


def ssm_cache_spec(batch: int, dims: SSMDims, dtype=jnp.float32) -> dict:
    return {
        "state": jax.ShapeDtypeStruct(
            (batch, dims.n_heads, dims.head_dim, dims.d_state), dtype),
        "conv": jax.ShapeDtypeStruct(
            (batch, dims.conv_width - 1, dims.conv_dim), dtype),
    }


def apply_ssm_decode(
    params,
    x: jax.Array,                  # (B, 1, d_model)
    cache: dict,
    dims: SSMDims,
    *,
    qcfg: QuantConfig = QuantConfig.off(),
    comp=None,
    name: str = "ssm",
) -> Tuple[jax.Array, dict]:
    bsz = x.shape[0]

    def mm(key, xin):
        return quantized_mm(params, key, xin, qcfg=qcfg, comp=comp,
                            name=name, dtype=x.dtype)

    xin_q = qat.fake_quant_act(x) if (qcfg.enabled and qcfg.act_quant) else x
    z = mm("in_proj", xin_q)[:, 0]
    zg, xi, b_mat, c_mat, dt_raw = _split_proj(z, dims)

    conv_in = jnp.concatenate([xi, b_mat, c_mat], axis=-1)     # (B, conv_dim)
    conv_hist = jnp.concatenate(
        [cache["conv"].astype(x.dtype), conv_in[:, None]], axis=1)
    w = params["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", conv_hist, w) + params["conv_b"].astype(x.dtype)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = conv_hist[:, 1:].astype(cache["conv"].dtype)

    xi = conv_out[..., :dims.d_inner]
    gn = dims.n_groups * dims.d_state
    b_vec = conv_out[..., dims.d_inner:dims.d_inner + gn]
    c_vec = conv_out[..., dims.d_inner + gn:]

    h, p, n = dims.n_heads, dims.head_dim, dims.d_state
    rep = h // dims.n_groups
    xh = xi.reshape(bsz, h, p)
    bg = jnp.repeat(b_vec.reshape(bsz, dims.n_groups, n), rep, axis=1)  # (B,H,N)
    cg = jnp.repeat(c_vec.reshape(bsz, dims.n_groups, n), rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a_neg = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a_neg)                                           # (B,H)

    state = cache["state"].astype(jnp.float32)
    upd = (xh * dt[..., None].astype(xh.dtype))[..., None] * bg[:, :, None, :]
    new_state = state * decay[..., None, None] + upd.astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", new_state.astype(xh.dtype), cg)
    y = y + xh * params["d_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(bsz, 1, dims.d_inner)

    y = apply_rmsnorm({"scale": params["norm_scale"]},
                      y * jax.nn.silu(zg[:, None]))
    if qcfg.enabled and qcfg.act_quant:
        y = qat.fake_quant_act(y)
    out = mm("out_proj", y)
    return out, {"state": new_state.astype(cache["state"].dtype), "conv": new_conv}
