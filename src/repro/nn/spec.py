"""Spec-first parameter system.

Models are described by *spec trees*: nested dicts whose leaves are
`ParamSpec` (shape, dtype, logical sharding axes, initializer). From a spec
tree we can derive, without ever materializing full-size arrays:

  * ``abstract_params``  -> ShapeDtypeStruct tree (for .lower() dry-runs)
  * ``init_params``      -> concrete initialized tree (eval/smoke/training)
  * ``param_axes``       -> logical-axes tree (consumed by
                            `repro.distributed.sharding` to build
                            NamedShardings)

This mirrors the T5X/Haiku "params as data" style and is what lets a 26B
model be lowered and compiled on a CPU-only host: `jax.jit(...).lower()` only
needs the abstract tree.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of a single parameter."""

    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    axes: Tuple[Optional[str], ...] = ()
    init: Optional[Initializer] = None

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank"
            )

    @property
    def size(self) -> int:
        return math.prod(self.shape) if self.shape else 1


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


# ----------------------------------------------------------------- initializers

def zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


def normal_init(stddev: float = 0.02):
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)

    return init


def fan_in_init(in_axis: int = -2, scale: float = 1.0):
    """LeCun-normal style init: stddev = scale / sqrt(fan_in)."""

    def init(key, shape, dtype):
        if len(shape) == 0:
            return jnp.zeros(shape, dtype)
        fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
        # conv kernels (kh, kw, cin, cout): fan_in = kh*kw*cin
        if len(shape) == 4:
            fan_in = shape[0] * shape[1] * shape[2]
        std = scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


def scaled_uniform_init(scale: float = 1.0):
    def init(key, shape, dtype):
        fan_in = shape[-2] if len(shape) > 1 else shape[0]
        if len(shape) == 4:
            fan_in = shape[0] * shape[1] * shape[2]
        bound = scale * math.sqrt(3.0 / max(fan_in, 1))
        return jax.random.uniform(
            key, shape, jnp.float32, minval=-bound, maxval=bound
        ).astype(dtype)

    return init


# ----------------------------------------------------------------- derivations

def abstract_params(spec_tree) -> Any:
    """ShapeDtypeStruct tree — no allocation; feeds jit(...).lower()."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=is_spec
    )


def param_axes(spec_tree) -> Any:
    """Logical-axes tree with the same structure as the params.

    Leaves are tuples of axis names; consumers must flatten with
    ``is_leaf=lambda x: isinstance(x, tuple)`` since tuples are themselves
    pytree nodes.
    """
    return jax.tree.map(
        lambda s: s.axes if s.axes else (None,) * len(s.shape),
        spec_tree,
        is_leaf=is_spec,
    )


def init_params(key: jax.Array, spec_tree) -> Any:
    """Concretely initialize every parameter with a per-leaf folded key."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    out = []
    for i, spec in enumerate(leaves):
        sub = jax.random.fold_in(key, i)
        init = spec.init or normal_init(0.02)
        out.append(init(sub, spec.shape, spec.dtype))
    return jax.tree.unflatten(treedef, out)


def spec_bytes(spec_tree) -> int:
    """Total parameter bytes implied by the spec tree."""
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(s.size * jnp.dtype(s.dtype).itemsize for s in leaves)


def spec_count(spec_tree) -> int:
    """Total parameter count implied by the spec tree."""
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(s.size for s in leaves)


def stack_specs(spec_tree, n_layers: int, layer_axis_name: Optional[str] = None) -> Any:
    """Lift a per-layer spec tree to a stacked (scan-over-layers) spec tree.

    Each leaf (shape, axes) becomes ((n_layers, *shape), (layer_axis_name,
    *axes)). Initializers are vmapped over the leading axis at init time by
    wrapping them to split the key per layer.
    """

    def lift(s: ParamSpec) -> ParamSpec:
        base_init = s.init or normal_init(0.02)

        def stacked_init(key, shape, dtype, _base=base_init, _inner=s.shape):
            keys = jax.random.split(key, shape[0])
            return jax.vmap(lambda k: _base(k, _inner, dtype))(keys)

        axes = s.axes if s.axes else (None,) * len(s.shape)
        return ParamSpec(
            shape=(n_layers, *s.shape),
            dtype=s.dtype,
            axes=(layer_axis_name, *axes),
            init=stacked_init,
        )

    return jax.tree.map(lift, spec_tree, is_leaf=is_spec)


def flatten_with_names(tree, prefix: str = "") -> Dict[str, Any]:
    """{'a/b/c': leaf} view of a nested-dict tree (for checkpoints/logs)."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(flatten_with_names(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten_with_names(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out
