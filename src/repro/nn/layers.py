"""Layer library: compressible Dense/Conv + norms + embeddings.

Every layer is a (make_*_spec, apply_*) pair. Compressible layers accept an
optional per-layer compression state (`repro.core.qat.CompState`) and a
`QuantConfig`; when quantization is enabled the forward path is
int8-fake-quantized with the codebook/mask applied, matching what the
systolic-array energy model assumes executes on hardware.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qat
from repro.nn.spec import ParamSpec, fan_in_init, normal_init, ones_init, zeros_init


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static quantization switches (hashable: usable as a jit static arg).

    ``comp_mode`` selects how a compressed layer executes:
      * ``"fake_quant"`` — dense matmul on fake-quantized weights (training
        and the QAT reference forward);
      * ``"serve"`` — dispatch layers that have a `ServeArtifact` to the
        packed 4-bit LUT GEMM (`repro.kernels.lut_matmul`); layers without
        an artifact fall back to fake-quant.
    """

    enabled: bool = False
    act_quant: bool = True
    comp_mode: str = "fake_quant"
    use_ref_kernel: bool = False  # serve via the jnp oracle (CPU-fast tests)

    @staticmethod
    def off() -> "QuantConfig":
        return QuantConfig(enabled=False)

    @staticmethod
    def on() -> "QuantConfig":
        return QuantConfig(enabled=True)

    @staticmethod
    def serve(*, use_ref_kernel: bool = False) -> "QuantConfig":
        return QuantConfig(enabled=True, comp_mode="serve",
                           use_ref_kernel=use_ref_kernel)


# epilogue activations a compressible layer can carry; on the serve path
# these fuse into the LUT-GEMM kernel epilogue (repro.kernels.lut_matmul),
# on the fake-quant/dense path they apply eagerly — identical math
ACTIVATIONS = {
    "none": lambda v: v,
    "relu": jax.nn.relu,
    "gelu": lambda v: jax.nn.gelu(v, approximate=True),
    "silu": jax.nn.silu,
}


def _record_tap(tap, tap_name, x, w, comp):
    """Profiling tap: int8 views of what sits in the MAC registers. Recorded
    on both the fake-quant and serve paths (the served weights dequantize to
    the same integers the tap reports)."""
    if tap is not None and tap_name is not None:
        tap[tap_name] = {
            "a_int": qat.quantize_act_int(x),
            "w_int": qat.quantize_weight_int(w, comp),
        }


# --------------------------------------------------------------------- dense


def make_dense_spec(
    in_dim: int,
    out_dim: int,
    *,
    use_bias: bool = True,
    dtype=jnp.float32,
    axes: Tuple[Optional[str], Optional[str]] = (None, None),
    init=None,
):
    spec = {
        "w": ParamSpec((in_dim, out_dim), dtype, axes, init or fan_in_init())
    }
    if use_bias:
        spec["b"] = ParamSpec((out_dim,), dtype, (axes[1],), zeros_init)
    return spec


def apply_dense(
    params,
    x: jax.Array,
    *,
    qcfg: QuantConfig = QuantConfig.off(),
    comp: Optional[qat.CompState] = None,
    serve_art=None,
    activation: str = "none",
    residual: Optional[jax.Array] = None,
    tap: Optional[dict] = None,
    tap_name: Optional[str] = None,
) -> jax.Array:
    """Dense layer with an optional fused epilogue:
    ``y = act(x @ w + b) + residual``. On the serve path bias, activation and
    residual all ride the LUT-GEMM kernel epilogue (one dispatch)."""
    w = params["w"]
    if qcfg.enabled and qcfg.act_quant:
        x = qat.fake_quant_act(x)
    _record_tap(tap, tap_name, x, w, comp)
    if qcfg.enabled and qcfg.comp_mode == "serve" and serve_art is not None:
        from repro.core.export import serve_dense

        return serve_dense(x, serve_art, bias=params.get("b"),
                           residual=residual, activation=activation,
                           use_ref=qcfg.use_ref_kernel)
    w_eff = qat.fake_quant_weight(w, comp) if qcfg.enabled else w
    y = jnp.einsum("...k,kn->...n", x, w_eff.astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    y = ACTIVATIONS[activation](y)
    if residual is not None:
        y = y + residual.astype(y.dtype)
    return y


def quantized_mm(params, key, xin, *, qcfg: QuantConfig, comp, name: str,
                 dtype) -> jax.Array:
    """``xin @ params[key]`` for a named compressible unit: fake-quantized
    under QAT, dispatched to the packed LUT GEMM when a `ServeArtifact` is
    attached and ``comp_mode == "serve"``. Shared by the scan mixers
    (ssm/rglru), whose projections are plain ``(..., K) @ (K, N)`` matmuls."""
    c = None if comp is None else comp.get(f"{name}/{key}")
    art = None if c is None else c.get("serve")
    if qcfg.enabled and qcfg.comp_mode == "serve" and art is not None:
        from repro.core.export import serve_dense

        return serve_dense(xin, art, use_ref=qcfg.use_ref_kernel).astype(dtype)
    w = params[key]
    w = qat.fake_quant_weight(w, c) if qcfg.enabled else w
    return jnp.einsum("...k,kn->...n", xin, w.astype(dtype))


# --------------------------------------------------------------------- conv2d


def make_conv_spec(
    c_in: int,
    c_out: int,
    kernel: int,
    *,
    use_bias: bool = True,
    dtype=jnp.float32,
    init=None,
):
    spec = {
        "w": ParamSpec(
            (kernel, kernel, c_in, c_out), dtype, (None, None, None, None),
            init or fan_in_init(),
        )
    }
    if use_bias:
        spec["b"] = ParamSpec((c_out,), dtype, (None,), zeros_init)
    return spec


def apply_conv(
    params,
    x: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    qcfg: QuantConfig = QuantConfig.off(),
    comp: Optional[qat.CompState] = None,
    serve_art=None,
    activation: str = "none",
    residual: Optional[jax.Array] = None,
    tap: Optional[dict] = None,
    tap_name: Optional[str] = None,
) -> jax.Array:
    """NHWC conv with HWIO kernel and an optional fused epilogue:
    ``y = act(conv(x, w) + b) + residual``. On the serve path the epilogue
    rides the im2col-fed LUT-GEMM kernel (one dispatch)."""
    w = params["w"]
    if qcfg.enabled and qcfg.act_quant:
        x = qat.fake_quant_act(x)
    _record_tap(tap, tap_name, x, w, comp)
    if qcfg.enabled and qcfg.comp_mode == "serve" and serve_art is not None:
        from repro.core.export import serve_conv

        return serve_conv(x, serve_art, stride=stride, padding=padding,
                          bias=params.get("b"), residual=residual,
                          activation=activation,
                          use_ref=qcfg.use_ref_kernel)
    w_eff = qat.fake_quant_weight(w, comp) if qcfg.enabled else w
    y = jax.lax.conv_general_dilated(
        x,
        w_eff.astype(x.dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    y = ACTIVATIONS[activation](y)
    if residual is not None:
        y = y + residual.astype(y.dtype)
    return y


# --------------------------------------------------------------------- norms


def make_batchnorm_spec(dim: int, dtype=jnp.float32):
    return {
        "scale": ParamSpec((dim,), dtype, (None,), ones_init),
        "bias": ParamSpec((dim,), dtype, (None,), zeros_init),
    }


def make_batchnorm_state(dim: int, dtype=jnp.float32):
    return {
        "mean": ParamSpec((dim,), dtype, (None,), zeros_init),
        "var": ParamSpec((dim,), dtype, (None,), ones_init),
    }


def apply_batchnorm(
    params, state, x: jax.Array, *, train: bool, momentum: float = 0.9,
    eps: float = 1e-5,
):
    """Returns (y, new_state). Reduces over all axes but the channel (last)."""
    reduce_axes = tuple(range(x.ndim - 1))
    if train:
        mean = jnp.mean(x, axis=reduce_axes)
        var = jnp.var(x, axis=reduce_axes)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    inv = jax.lax.rsqrt(var + eps) * params["scale"]
    y = (x - mean) * inv + params["bias"]
    return y, new_state


def make_rmsnorm_spec(dim: int, dtype=jnp.float32):
    return {"scale": ParamSpec((dim,), dtype, (None,), ones_init)}


def apply_rmsnorm(params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def make_layernorm_spec(dim: int, dtype=jnp.float32, *, parametric: bool = True):
    if not parametric:
        return {}
    return {
        "scale": ParamSpec((dim,), dtype, (None,), ones_init),
        "bias": ParamSpec((dim,), dtype, (None,), zeros_init),
    }


def apply_layernorm(params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    """LayerNorm; with empty params this is OLMo's non-parametric LN."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if params:
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


# --------------------------------------------------------------------- embed


def make_embed_spec(
    vocab: int, dim: int, *, dtype=jnp.float32,
    axes: Tuple[Optional[str], Optional[str]] = ("vocab", "embed"),
):
    return {"table": ParamSpec((vocab, dim), dtype, axes, normal_init(1.0))}


def apply_embed(params, ids: jax.Array) -> jax.Array:
    return jnp.take(params["table"], ids, axis=0)


def apply_unembed(params, x: jax.Array) -> jax.Array:
    """Tied read-out: logits = x @ table^T."""
    return jnp.einsum("...d,vd->...v", x, params["table"])


# --------------------------------------------------------------------- misc


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def max_pool(x: jax.Array, window: int = 2, stride: int = 2) -> jax.Array:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def avg_pool_global(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))
