"""Minimal-but-production optimizer stack (no optax dependency).

`Optimizer` is an (init, update) pair over arbitrary param pytrees, with the
update signature ``update(grads, state, params) -> (updates, new_state)``;
``updates`` are *deltas* to add to params. Learning-rate schedules are
callables of the int step (kept inside the state).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def _tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.asarray(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: Optional[float] = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": _tree_zeros_like(params),
            "nu": _tree_zeros_like(params),
        }

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        stepf = step.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state["nu"], grads)
        mu_hat_scale = 1.0 / (1.0 - b1**stepf)
        nu_hat_scale = 1.0 / (1.0 - b2**stepf)
        lr_t = lr_fn(step)

        def upd(m, v, p):
            mh = m * mu_hat_scale
            vh = v * nu_hat_scale
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p
            return (-lr_t * delta).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def sgdm(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    momentum: float = 0.9,
    weight_decay: float = 0.0,
    nesterov: bool = False,
    max_grad_norm: Optional[float] = None,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        return {"step": jnp.zeros((), jnp.int32), "vel": _tree_zeros_like(params)}

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        vel = jax.tree.map(lambda v, g: momentum * v + g, state["vel"], grads)
        if nesterov:
            eff = jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
        else:
            eff = vel
        updates = jax.tree.map(lambda e, p: (-lr_t * e).astype(p.dtype), eff, params)
        return updates, {"step": step, "vel": vel}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
