"""Optimizers, schedules, gradient transforms, gradient compression."""

from repro.optim.optimizers import Optimizer, adamw, sgdm  # noqa: F401
from repro.optim.schedules import constant, warmup_cosine  # noqa: F401
