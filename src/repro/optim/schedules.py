"""Learning-rate schedules (callables of the int step)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def fn(step):
        del step
        return jnp.asarray(lr, jnp.float32)

    return fn


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        stepf = jnp.asarray(step, jnp.float32)
        warm = peak_lr * stepf / max(warmup_steps, 1)
        progress = jnp.clip(
            (stepf - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return jnp.where(stepf < warmup_steps, warm, peak_lr * cos)

    return fn


def linear_decay(peak_lr: float, total_steps: int, final_frac: float = 0.0):
    def fn(step):
        stepf = jnp.asarray(step, jnp.float32)
        frac = jnp.clip(stepf / max(total_steps, 1), 0.0, 1.0)
        return peak_lr * (1.0 - (1.0 - final_frac) * frac)

    return fn
