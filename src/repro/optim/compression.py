"""Gradient compression for bandwidth-bound data parallelism.

Two classic compressors, both with error feedback (EF / memory) so the
compression error is re-injected next step (Seide et al.; Karimireddy et al.
— EF makes biased compressors convergent):

  * ``int8_compressor``   — per-leaf symmetric int8 quantization (4x over
    fp32 on the wire; the all-reduce runs on int8 + one fp32 scale).
  * ``topk_compressor``   — keep the top-k fraction by magnitude per leaf
    (sparsity on the wire; here k is a fraction, materialized as a mask).

`compressed(optimizer, compressor)` wraps any repro Optimizer: the update
sees the *decompressed* gradients (exactly what a compressed all-reduce
delivers), EF state rides in the optimizer state, and `wire_bytes` reports
the simulated network volume for the benchmark harness.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer


class Compressor(NamedTuple):
    init: Callable          # params -> ef_state
    compress: Callable      # (grads, ef_state) -> (grads', ef_state', stats)


def int8_compressor() -> Compressor:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def compress(grads, ef):
        def one(g, e):
            gf = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127)
            deq = q * scale
            return deq.astype(g.dtype), gf - deq

        out = jax.tree.map(one, grads, ef)
        deq = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        n_elems = sum(g.size for g in jax.tree.leaves(grads))
        stats = {"wire_bytes": n_elems * 1 + 4 * len(jax.tree.leaves(grads)),
                 "raw_bytes": n_elems * 4}
        return deq, new_ef, stats

    return Compressor(init, compress)


def topk_compressor(fraction: float = 0.01) -> Compressor:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def compress(grads, ef):
        def one(g, e):
            gf = g.astype(jnp.float32) + e
            flat = jnp.abs(gf).reshape(-1)
            k = max(1, int(fraction * flat.shape[0]))
            thresh = jax.lax.top_k(flat, k)[0][-1]
            mask = (jnp.abs(gf) >= thresh).astype(jnp.float32)
            kept = gf * mask
            return kept.astype(g.dtype), gf - kept

        out = jax.tree.map(one, grads, ef)
        kept = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        n_elems = sum(g.size for g in jax.tree.leaves(grads))
        kept_elems = int(max(1, fraction * n_elems))
        stats = {"wire_bytes": kept_elems * 8,  # value + index
                 "raw_bytes": n_elems * 4}
        return kept, new_ef, stats

    return Compressor(init, compress)


def compressed(optimizer: Optimizer, compressor: Compressor) -> Optimizer:
    """Optimizer wrapper: grads pass through the compressor (with EF) before
    the inner update."""

    def init(params):
        return {"inner": optimizer.init(params),
                "ef": compressor.init(params)}

    def update(grads, state, params):
        deq, ef, _stats = compressor.compress(grads, state["ef"])
        updates, inner = optimizer.update(deq, state["inner"], params)
        return updates, {"inner": inner, "ef": ef}

    return Optimizer(init, update)
