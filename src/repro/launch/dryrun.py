import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON manifest under benchmarks/out/dryrun/ with:
  * memory_analysis()  (bytes per device as XLA sees them)
  * cost_analysis()    (HLO flops / bytes accessed)
  * collective_bytes   (per collective kind, parsed from the optimized HLO)
  * sharding guard report (which logical axes fell back to replication)
These manifests are the input to benchmarks/roofline.py (EXPERIMENTS.md
§Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  python -m repro.launch.dryrun --arch olmo-1b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all --jobs 4      # everything, subprocesses
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "out" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    Counts `-start` variants once and skips `-done`. Returns
    {kind: {"bytes": int, "count": int}} plus a "total" entry. Result bytes
    approximate per-device transferred volume (ring all-gather moves
    ~result_bytes x (n-1)/n; all-reduce ~2x operand; the roofline term applies
    kind-specific multipliers).
    """
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done" in stripped:
            continue
        for kind in _COLLECTIVES:
            # match "= TYPE[SHAPE]{...} kind(" or " kind-start("
            if f" {kind}(" not in stripped and f" {kind}-start(" not in stripped:
                continue
            m = _SHAPE_RE.search(stripped)
            if not m:
                continue
            dtype, dims = m.group(1), m.group(2)
            if dtype == "tuple" or dtype not in _DTYPE_BYTES:
                # tuple-shaped (variadic) collectives: sum every element shape
                total = 0
                for m2 in _SHAPE_RE.finditer(stripped.split("=", 1)[-1]):
                    d2, dd = m2.group(1), m2.group(2)
                    if d2 in _DTYPE_BYTES:
                        n = 1
                        for x in dd.split(","):
                            if x:
                                n *= int(x)
                        total += n * _DTYPE_BYTES[d2]
                out[kind]["bytes"] += total
                out[kind]["count"] += 1
                break
            n = 1
            for x in dims.split(","):
                if x:
                    n *= int(x)
            out[kind]["bytes"] += n * _DTYPE_BYTES[dtype]
            out[kind]["count"] += 1
            break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             qat: bool = True, with_comp: bool = True,
             remat: bool = True, q_block: int = 512, kv_block: int = 512,
             rules_override: dict | None = None, flash: bool = False,
             grad_accum: int = 1, kv_seq_shard: bool = False,
             moe_local_dispatch: bool = False, remat_save_qat: bool = False,
             tag: str = "") -> dict:
    from repro.configs import SHAPES, cell_is_runnable, get_config, skip_reason
    from repro.distributed.sharding import DEFAULT_RULES
    from repro.launch import train as TR
    from repro.launch.mesh import make_production_mesh
    from repro.models.lm import build_lm

    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "seq": shape.seq, "batch": shape.batch,
        "qat": qat, "with_comp": with_comp, "flash": flash,
        "grad_accum": grad_accum, "q_block": q_block, "kv_block": kv_block,
        "kv_seq_shard": kv_seq_shard, "tag": tag,
    }
    if not cell_is_runnable(arch, shape_name):
        result["status"] = "skipped"
        result["skip_reason"] = skip_reason(arch, shape_name)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = DEFAULT_RULES
    if rules_override:
        rules = rules.replace(**rules_override)
    model = build_lm(cfg)
    guard: list = []
    step_cfg = TR.StepConfig(qat=qat, with_comp=with_comp, remat=remat,
                             q_block=q_block, kv_block=kv_block, flash=flash,
                             grad_accum=grad_accum,
                             remat_save_qat=remat_save_qat)

    if shape.kind == "train":
        state = TR.abstract_train_state(model)
        state_sh = TR.train_state_shardings(model, mesh, rules, guard)
        specs = TR.batch_specs(cfg, shape)
        specs_sh = TR.batch_shardings(specs, mesh, rules)
        step = TR.make_train_step(model, step_cfg, mesh, rules,
                                  moe_local_dispatch=moe_local_dispatch)
        if with_comp:
            comp = TR.comp_abstract(model)
            comp_sh = TR.comp_shardings(model, mesh, rules, guard)
            jitted = jax.jit(step, in_shardings=(state_sh, specs_sh, comp_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            with mesh:
                lowered = jitted.lower(state, specs, comp)
        else:
            jitted = jax.jit(step, in_shardings=(state_sh, specs_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            with mesh:
                lowered = jitted.lower(state, specs)
    elif shape.kind == "prefill":
        params = TR.abstract_serve_params(model)
        params_sh = TR.make_param_shardings(model.spec, mesh, rules,
                                            guard_report=guard)
        specs = TR.batch_specs(cfg, shape)
        specs_sh = TR.batch_shardings(specs, mesh, rules)
        step = TR.make_prefill_step(model, step_cfg, mesh, rules)
        jitted = jax.jit(step, in_shardings=(params_sh, specs_sh))
        with mesh:
            lowered = jitted.lower(params, specs)
    else:  # decode
        params = TR.abstract_serve_params(model)
        params_sh = TR.make_param_shardings(model.spec, mesh, rules,
                                            guard_report=guard)
        cache = TR.decode_cache_specs(model, shape)
        cache_sh = TR.cache_shardings(model, shape, mesh, rules,
                                      guard_report=guard,
                                      kv_seq_shard=kv_seq_shard)
        tokens = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
        tokens_sh = TR.batch_shardings({"tokens": tokens}, mesh, rules)["tokens"]
        step = TR.make_serve_step(model, step_cfg, mesh, rules)
        jitted = jax.jit(step, in_shardings=(params_sh, cache_sh, tokens_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(params, cache, tokens)

    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()

    mem = compiled.memory_analysis()
    mem_dict = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            mem_dict[attr] = int(getattr(mem, attr, 0) or 0)
    cost = compiled.cost_analysis() or {}
    cost_dict = {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float)) and (
                     k in ("flops", "bytes accessed", "transcendentals",
                           "optimal_seconds")
                     or k.startswith("bytes accessed"))}
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)

    # loop-corrected costs: XLA counts while bodies once; scan-over-layers
    # models under-report by ~n_layers without this (see launch/hlo_cost.py)
    from repro.launch.hlo_cost import loop_corrected_cost

    try:
        corrected = loop_corrected_cost(hlo)
        corrected_out = {
            "flops": corrected["flops"],
            "bytes": corrected["bytes"],
            "collectives": corrected["collectives"],
            "collective_total_bytes": corrected["collective_total_bytes"],
        }
    except Exception as e:  # parsing must never fail the cell
        corrected_out = {"error": repr(e)}

    result.update({
        "status": "ok",
        "corrected_cost": corrected_out,
        "lower_s": round(t_lower - t0, 1),
        "compile_s": round(t_compile - t_lower, 1),
        "memory_analysis": mem_dict,
        "cost_analysis": cost_dict,
        "collectives": coll,
        "guard_report": guard,
        "hlo_bytes": len(hlo),
        "n_devices": mesh.devices.size,
    })
    return result


def cell_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    return OUT_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--no-qat", action="store_true")
    ap.add_argument("--no-comp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--q-block", type=int, default=512)
    ap.add_argument("--kv-block", type=int, default=512)
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--kv-seq", action="store_true")
    ap.add_argument("--moe-local", action="store_true")
    ap.add_argument("--remat-save-qat", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--rules", default="",
                    help="logical=mesh overrides, e.g. embed=model,heads=None")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs import ALL_ARCHS, SHAPES
        jobs = []
        for arch in ALL_ARCHS:
            for shape in SHAPES:
                for mp in (False, True):
                    path = cell_path(arch, shape, mp, args.tag)
                    if path.exists() and not args.force:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape]
                    if mp:
                        cmd.append("--multi-pod")
                    if args.tag:
                        cmd += ["--tag", args.tag]
                    jobs.append((path, cmd))
        print(f"{len(jobs)} cells to run")
        running: list = []
        while jobs or running:
            while jobs and len(running) < args.jobs:
                path, cmd = jobs.pop(0)
                print("start", path.name, flush=True)
                running.append((path, subprocess.Popen(
                    cmd, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE,
                    cwd=str(OUT_DIR.parents[2]),
                    env={**os.environ, "PYTHONPATH": "src"})))
            still = []
            for path, proc in running:
                if proc.poll() is None:
                    still.append((path, proc))
                else:
                    ok = proc.returncode == 0 and path.exists()
                    print(("done " if ok else "FAIL ") + path.name, flush=True)
                    if not ok:
                        err = proc.stderr.read().decode()[-2000:]
                        path.with_suffix(".err").write_text(err)
            running = still
            time.sleep(3)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    rules_override = {}
    if args.rules:
        for kv in args.rules.split(","):
            k, v = kv.split("=")
            if v in ("None", "none", ""):
                rules_override[k] = None
            elif "+" in v:
                rules_override[k] = tuple(v.split("+"))
            else:
                rules_override[k] = v
    result = run_cell(
        args.arch, args.shape, args.multi_pod,
        qat=not args.no_qat, with_comp=not args.no_comp,
        remat=not args.no_remat, q_block=args.q_block,
        kv_block=args.kv_block, flash=args.flash,
        grad_accum=args.grad_accum, kv_seq_shard=args.kv_seq,
        moe_local_dispatch=args.moe_local,
        remat_save_qat=args.remat_save_qat,
        rules_override=rules_override or None, tag=args.tag)
    path = cell_path(args.arch, args.shape, args.multi_pod, args.tag)
    path.write_text(json.dumps(result, indent=2))
    print(json.dumps({k: v for k, v in result.items()
                      if k not in ("guard_report",)}, indent=2))


if __name__ == "__main__":
    main()
