"""Production meshes.

Exposed as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick to keep working.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axis names: ("data", "model") single-pod, ("pod", "data", "model") across
    pods. Robust to the host exposing *more* devices than the mesh needs
    (the dry-run forces 512 host devices; single-pod uses the first 256).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count (dry-run) or "
            "launch on the pod slice")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_host_mesh(*, model_parallel: int = 1) -> Mesh:
    """Mesh over whatever this host actually has (tests, examples)."""
    devices = jax.devices()
    n = len(devices)
    assert n % model_parallel == 0
    dev_array = np.asarray(devices).reshape(n // model_parallel, model_parallel)
    return Mesh(dev_array, ("data", "model"))
