"""Train / prefill / serve step factories + input specs + sharding assembly.

Everything here is mesh-agnostic until `lower()` time: abstract state trees
come from the spec system (no allocation), shardings from the logical-axis
rules, so the same code drives the real trainer, the smoke tests, and the
512-device dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import Shape
from repro.core.lm_compress import make_lm_comp_spec
from repro.distributed.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    activation_constraint,
    batch_sharding,
    logits_constraint,
    make_param_shardings,
    shardings_from_axes_tree,
)
from repro.models.config import ArchConfig
from repro.models.lm import LMModel
from repro.nn.layers import QuantConfig
from repro.nn.spec import abstract_params, init_params
from repro.optim.optimizers import Optimizer, adamw, apply_updates

WHISPER_DECODER_LEN = 448  # whisper's decoder context (enc length = seq_len)


# ===================================================================== steps


@dataclasses.dataclass(frozen=True)
class StepConfig:
    qat: bool = True            # paper setup: int8 QAT on all matmuls
    with_comp: bool = True      # thread masks/codebooks through the step
    remat: bool = True
    q_block: int = 512
    kv_block: int = 512
    lr: float = 3e-4
    weight_decay: float = 0.01
    grad_accum: int = 1         # microbatching: divides activation memory
    flash: bool = False         # flash-attention custom VJP (see nn/flash.py)
    remat_save_qat: bool = False  # save fake-quantized weights across remat

    @property
    def qcfg(self) -> QuantConfig:
        return QuantConfig(enabled=self.qat)


def make_optimizer(step_cfg: StepConfig) -> Optimizer:
    return adamw(step_cfg.lr, weight_decay=step_cfg.weight_decay,
                 max_grad_norm=1.0)


def moe_dispatch_constraint(mesh: Mesh, rules: ShardingRules):
    """Dispatch-buffer constraint hook (see repro.nn.moe): 'scatter' pins
    the (B, E, C, d) buffer model-replicated so the capacity scatter computes
    locally; 'expert' re-shards E over model (a local slice)."""
    from repro.distributed.sharding import _mesh_size, _present

    b_axis = _present(mesh, rules.lookup("batch"))
    e_axis = _present(mesh, rules.lookup("expert"))

    def hook(t, kind):
        b_ok = b_axis if (b_axis and t.shape[0] % _mesh_size(mesh, b_axis) == 0) else None
        e_ok = None
        if kind == "expert" and e_axis and t.shape[1] % _mesh_size(mesh, e_axis) == 0:
            e_ok = e_axis
        parts = [b_ok, e_ok] + [None] * (t.ndim - 2)
        return jax.lax.with_sharding_constraint(
            t, NamedSharding(mesh, PartitionSpec(*parts)))

    return hook


def make_train_step(model: LMModel, step_cfg: StepConfig,
                    mesh: Optional[Mesh] = None,
                    rules: ShardingRules = DEFAULT_RULES,
                    moe_local_dispatch: bool = False) -> Callable:
    """train_step(state, batch[, comp]) -> (state, metrics)."""
    optimizer = make_optimizer(step_cfg)
    shard = activation_constraint(mesh, rules) if mesh is not None else None
    shard_lg = logits_constraint(mesh, rules) if mesh is not None else None
    if moe_local_dispatch and mesh is not None:
        from repro.nn.moe import set_dispatch_constraint

        set_dispatch_constraint(moe_dispatch_constraint(mesh, rules))

    def loss_fn(params, batch, comp):
        return model.loss(params, batch, qcfg=step_cfg.qcfg, comp=comp,
                          remat=step_cfg.remat, q_block=step_cfg.q_block,
                          kv_block=step_cfg.kv_block, shard=shard,
                          shard_logits=shard_lg, use_flash=step_cfg.flash,
                          remat_policy=("save_qat" if step_cfg.remat_save_qat
                                        else None))

    if step_cfg.grad_accum > 1:
        n_micro = step_cfg.grad_accum
        base_loss_fn = loss_fn

        def loss_grad(params, batch, comp):
            """Microbatched grads: scan over batch slices, accumulate fp32."""
            def split(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def one(carry, mb):
                g_acc, l_acc, m_acc = carry
                (l, m), g = jax.value_and_grad(base_loss_fn, has_aux=True)(
                    params, mb, comp)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, l_acc + l, m_acc), None

            g0 = jax.tree.map(jnp.zeros_like, params)
            m0 = {"ce": jnp.zeros(()), "lb_loss": jnp.zeros(()),
                  "z_loss": jnp.zeros(())}
            (g, loss, metrics), _ = jax.lax.scan(one, (g0, jnp.zeros(()), m0),
                                                 micro)
            scale = 1.0 / n_micro
            g = jax.tree.map(lambda x: x * scale, g)
            metrics = jax.tree.map(lambda x: x * scale, metrics)
            return (loss * scale, metrics), g
    else:
        def loss_grad(params, batch, comp):
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, comp)

    if step_cfg.with_comp:
        def train_step(state, batch, comp):
            (loss, metrics), grads = loss_grad(state["params"], batch, comp)
            updates, opt = optimizer.update(grads, state["opt"], state["params"])
            params = apply_updates(state["params"], updates)
            metrics = dict(metrics, loss=loss)
            return {"params": params, "opt": opt}, metrics
    else:
        def train_step(state, batch):
            (loss, metrics), grads = loss_grad(state["params"], batch, None)
            updates, opt = optimizer.update(grads, state["opt"], state["params"])
            params = apply_updates(state["params"], updates)
            metrics = dict(metrics, loss=loss)
            return {"params": params, "opt": opt}, metrics

    return train_step


def make_prefill_step(model: LMModel, step_cfg: StepConfig,
                      mesh: Optional[Mesh] = None,
                      rules: ShardingRules = DEFAULT_RULES) -> Callable:
    """prefill_step(params, batch) -> logits (inference forward at length S)."""
    shard = activation_constraint(mesh, rules) if mesh is not None else None
    shard_lg = logits_constraint(mesh, rules) if mesh is not None else None

    def prefill_step(params, batch):
        logits, _ = model.forward(
            params, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            qcfg=QuantConfig.off(), remat=False,
            q_block=step_cfg.q_block, kv_block=step_cfg.kv_block, shard=shard,
            shard_logits=shard_lg)
        return logits

    return prefill_step


def make_serve_step(model: LMModel, step_cfg: StepConfig,
                    mesh: Optional[Mesh] = None,
                    rules: ShardingRules = DEFAULT_RULES) -> Callable:
    """serve_step(params, cache, tokens) -> (logits, cache): one decode step."""
    shard = activation_constraint(mesh, rules) if mesh is not None else None
    shard_lg = logits_constraint(mesh, rules) if mesh is not None else None

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens,
                                 qcfg=QuantConfig.off(), shard=shard,
                                 shard_logits=shard_lg)

    return serve_step


# ================================================================== state


def abstract_train_state(model: LMModel) -> dict:
    params = abstract_params(model.spec)
    zeros_like = lambda t: jax.tree.map(  # noqa: E731
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), t)
    return {
        "params": params,
        "opt": {
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "mu": zeros_like(params),
            "nu": zeros_like(params),
        },
    }


def init_train_state(model: LMModel, step_cfg: StepConfig, seed: int = 0) -> dict:
    params = init_params(jax.random.PRNGKey(seed), model.spec)
    opt = make_optimizer(step_cfg).init(params)
    return {"params": params, "opt": opt}


def train_state_shardings(model: LMModel, mesh: Mesh,
                          rules: ShardingRules = DEFAULT_RULES,
                          guard_report=None) -> dict:
    p_sh = make_param_shardings(model.spec, mesh, rules,
                                guard_report=guard_report)
    return {
        "params": p_sh,
        "opt": {
            "step": NamedSharding(mesh, PartitionSpec()),
            "mu": p_sh,
            "nu": p_sh,
        },
    }


def abstract_serve_params(model: LMModel):
    """Serve-time parameters in bf16."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
        abstract_params(model.spec))


def comp_abstract(model: LMModel):
    return abstract_params(make_lm_comp_spec(model))


def comp_shardings(model: LMModel, mesh: Mesh,
                   rules: ShardingRules = DEFAULT_RULES, guard_report=None):
    return make_param_shardings(make_lm_comp_spec(model), mesh, rules,
                                guard_report=guard_report)


# ================================================================== inputs


def batch_specs(cfg: ArchConfig, shape: Shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract batch for a (train | prefill) cell."""
    b = shape.batch
    s = shape.seq
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.encoder_decoder:
        s_dec = min(s, WHISPER_DECODER_LEN)
        specs["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_dec), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s_dec), jnp.int32)
        return specs
    s_tok = s - cfg.prefix_len
    if cfg.prefix_len:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
    specs["tokens"] = jax.ShapeDtypeStruct((b, s_tok), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s_tok), jnp.int32)
    return specs


def batch_shardings(specs, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    return {k: batch_sharding(mesh, v.shape, rules) for k, v in specs.items()}


def decode_cache_specs(model: LMModel, shape: Shape,
                       dtype=jnp.bfloat16) -> dict:
    cfg = model.cfg
    if cfg.encoder_decoder:
        # self-cache bounded by the decoder context; cross-KV over seq_len
        return model.cache_spec(shape.batch, WHISPER_DECODER_LEN, dtype,
                                cross_len=shape.seq)
    return model.cache_spec(shape.batch, shape.seq, dtype)


_CACHE_AXES_BY_NAME = {
    "k": ("batch", None, "kv_heads", None),
    "v": ("batch", None, "kv_heads", None),
    "xk": ("batch", None, "kv_heads", None),
    "xv": ("batch", None, "kv_heads", None),
    "state": ("batch", "inner", None, None),
    "conv": ("batch", None, "inner"),
    "h": ("batch", "inner"),
    "pos": ("batch",),
}


def cache_axes(cache_spec, *, kv_seq_shard: bool = False) -> Any:
    """Logical axes tree for a cache spec (layer-stacked leaves detected by
    rank: stacked leaves get a leading None for the scan axis).

    ``kv_seq_shard`` shards the K/V cache *sequence* dim over the model axis
    instead of the head dim — the production fallback when kv_heads does not
    divide the TP degree (MQA/GQA with few KV heads): the cache stops being
    replicated 16x and decode attention becomes a sharded reduction.
    """
    kv_axes = (("batch", "kv_seq", None, None) if kv_seq_shard
               else ("batch", None, "kv_heads", None))
    by_name = dict(_CACHE_AXES_BY_NAME)
    for key in ("k", "v", "xk", "xv"):
        by_name[key] = kv_axes

    def walk(node, name=None):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        base = by_name[name]
        extra = len(node.shape) - len(base)
        assert extra in (0, 1), (name, node.shape)
        return (None,) * extra + base

    return walk(cache_spec)


def cache_shardings(model: LMModel, shape: Shape, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES, dtype=jnp.bfloat16,
                    guard_report=None, *, kv_seq_shard: bool = False):
    spec = decode_cache_specs(model, shape, dtype)
    axes = cache_axes(spec, kv_seq_shard=kv_seq_shard)
    return shardings_from_axes_tree(axes, spec, mesh, rules,
                                    guard_report=guard_report)


# ====================================================================== CLI


def main(argv=None):
    """Thin train launcher over the unified pipeline: LM QAT base training
    (the pipeline's `profile` stage with ``train.qat_steps > 0``, built on
    this module's step factories) plus the energy model, saving the
    resulting `CompressionPlan` for a later ``repro compress/serve`` resume.

        python -m repro.launch.train --arch olmo-1b --reduced --steps 50 \
            --plan-out /tmp/olmo_plan
    """
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=50,
                    help="QAT training steps before profiling")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params instead of initializing")
    ap.add_argument("--plan-out", default=None, metavar="BASE",
                    help="save the plan to BASE.json + BASE.npz")
    args = ap.parse_args(argv)

    from repro.pipeline import (
        Pipeline,
        PipelineConfig,
        TargetConfig,
        TrainStageConfig,
    )

    cfg = PipelineConfig(
        target=TargetConfig(kind="lm", arch=args.arch, reduced=args.reduced,
                            seed=args.seed, batch_size=args.batch_size,
                            lr=args.lr, ckpt_dir=args.ckpt_dir),
        train=TrainStageConfig(qat_steps=args.steps, final_finetune_steps=0),
    )
    plan = Pipeline(cfg).run_until("energy_model", verbose=True)
    print(json.dumps(plan.summary(), indent=2))
    if args.plan_out:
        json_path, npz_path = plan.save(args.plan_out)
        print(f"plan saved: {json_path} + {npz_path}")


if __name__ == "__main__":
    main()
