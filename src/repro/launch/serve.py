"""Serving launcher: thin CLI over the continuous-batching engine.

Production shape: restore params from a checkpoint (mesh-elastic), build a
`repro.serving.ServingEngine`, and drain a request trace through it. On this
CPU host it drives reduced configs (examples/serve_lm.py shows the same flow
scripted); on a pod the identical code runs the engine's optional sharded
decode over `repro.distributed.sharding.request_mesh()`.

    python -m repro.launch.serve --arch gemma3-4b --reduced --batch 4

``--mode oneshot`` swaps the engine for its single-shot fallback (batch-1
waves, one request at a time, same buckets and compile cache) — the two
modes are output-identical, and `benchmarks/bench_serving.py` gates the
engine's throughput edge over this fallback.

``--compress-k N`` restricts every eligible matmul to an N-value codebook,
serves the compressed fake-quant forward, exports the packed 4-bit artifacts
(`repro.core.lm_compress.export_lm_matmuls`), and verifies the LUT GEMM
against the fake-quant matmul before serving (see docs/serving.md).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params, spec_count
from repro.serving import EngineConfig, ServingEngine


def compress_report(model, params, k: int, *, block_k: int = 128,
                    check_units: int = 4, seed: int = 2):
    """Export eligible LM matmuls at codebook size ``k`` and verify parity.

    Restricts every eligible matmul to a symmetric k-value codebook, exports
    the packed 4-bit artifacts, and checks the LUT GEMM against the QAT
    fake-quant matmul on random activations for ``check_units`` units.
    Returns (artifacts, summary dict).
    """
    from repro.core import lm_compress, qat
    from repro.core.export import export_summary, serve_dense

    values = lm_compress.symmetric_codebook_values(k)
    comp = lm_compress.init_lm_comp(model)
    comp = lm_compress.restrict_all_codebooks(model, comp, values)
    arts = lm_compress.export_lm_matmuls(model, params, comp, block_k=block_k)
    summary = export_summary(arts)

    checked = {}
    for name, w, c, layout in lm_compress.iter_restricted_units(
            model, params, comp):
        if len(checked) >= check_units or name not in arts:
            break
        art = arts[name]
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, art.k_dim))
        w_fake = qat.fake_quant_weight(w, c)
        w_mat = (w_fake.reshape(w.shape[0], -1) if layout == "in_first"
                 else w_fake.reshape(-1, w.shape[-1]))
        want = x @ w_mat
        got = serve_dense(x, art)
        rel = float(jnp.linalg.norm(got - want)
                    / jnp.maximum(jnp.linalg.norm(want), 1e-9))
        checked[name] = rel
    summary["parity_checked"] = checked
    summary["parity_max_rel_err"] = max(checked.values()) if checked else 0.0
    return arts, summary


def generate(model, params, prompts: jax.Array, *, new_tokens: int,
             temperature: float = 0.0, seed: int = 0, q_block: int = 8,
             kv_block: int = 8):
    """Reference single-dispatch generation: prefill once, loop decode.

    Kept as the pre-engine serving path; the engine reproduces it exactly
    when a prompt fills its bucket (tested in tests/test_serving_engine.py).
    """
    b, s = prompts.shape
    max_len = s + new_tokens
    logits, cache = model.prefill(params, prompts, max_len=max_len,
                                  cache_dtype=jnp.float32, q_block=q_block,
                                  kv_block=kv_block)

    def sample(lg, key):
        lg = lg[:, -1, :model.cfg.vocab] if lg.ndim == 3 else lg[:, :model.cfg.vocab]
        if temperature <= 0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(key, lg / temperature, axis=-1)

    key = jax.random.PRNGKey(seed)
    tok = sample(logits, key)[:, None]
    decode = jax.jit(model.decode_step)

    outs = [tok]
    for i in range(new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        key = jax.random.fold_in(key, i)
        tok = sample(logits[:, 0], key)[:, None]
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


def trace_shapes(n_requests: int, prompt_len: int, new_tokens: int,
                 mixed: bool) -> list:
    """(prompt_len, new_tokens) per request; ``mixed`` varies lengths
    deterministically to exercise several buckets."""
    if not mixed:
        return [(prompt_len, new_tokens)] * n_requests
    lens = [max(2, prompt_len - 7 * (i % 3)) for i in range(n_requests)]
    news = [max(2, new_tokens - 3 * (i % 2)) for i in range(n_requests)]
    return list(zip(lens, news))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a CheckpointManager directory")
    ap.add_argument("--mode", choices=("engine", "oneshot"), default="engine",
                    help="continuous-batching engine or single-shot fallback")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests in the trace")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--mixed", action="store_true",
                    help="vary request lengths across buckets")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="engine wave width")
    ap.add_argument("--compress-k", type=int, default=0,
                    help="restrict eligible matmuls to a k-value codebook, "
                         "export packed 4-bit artifacts, verify LUT parity, "
                         "and serve the compressed forward")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    print(f"serving {cfg.name}: {spec_count(model.spec)/1e6:.1f}M params")

    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        step, state = ckpt.restore()
        params = state["params"] if "params" in state else state
        print(f"restored checkpoint step {step}")
    else:
        params = init_params(jax.random.PRNGKey(0), model.spec)

    if args.compress_k:
        arts, summary = compress_report(model, params, args.compress_k)
        print(f"compressed export: {summary['layers']} matmuls, "
              f"{summary['weight_bytes_packed'] / 1e6:.2f} MB packed "
              f"({summary['compression_vs_int8']:.2f}x vs int8), "
              f"LUT parity max rel err "
              f"{summary['parity_max_rel_err']:.2e}")

    shapes = trace_shapes(args.batch, args.prompt_len, args.new_tokens,
                          args.mixed)
    p_bucket = max(s[0] for s in shapes)
    n_bucket = max(s[1] for s in shapes)
    ecfg = EngineConfig(max_batch=args.max_batch,
                        prompt_buckets=(max(p_bucket // 2, 2), p_bucket),
                        new_token_buckets=(n_bucket,))
    engine = ServingEngine(model, params, mode=args.mode, config=ecfg,
                           compress_k=args.compress_k)
    engine.warmup(shapes)

    prompts = [
        jax.random.randint(jax.random.PRNGKey(100 + i), (plen,), 0, cfg.vocab)
        for i, (plen, _) in enumerate(shapes)
    ]
    t0 = time.time()
    for prompt, (_, ntok) in zip(prompts, shapes):
        engine.submit(prompt, ntok, temperature=args.temperature)
    results = engine.run()
    dt = time.time() - t0

    rep = engine.report()
    print(f"{args.mode}: {rep['requests']} requests, "
          f"{rep['new_tokens']} tokens in {dt:.2f}s "
          f"({rep['tokens_per_s']:.1f} tok/s), "
          f"latency p50/p99 {rep['latency_p50_s']*1e3:.0f}/"
          f"{rep['latency_p99_s']*1e3:.0f} ms, "
          f"ttft p50 {rep['ttft_p50_s']*1e3:.0f} ms, "
          f"energy {rep['energy_eu_total']:.3g} eu "
          f"({rep['energy_eu_per_token']:.3g} eu/token), "
          f"{rep['cache_buckets_compiled']} buckets / "
          f"{rep['cache_compile_count']} compiles")
    for rid in sorted(results)[:2]:
        print(f"  req{rid}: {results[rid].tokens[:10]}...")


if __name__ == "__main__":
    main()
