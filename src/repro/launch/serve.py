"""Serving launcher: thin CLI over the unified compression pipeline.

Production shape: `repro.pipeline.Pipeline` with an LM target — restore
params from a checkpoint (mesh-elastic), optionally restrict every eligible
matmul to a k-value codebook + export the packed 4-bit artifacts, and drain
a request trace through `repro.serving.ServingEngine`. On this CPU host it
drives reduced configs (examples/serve_lm.py shows the same flow scripted);
on a pod the identical code runs the engine's optional sharded decode over
`repro.distributed.sharding.request_mesh()`.

    python -m repro.launch.serve --arch gemma3-4b --reduced --batch 4

Equivalent pipeline CLI: ``repro serve --target lm --arch gemma3-4b
--reduced`` (same stages, same plan; see docs/pipeline.md).

``--mode wave`` swaps the slot-level engine for the legacy wave-lockstep
scheduler and ``--mode oneshot`` for the single-shot fallback (batch-1
waves, one request at a time, same buckets and compile cache) — all three
modes are output-identical, and `benchmarks/bench_serving.py` gates the
engine's throughput edge over both baselines.

``--compress-k N`` restricts every eligible matmul to an N-value codebook,
serves the compressed fake-quant forward, exports the packed 4-bit artifacts
(`repro.core.lm_compress.export_lm_matmuls`), and verifies the LUT GEMM
against the fake-quant matmul before serving (see docs/serving.md).

``--plans SPEC [SPEC ...]`` (or ``--plans-dir DIR``) serves a **fleet**
instead of one pinned variant: every SPEC becomes a resident
`repro.serving.fleet.PlanHandle` (``base``, ``k4``, ``k8m2``, or a saved
CompressionPlan base path) and a `FleetRouter` picks the variant per request
from queue pressure and per-request budgets — degrading to aggressive
compression under load, recovering to high fidelity when idle:

    python -m repro.launch.serve --arch olmo-1b --reduced --plans k4 base
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp


def compress_report(model, params, k: int, *, block_k: int = 128,
                    check_units: int = 4, seed: int = 2):
    """Export eligible LM matmuls at codebook size ``k`` and verify parity.

    Standalone form of the pipeline's export stage
    (`repro.pipeline.targets.LMTarget.stage_export`) for callers holding a
    bare (model, params): restricts every eligible matmul to a symmetric
    k-value codebook, exports the packed 4-bit artifacts, and checks the LUT
    GEMM against the QAT fake-quant matmul on random activations for
    ``check_units`` units. Returns (artifacts, summary dict).
    """
    from repro.core import lm_compress
    from repro.core.export import export_summary

    values = lm_compress.symmetric_codebook_values(k)
    comp = lm_compress.init_lm_comp(model)
    comp = lm_compress.restrict_all_codebooks(model, comp, values)
    arts, skips = lm_compress.export_lm_matmuls(model, params, comp,
                                                block_k=block_k)
    summary = export_summary(arts)
    summary["skipped_units"] = skips
    checked = lm_compress.lut_parity_report(model, params, comp, arts,
                                            check_units=check_units,
                                            seed=seed)
    summary["parity_checked"] = checked
    summary["parity_max_rel_err"] = max(checked.values()) if checked else 0.0
    return arts, summary


def generate(model, params, prompts: jax.Array, *, new_tokens: int,
             temperature: float = 0.0, seed: int = 0, q_block: int = 8,
             kv_block: int = 8):
    """Reference single-dispatch generation: prefill once, loop decode.

    Kept as the pre-engine serving path; the engine reproduces it exactly
    when a prompt fills its bucket (tested in tests/test_serving_engine.py).
    """
    b, s = prompts.shape
    max_len = s + new_tokens
    logits, cache = model.prefill(params, prompts, max_len=max_len,
                                  cache_dtype=jnp.float32, q_block=q_block,
                                  kv_block=kv_block)

    def sample(lg, key):
        lg = lg[:, -1, :model.cfg.vocab] if lg.ndim == 3 else lg[:, :model.cfg.vocab]
        if temperature <= 0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(key, lg / temperature, axis=-1)

    key = jax.random.PRNGKey(seed)
    tok = sample(logits, key)[:, None]
    decode = jax.jit(model.decode_step)

    outs = [tok]
    for i in range(new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        key = jax.random.fold_in(key, i)
        tok = sample(logits[:, 0], key)[:, None]
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


def trace_shapes(n_requests: int, prompt_len: int, new_tokens: int,
                 mixed: bool) -> list:
    """(prompt_len, new_tokens) per request; ``mixed`` varies lengths
    deterministically to exercise several buckets. Delegates to the
    pipeline's trace generator so the CLI and the serve stage agree."""
    from repro.pipeline.targets import lm_trace_shapes

    return lm_trace_shapes(n_requests, prompt_len, new_tokens, mixed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a CheckpointManager directory")
    ap.add_argument("--mode", choices=("engine", "wave", "oneshot"),
                    default="engine",
                    help="continuous-batching engine or single-shot fallback")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests in the trace")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--mixed", action="store_true",
                    help="vary request lengths across buckets")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="engine wave width")
    ap.add_argument("--compress-k", type=int, default=0,
                    help="restrict eligible matmuls to a k-value codebook, "
                         "export packed 4-bit artifacts, verify LUT parity, "
                         "and serve the compressed forward")
    ap.add_argument("--plans", nargs="+", default=None, metavar="SPEC",
                    help="fleet serving: resident variants ('base', "
                         "'k<N>[m<M>]', or saved CompressionPlan base "
                         "paths) routed across by load and budget")
    ap.add_argument("--plans-dir", default=None, metavar="DIR",
                    help="fleet serving: load every saved CompressionPlan "
                         "under DIR as a resident variant")
    ap.add_argument("--plan-out", default=None, metavar="BASE",
                    help="save the CompressionPlan to BASE.json + BASE.npz")
    args = ap.parse_args(argv)

    from repro.pipeline import (
        Pipeline,
        PipelineConfig,
        ServeStageConfig,
        TargetConfig,
        TrainStageConfig,
    )

    cfg = PipelineConfig(
        target=TargetConfig(kind="lm", arch=args.arch, reduced=args.reduced,
                            ckpt_dir=args.ckpt_dir),
        train=TrainStageConfig(qat_steps=0, final_finetune_steps=0),
        serve=ServeStageConfig(mode=args.mode, compress_k=args.compress_k,
                               plans=tuple(args.plans or ()),
                               plans_dir=args.plans_dir,
                               requests=args.batch,
                               prompt_len=args.prompt_len,
                               new_tokens=args.new_tokens, mixed=args.mixed,
                               max_batch=args.max_batch,
                               temperature=args.temperature),
    )
    pipe = Pipeline(cfg)
    plan = pipe.run_until("serve", verbose=True)
    m = plan.metrics

    print(f"serving {pipe.target.name}: {m['n_params']/1e6:.1f}M params")
    if args.compress_k:
        print(f"compressed export: {m['export_layers']} matmuls, "
              f"{m['export_weight_bytes_packed'] / 1e6:.2f} MB packed "
              f"({m['export_compression_vs_int8']:.2f}x vs int8), "
              f"LUT parity max rel err "
              f"{m['export_parity_max_rel_err']:.2e}")

    if m.get("serve_mode") == "fleet":
        rep = pipe.target.last_fleet_report
        print(f"fleet [{m['serve_plans']}]: {m['serve_requests']} requests "
              f"({m['serve_tokens_per_s']:.1f} tok/s), "
              f"{m['serve_level_degrades']} degrades / "
              f"{m['serve_level_recovers']} recovers, "
              f"{m['serve_recompiles_after_warmup']} recompiles after warmup")
        for pid, p in rep["plans"].items():
            print(f"  plan {pid}: {p['requests']} requests, "
                  f"{p['new_tokens']} tokens, {p['energy_eu']:.3g} eu")
        for tid, t in sorted(rep["tenants"].items()):
            print(f"  tenant {tid}: {t['requests']} requests, "
                  f"{t['new_tokens']} tokens, {t['energy_eu']:.3g} eu, "
                  f"SLO {t['slo_hits']}/{t['slo_total']}")
        results = pipe.target.last_serve_results
        for rid in sorted(results)[:2]:
            print(f"  req{rid}: {results[rid].tokens[:10]}...")
        if args.plan_out:
            json_path, npz_path = plan.save(args.plan_out)
            print(f"plan saved: {json_path} + {npz_path}")
        return

    print(f"{args.mode}: {m['serve_requests']} requests, "
          f"{m['serve_new_tokens']} tokens in {m['serve_wall_s']:.2f}s "
          f"({m['serve_tokens_per_s']:.1f} tok/s), "
          f"latency p50/p99 {m['serve_latency_p50_s']*1e3:.0f}/"
          f"{m['serve_latency_p99_s']*1e3:.0f} ms, "
          f"ttft p50 {m['serve_ttft_p50_s']*1e3:.0f} ms, "
          f"energy {m['serve_energy_eu_total']:.3g} eu "
          f"({m['serve_energy_eu_per_token']:.3g} eu/token), "
          f"{m['serve_cache_buckets_compiled']} buckets / "
          f"{m['serve_cache_compile_count']} compiles")
    results = pipe.target.last_serve_results
    for rid in sorted(results)[:2]:
        print(f"  req{rid}: {results[rid].tokens[:10]}...")
    if args.plan_out:
        json_path, npz_path = plan.save(args.plan_out)
        print(f"plan saved: {json_path} + {npz_path}")


if __name__ == "__main__":
    main()
