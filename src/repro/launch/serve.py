"""Serving launcher: batched prefill + decode driver around `serve_step`.

Production shape: restore params from a checkpoint (mesh-elastic), build the
decode cache, run greedy/temperature decoding over a request batch. On this
CPU host it drives reduced configs (examples/serve_lm.py shows the same flow
scripted); on a pod the identical code runs under `make_production_mesh()`
with the sharding rules of `repro.distributed.sharding`.

    python -m repro.launch.serve --arch gemma3-4b --reduced --batch 4

``--compress-k N`` additionally restricts every eligible matmul to an
N-value codebook, exports the packed 4-bit serving artifacts
(`repro.core.lm_compress.export_lm_matmuls`), and verifies the LUT GEMM
against the fake-quant matmul before serving (see docs/serving.md).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params, spec_count


def compress_report(model, params, k: int, *, block_k: int = 128,
                    check_units: int = 4, seed: int = 2):
    """Export eligible LM matmuls at codebook size ``k`` and verify parity.

    Restricts every eligible matmul to a symmetric k-value codebook, exports
    the packed 4-bit artifacts, and checks the LUT GEMM against the QAT
    fake-quant matmul on random activations for ``check_units`` units.
    Returns (artifacts, summary dict).
    """
    import numpy as np

    from repro.core import lm_compress, qat
    from repro.core.export import export_summary, serve_dense

    # restricted set of exactly k values: 0 plus levels spread over the int8
    # range (one extra negative level when k is even)
    n_neg = k // 2
    n_pos = k - 1 - n_neg
    values = sorted(
        {0}
        | {-int(v) for v in np.linspace(16, 120, n_neg)}
        | {int(v) for v in np.linspace(16, 120, n_pos)})
    assert len(values) == k, (k, values)

    comp = lm_compress.init_lm_comp(model)
    for path in lm_compress.lm_comp_layers(model):
        comp = lm_compress.set_codebook(comp, path, values)
    arts = lm_compress.export_lm_matmuls(model, params, comp, block_k=block_k)
    summary = export_summary(arts)

    checked = {}
    for name, w, c, layout in lm_compress.iter_restricted_units(
            model, params, comp):
        if len(checked) >= check_units or name not in arts:
            break
        art = arts[name]
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, art.k_dim))
        w_fake = qat.fake_quant_weight(w, c)
        w_mat = (w_fake.reshape(w.shape[0], -1) if layout == "in_first"
                 else w_fake.reshape(-1, w.shape[-1]))
        want = x @ w_mat
        got = serve_dense(x, art)
        rel = float(jnp.linalg.norm(got - want)
                    / jnp.maximum(jnp.linalg.norm(want), 1e-9))
        checked[name] = rel
    summary["parity_checked"] = checked
    summary["parity_max_rel_err"] = max(checked.values()) if checked else 0.0
    return arts, summary


def generate(model, params, prompts: jax.Array, *, new_tokens: int,
             temperature: float = 0.0, seed: int = 0, q_block: int = 8,
             kv_block: int = 8):
    """Batched generation: prefill once, then scan decode steps."""
    b, s = prompts.shape
    max_len = s + new_tokens
    logits, cache = model.prefill(params, prompts, max_len=max_len,
                                  cache_dtype=jnp.float32, q_block=q_block,
                                  kv_block=kv_block)

    def sample(lg, key):
        lg = lg[:, -1, :model.cfg.vocab] if lg.ndim == 3 else lg[:, :model.cfg.vocab]
        if temperature <= 0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(key, lg / temperature, axis=-1)

    key = jax.random.PRNGKey(seed)
    tok = sample(logits, key)[:, None]
    decode = jax.jit(model.decode_step)

    outs = [tok]
    for i in range(new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        key = jax.random.fold_in(key, i)
        tok = sample(logits[:, 0], key)[:, None]
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a CheckpointManager directory")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--compress-k", type=int, default=0,
                    help="restrict eligible matmuls to a k-value codebook, "
                         "export packed 4-bit artifacts, verify LUT parity")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    print(f"serving {cfg.name}: {spec_count(model.spec)/1e6:.1f}M params")

    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        step, state = ckpt.restore()
        params = state["params"] if "params" in state else state
        print(f"restored checkpoint step {step}")
    else:
        params = init_params(jax.random.PRNGKey(0), model.spec)

    if args.compress_k:
        arts, summary = compress_report(model, params, args.compress_k)
        print(f"compressed export: {summary['layers']} matmuls, "
              f"{summary['weight_bytes_packed'] / 1e6:.2f} MB packed "
              f"({summary['compression_vs_int8']:.2f}x vs int8), "
              f"LUT parity max rel err "
              f"{summary['parity_max_rel_err']:.2e}")

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(model, params, prompts, new_tokens=args.new_tokens,
                   temperature=args.temperature)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.new_tokens} tokens in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"  req{i}: {list(map(int, out[i, :10]))}...")


if __name__ == "__main__":
    main()
