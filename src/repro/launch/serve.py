"""Serving launcher: batched prefill + decode driver around `serve_step`.

Production shape: restore params from a checkpoint (mesh-elastic), build the
decode cache, run greedy/temperature decoding over a request batch. On this
CPU host it drives reduced configs (examples/serve_lm.py shows the same flow
scripted); on a pod the identical code runs under `make_production_mesh()`
with the sharding rules of `repro.distributed.sharding`.

    python -m repro.launch.serve --arch gemma3-4b --reduced --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params, spec_count


def generate(model, params, prompts: jax.Array, *, new_tokens: int,
             temperature: float = 0.0, seed: int = 0, q_block: int = 8,
             kv_block: int = 8):
    """Batched generation: prefill once, then scan decode steps."""
    b, s = prompts.shape
    max_len = s + new_tokens
    logits, cache = model.prefill(params, prompts, max_len=max_len,
                                  cache_dtype=jnp.float32, q_block=q_block,
                                  kv_block=kv_block)

    def sample(lg, key):
        lg = lg[:, -1, :model.cfg.vocab] if lg.ndim == 3 else lg[:, :model.cfg.vocab]
        if temperature <= 0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(key, lg / temperature, axis=-1)

    key = jax.random.PRNGKey(seed)
    tok = sample(logits, key)[:, None]
    decode = jax.jit(model.decode_step)

    outs = [tok]
    for i in range(new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        key = jax.random.fold_in(key, i)
        tok = sample(logits[:, 0], key)[:, None]
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a CheckpointManager directory")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    print(f"serving {cfg.name}: {spec_count(model.spec)/1e6:.1f}M params")

    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        step, state = ckpt.restore()
        params = state["params"] if "params" in state else state
        print(f"restored checkpoint step {step}")
    else:
        params = init_params(jax.random.PRNGKey(0), model.spec)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(model, params, prompts, new_tokens=args.new_tokens,
                   temperature=args.temperature)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.new_tokens} tokens in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"  req{i}: {list(map(int, out[i, :10]))}...")


if __name__ == "__main__":
    main()
