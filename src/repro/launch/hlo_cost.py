"""Loop-corrected HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*,
regardless of trip count — scan-over-layers models therefore under-report
FLOPs/bytes by ~n_layers (verified: a scanned 8-step matmul reports 1/8 the
flops of its unrolled twin). This walker re-derives costs from the optimized
HLO text with loop multiplicity:

  * builds name -> shape for every instruction,
  * per computation sums dot FLOPs (2 * prod(result) * contracted_size,
    batch dims handled) and a bytes-accessed estimate (operands + result of
    top-level ops, mirroring XLA's convention for fusions),
  * resolves the call graph (fusions via calls=, while body/condition with
    the trip count parsed from the canonical `compare(iv, constant), LT`
    condition, conditionals take the max branch),
  * multiplies through and returns entry-computation totals.

Collective result bytes are multiplied the same way (a collective inside the
layer scan fires once per layer).
"""

from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DEF_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"^\(?(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*{\s*$")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_DOT_DIMS = {
    "lhs_contracting_dims": re.compile(r"lhs_contracting_dims=\{([\d,]*)\}"),
    "lhs_batch_dims": re.compile(r"lhs_batch_dims=\{([\d,]*)\}"),
}


def _parse_shape(rhs: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE_RE.match(rhs)
    if not m:
        return None
    dt, dims = m.group(1), m.group(2)
    if dt == "tuple":
        return None
    shape = tuple(int(x) for x in dims.split(",") if x)
    return dt, shape


def _nelem(shape) -> int:
    return math.prod(shape) if shape else 1


def _bytes_of(sig) -> int:
    if sig is None:
        return 0
    dt, shape = sig
    return _nelem(shape) * _DTYPE_BYTES.get(dt, 4)


class HloCost:
    def __init__(self, hlo_text: str):
        self.ops_by_comp: Dict[str, List[dict]] = {}
        self.shapes: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, dict] = {}

    # ------------------------------------------------------------- parsing

    def _parse(self, text: str) -> None:
        comp = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr and ("{" in line):
                comp = hdr.group(1)
                self.ops_by_comp.setdefault(comp, [])
                if line.strip().startswith("ENTRY"):
                    self.entry = comp
                continue
            if comp is None:
                continue
            if line.strip() == "}":
                comp = None
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(2), m.group(3)
            sig = _parse_shape(rhs)
            if sig:
                self.shapes[name] = sig
            self.ops_by_comp[comp].append({"name": name, "rhs": rhs,
                                           "sig": sig})

    # ---------------------------------------------------------- per-op cost

    def _dot_flops(self, op) -> float:
        rhs = op["rhs"]
        if " dot(" not in rhs:
            return 0.0
        sig = op["sig"]
        if sig is None:
            return 0.0
        operands = _OPERAND_RE.findall(rhs.split("dot(", 1)[1])
        lhs_sig = self.shapes.get(operands[0]) if operands else None
        contracted = 1
        m = _DOT_DIMS["lhs_contracting_dims"].search(rhs)
        if lhs_sig and m:
            for d in m.group(1).split(","):
                if d:
                    contracted *= lhs_sig[1][int(d)]
        return 2.0 * _nelem(sig[1]) * contracted

    def _op_bytes(self, op) -> int:
        rhs = op["rhs"]
        total = _bytes_of(op["sig"])
        inner = rhs.split("(", 1)
        if len(inner) == 2:
            for name in _OPERAND_RE.findall(inner[1]):
                if name in self.shapes:
                    total += _bytes_of(self.shapes[name])
        return total

    def _trip_count(self, cond_comp: str) -> int:
        consts = []
        for op in self.ops_by_comp.get(cond_comp, []):
            m = _CONST_RE.search("= " + op["rhs"]) or _CONST_RE.search(op["rhs"])
            if "constant(" in op["rhs"] and op["rhs"].startswith("s32[]"):
                mm = re.search(r"constant\((\d+)\)", op["rhs"])
                if mm:
                    consts.append(int(mm.group(1)))
            del m
        # canonical scan condition: iv < N; take the largest s32 constant
        return max(consts) if consts else 1

    # --------------------------------------------------------- aggregation

    def comp_cost(self, comp: str) -> dict:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = {"flops": 0.0, "bytes": 0.0,
                            "collectives": {k: 0.0 for k in _COLLECTIVES}}
        flops = 0.0
        bytes_ = 0.0
        coll = {k: 0.0 for k in _COLLECTIVES}
        for op in self.ops_by_comp.get(comp, []):
            rhs = op["rhs"]
            if " while(" in rhs:
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", rhs)
                mc = re.search(r"condition=%?([\w\.\-]+)", rhs)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                trips = self._trip_count(cond) if cond else 1
                if body:
                    sub = self.comp_cost(body)
                    flops += sub["flops"] * trips
                    bytes_ += sub["bytes"] * trips
                    for k in coll:
                        coll[k] += sub["collectives"][k] * trips
                continue
            if " conditional(" in rhs:
                m = _BRANCH_RE.search(rhs)
                if m:
                    subs = [self.comp_cost(c.strip().lstrip("%"))
                            for c in m.group(1).split(",")]
                    if subs:
                        best = max(subs, key=lambda s: s["flops"] + s["bytes"])
                        flops += best["flops"]
                        bytes_ += best["bytes"]
                        for k in coll:
                            coll[k] += best["collectives"][k]
                continue
            called = _CALL_RE.search(rhs)
            if called and (" fusion(" in rhs or " call(" in rhs
                           or " custom-call(" in rhs or " map(" in rhs
                           or " reduce(" in rhs or " sort(" in rhs
                           or " scatter(" in rhs or " select-and-scatter(" in rhs):
                sub = self.comp_cost(called.group(1))
                flops += sub["flops"]
                for k in coll:
                    coll[k] += sub["collectives"][k]
                # bytes: fusion counts its own operands/result, not internals
                bytes_ += self._op_bytes(op)
                continue
            flops += self._dot_flops(op)
            is_coll = False
            for kind in _COLLECTIVES:
                if f" {kind}(" in rhs or f" {kind}-start(" in rhs:
                    coll[kind] += _bytes_of(op["sig"])
                    is_coll = True
                    break
            if "-done(" in rhs and is_coll:
                coll[kind] -= _bytes_of(op["sig"])  # avoid double count
            bytes_ += self._op_bytes(op)
        out = {"flops": flops, "bytes": bytes_, "collectives": coll}
        self._memo[comp] = out
        return out

    def entry_cost(self) -> dict:
        assert self.entry, "no ENTRY computation found"
        out = dict(self.comp_cost(self.entry))
        out["collective_total_bytes"] = sum(out["collectives"].values())
        return out


def loop_corrected_cost(hlo_text: str) -> dict:
    return HloCost(hlo_text).entry_cost()
