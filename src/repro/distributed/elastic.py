"""Elastic scaling: restore any checkpoint onto any mesh.

Checkpoints are host-side numpy (see repro.checkpoint.manager), so elastic
restarts reduce to: build the new mesh from the devices that are actually
healthy, re-derive shardings from the (unchanged) logical axis rules, and
device_put. Because our sharding rules guard on divisibility per tensor, the
same rules produce valid placements at any power-of-two slice of the fleet —
a 2x16x16 job can resume on 16x16 or 8x16 without code changes.
"""

from __future__ import annotations

from typing import Any, Optional

import numpy as np

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import DEFAULT_RULES, ShardingRules


def available_mesh(model_parallel: int, *, axis_names=("data", "model"),
                   devices=None) -> Mesh:
    """Largest (data, model) mesh the healthy devices support."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    assert n % model_parallel == 0, (n, model_parallel)
    arr = np.asarray(devices[: (n // model_parallel) * model_parallel])
    return Mesh(arr.reshape(n // model_parallel, model_parallel), axis_names)


def elastic_restore(
    ckpt,                       # CheckpointManager
    model,                      # LMModel (for sharding re-derivation)
    mesh: Mesh,
    *,
    step: Optional[int] = None,
    rules: ShardingRules = DEFAULT_RULES,
) -> tuple[int, Any]:
    """Restore a train state onto `mesh` regardless of the mesh it was saved
    under."""
    from repro.launch.train import train_state_shardings

    shardings = train_state_shardings(model, mesh, rules)
    return ckpt.restore(step, shardings=shardings)


def reshard(state_host: Any, shardings: Any) -> Any:
    """device_put a host-side state tree onto new shardings."""
    return jax.tree.map(jax.device_put, state_host, shardings)
