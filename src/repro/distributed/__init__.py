"""Distribution: sharding rules, collectives, elasticity, fault tolerance."""

from repro.distributed.sharding import (  # noqa: F401
    DEFAULT_RULES,
    ShardingRules,
    logical_to_spec,
    make_param_shardings,
)
