"""Fault tolerance: resilient training loop, straggler detection, heartbeats.

`run_resilient_loop` is the production driver shape: a step function, a
deterministic step-indexed data source, a CheckpointManager, and a fault
policy. On any step failure (device loss manifests as an exception in the
runtime) the loop restores the last checkpoint and replays — the data
pipeline being a pure function of the step index guarantees bit-identical
replay. Fault injection hooks let tests exercise the recovery path.

`StragglerMonitor` tracks per-step wall times against a rolling median and
flags outliers; on real multi-host deployments its callback triggers
checkpoint + elastic rescale (see repro.distributed.elastic). Heartbeats are
recorded per logical worker so a coordinator can distinguish slow from dead.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class StragglerMonitor:
    """Rolling-median step-time outlier detection."""

    window: int = 32
    threshold: float = 2.5          # step > threshold x median => straggler
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    times: List[float] = dataclasses.field(default_factory=list)
    flagged: List[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        recent = self.times[-self.window:]
        if len(recent) >= 8:
            med = statistics.median(recent)
            if seconds > self.threshold * med:
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, seconds, med)
                return True
        return False


@dataclasses.dataclass
class Heartbeat:
    """Per-worker liveness registry (single-host simulation of the
    coordinator-side bookkeeping)."""

    timeout: float = 60.0
    last_seen: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, now: Optional[float] = None) -> None:
        self.last_seen[worker] = now if now is not None else time.time()

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return [w for w, t in self.last_seen.items() if now - t > self.timeout]


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    failures: int
    restores: int
    final_step: int
    losses: List[float]
    stragglers: List[int]


def run_resilient_loop(
    *,
    step_fn: Callable,                 # (state, batch) -> (state, metrics)
    data_fn: Callable[[int], Any],     # step -> batch (pure, deterministic)
    state: Any,
    ckpt: "CheckpointManager",
    n_steps: int,
    start_step: int = 0,
    checkpoint_every: int = 50,
    max_restores: int = 10,
    fault_hook: Optional[Callable[[int], None]] = None,  # raise to inject
    monitor: Optional[StragglerMonitor] = None,
) -> tuple[Any, LoopReport]:
    """Run with checkpoint/restart semantics. Restores after any exception
    in step_fn (or the injected fault) and replays from the last snapshot."""
    from repro.checkpoint.manager import CheckpointManager  # noqa: F401

    step = start_step
    failures = restores = ran = 0
    losses: List[float] = []
    if ckpt.latest_step() is None:
        ckpt.save(step, state, block=True)

    while step < start_step + n_steps:
        try:
            if fault_hook is not None:
                fault_hook(step)
            t0 = time.time()
            batch = data_fn(step)
            state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            if monitor is not None:
                monitor.record(step, dt)
            loss = metrics.get("loss") if isinstance(metrics, dict) else None
            if loss is not None:
                losses.append(float(loss))
            ran += 1
            step += 1
            if step % checkpoint_every == 0:
                ckpt.save(step, state)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            failures += 1
            if restores >= max_restores:
                raise
            ckpt.wait()
            restored_step, state = ckpt.restore()
            step = restored_step
            restores += 1
    ckpt.save(step, state, block=True)
    report = LoopReport(
        steps_run=ran, failures=failures, restores=restores, final_step=step,
        losses=losses, stragglers=(monitor.flagged if monitor else []))
    return state, report
