"""Logical-axis -> mesh sharding rules with a divisibility guard.

Parameters/caches carry *logical* axis names (see `repro.nn.spec.ParamSpec`);
this module maps them onto mesh axes:

    batch    -> ("pod", "data")   (data parallel, across pods too)
    vocab    -> "model"           (vocab is padded to 256 so it always divides)
    heads    -> "model"           (tensor parallel attention)
    kv_heads -> "model"
    mlp      -> "model"           (tensor parallel FFN)
    expert   -> "model"           (expert parallel MoE)
    inner    -> "model"           (SSM/RG-LRU inner dim)
    embed    -> "data"            (FSDP: parameters+optimizer sharded over
                                   the data axis; gathered per layer)
    layers   -> None              (scan axis; a future PP axis would go here)

**Divisibility guard**: a logical axis whose dimension does not divide the
product of its mesh axes falls back to replication for that tensor (logged).
E.g. recurrentgemma's 10 heads or whisper's 20 heads on a 16-way model axis
replicate, while their mlp/inner dims still shard 16-way. This is what makes
one rule set serve all 10 assigned architectures without per-arch special
cases — and the guard report is part of the dry-run manifest.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.nn.spec import ParamSpec, is_spec

log = logging.getLogger(__name__)

AxisVal = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Tuple[Tuple[str, AxisVal], ...]

    def lookup(self, logical: Optional[str]) -> AxisVal:
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def replace(self, **kw) -> "ShardingRules":
        new = []
        for k, v in self.rules:
            new.append((k, kw.pop(k, v)))
        for k, v in kw.items():
            new.append((k, v))
        return ShardingRules(tuple(new))


DEFAULT_RULES = ShardingRules((
    ("batch", ("pod", "data")),
    ("seq", "model"),        # sequence parallelism opt-in (see §Perf log:
                             # hurts on this XLA pipeline, kept as a knob)
    ("kv_seq", "model"),     # decode-cache sequence sharding (opt-in; used
                             # when kv_heads cannot divide the model axis)
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),
    ("moe_ff", None),        # expert FFN dim; switch with expert=None,
                             # moe_ff=model for tensor-parallel experts
    ("moe_embed", "data"),   # expert d_model dim (FSDP by default; experts
                             # are E-sharded already, so moe_embed=None drops
                             # the per-layer expert weight gathers)
    ("inner", "model"),
    ("embed", "data"),
    ("layers", None),
))


def _mesh_size(mesh: Mesh, axis: AxisVal) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.axis_names else 1
    return int(np.prod([mesh.shape[a] for a in axis if a in mesh.axis_names]))


def _present(mesh: Mesh, axis: AxisVal) -> AxisVal:
    """Drop mesh axes that don't exist in this mesh (e.g. 'pod' single-pod)."""
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in mesh.axis_names else None
    kept = tuple(a for a in axis if a in mesh.axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
    *,
    guard_report: Optional[List[str]] = None,
    tensor_name: str = "",
) -> PartitionSpec:
    """PartitionSpec for one tensor, applying the divisibility guard and
    ensuring no mesh axis is consumed twice."""
    used: set = set()
    parts = []
    for dim, logical in zip(shape, logical_axes):
        axis = _present(mesh, rules.lookup(logical))
        if axis is None:
            parts.append(None)
            continue
        axis_tuple = (axis,) if isinstance(axis, str) else tuple(axis)
        if any(a in used for a in axis_tuple):
            parts.append(None)
            continue
        size = _mesh_size(mesh, axis)
        if size <= 1:
            parts.append(None)
            continue
        if dim % size != 0:
            if guard_report is not None:
                guard_report.append(
                    f"{tensor_name}: dim {dim} (logical '{logical}') not "
                    f"divisible by mesh axis {axis} (size {size}); replicated")
            parts.append(None)
            continue
        parts.append(axis)
        used.update(axis_tuple)
    # trailing Nones can be dropped but are harmless
    return PartitionSpec(*parts)


def make_param_shardings(
    spec_tree,
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
    *,
    guard_report: Optional[List[str]] = None,
):
    """NamedSharding tree for a ParamSpec tree."""

    def one(s: ParamSpec) -> NamedSharding:
        axes = s.axes if s.axes else (None,) * len(s.shape)
        spec = logical_to_spec(axes, s.shape, mesh, rules,
                               guard_report=guard_report,
                               tensor_name="x".join(map(str, s.shape)))
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, spec_tree, is_leaf=is_spec)


def shardings_from_axes_tree(
    axes_tree,
    shape_tree,
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
    *,
    guard_report: Optional[List[str]] = None,
):
    """NamedShardings for an arbitrary pytree given parallel axes/shape trees
    (used for caches and batches). Axes-tree leaves are tuples."""
    is_tup = lambda x: isinstance(x, tuple) or x is None  # noqa: E731
    axes_leaves, treedef = jax.tree.flatten(axes_tree, is_leaf=is_tup)
    shape_leaves = jax.tree.leaves(shape_tree)
    assert len(axes_leaves) == len(shape_leaves), (
        len(axes_leaves), len(shape_leaves))
    out = []
    for axes, sds in zip(axes_leaves, shape_leaves):
        axes = axes if axes is not None else (None,) * len(sds.shape)
        spec = logical_to_spec(axes, sds.shape, mesh, rules,
                               guard_report=guard_report,
                               tensor_name="x".join(map(str, sds.shape)))
        out.append(NamedSharding(mesh, spec))
    return jax.tree.unflatten(treedef, out)


def batch_sharding(mesh: Mesh, shape: Sequence[int],
                   rules: ShardingRules = DEFAULT_RULES,
                   batch_dim: int = 0) -> NamedSharding:
    """Shard only the batch dim of an activation/batch tensor (guarded:
    a batch that does not divide the data axes replicates, e.g. batch=1
    long-context decode)."""
    axis = _present(mesh, rules.lookup("batch"))
    parts: list = [None] * len(shape)
    if axis is not None and shape[batch_dim] % _mesh_size(mesh, axis) == 0:
        parts[batch_dim] = axis
    return NamedSharding(mesh, PartitionSpec(*parts))


TILE_AXIS = "tiles"


def tile_mesh(devices: Optional[Sequence] = None) -> Mesh:
    """1-D ("tiles",) mesh over the host's devices for batched profiling.

    The profiler stacks every sampled systolic tile of a layer into
    (n_tiles, 64, 64) / (n_tiles, 64, T) batches; sharding the leading dim
    over this mesh runs each device's tile slice locally and psum-reduces the
    four (small, fixed-size) statistics outputs. Built lazily — importing
    this module never touches jax device state."""
    devs = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.asarray(devs), (TILE_AXIS,))


SWEEP_AXIS = "candidates"


def sweep_mesh(devices: Optional[Sequence] = None) -> Mesh:
    """1-D ("candidates",) mesh for the schedule's batched candidate sweep.

    Mirrors `tile_mesh`: the layer-wise schedule stacks every candidate
    ``(prune_ratio, k_target)`` trial — its comp tree plus the diverging
    params/opt_state copies — along a leading candidate axis; sharding that
    axis over this mesh trains and evaluates each device's candidate slice
    locally with no collectives (accept decisions need only the per-candidate
    accuracy vector, gathered at the end). `CnnRunner` pads the candidate
    batch to a multiple of the axis size and discards the padded slots.
    Built lazily — importing this module never touches jax device state."""
    devs = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.asarray(devs), (SWEEP_AXIS,))


def tile_batch_sharding(mesh: Mesh, axis: str = TILE_AXIS) -> NamedSharding:
    """NamedSharding for a stacked tile batch: leading (tile) dim over
    ``axis``, tile contents replicated. Callers pad n_tiles to a multiple of
    the axis size (the profiler masks the padding's contribution)."""
    return NamedSharding(mesh, PartitionSpec(axis))


def logits_constraint(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    """Callable for (B, S, V) logits: batch over ("pod","data"), vocab over
    "model" — keeps the fp32 logits (the largest train-time tensor) fully
    sharded instead of replicated over the model axis."""
    b_axis = _present(mesh, rules.lookup("batch"))
    v_axis = _present(mesh, rules.lookup("vocab"))

    def shard(x):
        parts = [b_axis] + [None] * (x.ndim - 2) + [v_axis]
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*parts)))

    return shard


def activation_constraint(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES,
                          *, sequence_parallel: bool = False):
    """Callable applied to (B, S, d) residual-stream activations inside the
    model: batch over ("pod","data"); with ``sequence_parallel`` the seq dim
    is additionally sharded over "model" (Megatron-SP style) — this is what
    keeps the per-layer saved residuals (the dominant train-time buffer,
    O(L x B x S x D)) sharded 16-ways instead of replicated on the model
    axis. Attention/collectives re-gather transiently inside the layer.

    Divisibility guards run per call: decode steps (S=1) and odd shapes fall
    back to batch-only sharding automatically.
    """
    b_axis = _present(mesh, rules.lookup("batch"))
    s_axis = _present(mesh, rules.lookup("seq")) if sequence_parallel else None
    b_size = _mesh_size(mesh, b_axis)
    s_size = _mesh_size(mesh, s_axis)

    def shard(x):
        ba = b_axis if (b_axis and x.shape[0] % b_size == 0 and b_size > 1) else None
        sa = None
        if x.ndim >= 3 and s_axis and s_size > 1 and x.shape[1] % s_size == 0:
            sa = s_axis
        parts = [ba, sa] + [None] * (x.ndim - 2)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*parts[:x.ndim])))

    return shard


REQUEST_AXIS = "requests"


def request_mesh(devices: Optional[Sequence] = None) -> Mesh:
    """1-D ("requests",) mesh for the serving engine's optional sharded
    decode.

    Mirrors `tile_mesh`/`sweep_mesh`: every tensor of a serving wave —
    padded prompts, the decode cache, the per-step token column — carries the
    wave's request slots on its leading batch dim; sharding that dim over
    this mesh runs each device's slot slice locally (attention, FFN and
    cache updates are batch-independent, so decode needs no collectives
    until the host gathers logits for sampling). `ServingEngine(mesh=...)`
    replicates params and shards batch-major arrays whose leading dim
    divides the mesh. Built lazily — importing this module never touches jax
    device state."""
    devs = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.asarray(devs), (REQUEST_AXIS,))
