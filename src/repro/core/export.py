"""Compressed serving export: post-schedule comp tree -> packed artifacts.

The schedule's output per layer is a `repro.core.qat.CompState` (pruning mask
+ restricted int8 codebook C_l, |C_l| <= 16). Deployment stores only what the
systolic array needs (paper Section 4 / Fig. 5):

  * ``packed``    (K_pad//2, N) int8 — 4-bit codebook indices, two K rows per
                  byte in the block-local layout of `pack_indices`,
  * ``codebook``  (16,) int8 — the layer's restricted weight set,
  * ``scale``     (N,) float32 — per-output-channel symmetric dequant scale.

`export_layer` mirrors `qat.fake_quant_weight` exactly (mask -> scale of the
masked weight -> round/clip -> nearest-C_l projection), so the served forward
agrees with the QAT fake-quant forward to float round-off. The one deliberate
divergence: pruned positions always serve as exact 0 (0 is force-included in
the serving codebook), i.e. zero-gated MACs stay zero-gated even if C_l
itself lacks 0 — the schedule always keeps 0, so in practice the paths agree.

Weight-matrix layouts (K = reduction axis, N = output channels):

  * ``out_last``: contraction over all leading axes — dense (in, out), conv
    HWIO (kh, kw, cin, cout) (reshape(-1, cout) matches the `im2col` row
    order), attention wo (H, hd, m);
  * ``in_first``: contraction over axis 0, outputs flattened — attention
    wq/wk/wv (m, H, hd) and other (in, *out) projections.

K is padded to a `block_k` multiple at export; the serve helpers zero-pad
activations over K so padded rows never contribute.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qat
from repro.kernels.lut_matmul.ops import (
    N_CODES,
    compress_layer_weights,
    lut_matmul_fused,
)


@dataclasses.dataclass
class ServeArtifact:
    """Packed 4-bit serving form of one compressed matmul weight."""

    packed: jax.Array        # (K_pad//2, N) int8
    codebook: jax.Array      # (16,) int8
    scale: jax.Array         # (N,) float32
    k_dim: int               # unpadded reduction dim (= X's contraction size)
    n_dim: int               # output channels
    block_k: int
    kind: str = "dense"      # "dense" | "conv"
    kernel: int = 1          # conv spatial kernel size (1 for dense)

    @property
    def weight_bytes(self) -> int:
        """Serving footprint: packed nibbles + codebook + f32 scales."""
        return int(self.packed.size + self.codebook.size + self.scale.size * 4)

    @property
    def dense_bytes_int8(self) -> int:
        """What the same (unpadded) weight costs stored as plain int8."""
        return int(self.k_dim * self.n_dim)

    def matmul_dims(self, n_tokens: int):
        """Systolic mapping of this artifact's GEMM for ``n_tokens`` streamed
        columns — bridges packed artifacts to `repro.core.layer_energy`
        (serving-side energy accounting)."""
        from repro.core.layer_energy import dense_matmul_dims

        return dense_matmul_dims(fan_in=self.k_dim, fan_out=self.n_dim,
                                 n_tokens=n_tokens)


def _flatten_tree(art: ServeArtifact):
    return (art.packed, art.codebook, art.scale), (
        art.k_dim, art.n_dim, art.block_k, art.kind, art.kernel)


def _unflatten_tree(aux, children):
    packed, codebook, scale = children
    k_dim, n_dim, block_k, kind, kernel = aux
    return ServeArtifact(packed, codebook, scale, k_dim, n_dim, block_k,
                         kind, kernel)


# registered as a pytree so artifact dicts pass through jit as data args
# (shapes/layout metadata ride in aux_data and stay static)
jax.tree_util.register_pytree_node(
    ServeArtifact, _flatten_tree, _unflatten_tree)


def servable(comp: qat.CompState) -> bool:
    """A layer can take the 4-bit LUT path iff its restriction is active and
    fits the 16-entry hardware codebook."""
    k = int(comp["codebook_k"])
    return 0 < k <= N_CODES


def _weight_matrix(qp: jax.Array, scale: jax.Array, layout: str
                   ) -> Tuple[jax.Array, jax.Array]:
    """Projected int weights + broadcast scale -> ((K, N) ints, (N,) scale)."""
    scale_full = jnp.broadcast_to(scale, qp.shape)
    if layout == "out_last":
        mat = qp.reshape(-1, qp.shape[-1])
        scale_n = scale_full.reshape(-1, qp.shape[-1])[0]
    elif layout == "in_first":
        mat = qp.reshape(qp.shape[0], -1)
        scale_n = scale_full[0].reshape(-1)
    else:
        raise ValueError(f"unknown layout {layout!r}")
    return mat, scale_n


def export_layer(
    w: jax.Array,
    comp: qat.CompState,
    *,
    kind: str = "dense",
    layout: str = "out_last",
    block_k: int = 128,
) -> Optional[ServeArtifact]:
    """Export one compressed weight tensor; None if it is not servable.

    Follows `qat.fake_quant_weight` step for step so the dequantized serving
    weights equal the fake-quant weights bit for bit (modulo the forced-0
    treatment of pruned positions, see module docstring).
    """
    if not servable(comp):
        return None
    if kind == "conv" and w.shape[0] != w.shape[1]:
        raise ValueError(
            f"serve_conv assumes square conv kernels, got {w.shape[:2]}")
    k_valid = int(comp["codebook_k"])
    values = sorted({int(v) for v in jnp.asarray(comp["codebook"])[:k_valid]})

    # the training scale reduces over all axes but the last of the *original*
    # tensor; reshape weight/mask/scale to the (K, N) serving layout and let
    # `compress_layer_weights` do the (shared) fake-quant-mirroring encode
    mask = comp["mask"].astype(w.dtype)
    scale = qat.weight_scale(w * mask)                # keepdims, per out chan
    w_mat, scale_n = _weight_matrix(w, scale, layout)
    mask_mat, _ = _weight_matrix(mask, scale, layout)
    packed, cb, scale_n = compress_layer_weights(
        w_mat, values, mask=mask_mat, scale=scale_n,
        msr_bits=int(comp.get("msr_bits", 0)), block_k=block_k,
        pad_k=True)

    k_dim, n_dim = w_mat.shape
    kernel = int(w.shape[0]) if kind == "conv" else 1
    return ServeArtifact(packed=packed, codebook=cb.astype(jnp.int8),
                         scale=scale_n.astype(jnp.float32), k_dim=k_dim,
                         n_dim=n_dim, block_k=block_k, kind=kind,
                         kernel=kernel)


def export_model(model, params, comp: Dict[str, qat.CompState], *,
                 block_k: int = 128) -> Dict[str, ServeArtifact]:
    """Export every servable compressible layer of a `CNNModel`.

    Layers whose restriction is inactive (codebook_k == 0) or too large for
    the 4-bit format stay on the fake-quant dense path and are simply absent
    from the returned dict — the serve dispatch in `repro.nn.layers` falls
    back per layer.
    """
    out: Dict[str, ServeArtifact] = {}
    for cl in model.comp_layers:
        art = export_layer(
            model.get_weight(params, cl.name), comp[cl.name],
            kind=cl.kind, layout="out_last", block_k=block_k)
        if art is not None:
            out[cl.name] = art
    return out


# ------------------------------------------------------------- serve forwards


def _pad_k(x2d: jax.Array, art: ServeArtifact) -> jax.Array:
    pad = 2 * art.packed.shape[0] - art.k_dim
    return jnp.pad(x2d, ((0, 0), (0, pad))) if pad else x2d


def serve_dense(x: jax.Array, art: ServeArtifact, *,
                bias: Optional[jax.Array] = None,
                residual: Optional[jax.Array] = None,
                activation: str = "none",
                block_m: Optional[int] = None,
                block_n: Optional[int] = None,
                block_k: Optional[int] = None,
                interpret: Optional[bool] = None,
                use_ref: bool = False) -> jax.Array:
    """(..., K) -> act((..., K) @ packed + bias) + residual, one fused
    LUT-GEMM dispatch.

    Thin dispatcher: flattens leading dims, zero-pads K to the artifact's
    pack block, and hands the epilogue (bias (N,), elementwise activation,
    residual of the output shape) to the kernel. Block shapes left ``None``
    resolve through the roofline autotuner.
    """
    lead = x.shape[:-1]
    x2d = _pad_k(x.reshape(-1, x.shape[-1]), art)
    res2d = None if residual is None else residual.reshape(-1, art.n_dim)
    y = lut_matmul_fused(x2d, art.packed, art.codebook, art.scale,
                         bias=bias, residual=res2d, activation=activation,
                         block_m=block_m, block_n=block_n, block_k=block_k,
                         pack_block=art.block_k, interpret=interpret,
                         use_ref=use_ref)
    return y.reshape(*lead, art.n_dim)


def serve_conv(x: jax.Array, art: ServeArtifact, *, stride: int = 1,
               padding: str = "SAME",
               bias: Optional[jax.Array] = None,
               residual: Optional[jax.Array] = None,
               activation: str = "none",
               block_m: Optional[int] = None,
               block_n: Optional[int] = None,
               interpret: Optional[bool] = None,
               use_ref: bool = False) -> jax.Array:
    """NHWC conv through im2col feeding the fused LUT GEMM (bias/activation/
    residual ride the kernel epilogue). Matches `lax.conv` to fp32 round-off
    (same contraction, different accumulation order)."""
    from repro.core.stats import im2col

    n, h, w_in, _ = x.shape
    kh = kw = art.kernel
    if padding == "SAME":
        ho, wo = -(-h // stride), -(-w_in // stride)
    elif padding == "VALID":
        ho, wo = (h - kh) // stride + 1, (w_in - kw) // stride + 1
    else:
        raise ValueError(padding)
    cols = im2col(x, (kh, kw), stride, padding)       # (K, N*Ho*Wo)
    res2d = None if residual is None \
        else residual.reshape(-1, art.n_dim)          # (N*Ho*Wo, C) row order
    y = serve_dense(cols.T, art, bias=bias, residual=res2d,
                    activation=activation, block_m=block_m, block_n=block_n,
                    interpret=interpret, use_ref=use_ref)
    return y.reshape(n, ho, wo, art.n_dim)


def export_summary(arts: Dict[str, ServeArtifact]) -> Dict[str, float]:
    """Aggregate footprint of an exported model."""
    packed_bytes = sum(a.weight_bytes for a in arts.values())
    int8_bytes = sum(a.dense_bytes_int8 for a in arts.values())
    return {
        "layers": len(arts),
        "weight_bytes_packed": int(packed_bytes),
        "weight_bytes_dense_int8": int(int8_bytes),
        "compression_vs_int8": int8_bytes / max(packed_bytes, 1),
    }
