"""Baselines the paper compares against.

* ``powerpruning_global`` — PowerPruning-style [15]: a *global* MAC energy
  model (layer-averaged LUT) drives a single network-wide restricted weight
  set (default size 32) applied uniformly to every layer, plus a uniform
  pruning ratio. No layer-wise scheduling, no greedy co-optimization.
* ``naive_topk`` — pick the k lowest-energy weight values globally
  (paper 5.3.3 Table 4). Demonstrates catastrophic accuracy collapse at k=16.
* ``global_strategy`` — Table 3's "Global" arm: the co-optimized selection is
  run once on network-aggregated statistics and the same (prune, K) applied
  to all layers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.core import qat
from repro.core.weight_selection import (
    SelectionConfig,
    greedy_backward_elimination,
    initial_candidate_set,
    naive_lowest_energy_set,
)


@dataclasses.dataclass
class BaselineResult:
    name: str
    codebook: List[int]
    prune_ratio: float
    acc_before: float
    acc_after: float
    energy_before: float
    energy_after: float

    @property
    def energy_saving(self) -> float:
        return 1.0 - self.energy_after / max(self.energy_before, 1e-12)


def _global_lut_counts(models: Dict[str, object]):
    """Energy-weighted global LUT + summed counts across layers (the 'global
    activation model' simplification of prior work)."""
    luts = jnp.stack([m.lut for m in models.values()])
    counts = jnp.stack([m.counts for m in models.values()])
    weights = counts.sum(axis=1, keepdims=True)
    lut = (luts * weights).sum(0) / jnp.maximum(weights.sum(0), 1.0)
    return lut, counts.sum(0)


def _apply_global_codebook(runner, comp, values):
    cb, k = qat.make_codebook(values)
    new_comp = {}
    for name, c in comp.items():
        c2 = dict(c)
        c2["codebook"], c2["codebook_k"] = cb, k
        new_comp[name] = c2
    return new_comp


def _apply_uniform_prune(runner, params, comp, ratio: float):
    new_comp = {}
    for cl in runner.model.comp_layers:
        c2 = dict(comp[cl.name])
        w = runner.model.get_weight(params, cl.name)
        c2["mask"] = qat.magnitude_prune_mask(w, ratio)
        new_comp[cl.name] = c2
    return new_comp


def _total_energy(runner, params, comp, models) -> float:
    refreshed = runner.refresh_counts(params, comp, models)
    return float(sum(m.energy for m in refreshed.values()))


def powerpruning_global(
    runner, params, state, opt_state, comp, stats, *,
    k: int = 32, prune_ratio: float = 0.5, finetune_steps: int = 100,
    eval_batches: int = 4,
) -> tuple:
    """PowerPruning-style global selection. Returns (params, state, opt_state,
    comp, BaselineResult)."""
    models = runner.energy_models(params, comp, stats)
    acc0 = runner.accuracy(params, state, comp, n_batches=eval_batches)
    e0 = float(sum(m.energy for m in models.values()))

    lut, counts = _global_lut_counts(models)
    # global joint energy/usage ranking, but no greedy co-optimization
    cfg = SelectionConfig(k_init=k, k_target=k)
    values = initial_candidate_set(counts, lut, cfg)

    comp = _apply_uniform_prune(runner, params, comp, prune_ratio)
    comp = _apply_global_codebook(runner, comp, values)
    params, state, opt_state, _ = runner.train(params, state, opt_state, comp,
                                               finetune_steps)
    acc1 = runner.accuracy(params, state, comp, n_batches=eval_batches)
    e1 = _total_energy(runner, params, comp, models)
    res = BaselineResult("powerpruning[15]", values, prune_ratio, acc0, acc1, e0, e1)
    return params, state, opt_state, comp, res


def naive_topk(
    runner, params, state, opt_state, comp, stats, *,
    k: int = 16, finetune_steps: int = 100, eval_batches: int = 4,
) -> tuple:
    """Naive lowest-energy top-k selection (Table 4)."""
    models = runner.energy_models(params, comp, stats)
    acc0 = runner.accuracy(params, state, comp, n_batches=eval_batches)
    e0 = float(sum(m.energy for m in models.values()))

    lut, _ = _global_lut_counts(models)
    values = naive_lowest_energy_set(lut, k)
    comp = _apply_global_codebook(runner, comp, values)
    params, state, opt_state, _ = runner.train(params, state, opt_state, comp,
                                               finetune_steps)
    acc1 = runner.accuracy(params, state, comp, n_batches=eval_batches)
    e1 = _total_energy(runner, params, comp, models)
    res = BaselineResult(f"naive-top{k}", values, 0.0, acc0, acc1, e0, e1)
    return params, state, opt_state, comp, res


def global_strategy(
    runner, params, state, opt_state, comp, stats, *,
    prune_ratio: float = 0.5, k_target: int = 16, acc0: Optional[float] = None,
    finetune_steps: int = 100, eval_batches: int = 4,
    sel_cfg: Optional[SelectionConfig] = None,
) -> tuple:
    """Table 3 'Global' arm: co-optimized selection on aggregated stats,
    uniform (prune, K) for every layer."""
    models = runner.energy_models(params, comp, stats)
    if acc0 is None:
        acc0 = runner.accuracy(params, state, comp, n_batches=eval_batches)
    e0 = float(sum(m.energy for m in models.values()))
    sel_cfg = sel_cfg or SelectionConfig(k_target=k_target)
    sel_cfg = dataclasses.replace(sel_cfg, k_target=k_target)

    comp = _apply_uniform_prune(runner, params, comp, prune_ratio)
    params, state, opt_state, _ = runner.train(params, state, opt_state, comp,
                                               max(finetune_steps // 2, 1))

    lut, counts = _global_lut_counts(runner.refresh_counts(params, comp, models))
    init_set = initial_candidate_set(counts, lut, sel_cfg)

    # single global elimination: build a pseudo layer model over summed counts
    from repro.core.layer_energy import LayerEnergyModel, MatmulDims

    total_n = sum(m.dims.n for m in models.values())
    pseudo = LayerEnergyModel("global", MatmulDims(64, 64, max(total_n, 64)),
                              lut, counts)

    def eval_with_codebook(values, n_batches):
        c2 = _apply_global_codebook(runner, comp, values)
        return runner.accuracy(params, state, c2, n_batches=n_batches)

    values, _ = greedy_backward_elimination(
        pseudo, init_set, sel_cfg, acc0, eval_with_codebook=eval_with_codebook)

    comp = _apply_global_codebook(runner, comp, values)
    params, state, opt_state, _ = runner.train(params, state, opt_state, comp,
                                               finetune_steps)
    acc1 = runner.accuracy(params, state, comp, n_batches=eval_batches)
    e1 = _total_energy(runner, params, comp, models)
    res = BaselineResult(f"global-p{prune_ratio}-k{k_target}", values,
                         prune_ratio, acc0, acc1, e0, e1)
    return params, state, opt_state, comp, res
