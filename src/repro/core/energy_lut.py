"""Per-layer, per-weight-value MAC energy LUTs (paper 3.1).

Two routes to the 256-entry LUT ``E_l(w)``:

1. ``trace`` — exact average over the sampled systolic trace
   (`LayerStats.trace_lut`). This is the ground truth our grouped model is
   validated against.

2. ``grouped`` — the paper's contribution: synthesize MAC input traces by
   sampling independently from (i) the layer's activation transition
   histogram and (ii) the 50x50 MSB/HD grouped partial-sum transition
   histogram, using per-group representative values. The resulting Monte
   Carlo estimate only needs the compact (256^2 + 50^2) statistics rather
   than the 2^44 raw transition space.

`grouped_model_lut` is deterministic given a PRNG key. `model_fidelity`
reports the correlation between the two LUTs (used by tests + benchmarks to
show the grouping preserves per-weight ordering, which is all the selection
algorithm consumes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.grouping import N_GROUPS, group_representatives
from repro.core.mac_model import DEFAULT_COEFFS, MacEnergyCoeffs, mac_transition_energy
from repro.core.stats import N_WVALS, LayerStats

_REP_CACHE: dict[int, jax.Array] = {}


def _reps(samples_per_group: int = 8, seed: int = 17) -> jax.Array:
    kk = (samples_per_group, seed)
    h = hash(kk)
    if h not in _REP_CACHE:
        _REP_CACHE[h] = group_representatives(jax.random.PRNGKey(seed), samples_per_group)
    return _REP_CACHE[h]


def grouped_model_lut(
    stats: LayerStats,
    *,
    n_mc: int = 4096,
    key: jax.Array | None = None,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    samples_per_group: int = 8,
) -> jax.Array:
    """Paper's grouped statistical per-weight LUT, shape (256,) float32."""
    if key is None:
        key = jax.random.PRNGKey(1)
    k_a, k_g, k_r1, k_r2 = jax.random.split(key, 4)

    act_logits = jnp.log(stats.act_hist.reshape(-1) + 1e-20)
    grp_logits = jnp.log(stats.group_hist.reshape(-1) + 1e-20)

    a_idx = jax.random.categorical(k_a, act_logits, shape=(n_mc,))
    a_prev = (a_idx // N_WVALS).astype(jnp.int32) - 128
    a_cur = (a_idx % N_WVALS).astype(jnp.int32) - 128

    g_idx = jax.random.categorical(k_g, grp_logits, shape=(n_mc,))
    g_prev = (g_idx // N_GROUPS).astype(jnp.int32)
    g_cur = (g_idx % N_GROUPS).astype(jnp.int32)

    reps = _reps(samples_per_group)  # (50, R)
    r1 = jax.random.randint(k_r1, (n_mc,), 0, reps.shape[1])
    r2 = jax.random.randint(k_r2, (n_mc,), 0, reps.shape[1])
    p_prev = reps[g_prev, r1]
    p_cur = reps[g_cur, r2]

    w_values = jnp.arange(-128, 128, dtype=jnp.int32)

    def per_weight(w):
        e = mac_transition_energy(w, a_prev, a_cur, p_prev, p_cur, coeffs)
        return jnp.mean(e)

    return jax.vmap(per_weight)(w_values)


def trace_lut(stats: LayerStats) -> jax.Array:
    """Ground-truth per-weight LUT from the sampled trace, shape (256,)."""
    return stats.trace_lut()


def blended_lut(stats: LayerStats, **grouped_kwargs) -> jax.Array:
    """LUT used by the compression pipeline: trace where observed, grouped
    model as fallback for weight values never seen in the trace."""
    t = stats.trace_lut()
    g = grouped_model_lut(stats, **grouped_kwargs)
    seen = stats.count > 0
    return jnp.where(seen, t, g)


def model_fidelity(stats: LayerStats, **grouped_kwargs) -> dict:
    """Correlation diagnostics between trace LUT and grouped-model LUT.

    Restricted to weight values actually observed in the trace. Returns
    pearson r, spearman (rank) r, and mean relative error.
    """
    t = stats.trace_lut()
    g = grouped_model_lut(stats, **grouped_kwargs)
    seen = stats.count > 0
    tv = t[seen]
    gv = g[seen]

    def _pearson(x, y):
        xm = x - x.mean()
        ym = y - y.mean()
        denom = jnp.sqrt(jnp.sum(xm**2) * jnp.sum(ym**2))
        return jnp.sum(xm * ym) / jnp.maximum(denom, 1e-12)

    def _rank(x):
        order = jnp.argsort(x)
        ranks = jnp.zeros_like(order).at[order].set(jnp.arange(x.shape[0]))
        return ranks.astype(jnp.float32)

    pearson = float(_pearson(tv, gv))
    spearman = float(_pearson(_rank(tv), _rank(gv)))
    rel_err = float(jnp.mean(jnp.abs(tv - gv) / jnp.maximum(tv, 1e-9)))
    return {"pearson": pearson, "spearman": spearman, "mean_rel_err": rel_err,
            "n_seen": int(jnp.sum(seen))}


_UNIFORM_LUT_CACHE: dict[tuple, jax.Array] = {}


def uniform_trace_lut(
    n_mc: int = 2048,
    seed: int = 23,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
) -> jax.Array:
    """Traffic-agnostic per-weight-value LUT (256,) for serve-time estimates.

    At serving time there are no profiled activation statistics, so the
    serving engine's per-request energy accounting Monte-Carlo-averages the
    MAC transition energy over *uniform* int8 activation transitions with
    accumulate-consistent partial sums (p_cur = p_prev + w * a_cur). Same
    units as `LayerStats.trace_lut`; deterministic given the seed, cached
    per process.
    """
    key = (n_mc, seed, coeffs)
    if key not in _UNIFORM_LUT_CACHE:
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        w = jnp.arange(-128, 128, dtype=jnp.int32)[:, None]      # (256, 1)
        a_prev = jax.random.randint(k1, (1, n_mc), -128, 128)
        a_cur = jax.random.randint(k2, (1, n_mc), -128, 128)
        p_prev = jax.random.randint(k3, (1, n_mc), -(1 << 21), 1 << 21)
        p_cur = p_prev + w * a_cur
        e = mac_transition_energy(w, a_prev, a_cur, p_prev, p_cur, coeffs)
        _UNIFORM_LUT_CACHE[key] = jnp.mean(e, axis=1)
    return _UNIFORM_LUT_CACHE[key]
