"""Core paper contribution: MAC energy modeling + layer-wise weight selection."""

from repro.core.bitops import (  # noqa: F401
    MASK16,
    MASK22,
    hamming_distance,
    hamming_weight22,
    msb22,
    popcount,
    to_bits8,
)
from repro.core.mac_model import (  # noqa: F401
    DEFAULT_COEFFS,
    MacEnergyCoeffs,
    mac_transition_energy,
)
from repro.core.grouping import (  # noqa: F401
    N_GROUPS,
    N_HD_SUBGROUPS,
    N_MSB_GROUPS,
    group_id,
    hd_subgroup,
    msb_group,
    stability_ratio,
)
