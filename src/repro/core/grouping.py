"""MSB x Hamming-weight grouping of the 22-bit partial-sum space (paper 3.1.1).

The 22-bit accumulator has a 2^22 x 2^22 transition space; the paper
approximates it with a two-stage grouping:

  Stage 1: MSB position (range 0..22, where "0" means value zero / no MSB)
           uniformly partitioned into ``N_MSB_GROUPS = 10`` groups —
           similar MSB => similar carry-propagation activity.
  Stage 2: within each MSB group, Hamming weight partitioned into
           ``N_HD_SUBGROUPS = 5`` subgroups — same subgroup => small HD.

=> 50 groups total. Grouping quality is scored by the *stability ratio*:
variance of inter-group means / mean intra-group variance (higher = better).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitops import PSUM_BITS, hamming_weight22, msb22

N_MSB_GROUPS = 10
N_HD_SUBGROUPS = 5
N_GROUPS = N_MSB_GROUPS * N_HD_SUBGROUPS

# MSB "value" in the paper's 0..22 range: 0 <=> zero value, k <=> msb index k-1.
_N_MSB_VALUES = PSUM_BITS + 1  # 23
_N_HW_VALUES = PSUM_BITS + 1   # Hamming weight in 0..22


def msb_group(p: jax.Array) -> jax.Array:
    """Stage-1 group in [0, N_MSB_GROUPS) from the 22-bit pattern of ``p``."""
    msb_val = msb22(p) + 1  # 0..22, 0 for zero
    g = (msb_val * N_MSB_GROUPS) // _N_MSB_VALUES
    return jnp.minimum(g, N_MSB_GROUPS - 1).astype(jnp.int32)


def hd_subgroup(p: jax.Array) -> jax.Array:
    """Stage-2 subgroup in [0, N_HD_SUBGROUPS) by Hamming weight."""
    hw = hamming_weight22(p)  # 0..22
    g = (hw * N_HD_SUBGROUPS) // _N_HW_VALUES
    return jnp.minimum(g, N_HD_SUBGROUPS - 1).astype(jnp.int32)


def group_id(p: jax.Array) -> jax.Array:
    """Full group id in [0, 50) for a 22-bit partial sum pattern."""
    return msb_group(p) * N_HD_SUBGROUPS + hd_subgroup(p)


def group_transition_id(p_prev: jax.Array, p_cur: jax.Array) -> jax.Array:
    """Id in [0, 2500) of the (group(p_prev) -> group(p_cur)) transition."""
    return group_id(p_prev) * N_GROUPS + group_id(p_cur)


def stability_ratio(values: jax.Array, groups: jax.Array, n_groups: int = N_GROUPS) -> jax.Array:
    """Grouping-quality score: var(inter-group means) / mean(intra-group var).

    ``values`` are per-sample scalars (e.g. measured MAC energies), ``groups``
    the group id of each sample. Empty groups are excluded from both terms.
    Higher is better (tight groups, well-separated means).
    """
    values = jnp.asarray(values, jnp.float32)
    groups = jnp.asarray(groups, jnp.int32)
    ones = jnp.ones_like(values)
    counts = jax.ops.segment_sum(ones, groups, num_segments=n_groups)
    sums = jax.ops.segment_sum(values, groups, num_segments=n_groups)
    sq_sums = jax.ops.segment_sum(values * values, groups, num_segments=n_groups)

    nonempty = counts > 0
    safe_counts = jnp.maximum(counts, 1.0)
    means = sums / safe_counts
    # biased intra-group variance
    variances = sq_sums / safe_counts - means * means
    variances = jnp.maximum(variances, 0.0)

    n_nonempty = jnp.maximum(jnp.sum(nonempty), 1)
    grand_mean = jnp.sum(jnp.where(nonempty, means, 0.0)) / n_nonempty
    inter_var = (
        jnp.sum(jnp.where(nonempty, (means - grand_mean) ** 2, 0.0)) / n_nonempty
    )
    intra_var = jnp.sum(jnp.where(nonempty, variances, 0.0)) / n_nonempty
    return inter_var / jnp.maximum(intra_var, 1e-12)


def group_representatives(key: jax.Array, samples_per_group: int = 8) -> jax.Array:
    """Representative 22-bit values for each of the 50 groups.

    Rejection-free construction: for each (msb_group, hw_subgroup) pick an MSB
    position and Hamming weight inside the cell, then scatter the remaining
    set bits uniformly below the MSB. Returns (N_GROUPS, samples_per_group)
    int32. Groups that are combinatorially empty (hw > msb+1) fall back to the
    closest feasible Hamming weight.
    """
    reps = []
    for mg in range(N_MSB_GROUPS):
        # msb values covered by this group (in the 0..22 "msb value" space)
        lo = -(-mg * _N_MSB_VALUES // N_MSB_GROUPS)  # ceil
        msb_vals = [v for v in range(23) if (v * N_MSB_GROUPS) // _N_MSB_VALUES == mg]
        del lo
        for hg in range(N_HD_SUBGROUPS):
            hw_vals = [
                v for v in range(_N_HW_VALUES)
                if min((v * N_HD_SUBGROUPS) // _N_HW_VALUES, N_HD_SUBGROUPS - 1) == hg
            ]
            cell = []
            key, sub = jax.random.split(key)
            sub_keys = jax.random.split(sub, samples_per_group)
            for i in range(samples_per_group):
                k1, k2, k3 = jax.random.split(sub_keys[i], 3)
                msb_val = int(msb_vals[int(jax.random.randint(k1, (), 0, len(msb_vals)))])
                hw = int(hw_vals[int(jax.random.randint(k2, (), 0, len(hw_vals)))])
                if msb_val == 0:
                    cell.append(0)
                    continue
                msb_pos = msb_val - 1
                hw = max(1, min(hw, msb_pos + 1))  # feasibility clamp
                # choose hw-1 extra bit positions below msb_pos
                if msb_pos == 0 or hw == 1:
                    cell.append(1 << msb_pos)
                    continue
                perm = jax.random.permutation(k3, msb_pos)
                extra = perm[: hw - 1]
                val = 1 << msb_pos
                for b in list(jax.device_get(extra)):
                    val |= 1 << int(b)
                cell.append(val)
            reps.append(cell)
    return jnp.asarray(reps, jnp.int32)
