"""CNN training/eval/profiling runner used by the compression pipeline.

Bundles a `CNNModel`, a synthetic dataset, and jitted train/eval steps. The
compression state `comp` ({layer_name: CompState}) is a *data* argument of
every jitted function — its structure is fixed at init (identity comps), so
codebook/mask edits made by the scheduler never trigger recompiles.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import qat
from repro.core.layer_energy import LayerEnergyModel, MatmulDims
from repro.core.stats import (
    LayerStats,
    collect_layer_stats,
    conv_weight_matrix,
    im2col,
)
from repro.data.synthetic import SyntheticImages
from repro.nn.cnn import CNNModel
from repro.nn.layers import QuantConfig
from repro.nn.spec import init_params
from repro.optim.optimizers import adamw, apply_updates


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


@dataclasses.dataclass
class CnnRunner:
    model: CNNModel
    dataset: SyntheticImages
    batch_size: int = 128
    lr: float = 1e-3
    qcfg: QuantConfig = QuantConfig.on()
    seed: int = 0
    use_kernel_stats: bool = False
    profile_mesh: Optional[object] = None  # 1-D tile mesh (sharding.tile_mesh)

    def __post_init__(self):
        self.optimizer = adamw(self.lr)
        self._stats_cache: Optional[Dict[str, LayerStats]] = None
        model = self.model
        qcfg = self.qcfg

        def loss_fn(params, state, comp, batch):
            x, y = batch
            logits, new_state, _ = model.apply(
                params, state, x, train=True, qcfg=qcfg, comp=comp)
            return cross_entropy(logits, y), new_state

        def train_step(params, state, opt_state, comp, batch):
            (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, state, comp, batch)
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, new_state, opt_state, loss

        def eval_step(params, state, comp, batch):
            x, y = batch
            logits, _, _ = model.apply(
                params, state, x, train=False, qcfg=qcfg, comp=comp)
            return jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.int32))

        self._train_step = jax.jit(train_step)
        self._eval_step = jax.jit(eval_step)
        self._tap_fn = jax.jit(
            lambda params, state, comp, x: model.apply(
                params, state, x, train=False, qcfg=qcfg, comp=comp,
                capture_taps=True)[2]
        )

    # ------------------------------------------------------------------ setup

    def init(self):
        key = jax.random.PRNGKey(self.seed)
        params = init_params(key, self.model.spec)
        state = init_params(key, self.model.state_spec)
        opt_state = self.optimizer.init(params)
        comp = self.identity_comp(params)
        return params, state, opt_state, comp

    def identity_comp(self, params) -> Dict[str, qat.CompState]:
        comp = {}
        for cl in self.model.comp_layers:
            w = self.model.get_weight(params, cl.name)
            comp[cl.name] = qat.identity_comp(w.shape, w.dtype)
        return comp

    # ------------------------------------------------------------------ train

    def train(self, params, state, opt_state, comp, n_steps: int,
              start_step: int = 0, log_every: int = 0):
        loss = jnp.nan
        for i in range(n_steps):
            batch = self.dataset.batch(start_step + i, self.batch_size, "train")
            params, state, opt_state, loss = self._train_step(
                params, state, opt_state, comp, batch)
            if log_every and (i + 1) % log_every == 0:
                print(f"  step {start_step + i + 1}: loss={float(loss):.4f}")
        return params, state, opt_state, float(loss)

    def accuracy(self, params, state, comp, n_batches: int = 8,
                 split: str = "val") -> float:
        correct = 0
        for i in range(n_batches):
            batch = self.dataset.batch(i, self.batch_size, split)
            correct += int(self._eval_step(params, state, comp, batch))
        return correct / (n_batches * self.batch_size)

    # ---------------------------------------------------------------- profile

    def capture_taps(self, params, state, comp, n_batches: int = 1):
        """Merged taps {layer: {a_int, w_int}} over a few val batches."""
        taps_all: Dict[str, dict] = {}
        for i in range(n_batches):
            x, _ = self.dataset.batch(i, self.batch_size, "val")
            taps = self._tap_fn(params, state, comp, x)
            for name, t in taps.items():
                if name in taps_all:
                    taps_all[name]["a_int"] = jnp.concatenate(
                        [taps_all[name]["a_int"], t["a_int"]], axis=0)
                else:
                    taps_all[name] = dict(t)
        return taps_all

    def layer_trace_inputs(self, cl, tap):
        """(W_mat (M,K) int, X_col (K,N) int) for one compressible layer."""
        if cl.kind == "conv":
            w_mat = conv_weight_matrix(tap["w_int"])
            x_col = im2col(tap["a_int"], (cl.kernel, cl.kernel), cl.stride,
                           cl.padding)
        else:
            w_mat = tap["w_int"].T  # dense w is (in, out) -> (M=out, K=in)
            a = tap["a_int"].reshape(-1, tap["a_int"].shape[-1])
            x_col = a.T
        return w_mat, x_col

    def profile(self, params, state, comp, *, n_batches: int = 1,
                max_tiles: int = 24) -> Dict[str, LayerStats]:
        """Per-layer systolic trace statistics from captured activations.

        Each layer's sampled tiles run as ONE batched kernel/oracle
        invocation (`repro.core.profiler`), sharded over `profile_mesh` when
        set. The result is cached on the runner so `energy_models` (and the
        schedule's ΔE refreshes) can reuse it without re-tracing.
        """
        taps = self.capture_taps(params, state, comp, n_batches)
        out: Dict[str, LayerStats] = {}
        for cl in self.model.comp_layers:
            w_mat, x_col = self.layer_trace_inputs(cl, taps[cl.name])
            # crc32, not hash(): str hash is salted per interpreter run,
            # which would resample tiles (and flip schedule decisions) on
            # every invocation of the same script
            out[cl.name] = collect_layer_stats(
                w_mat, x_col, max_tiles=max_tiles,
                key=jax.random.PRNGKey(
                    zlib.crc32(cl.name.encode()) % (2**31)),
                use_kernel=self.use_kernel_stats,
                mesh=self.profile_mesh,
            )
        self._stats_cache = out
        return out

    def layer_stats(self, params, state, comp,
                    **profile_kw) -> Dict[str, LayerStats]:
        """Cached per-layer stats; profiles (batched) on first use.

        Explicit ``profile_kw`` always re-profiles — a warm cache only
        answers the no-argument form (whatever settings produced it)."""
        if self._stats_cache is None or profile_kw:
            self.profile(params, state, comp, **profile_kw)
        return self._stats_cache

    def energy_models(self, params, comp,
                      stats: Optional[Dict[str, LayerStats]] = None,
                      batch: int = 1) -> Dict[str, LayerEnergyModel]:
        """LayerEnergyModel per compressible layer at inference batch size.

        ``stats=None`` falls back to the cache left by the latest `profile`
        call — trace statistics depend only weakly on fine-tuning, so ΔE
        refreshes reuse them instead of re-running the trace."""
        from repro.core.energy_lut import blended_lut
        from repro.core.layer_energy import weight_value_counts

        if stats is None:
            stats = self._stats_cache
            if stats is None:
                raise ValueError(
                    "no LayerStats given and no cached profile: call "
                    "runner.profile(...) first or pass stats explicitly")
        out = {}
        for cl in self.model.comp_layers:
            dims = cl.matmul_dims(batch)
            lut = blended_lut(stats[cl.name])
            w = self.model.get_weight(params, cl.name)
            w_int = qat.quantize_weight_int(w, comp[cl.name])
            if cl.kind == "conv":
                w_int = conv_weight_matrix(w_int)
            else:
                w_int = w_int.T
            counts = weight_value_counts(w_int, dims)
            out[cl.name] = LayerEnergyModel(cl.name, dims, lut, counts)
        return out

    def refresh_counts(self, params, comp,
                       models: Dict[str, LayerEnergyModel]) -> Dict[str, LayerEnergyModel]:
        """Recompute weight-value histograms after params/comp changed."""
        from repro.core.layer_energy import weight_value_counts

        out = {}
        for cl in self.model.comp_layers:
            m = models[cl.name]
            w = self.model.get_weight(params, cl.name)
            w_int = qat.quantize_weight_int(w, comp[cl.name])
            w_int = conv_weight_matrix(w_int) if cl.kind == "conv" else w_int.T
            out[cl.name] = m.with_counts(weight_value_counts(w_int, m.dims))
        return out


def total_energy(models: Dict[str, LayerEnergyModel]) -> float:
    return float(sum(m.energy for m in models.values()))
