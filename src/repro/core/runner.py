"""CNN training/eval/profiling runner used by the compression pipeline.

Bundles a `CNNModel`, a synthetic dataset, and jitted train/eval steps. The
compression state `comp` ({layer_name: CompState}) is a *data* argument of
every jitted function — its structure is fixed at init (identity comps), so
codebook/mask edits made by the scheduler never trigger recompiles.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qat
from repro.core.layer_energy import LayerEnergyModel
from repro.core.stats import (
    LayerStats,
    collect_layer_stats,
    conv_weight_matrix,
    im2col,
)
from repro.data.synthetic import SyntheticImages
from repro.nn.cnn import CNNModel
from repro.nn.layers import QuantConfig
from repro.nn.spec import init_params
from repro.optim.optimizers import adamw, apply_updates


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


@dataclasses.dataclass
class CnnRunner:
    model: CNNModel
    dataset: SyntheticImages
    batch_size: int = 128
    lr: float = 1e-3
    qcfg: QuantConfig = QuantConfig.on()
    seed: int = 0
    use_kernel_stats: bool = False
    profile_mesh: Optional[object] = None  # 1-D tile mesh (sharding.tile_mesh)
    sweep_mesh: Optional[object] = None    # 1-D candidate mesh (sharding.sweep_mesh)

    def __post_init__(self):
        self.optimizer = adamw(self.lr)
        self._stats_cache: Optional[Dict[str, LayerStats]] = None
        model = self.model
        qcfg = self.qcfg

        def loss_fn(params, state, comp, batch):
            x, y = batch
            logits, new_state, _ = model.apply(
                params, state, x, train=True, qcfg=qcfg, comp=comp)
            return cross_entropy(logits, y), new_state

        def train_step(params, state, opt_state, comp, batch):
            (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, state, comp, batch)
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, new_state, opt_state, loss

        def eval_step(params, state, comp, batch):
            x, y = batch
            logits, _, _ = model.apply(
                params, state, x, train=False, qcfg=qcfg, comp=comp)
            return jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.int32))

        self._train_step = jax.jit(train_step)
        self._eval_step = jax.jit(eval_step)
        # candidate-sweep entry points (schedule ``search_mode="batched"``):
        # vmap over the leading candidate axis of the stacked trees, the data
        # batch shared across candidates. comp is a pure data argument with a
        # fixed tree structure, so one sweep compiles once per candidate
        # count and codebook/mask edits never retrigger compilation.
        self._train_step_raw = train_step
        self._eval_step_raw = eval_step
        self._cand_train_step = jax.jit(
            jax.vmap(train_step, in_axes=(0, 0, 0, 0, None)))
        self._cand_eval_step = jax.jit(
            jax.vmap(eval_step, in_axes=(0, 0, 0, None)))
        self._comp_eval_step = jax.jit(
            jax.vmap(eval_step, in_axes=(None, None, 0, None)))

        def gather_eval(params_s, state_s, comps_e, idx, batch):
            p = jax.tree.map(lambda x: x[idx], params_s)
            s = jax.tree.map(lambda x: x[idx], state_s)
            return jax.vmap(eval_step, in_axes=(0, 0, 0, None))(
                p, s, comps_e, batch)

        self._gather_eval_step = jax.jit(gather_eval)
        self._sweep_sharded = None
        self._tap_fn = jax.jit(
            lambda params, state, comp, x: model.apply(
                params, state, x, train=False, qcfg=qcfg, comp=comp,
                capture_taps=True)[2]
        )

    # ------------------------------------------------------------------ setup

    def init(self):
        key = jax.random.PRNGKey(self.seed)
        params = init_params(key, self.model.spec)
        state = init_params(key, self.model.state_spec)
        opt_state = self.optimizer.init(params)
        comp = self.identity_comp(params)
        return params, state, opt_state, comp

    def identity_comp(self, params) -> Dict[str, qat.CompState]:
        comp = {}
        for cl in self.model.comp_layers:
            w = self.model.get_weight(params, cl.name)
            comp[cl.name] = qat.identity_comp(w.shape, w.dtype)
        return comp

    # ------------------------------------------------------------------ train

    def train(self, params, state, opt_state, comp, n_steps: int,
              start_step: int = 0, log_every: int = 0):
        loss = jnp.nan
        for i in range(n_steps):
            batch = self.dataset.batch(start_step + i, self.batch_size, "train")
            params, state, opt_state, loss = self._train_step(
                params, state, opt_state, comp, batch)
            if log_every and (i + 1) % log_every == 0:
                print(f"  step {start_step + i + 1}: loss={float(loss):.4f}")
        return params, state, opt_state, float(loss)

    def accuracy(self, params, state, comp, n_batches: int = 8,
                 split: str = "val") -> float:
        correct = 0
        for i in range(n_batches):
            batch = self.dataset.batch(i, self.batch_size, split)
            correct += int(self._eval_step(params, state, comp, batch))
        return correct / (n_batches * self.batch_size)

    # ------------------------------------------------------- candidate sweep

    def _sweep_fns(self):
        """(train, eval, comp_eval) batched steps, honoring ``sweep_mesh``.

        Without a mesh these are the plain vmapped steps; with one, each is
        wrapped in `shard_map` over the 1-D candidate axis — every device
        trains/evaluates its local candidate slice, no collectives (the
        accept decision only needs the gathered per-candidate accuracies).
        """
        if self.sweep_mesh is None:
            return (self._cand_train_step, self._cand_eval_step,
                    self._comp_eval_step)
        if self._sweep_sharded is None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec
            from repro.distributed.sharding import SWEEP_AXIS

            mesh = self.sweep_mesh
            cand = PartitionSpec(SWEEP_AXIS)
            rep = PartitionSpec()
            vt = jax.vmap(self._train_step_raw, in_axes=(0, 0, 0, 0, None))
            ve = jax.vmap(self._eval_step_raw, in_axes=(0, 0, 0, None))
            vc = jax.vmap(self._eval_step_raw, in_axes=(None, None, 0, None))
            self._sweep_sharded = (
                jax.jit(shard_map(
                    vt, mesh, in_specs=(cand, cand, cand, cand, rep),
                    out_specs=cand, check_rep=False)),
                jax.jit(shard_map(
                    ve, mesh, in_specs=(cand, cand, cand, rep),
                    out_specs=cand, check_rep=False)),
                jax.jit(shard_map(
                    vc, mesh, in_specs=(rep, rep, cand, rep),
                    out_specs=cand, check_rep=False)),
            )
        return self._sweep_sharded

    def _sweep_multiple(self) -> int:
        if self.sweep_mesh is None:
            return 1
        from repro.distributed.sharding import SWEEP_AXIS

        return int(self.sweep_mesh.shape[SWEEP_AXIS])

    @staticmethod
    def _n_candidates(comps) -> int:
        return int(jax.tree.leaves(comps)[0].shape[0])

    def train_batched(self, params, state, opt_state, comps, n_steps: int,
                      start_step: int = 0):
        """Train N stacked candidates in lockstep, one vmapped step per batch.

        ``params/state/opt_state/comps`` carry a leading candidate axis (see
        `qat.stack_pytrees` / `qat.broadcast_pytree`). Every candidate sees
        exactly the batch stream the serial path would feed it, so the
        per-candidate trajectories reproduce serial trial fine-tunes.
        Returns (params, state, opt_state, per-candidate final loss).
        """
        train_fn, _, _ = self._sweep_fns()
        n = self._n_candidates(comps)
        m = self._sweep_multiple()
        n_pad = -(-n // m) * m
        if n_pad != n:
            params, state, opt_state, comps = (
                qat.pad_leading(t, n_pad)
                for t in (params, state, opt_state, comps))
        loss = jnp.full((n_pad,), jnp.nan)
        for i in range(n_steps):
            batch = self.dataset.batch(start_step + i, self.batch_size,
                                       "train")
            params, state, opt_state, loss = train_fn(
                params, state, opt_state, comps, batch)
        if n_pad != n:
            params, state, opt_state = (
                jax.tree.map(lambda x: x[:n], t)
                for t in (params, state, opt_state))
            loss = loss[:n]
        return params, state, opt_state, np.asarray(jax.device_get(loss))

    def accuracy_batched(self, params, state, comps, n_batches: int = 8,
                         split: str = "val") -> np.ndarray:
        """Per-candidate accuracy vector: stacked params/state/comps."""
        _, eval_fn, _ = self._sweep_fns()
        n = self._n_candidates(comps)
        m = self._sweep_multiple()
        n_pad = -(-n // m) * m
        if n_pad != n:
            params, state, comps = (
                qat.pad_leading(t, n_pad) for t in (params, state, comps))
        correct = jnp.zeros((n_pad,), jnp.int32)
        for i in range(n_batches):
            batch = self.dataset.batch(i, self.batch_size, split)
            correct = correct + eval_fn(params, state, comps, batch)
        correct = np.asarray(jax.device_get(correct), np.float64)[:n]
        return correct / (n_batches * self.batch_size)

    def accuracy_comps(self, params, state, comps, n_batches: int = 8,
                       split: str = "val") -> np.ndarray:
        """Accuracy of N stacked comp variants sharing one params/state —
        one vmapped (or sharded) dispatch instead of one eval per variant.
        The schedule's lockstep elimination uses `accuracy_gather` (variants
        against *per-candidate* params); this is the shared-params form for
        ablations and sweeps over comp settings."""
        _, _, comp_fn = self._sweep_fns()
        n = self._n_candidates(comps)
        m = self._sweep_multiple()
        n_pad = -(-n // m) * m
        if n_pad != n:
            comps = qat.pad_leading(comps, n_pad)
        correct = jnp.zeros((n_pad,), jnp.int32)
        for i in range(n_batches):
            batch = self.dataset.batch(i, self.batch_size, split)
            correct = correct + comp_fn(params, state, comps, batch)
        correct = np.asarray(jax.device_get(correct), np.float64)[:n]
        return correct / (n_batches * self.batch_size)

    def accuracy_gather(self, params_s, state_s, comps_e, idx,
                        n_batches: int = 8, split: str = "val") -> np.ndarray:
        """Accuracy of E comp variants, element e using the params/state of
        stacked candidate ``idx[e]``.

        This serves `lockstep_backward_elimination`: one dispatch evaluates a
        whole elimination round's trial codebooks across ALL sweep candidates
        (each against its own fine-tuned weights). The candidate gather runs
        inside the jit, so E-element rounds cost one compiled call per
        distinct E (callers pad to fixed capacities). Always runs through
        the vmapped step — ``sweep_mesh`` shards the train/accept stages,
        but gathered per-request evals stay single-replica for now.
        """
        idx = jnp.asarray(idx, jnp.int32)
        n_e = self._n_candidates(comps_e)
        correct = jnp.zeros((n_e,), jnp.int32)
        for i in range(n_batches):
            batch = self.dataset.batch(i, self.batch_size, split)
            correct = correct + self._gather_eval_step(
                params_s, state_s, comps_e, idx, batch)
        correct = np.asarray(jax.device_get(correct), np.float64)
        return correct / (n_batches * self.batch_size)

    # ---------------------------------------------------------------- profile

    def capture_taps(self, params, state, comp, n_batches: int = 1):
        """Merged taps {layer: {a_int, w_int}} over a few val batches."""
        taps_all: Dict[str, dict] = {}
        for i in range(n_batches):
            x, _ = self.dataset.batch(i, self.batch_size, "val")
            taps = self._tap_fn(params, state, comp, x)
            for name, t in taps.items():
                if name in taps_all:
                    taps_all[name]["a_int"] = jnp.concatenate(
                        [taps_all[name]["a_int"], t["a_int"]], axis=0)
                else:
                    taps_all[name] = dict(t)
        return taps_all

    def layer_trace_inputs(self, cl, tap):
        """(W_mat (M,K) int, X_col (K,N) int) for one compressible layer."""
        if cl.kind == "conv":
            w_mat = conv_weight_matrix(tap["w_int"])
            x_col = im2col(tap["a_int"], (cl.kernel, cl.kernel), cl.stride,
                           cl.padding)
        else:
            w_mat = tap["w_int"].T  # dense w is (in, out) -> (M=out, K=in)
            a = tap["a_int"].reshape(-1, tap["a_int"].shape[-1])
            x_col = a.T
        return w_mat, x_col

    def profile(self, params, state, comp, *, n_batches: int = 1,
                max_tiles: int = 24) -> Dict[str, LayerStats]:
        """Per-layer systolic trace statistics from captured activations.

        Each layer's sampled tiles run as ONE batched kernel/oracle
        invocation (`repro.core.profiler`), sharded over `profile_mesh` when
        set. The result is cached on the runner so `energy_models` (and the
        schedule's ΔE refreshes) can reuse it without re-tracing.
        """
        taps = self.capture_taps(params, state, comp, n_batches)
        out: Dict[str, LayerStats] = {}
        for cl in self.model.comp_layers:
            w_mat, x_col = self.layer_trace_inputs(cl, taps[cl.name])
            # crc32, not hash(): str hash is salted per interpreter run,
            # which would resample tiles (and flip schedule decisions) on
            # every invocation of the same script
            out[cl.name] = collect_layer_stats(
                w_mat, x_col, max_tiles=max_tiles,
                key=jax.random.PRNGKey(
                    zlib.crc32(cl.name.encode()) % (2**31)),
                use_kernel=self.use_kernel_stats,
                mesh=self.profile_mesh,
            )
        self._stats_cache = out
        return out

    def layer_stats(self, params, state, comp,
                    **profile_kw) -> Dict[str, LayerStats]:
        """Cached per-layer stats; profiles (batched) on first use.

        Explicit ``profile_kw`` always re-profiles — a warm cache only
        answers the no-argument form (whatever settings produced it)."""
        if self._stats_cache is None or profile_kw:
            self.profile(params, state, comp, **profile_kw)
        return self._stats_cache

    def energy_models(self, params, comp,
                      stats: Optional[Dict[str, LayerStats]] = None,
                      batch: int = 1) -> Dict[str, LayerEnergyModel]:
        """LayerEnergyModel per compressible layer at inference batch size.

        ``stats=None`` falls back to the cache left by the latest `profile`
        call — trace statistics depend only weakly on fine-tuning, so ΔE
        refreshes reuse them instead of re-running the trace."""
        from repro.core.energy_lut import blended_lut
        from repro.core.layer_energy import weight_value_counts

        if stats is None:
            stats = self._stats_cache
            if stats is None:
                raise ValueError(
                    "no LayerStats given and no cached profile: call "
                    "runner.profile(...) first or pass stats explicitly")
        out = {}
        for cl in self.model.comp_layers:
            dims = cl.matmul_dims(batch)
            lut = blended_lut(stats[cl.name])
            w = self.model.get_weight(params, cl.name)
            w_int = qat.quantize_weight_int(w, comp[cl.name])
            if cl.kind == "conv":
                w_int = conv_weight_matrix(w_int)
            else:
                w_int = w_int.T
            counts = weight_value_counts(w_int, dims)
            out[cl.name] = LayerEnergyModel(cl.name, dims, lut, counts)
        return out

    def refresh_counts(self, params, comp,
                       models: Dict[str, LayerEnergyModel]) -> Dict[str, LayerEnergyModel]:
        """Recompute weight-value histograms after params/comp changed."""
        out = {}
        for cl in self.model.comp_layers:
            out[cl.name] = self.refresh_layer_counts(params, comp, models,
                                                     cl.name)
        return out

    def refresh_layer_counts(self, params, comp,
                             models: Dict[str, LayerEnergyModel],
                             layer: str) -> LayerEnergyModel:
        """One layer's refreshed histogram — the candidate sweep's per-trial
        ΔE refresh only needs the layer under search, so it skips the other
        layers' quantize dispatches."""
        from repro.core.layer_energy import weight_value_counts

        cl = self.model.comp_layer(layer)
        m = models[layer]
        w = self.model.get_weight(params, layer)
        w_int = qat.quantize_weight_int(w, comp[layer])
        w_int = conv_weight_matrix(w_int) if cl.kind == "conv" else w_int.T
        return m.with_counts(weight_value_counts(w_int, m.dims))


def total_energy(models: Dict[str, LayerEnergyModel]) -> float:
    return float(sum(m.energy for m in models.values()))
