"""Convolution/linear layer energy via the tile-level systolic mapping (3.2).

im2col turns each conv into ``Y = W_mat @ X_col`` with
``W_mat in R^{M x K}``, ``X_col in R^{K x N}`` (M = C_out, K = C_in*k^2,
N = H_out*W_out). The matmul is partitioned into 64x64 weight-stationary
tiles; each (m, k) weight tile is streamed with ceil(N/64) activation blocks,
each taking 128 cycles (64 fill + 64 drain at clock f):

    T       = 64 / f                  (we use f = 1: unit clock)
    E_tile  = 2 * P_tile * T
    E_layer = N_tiles * E_tile        (linear accumulation, no inter-tile reuse)

``P_tile`` is the summed per-cycle MAC power of the tile's 64x64 stationary
weights, read from the layer's per-weight LUT, so the whole formula collapses
to a weight-value histogram dot product:

    E_layer = sum_w counts_padded(w) * LUT(w) * (2 * T) * ceil(N/64)

where ``counts_padded`` counts each weight once per (m, k) tile including the
zero padding of partial tiles (padded MACs hold w = 0 and still clock).
This makes the scheduler's ΔE queries O(256).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.stats import N_WVALS, TILE

CLOCK_F = 1.0
T_CYCLES = TILE / CLOCK_F          # paper: T = 64 / f
PASS_ENERGY_SCALE = 2.0 * T_CYCLES  # paper: E_tile = 2 * P_tile * T


@dataclass(frozen=True)
class MatmulDims:
    """Dimensions of a layer's matmul as mapped on the systolic array."""

    m: int  # output channels / features
    k: int  # reduction (C_in * k_h * k_w, or fan-in)
    n: int  # streamed columns (H_out * W_out * batch, or tokens)

    @property
    def m_tiles(self) -> int:
        return -(-self.m // TILE)

    @property
    def k_tiles(self) -> int:
        return -(-self.k // TILE)

    @property
    def n_tiles(self) -> int:
        return -(-self.n // TILE)

    @property
    def total_tiles(self) -> int:
        return self.m_tiles * self.k_tiles * self.n_tiles

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


def conv_matmul_dims(
    c_in: int,
    c_out: int,
    kernel_hw: Tuple[int, int],
    out_hw: Tuple[int, int],
    batch: int = 1,
) -> MatmulDims:
    kh, kw = kernel_hw
    ho, wo = out_hw
    return MatmulDims(m=c_out, k=c_in * kh * kw, n=ho * wo * batch)


def dense_matmul_dims(fan_in: int, fan_out: int, n_tokens: int) -> MatmulDims:
    return MatmulDims(m=fan_out, k=fan_in, n=n_tokens)


def weight_value_counts(w_int: jax.Array, dims: MatmulDims) -> jax.Array:
    """Histogram (256,) of int8 weight values over the *padded* weight matrix.

    ``w_int`` is the (M, K) integer weight matrix (any layout reshapable to
    M*K). Zero padding of partial tiles adds to the count of w = 0.
    """
    w_flat = jnp.asarray(w_int, jnp.int32).reshape(-1)
    counts = jax.ops.segment_sum(
        jnp.ones_like(w_flat, jnp.float32), w_flat + 128, num_segments=N_WVALS
    )
    padded = dims.m_tiles * dims.k_tiles * TILE * TILE
    pad_zeros = padded - w_flat.shape[0]
    return counts.at[128].add(jnp.float32(pad_zeros))


def layer_energy_from_counts(counts: jax.Array, lut: jax.Array, dims: MatmulDims) -> jax.Array:
    """E_layer = sum_w counts(w) * LUT(w) * 2T * ceil(N/64)  (scalar, eu)."""
    per_pass_power = jnp.sum(counts * lut)  # sum of per-cycle MAC powers
    return per_pass_power * PASS_ENERGY_SCALE * dims.n_tiles


def layer_energy(w_int: jax.Array, lut: jax.Array, dims: MatmulDims) -> jax.Array:
    return layer_energy_from_counts(weight_value_counts(w_int, dims), lut, dims)


def tile_power(counts: jax.Array, lut: jax.Array, dims: MatmulDims) -> jax.Array:
    """P_tile^(l): average per-tile power (paper 3.2), for reporting."""
    n_weight_tiles = jnp.maximum(dims.m_tiles * dims.k_tiles, 1)
    return jnp.sum(counts * lut) / n_weight_tiles


def tile_energy(counts: jax.Array, lut: jax.Array, dims: MatmulDims) -> jax.Array:
    """E_tile = 2 * P_tile * T."""
    return PASS_ENERGY_SCALE * tile_power(counts, lut, dims)


def delta_energy_remove(
    counts: jax.Array,
    lut: jax.Array,
    dims: MatmulDims,
    w_value: int | jax.Array,
    nearest_value: int | jax.Array,
) -> jax.Array:
    """Energy delta (>0 = saving) of disallowing ``w_value`` in this layer.

    All occurrences are remapped to ``nearest_value`` (paper 4.2.2 (i)).
    """
    w_idx = jnp.asarray(w_value, jnp.int32) + 128
    n_idx = jnp.asarray(nearest_value, jnp.int32) + 128
    moved = counts[w_idx]
    per_pass = moved * (lut[w_idx] - lut[n_idx])
    return per_pass * PASS_ENERGY_SCALE * dims.n_tiles


@dataclass
class LayerEnergyModel:
    """Everything the scheduler needs to reason about one layer's energy."""

    name: str
    dims: MatmulDims
    lut: jax.Array          # (256,) per-weight-value per-cycle energy
    counts: jax.Array       # (256,) current weight-value histogram (padded)

    @property
    def energy(self) -> float:
        return float(layer_energy_from_counts(self.counts, self.lut, self.dims))

    def with_counts(self, counts: jax.Array) -> "LayerEnergyModel":
        return LayerEnergyModel(self.name, self.dims, self.lut, counts)


def energy_shares(models: list[LayerEnergyModel]) -> jax.Array:
    """rho_l = E_l / sum_j E_j (paper 4.3)."""
    e = jnp.asarray([m.energy for m in models], jnp.float32)
    return e / jnp.maximum(jnp.sum(e), 1e-12)
