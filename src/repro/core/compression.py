"""End-to-end compression pipeline orchestration (paper Section 5 protocol).

    1. quantization-aware training of the base model (8-bit W/A),
    2. per-layer systolic-trace profiling -> energy LUTs + layer energies,
    3. energy-prioritized layer-wise compression (pruning + weight selection),
    4. final fine-tune + report.

`CompressionPipeline.run()` returns a `PipelineResult` with everything the
paper's tables report: accuracy before/after, conv-layer energy saving,
selected weight counts, and per-layer decisions.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Optional

from repro.core.runner import CnnRunner
from repro.core.schedule import (
    ScheduleConfig,
    ScheduleResult,
    energy_prioritized_compression,
)
from repro.core.weight_selection import SelectionConfig


@dataclasses.dataclass
class PipelineConfig:
    qat_steps: int = 300
    profile_batches: int = 1
    profile_max_tiles: int = 16
    final_finetune_steps: int = 100
    eval_batches: int = 4
    schedule: ScheduleConfig = dataclasses.field(default_factory=ScheduleConfig)
    selection: SelectionConfig = dataclasses.field(default_factory=SelectionConfig)


@dataclasses.dataclass
class PipelineResult:
    acc_base: float
    acc_final: float
    energy_before: float
    energy_after: float
    max_codebook: int
    schedule: ScheduleResult
    wall_seconds: float

    @property
    def energy_saving(self) -> float:
        return 1.0 - self.energy_after / max(self.energy_before, 1e-12)

    @property
    def accuracy_drop(self) -> float:
        return self.acc_base - self.acc_final

    def summary(self) -> Dict:
        return {
            "acc_base": round(self.acc_base, 4),
            "acc_final": round(self.acc_final, 4),
            "accuracy_drop": round(self.accuracy_drop, 4),
            "energy_saving": round(self.energy_saving, 4),
            "max_codebook": self.max_codebook,
            "layers": [
                {
                    "layer": d.layer,
                    "share": round(d.share, 4),
                    "prune": d.prune_ratio,
                    "k": d.k,
                    "saving": round(d.saving, 4),
                    "accepted": d.accepted,
                }
                for d in self.schedule.decisions
            ],
            "wall_seconds": round(self.wall_seconds, 1),
        }


class CompressionPipeline:
    def __init__(self, runner: CnnRunner, cfg: Optional[PipelineConfig] = None):
        self.runner = runner
        self.cfg = cfg or PipelineConfig()

    def run(self, *, verbose: bool = False) -> PipelineResult:
        t0 = time.time()
        cfg = self.cfg
        runner = self.runner

        # 1. QAT base training
        params, state, opt_state, comp = runner.init()
        params, state, opt_state, loss = runner.train(
            params, state, opt_state, comp, cfg.qat_steps)
        acc_base = runner.accuracy(params, state, comp,
                                   n_batches=cfg.eval_batches)
        if verbose:
            print(f"[pipeline] QAT base: loss={loss:.4f} acc={acc_base:.3f}")

        # 2. profile
        stats = runner.profile(params, state, comp,
                               n_batches=cfg.profile_batches,
                               max_tiles=cfg.profile_max_tiles)

        # 3. energy-prioritized layer-wise compression
        params, state, opt_state, comp, sched = energy_prioritized_compression(
            runner, params, state, opt_state, comp, stats, cfg.schedule,
            cfg.selection, verbose=verbose)

        # 4. final fine-tune
        if cfg.final_finetune_steps:
            params, state, opt_state, _ = runner.train(
                params, state, opt_state, comp, cfg.final_finetune_steps)
        acc_final = runner.accuracy(params, state, comp,
                                    n_batches=cfg.eval_batches)

        models = runner.refresh_counts(
            params, comp, runner.energy_models(params, comp, stats))
        e_after = sum(m.energy for m in models.values())

        ks = [int(d.k) for d in sched.decisions if d.k is not None]
        result = PipelineResult(
            acc_base=acc_base,
            acc_final=acc_final,
            energy_before=sched.energy_before,
            energy_after=float(e_after),
            max_codebook=max(ks) if ks else 256,
            schedule=sched,
            wall_seconds=time.time() - t0,
        )
        self.params, self.state, self.opt_state, self.comp = params, state, opt_state, comp
        self.stats = stats
        if verbose:
            print(json.dumps(result.summary(), indent=2))
        return result
