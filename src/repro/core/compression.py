"""Deprecated shim: the seed-era CNN pipeline API over `repro.pipeline`.

The orchestration that used to live here — QAT base training, per-layer
systolic-trace profiling, energy-prioritized layer-wise compression, final
fine-tune — is now the `profile -> energy_model -> schedule` prefix of the
staged `repro.pipeline.Pipeline` (see docs/pipeline.md), which adds the
export and serve stages, a serializable `CompressionPlan` artifact, resume,
and the LM target behind the same interface.

`CompressionPipeline` and this module's `PipelineConfig` survive as thin
delegates so seed-era callers and tests keep working; new code should build
a `repro.pipeline.PipelineConfig` and call `Pipeline` directly.
"""

from __future__ import annotations

import dataclasses
import json
import time
import warnings
from typing import Dict, Optional

from repro.core.runner import CnnRunner
from repro.core.schedule import ScheduleConfig, ScheduleResult
from repro.core.weight_selection import SelectionConfig


@dataclasses.dataclass
class PipelineConfig:
    qat_steps: int = 300
    profile_batches: int = 1
    profile_max_tiles: int = 16
    final_finetune_steps: int = 100
    eval_batches: int = 4
    schedule: ScheduleConfig = dataclasses.field(default_factory=ScheduleConfig)
    selection: SelectionConfig = dataclasses.field(default_factory=SelectionConfig)


@dataclasses.dataclass
class PipelineResult:
    acc_base: float
    acc_final: float
    energy_before: float
    energy_after: float
    max_codebook: int
    schedule: ScheduleResult
    wall_seconds: float

    @property
    def energy_saving(self) -> float:
        return 1.0 - self.energy_after / max(self.energy_before, 1e-12)

    @property
    def accuracy_drop(self) -> float:
        return self.acc_base - self.acc_final

    def summary(self) -> Dict:
        return {
            "acc_base": round(self.acc_base, 4),
            "acc_final": round(self.acc_final, 4),
            "accuracy_drop": round(self.accuracy_drop, 4),
            "energy_saving": round(self.energy_saving, 4),
            "max_codebook": self.max_codebook,
            "layers": [
                {
                    "layer": d.layer,
                    "share": round(d.share, 4),
                    "prune": d.prune_ratio,
                    "k": d.k,
                    "saving": round(d.saving, 4),
                    "accepted": d.accepted,
                }
                for d in self.schedule.decisions
            ],
            "wall_seconds": round(self.wall_seconds, 1),
        }


class CompressionPipeline:
    """Deprecated delegate over `repro.pipeline.Pipeline` (CNN target).

    Runs the `profile -> energy_model -> schedule` stage prefix on the
    caller's runner and maps the resulting `CompressionPlan` back onto the
    seed-era `PipelineResult`. Attribute contract is unchanged: after
    ``run()`` the instance exposes ``params / state / opt_state / comp /
    stats`` (plus the new ``plan``)."""

    def __init__(self, runner: CnnRunner, cfg: Optional[PipelineConfig] = None):
        self.runner = runner
        self.cfg = cfg or PipelineConfig()

    def run(self, *, verbose: bool = False) -> PipelineResult:
        from repro.pipeline.config import from_legacy
        from repro.pipeline.pipeline import Pipeline
        from repro.pipeline.targets import CnnTarget

        warnings.warn(
            "repro.core.compression.CompressionPipeline is deprecated; "
            "use repro.pipeline.Pipeline (see docs/pipeline.md)",
            DeprecationWarning, stacklevel=2)
        t0 = time.time()
        pcfg = from_legacy(self.cfg,
                           arch=getattr(self.runner.model, "name", None))
        target = CnnTarget(pcfg, runner=self.runner)
        plan = Pipeline(target, pcfg).run_until("schedule", verbose=verbose)
        sched = target.last_schedule_result

        self.params, self.state = plan.params, plan.state
        self.opt_state, self.comp = plan.opt_state, plan.comp
        self.stats = plan.stats
        self.plan = plan
        result = PipelineResult(
            acc_base=plan.metrics["acc_base"],
            acc_final=plan.metrics["acc_final"],
            energy_before=sched.energy_before,
            energy_after=plan.metrics["energy_after"],
            max_codebook=plan.metrics["max_codebook"],
            schedule=sched,
            wall_seconds=time.time() - t0,
        )
        if verbose:
            print(json.dumps(result.summary(), indent=2))
        return result
