"""Routing/activity profiling for traffic-weighted unit compression.

The paper's schedule compresses high-energy layers more aggressively; for
MoE and recurrent-scan workloads the relevant energy prior is not the layer
position but the *measured traffic* through each unit: how often the router
dispatches tokens to an expert, and how much signal flows through each scan
layer. This module collects those statistics from calibration traces and
turns them into per-unit compression aggressiveness (hot experts keep
gentler codebooks, cold experts compress hard).

Mechanics: the mixer/FFN kernels (`nn.moe`, `nn.ssm`, `nn.rglru`) emit one
event per call through a collector contextvar — a no-op unless profiling is
active. `collect_lm_routing_stats` drives `LMModel.prefill` *eagerly* (the
prefill path unrolls blocks per layer, so events arrive as concrete arrays
in deterministic call order) and maps the event stream back onto named comp
units ("blocks/g0/moe", layer index within the stack).

Everything downstream is plain numpy: traffic shares normalize per layer,
and `assign_rank_k` buckets units by traffic rank onto a k ladder sorted
gentle->aggressive, which makes hot-gentler/cold-aggressive monotone by
construction.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Collector signature: fn(kind, name, value) with kind in
# {"moe", "ssm", "rglru"}, name the block-local comp prefix (e.g. "moe"),
# and value a per-call statistic ((E,) kept-dispatch counts for MoE, scalar
# mean-square activation for scan mixers). Only set this around *eager*
# model calls — under jit/scan the events would be tracers in traced order.
_COLLECTOR: contextvars.ContextVar[Optional[Callable]] = \
    contextvars.ContextVar("routing_stats_collector", default=None)


def get_collector() -> Optional[Callable]:
    return _COLLECTOR.get()


def set_collector(fn: Optional[Callable]):
    """Returns a contextvars token; reset with the token when done."""
    return _COLLECTOR.set(fn)


@contextlib.contextmanager
def collecting(fn: Callable):
    token = set_collector(fn)
    try:
        yield
    finally:
        _COLLECTOR.reset(token)


# ------------------------------------------------------------------ stats


@dataclasses.dataclass
class RoutingStats:
    """Accumulated calibration statistics, keyed by comp-unit base path.

    ``moe_counts["blocks/g0/moe"]`` is a (n_layers_in_stack, E) float64 array
    of kept-dispatch token counts (capacity-dropped tokens excluded — they
    never reach the expert matmuls, so they cost no expert energy).
    ``scan_activity["blocks/g0/ssm"]`` is (n_layers_in_stack,) mean-square
    pre-mixer activation, one entry per scan layer. Tail (unstacked) units
    get a leading layer axis of 1.
    """
    moe_counts: Dict[str, np.ndarray]
    scan_activity: Dict[str, np.ndarray]
    tokens: int    # total calibration tokens seen (batches * batch * seq)

    def as_arrays(self) -> Dict[str, np.ndarray]:
        """Flat {key: array} form that round-trips through plan npz stores."""
        out = {f"moe:{k}": v for k, v in self.moe_counts.items()}
        out.update({f"scan:{k}": v for k, v in self.scan_activity.items()})
        out["tokens"] = np.asarray(self.tokens, np.int64)
        return out

    @classmethod
    def from_arrays(cls, arrays: Dict[str, np.ndarray]) -> "RoutingStats":
        moe = {k[len("moe:"):]: np.asarray(v) for k, v in arrays.items()
               if k.startswith("moe:")}
        scan = {k[len("scan:"):]: np.asarray(v) for k, v in arrays.items()
                if k.startswith("scan:")}
        return cls(moe_counts=moe, scan_activity=scan,
                   tokens=int(np.asarray(arrays.get("tokens", 0))))


def _block_stat_kind(cfg, block_type: str) -> Optional[str]:
    """Which event (if any) one block of this type emits per forward call."""
    if block_type in ("attn", "local") and cfg.is_moe:
        return "moe"
    if block_type in ("ssm", "rglru"):
        return block_type
    return None


def expected_units(model) -> List[Tuple[str, str, Optional[int]]]:
    """Event schedule of one eager prefill: (unit_base, kind, layer_index).

    Mirrors `LMModel.prefill`'s unrolled walk: repeats outer, pattern inner,
    then tail blocks. layer_index is the repeat index within the stacked
    group (None for tail units, stored as layer 0).
    """
    cfg = model.cfg
    out: List[Tuple[str, str, Optional[int]]] = []
    for r in range(model.n_rep):
        for i, bt in enumerate(cfg.pattern):
            kind = _block_stat_kind(cfg, bt)
            if kind is not None:
                out.append((f"blocks/g{i}/{kind}", kind, r))
    for j in range(model.n_tail):
        kind = _block_stat_kind(cfg, cfg.pattern[j])
        if kind is not None:
            out.append((f"tail/t{j}/{kind}", kind, None))
    return out


def calibration_batches(vocab: int, batches: int, batch_size: int,
                        seq_len: int, seed: int):
    """Deterministic synthetic token batches for routing calibration."""
    key = jax.random.PRNGKey(seed)
    for i in range(batches):
        yield jax.random.randint(jax.random.fold_in(key, i),
                                 (batch_size, seq_len), 0, vocab, jnp.int32)


def collect_lm_routing_stats(model, params, *, comp=None, qcfg=None,
                             batches: int = 2, batch_size: int = 2,
                             seq_len: int = 32, seed: int = 0) -> RoutingStats:
    """Profile routing/activity over synthetic calibration traces.

    Runs `model.prefill` eagerly per batch under an event collector and
    accumulates per-unit statistics. Deterministic for a fixed seed: the
    token batches come from a fixed PRNG chain and dispatch itself has no
    stochastic component.
    """
    if qcfg is None:
        from repro.nn.layers import QuantConfig
        qcfg = QuantConfig.off()

    schedule = expected_units(model)
    if not schedule:
        raise ValueError(
            f"arch {model.cfg.name!r} has no MoE or scan units to profile")

    n_rep = max(model.n_rep, 1)
    moe_counts: Dict[str, np.ndarray] = {}
    scan_sums: Dict[str, np.ndarray] = {}
    n_calls = 0

    events: List[Tuple[str, str, np.ndarray]] = []

    def on_event(kind, name, value):
        events.append((kind, name, np.asarray(jax.device_get(value),
                                              np.float64)))

    tokens_total = 0
    for toks in calibration_batches(model.cfg.vocab, batches, batch_size,
                                    seq_len, seed):
        events.clear()
        with collecting(on_event):
            model.prefill(params, toks, max_len=int(toks.shape[1]),
                          qcfg=qcfg, comp=comp)
        if len(events) != len(schedule):
            raise RuntimeError(
                f"routing collector saw {len(events)} events, expected "
                f"{len(schedule)} — was prefill traced instead of eager?")
        for (unit, kind, li), (ev_kind, _name, value) in zip(schedule, events):
            if ev_kind != kind:
                raise RuntimeError(
                    f"event kind mismatch at {unit}: got {ev_kind}")
            row = 0 if li is None else li
            n_layers = 1 if li is None else n_rep
            if kind == "moe":
                acc = moe_counts.setdefault(
                    unit, np.zeros((n_layers, value.shape[-1]), np.float64))
                acc[row] += value
            else:
                acc = scan_sums.setdefault(unit,
                                           np.zeros((n_layers,), np.float64))
                acc[row] += float(value)
        tokens_total += int(toks.shape[0] * toks.shape[1])
        n_calls += 1

    scan_activity = {k: v / max(n_calls, 1) for k, v in scan_sums.items()}
    return RoutingStats(moe_counts=moe_counts, scan_activity=scan_activity,
                        tokens=tokens_total)


# ------------------------------------------------------- shares + k ladders


def traffic_shares(counts: np.ndarray) -> np.ndarray:
    """Per-layer traffic shares: rows of (L, E) counts normalized to sum 1.

    A row with zero traffic (no kept dispatches in the calibration trace)
    falls back to the uniform share — no information means no reason to
    treat experts differently.
    """
    counts = np.asarray(counts, np.float64)
    if counts.ndim == 1:
        counts = counts[None, :]
    totals = counts.sum(axis=-1, keepdims=True)
    uniform = np.full_like(counts, 1.0 / counts.shape[-1])
    with np.errstate(invalid="ignore", divide="ignore"):
        shares = np.where(totals > 0, counts / np.maximum(totals, 1e-12),
                          uniform)
    return shares


def activity_shares(activity: np.ndarray) -> np.ndarray:
    """(L,) activity statistics normalized to shares summing to 1."""
    act = np.asarray(activity, np.float64).reshape(-1)
    total = act.sum()
    if total <= 0:
        return np.full_like(act, 1.0 / max(act.size, 1))
    return act / total


def assign_rank_k(shares: np.ndarray, ladder: Sequence[int]) -> np.ndarray:
    """Bucket units onto a k ladder by traffic rank: hottest -> gentlest.

    ``ladder`` is the set of codebook sizes to use (order-insensitive); the
    hottest ceil(n/len(ladder)) units get the largest k, the coldest the
    smallest. Monotone by construction: share_i > share_j implies
    k_i >= k_j. Ties break on unit index (stable sort) for determinism.
    """
    shares = np.asarray(shares, np.float64).reshape(-1)
    gentle_first = sorted({int(k) for k in ladder}, reverse=True)
    if not gentle_first:
        raise ValueError("empty k ladder")
    n, n_l = shares.size, len(gentle_first)
    order = np.argsort(-shares, kind="stable")    # hottest first
    ks = np.zeros(n, np.int64)
    for rank, idx in enumerate(order):
        ks[idx] = gentle_first[min(rank * n_l // max(n, 1), n_l - 1)]
    return ks


def traffic_weighted_energy(unit_energy: np.ndarray,
                            shares: np.ndarray) -> np.ndarray:
    """Scale per-unit tile energies by measured traffic share.

    The tile-level energy model charges each expert slice as if every token
    passed through it; in an MoE only a ``share`` fraction of the routed
    tokens does. Multiplying by ``share * n_units`` keeps the layer total
    comparable to the dense accounting (uniform traffic changes nothing)
    while concentrating the prior on hot units.
    """
    unit_energy = np.asarray(unit_energy, np.float64)
    shares = np.asarray(shares, np.float64)
    return unit_energy * shares * shares.shape[-1]
