"""LM-side compression state: per-layer masks + codebooks for scanned blocks.

Builds a comp tree mirroring the LM's grouped parameter layout:

    comp = {
      "blocks":     {"g0": {"attn/wq": CompState, "mlp/w_gate": ...}, ...}
                    with leaves stacked over the scan (layers) axis,
      "tail":       {"t0": {...}},           # unstacked
      "enc_blocks": {...},                   # whisper encoder (stacked)
    }

Eligible tensors are exactly the matmul weights that occupy systolic
weight-stationary registers (DESIGN.md §Arch-applicability): attention
projections, FFN/expert matrices, SSM/RG-LRU projections and gate matrices.
Router weights, depthwise-conv taps, per-head scalars (A/dt/Lambda), biases
and norms are excluded. Masks are stored int8 to bound the footprint at 26B+
scale (cast to the weight dtype inside `repro.core.qat`).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qat
from repro.models.lm import LMModel
from repro.nn.spec import ParamSpec

# sub-module name -> weight keys eligible for weight-value restriction
ELIGIBLE: Dict[str, Tuple[str, ...]] = {
    "attn": ("wq", "wk", "wv", "wo"),
    "xattn": ("wq", "wk", "wv", "wo"),
    "mlp": ("w_gate", "w_up", "w_down"),
    "moe": ("w_gate", "w_up", "w_down",
            "shared_gate", "shared_up", "shared_down"),
    "ssm": ("in_proj", "out_proj"),
    "rglru": ("in_proj", "gate_proj", "w_a", "w_x", "out_proj"),
}

# expert-batched MoE tensors carry a leading expert axis and get *per-expert*
# codebooks/k (hot experts gentler, cold aggressive — repro.core.routing_stats
# supplies the traffic prior). Identified by (sub == "moe", key) — the "mlp"
# sub reuses the same key names for plain 2-D matrices.
MOE_EXPERT_KEYS: Tuple[str, ...] = ("w_gate", "w_up", "w_down")


def is_expert_unit(unit: str) -> bool:
    """True for 'moe/w_gate'-style expert-batched units ('sub/key' form)."""
    sub, key = unit.split("/")
    return sub == "moe" and key in MOE_EXPERT_KEYS


def _block_comp_spec(block_spec: dict) -> dict:
    """{'attn/wq': comp-spec-dict} for one (possibly stacked) block spec."""
    out = {}
    for sub, keys in ELIGIBLE.items():
        if sub not in block_spec:
            continue
        for key in keys:
            if key not in block_spec[sub]:
                continue
            p: ParamSpec = block_spec[sub][key]
            stacked = bool(p.axes and p.axes[0] == "layers")
            if sub == "moe" and key in MOE_EXPERT_KEYS:
                # leading (layers?, expert) axes: one codebook per expert
                lead = p.shape[:2] if stacked else p.shape[:1]
                lead_axes = ("layers", "expert") if stacked else ("expert",)
            else:
                lead = (p.shape[0],) if stacked else ()
                lead_axes = ("layers",) if stacked else ()
            out[f"{sub}/{key}"] = {
                "mask": ParamSpec(p.shape, jnp.int8, p.axes,
                                  lambda k, s, t: jnp.ones(s, t)),
                "codebook": ParamSpec((*lead, qat.K_MAX), jnp.int32,
                                      (*lead_axes, None),
                                      lambda k, s, t: jnp.zeros(s, t)),
                "codebook_k": ParamSpec(lead, jnp.int32, lead_axes,
                                        lambda k, s, t: jnp.zeros(s, t)),
            }
    return out


def make_lm_comp_spec(model: LMModel) -> dict:
    """Comp spec tree (ParamSpec leaves) for the whole LM."""
    comp: dict = {}
    spec = model.spec
    if "blocks" in spec:
        comp["blocks"] = {
            g: _block_comp_spec(spec["blocks"][g]) for g in spec["blocks"]
        }
    if "tail" in spec:
        comp["tail"] = {
            t: _block_comp_spec(spec["tail"][t]) for t in spec["tail"]
        }
    if "enc_blocks" in spec:
        comp["enc_blocks"] = _block_comp_spec(spec["enc_blocks"])
    return comp


def init_lm_comp(model: LMModel) -> dict:
    """Concrete identity comp (all-ones masks, empty codebooks)."""
    from repro.nn.spec import init_params

    return init_params(jax.random.PRNGKey(0), make_lm_comp_spec(model))


def lm_comp_layers(model: LMModel) -> List[str]:
    """Flat names of compressible units ('blocks/g0/attn/wq', ...)."""
    spec = make_lm_comp_spec(model)
    names = []
    for top, groups in spec.items():
        if top == "enc_blocks":
            names.extend(f"{top}/{k}" for k in groups)
        else:
            for g, entries in groups.items():
                names.extend(f"{top}/{g}/{k}" for k in entries)
    return names


# ---------------------------------------------------------------- serving

# how each eligible weight reshapes to a (K, N) serving matrix:
# "in_first"  — contraction over axis 0, outputs flattened (wq/wk/wv (d,H,hd))
# "out_last"  — contraction over all leading axes (2-D mats, wo (H,hd,d))
_SERVE_LAYOUTS: Dict[str, str] = {
    "wq": "in_first", "wk": "in_first", "wv": "in_first", "wo": "out_last",
}


def _serve_layout(key: str, ndim: int) -> Optional[str]:
    """Layout for the 4-bit LUT GEMM; None = not servable as one matmul.

    Expert-batched MoE tensors never reach this table: the unit walkers
    slice them per (scan layer, expert) into plain 2-D matrices first, each
    carrying its own codebook and per-output-channel scale — the same
    semantics the per-expert vmapped fake-quant uses in training.
    """
    if ndim == 2:
        return "out_last"
    if ndim == 3:
        return _SERVE_LAYOUTS.get(key)
    return None


def _slice_comp(c: Optional[dict], idx: tuple) -> Optional[dict]:
    """Per-slice comp entry for one (layer[, expert]) slice of a unit."""
    if c is None:
        return None
    out = {"mask": c["mask"][idx], "codebook": c["codebook"][idx],
           "codebook_k": c["codebook_k"][idx]}
    if "msr_bits" in c:
        mb = c["msr_bits"]
        # msr_bits is scalar or per-scan-layer; never per-expert
        out["msr_bits"] = mb if jnp.ndim(mb) == 0 else mb[idx[0]]
    return out


def iter_eligible_units(model: LMModel, params: dict,
                        comp: Optional[dict] = None, *,
                        include_skipped: bool = False):
    """Yield (name, weight, comp_entry_or_None, layout) for every eligible
    matmul the serving engine treats as one (K, N) GEMM, regardless of
    restriction state.

    Stacked (scanned) units are yielded per scan layer — the scan applies
    fake-quant to per-layer slices, so each slice exports independently with
    its own scale, exactly matching the training semantics. Names follow
    ``blocks/g0/attn/wq[3]`` for layer 3 of a stack. Expert-batched MoE units
    additionally slice per expert (``blocks/g0/moe/w_gate[3][e2]``), matching
    the per-expert vmapped fake-quant. With ``comp=None`` the comp entries
    are None (used by serve-time energy accounting, which charges the
    unrestricted int8 histogram). With ``include_skipped``, units that have
    no serving layout are yielded once (unsliced) with ``layout=None``
    instead of being silently dropped.
    """
    spec = make_lm_comp_spec(model)
    for top, groups in spec.items():
        entries = ({None: groups} if top == "enc_blocks"
                   else {g: groups[g] for g in groups})
        for g, units in entries.items():
            for unit in units:
                sub, key = unit.split("/")
                node_p = params[top] if g is None else params[top][g]
                w = node_p[sub][key]
                spec_entry = (spec[top][unit] if g is None
                              else spec[top][g][unit])
                stacked = bool(spec_entry["mask"].axes
                               and spec_entry["mask"].axes[0] == "layers")
                c = None
                if comp is not None:
                    node_c = comp[top] if g is None else comp[top][g]
                    c = node_c[unit]
                base = f"{top}/{g}/{unit}" if g is not None else f"{top}/{unit}"
                if is_expert_unit(unit):
                    if stacked:
                        for li in range(w.shape[0]):
                            for ei in range(w.shape[1]):
                                yield (f"{base}[{li}][e{ei}]", w[li, ei],
                                       _slice_comp(c, (li, ei)), "out_last")
                    else:
                        for ei in range(w.shape[0]):
                            yield (f"{base}[e{ei}]", w[ei],
                                   _slice_comp(c, (ei,)), "out_last")
                elif stacked:
                    layout = _serve_layout(key, w.ndim - 1)
                    if layout is None:
                        if include_skipped:
                            yield base, w, c, None
                        continue
                    for li in range(w.shape[0]):
                        yield (f"{base}[{li}]", w[li],
                               _slice_comp(c, (li,)), layout)
                else:
                    layout = _serve_layout(key, w.ndim)
                    if layout is not None or include_skipped:
                        yield base, w, c, layout


def iter_restricted_units(model: LMModel, params: dict, comp: dict):
    """Yield (name, weight, comp_entry, layout) for every *servable* unit —
    the `iter_eligible_units` walk filtered to active <=16-value codebooks."""
    from repro.core import export as _export

    for name, w, c, layout in iter_eligible_units(model, params, comp):
        if c is not None and _export.servable(c):
            yield name, w, c, layout


def export_lm_matmuls(model: LMModel, params: dict, comp: dict, *,
                      block_k: int = 128, limit: Optional[int] = None
                      ) -> Tuple[Dict, List[Dict[str, str]]]:
    """Export every restricted eligible LM matmul to a `ServeArtifact`.

    Returns ``({unit_name: ServeArtifact}, skip_report)``;
    `repro.core.export.serve_dense` runs any of the artifacts (x flattened
    over leading axes, outputs reshaped by the caller per the unit's einsum).
    The skip report lists every eligible unit that did *not* export, as
    ``{"unit", "reason", "detail"}`` with reason one of ``no_layout``
    (no single-GEMM serving layout for the tensor rank),
    ``inactive_codebook`` (restriction never applied, codebook_k == 0) and
    ``codebook_too_large`` (k exceeds the 16-entry LUT hardware codebook) —
    nothing is dropped silently.
    """
    from repro.core import export as _export

    out: Dict = {}
    skips: List[Dict[str, str]] = []
    for name, w, c, layout in iter_eligible_units(model, params, comp,
                                                  include_skipped=True):
        if layout is None:
            skips.append({"unit": name, "reason": "no_layout",
                          "detail": f"rank-{w.ndim} tensor has no serving "
                                    "layout"})
            continue
        k = 0 if c is None else int(c["codebook_k"])
        if not (c is not None and _export.servable(c)):
            reason = "inactive_codebook" if k <= 0 else "codebook_too_large"
            skips.append({"unit": name, "reason": reason,
                          "detail": f"codebook_k={k}"})
            continue
        out[name] = _export.export_layer(w, c, kind="dense", layout=layout,
                                         block_k=block_k)
        if limit is not None and len(out) >= limit:
            break
    return out, skips


def attach_serve_artifacts(model: LMModel, params: dict, comp: dict, *,
                           block_k: int = 128) -> Tuple[dict, int]:
    """Return (comp copy with packed `ServeArtifact`s attached, unit count).

    Every servable eligible unit gains a ``"serve"`` key in its comp entry
    holding the packed 4-bit form of its weight; `QuantConfig.serve` forwards
    (attention `_project`, FFN `mm`, MoE expert/shared matmuls, scan-mixer
    projections, dense/conv layers) dispatch on that key to the fused LUT
    GEMM. Stacked (scanned) units export per scan layer — each layer keeps
    its own scale/codebook, exactly matching the per-slice fake-quant
    semantics — and the slices are stacked leaf-wise, so the artifact rides
    ``lax.scan`` xs and `jax.tree.map` layer slicing like every other comp
    leaf. Expert-batched MoE units additionally export per expert and stack
    the artifacts over the expert axis (`nn.moe` slices them back per expert
    at dispatch). Units that are not servable (inactive or >16-value
    codebooks, undefined layouts) keep their entries unchanged and fall back
    to fake-quant per unit.

    The ``"serve"`` key is derived content: `comp_fingerprint` skips it, so
    attaching artifacts never changes a plan's identity.
    """
    from repro.core import export as _export

    def all_servable(c) -> bool:
        from repro.kernels.lut_matmul.ops import N_CODES

        ks = jnp.asarray(c["codebook_k"]).reshape(-1)
        return bool(jnp.all((ks > 0) & (ks <= N_CODES)))

    def stack_arts(slices):
        if any(s is None for s in slices):
            return None
        return jax.tree.map(lambda *xs: jnp.stack(xs), *slices)

    def export_slice(w, c, idx, layout):
        return _export.export_layer(w[idx], _slice_comp(c, idx), kind="dense",
                                    layout=layout, block_k=block_k)

    def export_stacked(w, c, key):
        layout = _serve_layout(key, w.ndim - 1)
        if layout is None or not all_servable(c):
            return None
        return stack_arts([export_slice(w, c, (li,), layout)
                           for li in range(w.shape[0])])

    def export_expert(w, c, stacked):
        if not all_servable(c):
            return None
        if stacked:
            rows = [stack_arts([export_slice(w, c, (li, ei), "out_last")
                                for ei in range(w.shape[1])])
                    for li in range(w.shape[0])]
            return stack_arts(rows)
        return stack_arts([export_slice(w, c, (ei,), "out_last")
                           for ei in range(w.shape[0])])

    def attach_entries(node_p, entries):
        new, n = {}, 0
        for unit, c in entries.items():
            sub, key = unit.split("/")
            w = node_p[sub][key]
            entry = {k: v for k, v in c.items() if k != "serve"}
            if is_expert_unit(unit):
                art = export_expert(w, c, stacked=c["codebook"].ndim == 3)
            elif c["codebook"].ndim == 2:        # stacked over scan layers
                art = export_stacked(w, c, key)
            else:
                layout = _serve_layout(key, w.ndim)
                art = None if layout is None or not _export.servable(c) else \
                    _export.export_layer(w, c, kind="dense", layout=layout,
                                         block_k=block_k)
            if art is not None:
                entry["serve"] = art
                n += 1
            new[unit] = entry
        return new, n

    out, total = {}, 0
    for top, groups in comp.items():
        if top == "enc_blocks":
            out[top], n = attach_entries(params[top], groups)
            total += n
        elif top in ("blocks", "tail"):
            out[top] = {}
            for g, entries in groups.items():
                out[top][g], n = attach_entries(params[top][g], entries)
                total += n
        else:
            out[top] = groups
    return out, total


def lut_parity_report(model: LMModel, params: dict, comp: dict, arts: Dict,
                      *, check_units: int = 4, seed: int = 2) -> Dict[str, float]:
    """LUT-GEMM vs fake-quant-matmul parity on random activations.

    Checks up to ``check_units`` exported units (units without an artifact —
    e.g. export called with ``limit`` — are skipped, not treated as the end
    of the walk). Returns {unit_name: rel_err}. Shared by the pipeline's LM
    export stage and `repro.launch.serve.compress_report`.
    """
    from repro.core.export import serve_dense

    checked: Dict[str, float] = {}
    for name, w, c, layout in iter_restricted_units(model, params, comp):
        if len(checked) >= check_units:
            break
        if name not in arts:
            continue
        art = arts[name]
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, art.k_dim))
        w_fake = qat.fake_quant_weight(w, c)
        w_mat = (w_fake.reshape(w.shape[0], -1) if layout == "in_first"
                 else w_fake.reshape(-1, w.shape[-1]))
        want = x @ w_mat
        got = serve_dense(x, art)
        checked[name] = float(
            jnp.linalg.norm(got - want)
            / jnp.maximum(jnp.linalg.norm(want), 1e-9))
    return checked


def symmetric_codebook_values(k: int) -> list:
    """Restricted set of exactly k int8 values: 0 plus levels spread over the
    int8 range (one extra negative level when k is even)."""
    import numpy as np

    n_neg = k // 2
    n_pos = k - 1 - n_neg
    values = sorted(
        {0}
        | {-int(v) for v in np.linspace(16, 120, n_neg)}
        | {int(v) for v in np.linspace(16, 120, n_pos)})
    assert len(values) == k, (k, values)
    return values


def restrict_all_codebooks(model: LMModel, comp: dict, values) -> dict:
    """Apply one codebook value set to every compressible unit of the LM."""
    for path in lm_comp_layers(model):
        comp = set_codebook(comp, path, values)
    return comp


def set_codebook(comp: dict, path: str, values, layer: Optional[int] = None,
                 expert: Optional[int] = None) -> dict:
    """Functional codebook update for unit `path` ('blocks/g0/mlp/w_down').

    For stacked (scanned) units, `layer` selects the repeat index; for
    expert-batched MoE units, `expert` selects the expert. A None index
    broadcasts the codebook over that whole axis.
    """
    cb, k = qat.make_codebook(values)
    parts = path.split("/")
    unit = "/".join(parts[-2:])
    node_path = parts[:-2]

    def set_entry(entry):
        lead = entry["codebook"].shape[:-1]  # () | (L,) | (E,) | (L, E)
        if len(lead) == 2:
            idx: Tuple[Optional[int], ...] = (layer, expert)
        elif len(lead) == 1:
            idx = (expert,) if is_expert_unit(unit) else (layer,)
        else:
            entry["codebook"] = cb
            entry["codebook_k"] = jnp.asarray(k)
            return entry
        if all(i is None for i in idx):
            entry["codebook"] = jnp.broadcast_to(
                cb, entry["codebook"].shape).copy()
            entry["codebook_k"] = jnp.full_like(entry["codebook_k"], k)
        elif len(idx) == 2 and idx[0] is None:   # every layer, one expert
            entry["codebook"] = entry["codebook"].at[:, idx[1]].set(cb)
            entry["codebook_k"] = entry["codebook_k"].at[:, idx[1]].set(k)
        else:
            ii = tuple(i for i in idx if i is not None)  # full or row index
            entry["codebook"] = entry["codebook"].at[ii].set(cb)
            entry["codebook_k"] = entry["codebook_k"].at[ii].set(k)
        return entry

    def update(tree, keys):
        out = dict(tree)
        if not keys:
            out[unit] = set_entry(dict(tree[unit]))
            return out
        out[keys[0]] = update(tree[keys[0]], keys[1:])
        return out

    return update(comp, node_path)
