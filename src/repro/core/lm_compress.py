"""LM-side compression state: per-layer masks + codebooks for scanned blocks.

Builds a comp tree mirroring the LM's grouped parameter layout:

    comp = {
      "blocks":     {"g0": {"attn/wq": CompState, "mlp/w_gate": ...}, ...}
                    with leaves stacked over the scan (layers) axis,
      "tail":       {"t0": {...}},           # unstacked
      "enc_blocks": {...},                   # whisper encoder (stacked)
    }

Eligible tensors are exactly the matmul weights that occupy systolic
weight-stationary registers (DESIGN.md §Arch-applicability): attention
projections, FFN/expert matrices, SSM/RG-LRU projections and gate matrices.
Router weights, depthwise-conv taps, per-head scalars (A/dt/Lambda), biases
and norms are excluded. Masks are stored int8 to bound the footprint at 26B+
scale (cast to the weight dtype inside `repro.core.qat`).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qat
from repro.models.lm import LMModel
from repro.nn.spec import ParamSpec

# sub-module name -> weight keys eligible for weight-value restriction
ELIGIBLE: Dict[str, Tuple[str, ...]] = {
    "attn": ("wq", "wk", "wv", "wo"),
    "xattn": ("wq", "wk", "wv", "wo"),
    "mlp": ("w_gate", "w_up", "w_down"),
    "moe": ("w_gate", "w_up", "w_down",
            "shared_gate", "shared_up", "shared_down"),
    "ssm": ("in_proj", "out_proj"),
    "rglru": ("in_proj", "gate_proj", "w_a", "w_x", "out_proj"),
}


def _block_comp_spec(block_spec: dict) -> dict:
    """{'attn/wq': comp-spec-dict} for one (possibly stacked) block spec."""
    out = {}
    for sub, keys in ELIGIBLE.items():
        if sub not in block_spec:
            continue
        for key in keys:
            if key not in block_spec[sub]:
                continue
            p: ParamSpec = block_spec[sub][key]
            stacked = p.axes and p.axes[0] == "layers"
            cb_shape = (p.shape[0], qat.K_MAX) if stacked else (qat.K_MAX,)
            k_shape = (p.shape[0],) if stacked else ()
            out[f"{sub}/{key}"] = {
                "mask": ParamSpec(p.shape, jnp.int8, p.axes,
                                  lambda k, s, t: jnp.ones(s, t)),
                "codebook": ParamSpec(cb_shape, jnp.int32,
                                      ("layers", None) if stacked else (None,),
                                      lambda k, s, t: jnp.zeros(s, t)),
                "codebook_k": ParamSpec(k_shape, jnp.int32,
                                        ("layers",) if stacked else (),
                                        lambda k, s, t: jnp.zeros(s, t)),
            }
    return out


def make_lm_comp_spec(model: LMModel) -> dict:
    """Comp spec tree (ParamSpec leaves) for the whole LM."""
    comp: dict = {}
    spec = model.spec
    if "blocks" in spec:
        comp["blocks"] = {
            g: _block_comp_spec(spec["blocks"][g]) for g in spec["blocks"]
        }
    if "tail" in spec:
        comp["tail"] = {
            t: _block_comp_spec(spec["tail"][t]) for t in spec["tail"]
        }
    if "enc_blocks" in spec:
        comp["enc_blocks"] = _block_comp_spec(spec["enc_blocks"])
    return comp


def init_lm_comp(model: LMModel) -> dict:
    """Concrete identity comp (all-ones masks, empty codebooks)."""
    from repro.nn.spec import init_params

    return init_params(jax.random.PRNGKey(0), make_lm_comp_spec(model))


def lm_comp_layers(model: LMModel) -> List[str]:
    """Flat names of compressible units ('blocks/g0/attn/wq', ...)."""
    spec = make_lm_comp_spec(model)
    names = []
    for top, groups in spec.items():
        if top == "enc_blocks":
            names.extend(f"{top}/{k}" for k in groups)
        else:
            for g, entries in groups.items():
                names.extend(f"{top}/{g}/{k}" for k in entries)
    return names


# ---------------------------------------------------------------- serving

# how each eligible weight reshapes to a (K, N) serving matrix:
# "in_first"  — contraction over axis 0, outputs flattened (wq/wk/wv (d,H,hd))
# "out_last"  — contraction over all leading axes (2-D mats, wo (H,hd,d))
_SERVE_LAYOUTS: Dict[str, str] = {
    "wq": "in_first", "wk": "in_first", "wv": "in_first", "wo": "out_last",
}


def _serve_layout(key: str, ndim: int) -> Optional[str]:
    """Layout for the 4-bit LUT GEMM; None = not servable as one matmul.

    Per-expert MoE tensors (expert-batched matmuls sharing one quant scale
    across experts) are excluded: slicing them per expert would change the
    scale semantics vs training. They stay on the fake-quant path.
    """
    if ndim == 2:
        return "out_last"
    if ndim == 3:
        return _SERVE_LAYOUTS.get(key)
    return None


def iter_eligible_units(model: LMModel, params: dict, comp: Optional[dict] = None):
    """Yield (name, weight, comp_entry_or_None, layout) for every eligible
    matmul the serving engine treats as one (K, N) GEMM, regardless of
    restriction state.

    Stacked (scanned) units are yielded per scan layer — the scan applies
    fake-quant to per-layer slices, so each slice exports independently with
    its own scale, exactly matching the training semantics. Names follow
    ``blocks/g0/attn/wq[3]`` for layer 3 of a stack. With ``comp=None`` the
    comp entries are None (used by serve-time energy accounting, which
    charges the unrestricted int8 histogram).
    """
    spec = make_lm_comp_spec(model)
    for top, groups in spec.items():
        entries = ({None: groups} if top == "enc_blocks"
                   else {g: groups[g] for g in groups})
        for g, units in entries.items():
            for unit in units:
                sub, key = unit.split("/")
                node_p = params[top] if g is None else params[top][g]
                w = node_p[sub][key]
                if comp is None:
                    c = None
                    stacked = (spec[top][unit] if g is None
                               else spec[top][g][unit])["codebook"].shape != (qat.K_MAX,)
                else:
                    node_c = comp[top] if g is None else comp[top][g]
                    c = node_c[unit]
                    stacked = c["codebook"].ndim == 2
                base = f"{top}/{g}/{unit}" if g is not None else f"{top}/{unit}"
                if stacked:
                    layout = _serve_layout(key, w.ndim - 1)
                    if layout is None:
                        continue
                    for li in range(w.shape[0]):
                        c_l = None if c is None else {
                            "mask": c["mask"][li],
                            "codebook": c["codebook"][li],
                            "codebook_k": c["codebook_k"][li]}
                        yield f"{base}[{li}]", w[li], c_l, layout
                else:
                    layout = _serve_layout(key, w.ndim)
                    if layout is not None:
                        yield base, w, c, layout


def iter_restricted_units(model: LMModel, params: dict, comp: dict):
    """Yield (name, weight, comp_entry, layout) for every *servable* unit —
    the `iter_eligible_units` walk filtered to active <=16-value codebooks."""
    from repro.core import export as _export

    for name, w, c, layout in iter_eligible_units(model, params, comp):
        if c is not None and _export.servable(c):
            yield name, w, c, layout


def export_lm_matmuls(model: LMModel, params: dict, comp: dict, *,
                      block_k: int = 128, limit: Optional[int] = None) -> Dict:
    """Export every restricted eligible LM matmul to a `ServeArtifact`.

    Returns {unit_name: ServeArtifact}; `repro.core.export.serve_dense`
    runs any of them (x flattened over leading axes, outputs reshaped by the
    caller per the unit's einsum).
    """
    from repro.core import export as _export

    out = {}
    for name, w, c, layout in iter_restricted_units(model, params, comp):
        out[name] = _export.export_layer(w, c, kind="dense", layout=layout,
                                         block_k=block_k)
        if limit is not None and len(out) >= limit:
            break
    return out


def attach_serve_artifacts(model: LMModel, params: dict, comp: dict, *,
                           block_k: int = 128) -> Tuple[dict, int]:
    """Return (comp copy with packed `ServeArtifact`s attached, unit count).

    Every servable eligible unit gains a ``"serve"`` key in its comp entry
    holding the packed 4-bit form of its weight; `QuantConfig.serve` forwards
    (attention `_project`, FFN `mm`, dense/conv layers) dispatch on that key
    to the fused LUT GEMM. Stacked (scanned) units export per scan layer —
    each layer keeps its own scale/codebook, exactly matching the per-slice
    fake-quant semantics — and the slices are stacked leaf-wise, so the
    artifact rides ``lax.scan`` xs and `jax.tree.map` layer slicing like
    every other comp leaf. Units that are not servable (inactive or >16-value
    codebooks, undefined layouts, MoE experts) keep their entries unchanged
    and fall back to fake-quant per unit.

    The ``"serve"`` key is derived content: `comp_fingerprint` skips it, so
    attaching artifacts never changes a plan's identity.
    """
    from repro.core import export as _export

    def export_stacked(w, c, key):
        layout = _serve_layout(key, w.ndim - 1)
        if layout is None:
            return None
        from repro.kernels.lut_matmul.ops import N_CODES

        ks = jnp.asarray(c["codebook_k"]).reshape(-1)
        if not bool(jnp.all((ks > 0) & (ks <= N_CODES))):
            return None
        slices = []
        for li in range(w.shape[0]):
            c_l = {"mask": c["mask"][li], "codebook": c["codebook"][li],
                   "codebook_k": c["codebook_k"][li]}
            if "msr_bits" in c:
                mb = c["msr_bits"]
                c_l["msr_bits"] = mb if jnp.ndim(mb) == 0 else mb[li]
            art = _export.export_layer(w[li], c_l, kind="dense",
                                       layout=layout, block_k=block_k)
            if art is None:
                return None
            slices.append(art)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *slices)

    def attach_entries(node_p, entries):
        new, n = {}, 0
        for unit, c in entries.items():
            sub, key = unit.split("/")
            w = node_p[sub][key]
            entry = {k: v for k, v in c.items() if k != "serve"}
            if c["codebook"].ndim == 2:          # stacked over scan layers
                art = export_stacked(w, c, key)
            else:
                layout = _serve_layout(key, w.ndim)
                art = None if layout is None or not _export.servable(c) else \
                    _export.export_layer(w, c, kind="dense", layout=layout,
                                         block_k=block_k)
            if art is not None:
                entry["serve"] = art
                n += 1
            new[unit] = entry
        return new, n

    out, total = {}, 0
    for top, groups in comp.items():
        if top == "enc_blocks":
            out[top], n = attach_entries(params[top], groups)
            total += n
        elif top in ("blocks", "tail"):
            out[top] = {}
            for g, entries in groups.items():
                out[top][g], n = attach_entries(params[top][g], entries)
                total += n
        else:
            out[top] = groups
    return out, total


def lut_parity_report(model: LMModel, params: dict, comp: dict, arts: Dict,
                      *, check_units: int = 4, seed: int = 2) -> Dict[str, float]:
    """LUT-GEMM vs fake-quant-matmul parity on random activations.

    Checks up to ``check_units`` exported units (units without an artifact —
    e.g. export called with ``limit`` — are skipped, not treated as the end
    of the walk). Returns {unit_name: rel_err}. Shared by the pipeline's LM
    export stage and `repro.launch.serve.compress_report`.
    """
    from repro.core.export import serve_dense

    checked: Dict[str, float] = {}
    for name, w, c, layout in iter_restricted_units(model, params, comp):
        if len(checked) >= check_units:
            break
        if name not in arts:
            continue
        art = arts[name]
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, art.k_dim))
        w_fake = qat.fake_quant_weight(w, c)
        w_mat = (w_fake.reshape(w.shape[0], -1) if layout == "in_first"
                 else w_fake.reshape(-1, w.shape[-1]))
        want = x @ w_mat
        got = serve_dense(x, art)
        checked[name] = float(
            jnp.linalg.norm(got - want)
            / jnp.maximum(jnp.linalg.norm(want), 1e-9))
    return checked


def symmetric_codebook_values(k: int) -> list:
    """Restricted set of exactly k int8 values: 0 plus levels spread over the
    int8 range (one extra negative level when k is even)."""
    import numpy as np

    n_neg = k // 2
    n_pos = k - 1 - n_neg
    values = sorted(
        {0}
        | {-int(v) for v in np.linspace(16, 120, n_neg)}
        | {int(v) for v in np.linspace(16, 120, n_pos)})
    assert len(values) == k, (k, values)
    return values


def restrict_all_codebooks(model: LMModel, comp: dict, values) -> dict:
    """Apply one codebook value set to every compressible unit of the LM."""
    for path in lm_comp_layers(model):
        comp = set_codebook(comp, path, values)
    return comp


def set_codebook(comp: dict, path: str, values, layer: Optional[int] = None) -> dict:
    """Functional codebook update for unit `path` ('blocks/g0/mlp/w_down').

    For stacked (scanned) units, `layer` selects the repeat index; None
    applies the same codebook to every layer of the stack.
    """
    cb, k = qat.make_codebook(values)
    parts = path.split("/")
    unit = "/".join(parts[-2:])
    node_path = parts[:-2]

    def update(tree, keys):
        if not keys:
            entry = dict(tree[unit])
            if entry["codebook"].ndim == 2:  # stacked
                if layer is None:
                    entry["codebook"] = jnp.broadcast_to(
                        cb, entry["codebook"].shape).copy()
                    entry["codebook_k"] = jnp.full_like(entry["codebook_k"], k)
                else:
                    entry["codebook"] = entry["codebook"].at[layer].set(cb)
                    entry["codebook_k"] = entry["codebook_k"].at[layer].set(k)
            else:
                entry["codebook"] = cb
                entry["codebook_k"] = jnp.asarray(k)
            out = dict(tree)
            out[unit] = entry
            return out
        out = dict(tree)
        out[keys[0]] = update(tree[keys[0]], keys[1:])
        return out

    return update(comp, node_path)
