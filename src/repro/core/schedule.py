"""Energy-prioritized layer-wise compression schedule (paper 4.3).

Layers are sorted by normalized energy share ρ_l = E_l / Σ_j E_j and
processed in descending order. For each layer we try candidate configurations
(prune ratio × target codebook size × MSR truncation depth, see
`qat.msr_truncate_int` and docs/cosim.md), most aggressive first (ranked by
estimated energy saving), and accept the first whose post-finetune *global*
validation accuracy stays above ``acc0 - δ``. Low-energy layers therefore
naturally receive milder compression — exactly the behaviour of Table 2.

Two search modes implement the same accept semantics:

* ``search_mode="serial"`` — the reference trial-and-rollback loop: one
  candidate at a time, each paying its own trial fine-tune, greedy weight
  selection and eval before rolling back on reject.
* ``search_mode="batched"`` (default) — the candidate sweep: all candidate
  comp states for a layer are stacked along a leading axis
  (`qat.stack_pytrees`) and the trial fine-tune + accuracy evals run for the
  whole candidate set in one vmapped dispatch per step
  (`CnnRunner.train_batched` / `accuracy_batched`); the greedy weight-set
  eliminations of all candidates advance in lockstep
  (`weight_selection.lockstep_backward_elimination`), fusing each round's
  codebook evals across candidates into one gathered dispatch
  (`CnnRunner.accuracy_gather`). Accept-the-most-aggressive becomes a
  single scan over the per-candidate accuracy vector against the
  ``acc0 - δ`` floor — because `_config_order` sorts most-aggressive-first,
  the first passing index is exactly the candidate the serial walk would
  accept. An optional 1-D device mesh (`CnnRunner.sweep_mesh`,
  `repro.distributed.sharding.sweep_mesh`) shards the candidate axis via
  `shard_map`, mirroring the profiler's tile mesh. Decision parity with the
  serial walk is exact (see docs/schedule.md) and gated in CI.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core import qat
from repro.core.weight_selection import (
    SelectionConfig,
    SelectionReport,
    codebook_comp,
    greedy_backward_elimination,
    initial_candidate_set,
    lockstep_backward_elimination,
)


@dataclasses.dataclass
class ScheduleConfig:
    # candidate configurations, aggressive -> mild (paper: ratios {0.3,0.5,0.7},
    # sizes {32,24,16})
    prune_ratios: Tuple[float, ...] = (0.7, 0.5, 0.3)
    k_targets: Tuple[int, ...] = (16, 24, 32)
    # third candidate axis: MSR truncation depths (qat.msr_truncate_int);
    # 0 = off. The default keeps the candidate set — and hence every
    # existing decision trace — identical to the pre-MSR schedule.
    msr_bits: Tuple[int, ...] = (0,)
    delta_acc: float = 0.03
    finetune_steps: int = 60        # after each accepted layer config
    trial_finetune_steps: int = 30  # inside a trial, before the accept check
    eval_batches: int = 4
    min_energy_share: float = 0.01  # skip layers below this ρ (tiny fc heads)
    max_layers: Optional[int] = None  # cap processed layers (tests)
    search_mode: str = "batched"    # "batched" candidate sweep | "serial"
    # when MSR depths are in play, rank candidates by a *measured* energy
    # prior — quantize this layer's weights under each (prune, k, msr)
    # combo and score the resulting value histogram against the layer's
    # energy LUT — instead of the static lexicographic aggressiveness
    # proxy. With msr_bits=(0,) the prior is a no-op (order unchanged), so
    # existing decision traces are untouched.
    msr_energy_prior: bool = True


@dataclasses.dataclass
class LayerDecision:
    layer: str
    share: float
    prune_ratio: Optional[float]
    k: Optional[int]
    energy_before: float
    energy_after: float
    accuracy: float
    accepted: bool
    tried: List[Tuple[float, int, int]] = dataclasses.field(
        default_factory=list)
    msr: Optional[int] = None   # accepted MSR depth (0/None = off)

    @property
    def saving(self) -> float:
        if self.energy_before <= 0:
            return 0.0
        return 1.0 - self.energy_after / self.energy_before


@dataclasses.dataclass
class ScheduleResult:
    decisions: List[LayerDecision]
    acc0: float
    acc_final: float
    energy_before: float
    energy_after: float
    selection_reports: List[SelectionReport]

    @property
    def energy_saving(self) -> float:
        return 1.0 - self.energy_after / max(self.energy_before, 1e-12)


# upper bound on how many gathered param/comp copies one lockstep eval may
# materialize at once (memory guard; requests beyond it are chunked)
_MAX_EVAL_FANOUT = 64


def _config_order(cfg: ScheduleConfig) -> List[Tuple[float, int, int]]:
    """All (prune, k, msr) combos, most aggressive (highest expected saving)
    first: higher prune, then MSR truncation on before off (fewer kept bits
    = more aggressive), then smaller k. With the default ``msr_bits=(0,)``
    this reduces exactly to the historical (prune, k) order."""
    combos = [(p, k, m) for p in cfg.prune_ratios for k in cfg.k_targets
              for m in cfg.msr_bits]
    return sorted(combos, key=lambda c: (-c[0], c[2] == 0, c[2], c[1]))


def _candidate_order(runner, params, comp, models, layer,
                     cfg: ScheduleConfig) -> List[Tuple[float, int, int]]:
    """Candidate combos for one layer, most aggressive first.

    With ``msr_energy_prior`` off — or no non-zero MSR depth in play — this
    is exactly `_config_order`. Otherwise each combo's post-compression
    layer energy is *estimated* (prune mask + symmetric k-value codebook
    proxy + MSR truncation -> int weight histogram -> LUT energy) and the
    combos are reordered by that estimate ascending (largest expected
    saving first), ties broken by the static order. Both search modes call
    this helper with identical inputs, so serial/batched decision parity is
    preserved by construction.
    """
    combos = _config_order(cfg)
    if not cfg.msr_energy_prior or all(m == 0 for m in cfg.msr_bits):
        return combos

    from repro.core.layer_energy import (
        layer_energy_from_counts,
        weight_value_counts,
    )
    from repro.core.lm_compress import symmetric_codebook_values
    from repro.core.stats import conv_weight_matrix

    cl = runner.model.comp_layer(layer)
    m = models[layer]
    w = runner.model.get_weight(params, layer)
    cost = []
    for prune, k_target, msr in combos:
        cb, k = qat.make_codebook(symmetric_codebook_values(k_target))
        c_est = dict(comp[layer])
        c_est["mask"] = qat.magnitude_prune_mask(w, prune)
        c_est["codebook"] = cb
        c_est["codebook_k"] = k
        c_est["msr_bits"] = jnp.asarray(msr, jnp.int32)
        w_int = qat.quantize_weight_int(w, c_est)
        w_int = conv_weight_matrix(w_int) if cl.kind == "conv" else w_int.T
        counts = weight_value_counts(w_int, m.dims)
        cost.append(float(layer_energy_from_counts(counts, m.lut, m.dims)))
    order = sorted(range(len(combos)), key=lambda i: (cost[i], i))
    return [combos[i] for i in order]


def _sweep_layer_serial(runner, params, state, opt_state, comp, models,
                        layer, share, acc0, cfg, sel_cfg, verbose):
    """Reference trial-and-rollback walk: one candidate config at a time."""
    e_before = models[layer].energy
    tried: List[Tuple[float, int, int]] = []
    for prune, k_target, msr in _candidate_order(runner, params, comp,
                                                 models, layer, cfg):
        tried.append((prune, k_target, msr))
        t0 = time.time()
        # --- trial state (rollback on reject)
        t_params, t_state, t_opt = params, state, opt_state
        t_comp = {n: dict(c) for n, c in comp.items()}

        # 1. prune + MSR truncation depth for this candidate
        w = runner.model.get_weight(t_params, layer)
        t_comp[layer]["mask"] = qat.magnitude_prune_mask(w, prune)
        t_comp[layer]["msr_bits"] = jnp.asarray(msr, jnp.int32)

        # 2. fine-tune with the mask (paper: pruning first, then finetune)
        if cfg.trial_finetune_steps:
            t_params, t_state, t_opt, _ = runner.train(
                t_params, t_state, t_opt, t_comp, cfg.trial_finetune_steps)

        # 3. weight-set selection on the pruned layer
        t_models = runner.refresh_counts(t_params, t_comp, models)
        lsel = dataclasses.replace(sel_cfg, k_target=k_target)
        init_set = initial_candidate_set(
            t_models[layer].counts, t_models[layer].lut, lsel)

        def eval_with_codebook(values, n_batches, _layer=layer,
                               _params=t_params, _state=t_state,
                               _comp=t_comp):
            c2 = codebook_comp(_comp, _layer, values)
            return runner.accuracy(_params, _state, c2, n_batches=n_batches)

        final_set, rep = greedy_backward_elimination(
            t_models[layer], init_set, lsel, acc0,
            eval_with_codebook=eval_with_codebook)
        t_comp = codebook_comp(t_comp, layer, final_set)

        # 4. short fine-tune with the restriction active, then accept check
        if cfg.finetune_steps:
            t_params, t_state, t_opt, _ = runner.train(
                t_params, t_state, t_opt, t_comp, cfg.finetune_steps)
        acc = runner.accuracy(t_params, t_state, t_comp,
                              n_batches=cfg.eval_batches)
        if verbose:
            print(f"  try prune={prune} k={k_target} msr={msr}: "
                  f"acc={acc:.3f} (floor {acc0 - cfg.delta_acc:.3f}) "
                  f"[{time.time() - t0:.1f}s]")
        if acc >= acc0 - cfg.delta_acc:
            models = runner.refresh_counts(t_params, t_comp, models)
            decision = LayerDecision(
                layer, share, prune, k_target, e_before,
                models[layer].energy, acc, True, tried, msr=msr)
            return t_params, t_state, t_opt, t_comp, models, decision, rep

    decision = LayerDecision(layer, share, None, None, e_before, e_before,
                             acc0, False, tried)
    return params, state, opt_state, comp, models, decision, None


def _sweep_layer_batched(runner, params, state, opt_state, comp, models,
                         layer, share, acc0, cfg, sel_cfg, verbose):
    """Batched candidate sweep: every (prune, k, msr) trial advances in
    lockstep.

    The N candidates are independent given their comp states, so the serial
    walk's rollback discipline is free here — rejected candidates are simply
    never selected out of the stacked trees, and the caller's
    params/opt_state are returned untouched when no candidate passes.
    """
    combos = _candidate_order(runner, params, comp, models, layer, cfg)
    n = len(combos)
    e_before = models[layer].energy
    t0 = time.time()
    w = runner.model.get_weight(params, layer)

    # 1. prune: per-candidate comp trees (identical except this layer's
    # mask and MSR truncation depth)
    cand_comps = []
    for prune, _k, msr in combos:
        c = {nm: dict(cc) for nm, cc in comp.items()}
        c[layer]["mask"] = qat.magnitude_prune_mask(w, prune)
        c[layer]["msr_bits"] = jnp.asarray(msr, jnp.int32)
        cand_comps.append(c)
    comps_s = qat.stack_pytrees(cand_comps)
    params_s = qat.broadcast_pytree(params, n)
    state_s = qat.broadcast_pytree(state, n)
    opt_s = qat.broadcast_pytree(opt_state, n)

    # 2. trial fine-tune, all candidates per step in one vmapped dispatch;
    # each candidate sees the batch stream the serial walk would feed it
    if cfg.trial_finetune_steps:
        params_s, state_s, opt_s, _ = runner.train_batched(
            params_s, state_s, opt_s, comps_s, cfg.trial_finetune_steps)

    # 3. weight-set selection: all candidates' greedy eliminations advance
    # in lockstep — every sync point fuses the outstanding codebook evals
    # across candidates (a round's trial codebooks, then the accept checks,
    # then the acc_ref refreshes) into one gathered vmapped dispatch, each
    # trial scored against its own candidate's fine-tuned weights. The
    # per-trial ΔE refresh touches only the layer under search.
    lsels = [dataclasses.replace(sel_cfg, k_target=k) for _, k, _ in combos]
    t_models: List[object] = []
    init_sets: List[List[int]] = []
    for i in range(n):
        t_params = qat.index_pytree(params_s, i)
        m_i = runner.refresh_layer_counts(t_params, cand_comps[i], models,
                                          layer)
        t_models.append(m_i)
        init_sets.append(initial_candidate_set(m_i.counts, m_i.lut, lsels[i]))

    masks_s = comps_s[layer]["mask"]
    msrs_s = comps_s[layer]["msr_bits"]
    # requests are padded to multiples of n so `accuracy_gather` compiles a
    # handful of shapes per sweep while late rounds — when most candidates
    # have finished — don't re-evaluate a full scoring round's worth of
    # padding. Each gathered eval materializes `cap` param/comp copies, so
    # big rounds (n x max_score_candidates requests) are chunked to keep
    # device memory bounded; the shared non-target comp broadcasts are
    # cached per capacity.
    rest_cache: Dict[int, Dict[str, qat.CompState]] = {}
    max_chunk = max(n, (_MAX_EVAL_FANOUT // n) * n)

    def eval_chunk(reqs, n_batches):
        n_req = len(reqs)
        cap = -(-n_req // n) * n
        padded = list(reqs) + [reqs[-1]] * (cap - n_req)
        idx = [i for i, _ in padded]
        cbs, ks = qat.make_codebooks([v for _, v in padded])
        if cap not in rest_cache:
            rest_cache[cap] = {nm: qat.broadcast_pytree(cc, cap)
                               for nm, cc in comp.items() if nm != layer}
        comps_e = dict(rest_cache[cap])
        comps_e[layer] = {
            "mask": jnp.take(masks_s, jnp.asarray(idx), axis=0),
            "codebook": cbs,
            "codebook_k": ks,
            # each request scores against its own candidate's MSR depth —
            # dropping this would silently diverge from the serial walk
            "msr_bits": jnp.take(msrs_s, jnp.asarray(idx), axis=0),
        }
        return runner.accuracy_gather(params_s, state_s, comps_e, idx,
                                      n_batches=n_batches)[:n_req]

    def eval_requests(reqs, n_batches):
        out = []
        for lo in range(0, len(reqs), max_chunk):
            out.extend(eval_chunk(reqs[lo:lo + max_chunk], n_batches))
        return out

    sel_out = lockstep_backward_elimination(
        t_models, init_sets, lsels, acc0, eval_requests=eval_requests)
    sel_reports: List[SelectionReport] = [rep for _, rep in sel_out]
    for i, (final_set, _) in enumerate(sel_out):
        cand_comps[i] = codebook_comp(cand_comps[i], layer, final_set)
    comps_s = qat.stack_pytrees(cand_comps)

    # 4. short fine-tune with restrictions active, then the accept check:
    # one vmapped eval yields the whole per-candidate accuracy vector
    if cfg.finetune_steps:
        params_s, state_s, opt_s, _ = runner.train_batched(
            params_s, state_s, opt_s, comps_s, cfg.finetune_steps)
    accs = runner.accuracy_batched(params_s, state_s, comps_s,
                                   n_batches=cfg.eval_batches)

    floor = acc0 - cfg.delta_acc
    if verbose:
        for (prune, k_target, msr), acc in zip(combos, accs):
            print(f"  cand prune={prune} k={k_target} msr={msr}: "
                  f"acc={acc:.3f} (floor {floor:.3f})")
        print(f"  [batched sweep of {n} candidates: {time.time() - t0:.1f}s]")

    # accept the most aggressive passing candidate (combos are ordered
    # aggressive -> mild, so this is the serial walk's first accept)
    passing = [i for i, acc in enumerate(accs) if acc >= floor]
    if not passing:
        decision = LayerDecision(layer, share, None, None, e_before, e_before,
                                 acc0, False, list(combos))
        return params, state, opt_state, comp, models, decision, None

    i = passing[0]
    prune, k_target, msr = combos[i]
    params = qat.index_pytree(params_s, i)
    state = qat.index_pytree(state_s, i)
    opt_state = qat.index_pytree(opt_s, i)
    comp = cand_comps[i]
    models = runner.refresh_counts(params, comp, models)
    decision = LayerDecision(layer, share, prune, k_target, e_before,
                             models[layer].energy, float(accs[i]), True,
                             list(combos[: i + 1]), msr=msr)
    return params, state, opt_state, comp, models, decision, sel_reports[i]


_SEARCH_MODES = {"serial": _sweep_layer_serial, "batched": _sweep_layer_batched}


def energy_prioritized_compression(
    runner,
    params,
    state,
    opt_state,
    comp: Dict[str, qat.CompState],
    stats,
    cfg: ScheduleConfig,
    sel_cfg: Optional[SelectionConfig] = None,
    *,
    verbose: bool = False,
) -> Tuple[object, object, object, Dict[str, qat.CompState], ScheduleResult]:
    """Run the full layer-wise schedule. Returns updated (params, state,
    opt_state, comp, result).

    ``stats=None`` profiles through the runner's batched profiler (cached on
    the runner); every ΔE refresh below reuses those trace statistics — only
    the O(256) weight-value histograms are recomputed per trial."""
    sel_cfg = sel_cfg or SelectionConfig(delta_acc=cfg.delta_acc)
    try:
        sweep_layer = _SEARCH_MODES[cfg.search_mode]
    except KeyError:
        raise ValueError(
            f"search_mode must be one of {sorted(_SEARCH_MODES)}, "
            f"got {cfg.search_mode!r}") from None

    acc0 = runner.accuracy(params, state, comp, n_batches=cfg.eval_batches)
    if stats is None:
        stats = runner.layer_stats(params, state, comp)
    models = runner.energy_models(params, comp, stats)
    e_total_before = sum(m.energy for m in models.values())
    shares = {n: m.energy / max(e_total_before, 1e-12) for n, m in models.items()}
    order = sorted(shares, key=lambda n: -shares[n])
    if cfg.max_layers is not None:
        order = order[: cfg.max_layers]

    decisions: List[LayerDecision] = []
    reports: List[SelectionReport] = []

    for layer in order:
        share = shares[layer]
        e_before = models[layer].energy
        if share < cfg.min_energy_share:
            decisions.append(LayerDecision(layer, share, None, None, e_before,
                                           e_before, acc0, False))
            continue
        if verbose:
            print(f"[schedule] layer={layer} share={share:.3f} "
                  f"mode={cfg.search_mode}")

        params, state, opt_state, comp, models, decision, rep = sweep_layer(
            runner, params, state, opt_state, comp, models, layer, share,
            acc0, cfg, sel_cfg, verbose)
        decisions.append(decision)
        if rep is not None:
            reports.append(rep)

    models = runner.refresh_counts(params, comp, models)
    e_total_after = sum(m.energy for m in models.values())
    acc_final = runner.accuracy(params, state, comp, n_batches=cfg.eval_batches)
    result = ScheduleResult(
        decisions=decisions,
        acc0=acc0,
        acc_final=acc_final,
        energy_before=e_total_before,
        energy_after=e_total_after,
        selection_reports=reports,
    )
    return params, state, opt_state, comp, result
