"""Energy-prioritized layer-wise compression schedule (paper 4.3).

Layers are sorted by normalized energy share ρ_l = E_l / Σ_j E_j and
processed in descending order. For each layer we try candidate configurations
(prune ratio × target codebook size), most aggressive first (ranked by
estimated energy saving), and accept the first whose post-finetune *global*
validation accuracy stays above ``acc0 - δ``. Low-energy layers therefore
naturally receive milder compression — exactly the behaviour of Table 2.
"""

from __future__ import annotations

import copy
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core import qat
from repro.core.layer_energy import LayerEnergyModel, layer_energy_from_counts
from repro.core.weight_selection import (
    SelectionConfig,
    SelectionReport,
    codebook_comp,
    greedy_backward_elimination,
    initial_candidate_set,
)


@dataclasses.dataclass
class ScheduleConfig:
    # candidate configurations, aggressive -> mild (paper: ratios {0.3,0.5,0.7},
    # sizes {32,24,16})
    prune_ratios: Tuple[float, ...] = (0.7, 0.5, 0.3)
    k_targets: Tuple[int, ...] = (16, 24, 32)
    delta_acc: float = 0.03
    finetune_steps: int = 60        # after each accepted layer config
    trial_finetune_steps: int = 30  # inside a trial, before the accept check
    eval_batches: int = 4
    min_energy_share: float = 0.01  # skip layers below this ρ (tiny fc heads)
    max_layers: Optional[int] = None  # cap processed layers (tests)


@dataclasses.dataclass
class LayerDecision:
    layer: str
    share: float
    prune_ratio: Optional[float]
    k: Optional[int]
    energy_before: float
    energy_after: float
    accuracy: float
    accepted: bool
    tried: List[Tuple[float, int]] = dataclasses.field(default_factory=list)

    @property
    def saving(self) -> float:
        if self.energy_before <= 0:
            return 0.0
        return 1.0 - self.energy_after / self.energy_before


@dataclasses.dataclass
class ScheduleResult:
    decisions: List[LayerDecision]
    acc0: float
    acc_final: float
    energy_before: float
    energy_after: float
    selection_reports: List[SelectionReport]

    @property
    def energy_saving(self) -> float:
        return 1.0 - self.energy_after / max(self.energy_before, 1e-12)


def _config_order(cfg: ScheduleConfig) -> List[Tuple[float, int]]:
    """All (prune, k) combos, most aggressive (highest expected saving) first."""
    combos = [(p, k) for p in cfg.prune_ratios for k in cfg.k_targets]
    # higher prune + smaller k first
    return sorted(combos, key=lambda pk: (-pk[0], pk[1]))


def energy_prioritized_compression(
    runner,
    params,
    state,
    opt_state,
    comp: Dict[str, qat.CompState],
    stats,
    cfg: ScheduleConfig,
    sel_cfg: Optional[SelectionConfig] = None,
    *,
    verbose: bool = False,
) -> Tuple[object, object, object, Dict[str, qat.CompState], ScheduleResult]:
    """Run the full layer-wise schedule. Returns updated (params, state,
    opt_state, comp, result).

    ``stats=None`` profiles through the runner's batched profiler (cached on
    the runner); every ΔE refresh below reuses those trace statistics — only
    the O(256) weight-value histograms are recomputed per trial."""
    sel_cfg = sel_cfg or SelectionConfig(delta_acc=cfg.delta_acc)

    acc0 = runner.accuracy(params, state, comp, n_batches=cfg.eval_batches)
    if stats is None:
        stats = runner.layer_stats(params, state, comp)
    models = runner.energy_models(params, comp, stats)
    e_total_before = sum(m.energy for m in models.values())
    shares = {n: m.energy / max(e_total_before, 1e-12) for n, m in models.items()}
    order = sorted(shares, key=lambda n: -shares[n])
    if cfg.max_layers is not None:
        order = order[: cfg.max_layers]

    decisions: List[LayerDecision] = []
    reports: List[SelectionReport] = []

    for layer in order:
        share = shares[layer]
        e_before = models[layer].energy
        if share < cfg.min_energy_share:
            decisions.append(LayerDecision(layer, share, None, None, e_before,
                                           e_before, acc0, False))
            continue
        if verbose:
            print(f"[schedule] layer={layer} share={share:.3f}")

        accepted = False
        tried: List[Tuple[float, int]] = []
        for prune, k_target in _config_order(cfg):
            tried.append((prune, k_target))
            t0 = time.time()
            # --- trial state (rollback on reject)
            t_params, t_state, t_opt = params, state, opt_state
            t_comp = {n: dict(c) for n, c in comp.items()}

            # 1. prune
            w = runner.model.get_weight(t_params, layer)
            t_comp[layer]["mask"] = qat.magnitude_prune_mask(w, prune)

            # 2. fine-tune with the mask (paper: pruning first, then finetune)
            if cfg.trial_finetune_steps:
                t_params, t_state, t_opt, _ = runner.train(
                    t_params, t_state, t_opt, t_comp, cfg.trial_finetune_steps)

            # 3. weight-set selection on the pruned layer
            t_models = runner.refresh_counts(t_params, t_comp, models)
            lsel = dataclasses.replace(sel_cfg, k_target=k_target)
            init_set = initial_candidate_set(
                t_models[layer].counts, t_models[layer].lut, lsel)

            def eval_with_codebook(values, n_batches, _layer=layer,
                                   _params=t_params, _state=t_state,
                                   _comp=t_comp):
                c2 = codebook_comp(_comp, _layer, values)
                return runner.accuracy(_params, _state, c2, n_batches=n_batches)

            final_set, rep = greedy_backward_elimination(
                t_models[layer], init_set, lsel, acc0,
                eval_with_codebook=eval_with_codebook)
            t_comp = codebook_comp(t_comp, layer, final_set)

            # 4. short fine-tune with the restriction active, then accept check
            if cfg.finetune_steps:
                t_params, t_state, t_opt, _ = runner.train(
                    t_params, t_state, t_opt, t_comp, cfg.finetune_steps)
            acc = runner.accuracy(t_params, t_state, t_comp,
                                  n_batches=cfg.eval_batches)
            if verbose:
                print(f"  try prune={prune} k={k_target}: acc={acc:.3f} "
                      f"(floor {acc0 - cfg.delta_acc:.3f}) "
                      f"[{time.time() - t0:.1f}s]")
            if acc >= acc0 - cfg.delta_acc:
                params, state, opt_state, comp = t_params, t_state, t_opt, t_comp
                models = runner.refresh_counts(params, comp, models)
                e_after = models[layer].energy
                decisions.append(LayerDecision(
                    layer, share, prune, k_target, e_before, e_after, acc,
                    True, tried))
                reports.append(rep)
                accepted = True
                break

        if not accepted:
            decisions.append(LayerDecision(layer, share, None, None, e_before,
                                           e_before, acc0, False, tried))

    models = runner.refresh_counts(params, comp, models)
    e_total_after = sum(m.energy for m in models.values())
    acc_final = runner.accuracy(params, state, comp, n_batches=cfg.eval_batches)
    result = ScheduleResult(
        decisions=decisions,
        acc0=acc0,
        acc_final=acc_final,
        energy_before=e_total_before,
        energy_after=e_total_after,
        selection_reports=reports,
    )
    return params, state, opt_state, comp, result
