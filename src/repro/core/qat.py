"""Quantization-aware training with weight-set restriction (paper 4.2).

All compressible layers train with int8 symmetric fake-quantization
(straight-through estimator), per the paper's setup ("weights and activations
quantized to 8-bit precision"). On top of plain QAT we support the two
compression mechanisms the paper composes:

  * **pruning**: a binary mask zeroes weights before quantization (zeroed
    MACs are zero-gated in the energy model);
  * **weight-set restriction**: the quantized integer weights are projected
    to the nearest member of a per-layer *codebook* ``C_l`` of allowed int8
    values (the restricted weight set the selection algorithm constructs).

The compression state of a layer is a plain pytree dict so it can be threaded
through jit/scan and checkpointed:

    comp = {
      "mask":       float array, same shape as w (all-ones = no pruning)
      "codebook":   (K_MAX,) int32 sorted allowed values (padded by repeats)
      "codebook_k": () int32, number of valid entries; 0 = unrestricted
    }

Weight layout convention: the *last* axis of a weight tensor is the output
channel; quantization scales are per-output-channel over all other axes.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import ad_checkpoint as _adc

K_MAX = 32          # maximum codebook size the pipeline ever uses (paper: 32)
QMAX = 127          # symmetric int8 range [-127, 127]


CompState = Dict[str, jax.Array]


def identity_comp(w_shape: Tuple[int, ...], dtype=jnp.float32) -> CompState:
    """No-op compression state (no pruning, no restriction)."""
    return {
        "mask": jnp.ones(w_shape, dtype),
        "codebook": jnp.zeros((K_MAX,), jnp.int32),
        "codebook_k": jnp.zeros((), jnp.int32),
        "msr_bits": jnp.zeros((), jnp.int32),
    }


def make_codebook(values) -> Tuple[jax.Array, jax.Array]:
    """Build a padded sorted codebook from a python/array list of int values."""
    vals = sorted(int(v) for v in values)
    k = len(vals)
    if k == 0:
        return jnp.zeros((K_MAX,), jnp.int32), jnp.zeros((), jnp.int32)
    if k > K_MAX:
        raise ValueError(f"codebook size {k} exceeds K_MAX={K_MAX}")
    padded = vals + [vals[-1]] * (K_MAX - k)
    return jnp.asarray(padded, jnp.int32), jnp.asarray(k, jnp.int32)


def make_codebooks(value_sets) -> Tuple[jax.Array, jax.Array]:
    """Batched `make_codebook`: (E, K_MAX) sorted padded codebooks + (E,)
    valid counts, built host-side and shipped as TWO device arrays.

    The lockstep elimination evaluates dozens of trial codebooks per round;
    per-set `make_codebook` calls would cost two dispatches each."""
    cbs = np.zeros((len(value_sets), K_MAX), np.int32)
    ks = np.zeros((len(value_sets),), np.int32)
    for e, values in enumerate(value_sets):
        vals = sorted(int(v) for v in values)
        k = len(vals)
        if k > K_MAX:
            raise ValueError(f"codebook size {k} exceeds K_MAX={K_MAX}")
        ks[e] = k
        if k:
            cbs[e, :k] = vals
            cbs[e, k:] = vals[-1]
    return jnp.asarray(cbs), jnp.asarray(ks)


def weight_scale(w: jax.Array) -> jax.Array:
    """Per-output-channel symmetric scale, broadcastable against ``w``."""
    reduce_axes = tuple(range(w.ndim - 1))
    amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    return jnp.maximum(amax, 1e-8) / QMAX


def project_to_codebook(q: jax.Array, codebook: jax.Array, k: jax.Array) -> jax.Array:
    """Map integer weights to the nearest of the first ``k`` codebook values.

    ``q`` int32 of any shape, ``codebook`` (K_MAX,) int32 sorted. ``k == 0``
    means unrestricted (identity). Ties break toward the smaller value.

    Implemented in the *value* domain: the nearest-member map is resolved
    once for all 256 possible int8 values (256 x K_MAX mini-table) and
    applied to the weights as a single gather. The naive form — a
    ``|w| x K_MAX`` distance matrix per projection — was the dominant
    compute of every train/eval step once the candidate sweep batched away
    the dispatch overhead (|w| ~ 6e4 per LeNet eval, x candidates x trial
    codebooks per sweep round).
    """
    valid = jnp.arange(K_MAX) < jnp.maximum(k, 1)
    vals = jnp.arange(-128, 128, dtype=jnp.int32)
    dist = jnp.abs(vals[:, None] - codebook[None, :])
    dist = jnp.where(valid, dist, jnp.int32(1 << 20))
    proj_lut = codebook[jnp.argmin(dist, axis=-1)]       # (256,)
    projected = proj_lut[q + 128]
    return jnp.where(k > 0, projected, q)


def msr_truncate_int(q: jax.Array, bits) -> jax.Array:
    """Most-significant-run truncation of integer weights.

    Keeps the top ``bits`` significant bits of ``|q|`` (from its MSB down)
    and zeroes the rest, preserving sign: the weight becomes a short run of
    significant bits followed by zeros, which shortens partial-product
    carry chains in the MAC (the energy model prices the resulting value
    distribution via `weight_value_counts`). ``bits == 0`` disables
    truncation (identity) — the `identity_comp` default. ``bits`` may be a
    traced scalar (the batched candidate sweep vmaps over it).
    """
    bits = jnp.asarray(bits, jnp.int32)
    mag = jnp.abs(q)
    msb_val = 32 - jax.lax.clz(mag)          # 1-based MSB index, 0 for 0
    shift = jnp.maximum(msb_val - bits, 0)
    trunc = jnp.sign(q) * ((mag >> shift) << shift)
    return jnp.where(bits > 0, trunc, q)


def quantize_weight_int(w: jax.Array, comp: Optional[CompState] = None) -> jax.Array:
    """Integer (int32-valued int8) view of a weight tensor after mask/quant/
    MSR-truncation/projection — what actually sits in the MAC weight
    registers. ``comp["msr_bits"]`` is optional (absent == 0 == off) so
    pre-MSR comp dicts keep working."""
    if comp is not None:
        w = w * comp["mask"].astype(w.dtype)
    scale = weight_scale(w)
    q = jnp.clip(jnp.round(w / scale), -QMAX, QMAX).astype(jnp.int32)
    if comp is not None:
        msr = comp.get("msr_bits")
        if msr is not None:
            q = msr_truncate_int(q, msr)
        q = project_to_codebook(q, comp["codebook"], comp["codebook_k"])
    return q


def fake_quant_weight(
    w: jax.Array, comp: Optional[CompState] = None
) -> jax.Array:
    """Fake-quantized (float) weights with STE; applies mask + optional MSR
    truncation + codebook.

    Masks may be stored in a narrow dtype (int8 on the LM path to bound the
    dry-run memory footprint); they are cast to the weight dtype here.
    """
    wm = w * comp["mask"].astype(w.dtype) if comp is not None else w
    scale = weight_scale(wm)
    q = jnp.clip(jnp.round(wm / scale), -QMAX, QMAX)
    if comp is not None:
        qi = q.astype(jnp.int32)
        msr = comp.get("msr_bits")
        if msr is not None:
            qi = msr_truncate_int(qi, msr)
        qi = project_to_codebook(qi, comp["codebook"], comp["codebook_k"])
        q = qi.astype(wm.dtype)
    wq = q * scale
    # named for remat policies: saving 'qat_weights' across the checkpoint
    # boundary skips re-running the quantize+project chain in the backward
    # pass (opt-in via StepConfig.remat_save_qat; §Perf cell A-H4)
    wq = _adc.checkpoint_name(wq, "qat_weights")
    # straight-through: forward value wq, gradient of identity wrt wm
    return wm + jax.lax.stop_gradient(wq - wm)


def fake_quant_act(a: jax.Array) -> jax.Array:
    """Dynamic per-tensor symmetric int8 fake-quantization of activations."""
    amax = jnp.max(jnp.abs(a))
    scale = jnp.maximum(amax, 1e-8) / QMAX
    q = jnp.clip(jnp.round(a / scale), -QMAX, QMAX) * scale
    return a + jax.lax.stop_gradient(q - a)


def quantize_act_int(a: jax.Array) -> jax.Array:
    """Integer int8 view of activations (for energy-trace profiling)."""
    amax = jnp.max(jnp.abs(a))
    scale = jnp.maximum(amax, 1e-8) / QMAX
    return jnp.clip(jnp.round(a / scale), -QMAX, QMAX).astype(jnp.int32)


def magnitude_prune_mask(w: jax.Array, ratio: float) -> jax.Array:
    """Unstructured magnitude pruning mask keeping the top (1-ratio) weights."""
    if ratio <= 0.0:
        return jnp.ones_like(w)
    flat = jnp.abs(w).reshape(-1)
    k = int(round(ratio * flat.shape[0]))
    k = min(max(k, 0), flat.shape[0] - 1)
    thresh = jnp.sort(flat)[k]
    return (jnp.abs(w) >= thresh).astype(w.dtype)


def apply_comp_dtype(comp: CompState, dtype) -> CompState:
    out = dict(comp)
    out["mask"] = comp["mask"].astype(dtype)
    return out


# ----------------------------------------------------------- stacked pytrees
#
# The schedule's batched candidate sweep (`repro.core.schedule`,
# ``search_mode="batched"``) stacks N per-candidate pytrees — comp dicts, but
# also params/opt_state once the trial fine-tunes diverge — along a new
# leading *candidate* axis and runs the jitted train/eval steps under
# ``jax.vmap`` (optionally ``shard_map`` over a 1-D device mesh). The tree
# structure is fixed, so the whole sweep compiles once per candidate count.


def stack_pytrees(trees: Sequence):
    """Stack identically-structured pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def broadcast_pytree(tree, n: int):
    """Replicate every leaf ``n`` times along a new leading candidate axis."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


def index_pytree(tree, i: int):
    """Slice candidate ``i`` out of a stacked pytree."""
    return jax.tree.map(lambda x: x[i], tree)


def pad_leading(tree, n_to: int):
    """Pad the leading axis up to ``n_to`` by repeating the last entry.

    Used to round a candidate batch up to a multiple of the sweep-mesh size;
    callers discard the padded slots (the repeats are correct-by-construction
    but redundant)."""

    def one(x):
        pad = n_to - x.shape[0]
        if pad <= 0:
            return x
        return jnp.concatenate([x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])])

    return jax.tree.map(one, tree)
