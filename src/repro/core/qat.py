"""Quantization-aware training with weight-set restriction (paper 4.2).

All compressible layers train with int8 symmetric fake-quantization
(straight-through estimator), per the paper's setup ("weights and activations
quantized to 8-bit precision"). On top of plain QAT we support the two
compression mechanisms the paper composes:

  * **pruning**: a binary mask zeroes weights before quantization (zeroed
    MACs are zero-gated in the energy model);
  * **weight-set restriction**: the quantized integer weights are projected
    to the nearest member of a per-layer *codebook* ``C_l`` of allowed int8
    values (the restricted weight set the selection algorithm constructs).

The compression state of a layer is a plain pytree dict so it can be threaded
through jit/scan and checkpointed:

    comp = {
      "mask":       float array, same shape as w (all-ones = no pruning)
      "codebook":   (K_MAX,) int32 sorted allowed values (padded by repeats)
      "codebook_k": () int32, number of valid entries; 0 = unrestricted
    }

Weight layout convention: the *last* axis of a weight tensor is the output
channel; quantization scales are per-output-channel over all other axes.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import ad_checkpoint as _adc

K_MAX = 32          # maximum codebook size the pipeline ever uses (paper: 32)
QMAX = 127          # symmetric int8 range [-127, 127]


CompState = Dict[str, jax.Array]


def identity_comp(w_shape: Tuple[int, ...], dtype=jnp.float32) -> CompState:
    """No-op compression state (no pruning, no restriction)."""
    return {
        "mask": jnp.ones(w_shape, dtype),
        "codebook": jnp.zeros((K_MAX,), jnp.int32),
        "codebook_k": jnp.zeros((), jnp.int32),
    }


def make_codebook(values) -> Tuple[jax.Array, jax.Array]:
    """Build a padded sorted codebook from a python/array list of int values."""
    vals = sorted(int(v) for v in values)
    k = len(vals)
    if k == 0:
        return jnp.zeros((K_MAX,), jnp.int32), jnp.zeros((), jnp.int32)
    if k > K_MAX:
        raise ValueError(f"codebook size {k} exceeds K_MAX={K_MAX}")
    padded = vals + [vals[-1]] * (K_MAX - k)
    return jnp.asarray(padded, jnp.int32), jnp.asarray(k, jnp.int32)


def weight_scale(w: jax.Array) -> jax.Array:
    """Per-output-channel symmetric scale, broadcastable against ``w``."""
    reduce_axes = tuple(range(w.ndim - 1))
    amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    return jnp.maximum(amax, 1e-8) / QMAX


def project_to_codebook(q: jax.Array, codebook: jax.Array, k: jax.Array) -> jax.Array:
    """Map integer weights to the nearest of the first ``k`` codebook values.

    ``q`` int32 of any shape, ``codebook`` (K_MAX,) int32 sorted. ``k == 0``
    means unrestricted (identity). Ties break toward the smaller value.
    """
    valid = jnp.arange(K_MAX) < jnp.maximum(k, 1)
    dist = jnp.abs(q[..., None] - codebook[(None,) * q.ndim])
    dist = jnp.where(valid, dist, jnp.int32(1 << 20))
    idx = jnp.argmin(dist, axis=-1)
    projected = codebook[idx]
    return jnp.where(k > 0, projected, q)


def quantize_weight_int(w: jax.Array, comp: Optional[CompState] = None) -> jax.Array:
    """Integer (int32-valued int8) view of a weight tensor after mask/quant/
    projection — what actually sits in the MAC weight registers."""
    if comp is not None:
        w = w * comp["mask"].astype(w.dtype)
    scale = weight_scale(w)
    q = jnp.clip(jnp.round(w / scale), -QMAX, QMAX).astype(jnp.int32)
    if comp is not None:
        q = project_to_codebook(q, comp["codebook"], comp["codebook_k"])
    return q


def fake_quant_weight(
    w: jax.Array, comp: Optional[CompState] = None
) -> jax.Array:
    """Fake-quantized (float) weights with STE; applies mask + codebook.

    Masks may be stored in a narrow dtype (int8 on the LM path to bound the
    dry-run memory footprint); they are cast to the weight dtype here.
    """
    wm = w * comp["mask"].astype(w.dtype) if comp is not None else w
    scale = weight_scale(wm)
    q = jnp.clip(jnp.round(wm / scale), -QMAX, QMAX)
    if comp is not None:
        qi = project_to_codebook(q.astype(jnp.int32), comp["codebook"], comp["codebook_k"])
        q = qi.astype(wm.dtype)
    wq = q * scale
    # named for remat policies: saving 'qat_weights' across the checkpoint
    # boundary skips re-running the quantize+project chain in the backward
    # pass (opt-in via StepConfig.remat_save_qat; §Perf cell A-H4)
    wq = _adc.checkpoint_name(wq, "qat_weights")
    # straight-through: forward value wq, gradient of identity wrt wm
    return wm + jax.lax.stop_gradient(wq - wm)


def fake_quant_act(a: jax.Array) -> jax.Array:
    """Dynamic per-tensor symmetric int8 fake-quantization of activations."""
    amax = jnp.max(jnp.abs(a))
    scale = jnp.maximum(amax, 1e-8) / QMAX
    q = jnp.clip(jnp.round(a / scale), -QMAX, QMAX) * scale
    return a + jax.lax.stop_gradient(q - a)


def quantize_act_int(a: jax.Array) -> jax.Array:
    """Integer int8 view of activations (for energy-trace profiling)."""
    amax = jnp.max(jnp.abs(a))
    scale = jnp.maximum(amax, 1e-8) / QMAX
    return jnp.clip(jnp.round(a / scale), -QMAX, QMAX).astype(jnp.int32)


def magnitude_prune_mask(w: jax.Array, ratio: float) -> jax.Array:
    """Unstructured magnitude pruning mask keeping the top (1-ratio) weights."""
    if ratio <= 0.0:
        return jnp.ones_like(w)
    flat = jnp.abs(w).reshape(-1)
    k = int(round(ratio * flat.shape[0]))
    k = min(max(k, 0), flat.shape[0] - 1)
    thresh = jnp.sort(flat)[k]
    return (jnp.abs(w) >= thresh).astype(w.dtype)


def apply_comp_dtype(comp: CompState, dtype) -> CompState:
    out = dict(comp)
    out["mask"] = comp["mask"].astype(dtype)
    return out
