"""Layer-specific activation & partial-sum transition statistics (paper 3.1.2).

For every convolution/linear layer we collect, from traced int8 activations
and the layer's int8 weights:

  * the activation transition histogram  ``act_hist[256, 256]``
    (indexed by ``a_prev + 128`` / ``a_cur + 128``),
  * the grouped partial-sum transition histogram ``group_hist[50, 50]``
    (MSB x Hamming-weight groups of `repro.core.grouping`),
  * the per-weight-value trace energy accumulators
    ``energy_sum[256]`` / ``count[256]``.

The trace follows the weight-stationary 64x64 systolic mapping: the weight
matrix W (M x K) is tiled into (64-K x 64-M) stationary tiles, an activation
block X (64-K x T) streams through, and MAC (r, c) holds
``S[r, c, t] = sum_{r' <= r} W_tile[r', c] * A[r', t]`` in its accumulator.
Transitions are taken along t (the streaming axis). Skewed streaming only
time-shifts each MAC's sequence, so the transition *multiset* is identical to
the unskewed prefix-sum trace we compute.

This file is the pure-jnp oracle; `repro.kernels.transition_energy` provides
the Pallas TPU kernel for the same computation.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.grouping import N_GROUPS
from repro.core.mac_model import DEFAULT_COEFFS, MacEnergyCoeffs

TILE = 64  # systolic array dimension (64x64 weight-stationary, paper 3.2)
N_WVALS = 256  # int8 weight values, indexed by w + 128


@dataclass
class LayerStats:
    """Accumulated transition statistics for one layer."""

    act_hist: jax.Array        # (256, 256) float32 counts
    group_hist: jax.Array      # (50, 50) float32 counts
    energy_sum: jax.Array      # (256,) float32, summed transition energy per weight value
    count: jax.Array           # (256,) float32, number of transitions per weight value
    n_transitions: int         # total transitions traced

    def act_probs(self) -> jax.Array:
        total = jnp.maximum(jnp.sum(self.act_hist), 1.0)
        return self.act_hist / total

    def group_probs(self) -> jax.Array:
        total = jnp.maximum(jnp.sum(self.group_hist), 1.0)
        return self.group_hist / total

    def trace_lut(self) -> jax.Array:
        """Per-weight-value average transition energy; zero-count -> mean fill."""
        counts = jnp.maximum(self.count, 1.0)
        lut = self.energy_sum / counts
        seen = self.count > 0
        mean_seen = jnp.sum(jnp.where(seen, lut, 0.0)) / jnp.maximum(jnp.sum(seen), 1)
        return jnp.where(seen, lut, mean_seen)


# registered as a pytree so stats dicts — and the CompressionPlan carrying
# them between pipeline stages — pass through jax.tree utilities and device
# placement as data (n_transitions is static aux)
jax.tree_util.register_pytree_node(
    LayerStats,
    lambda s: ((s.act_hist, s.group_hist, s.energy_sum, s.count),
               s.n_transitions),
    lambda aux, ch: LayerStats(ch[0], ch[1], ch[2], ch[3], aux),
)


def empty_stats() -> LayerStats:
    return LayerStats(
        act_hist=jnp.zeros((N_WVALS, N_WVALS), jnp.float32),
        group_hist=jnp.zeros((N_GROUPS, N_GROUPS), jnp.float32),
        energy_sum=jnp.zeros((N_WVALS,), jnp.float32),
        count=jnp.zeros((N_WVALS,), jnp.float32),
        n_transitions=0,
    )


def tile_psum_trace(w_tile: jax.Array, a_block: jax.Array) -> jax.Array:
    """Partial-sum trace S[r, c, t] of one weight-stationary tile.

    w_tile: (K_t, M_t) int  — stationary weights (rows = reduction dim)
    a_block: (K_t, T) int   — streamed activation columns
    returns (K_t, M_t, T) int32 partial sums (22-bit range by construction).
    """
    w_tile = jnp.asarray(w_tile, jnp.int32)
    a_block = jnp.asarray(a_block, jnp.int32)
    prods = w_tile[:, :, None] * a_block[:, None, :]  # (K, M, T)
    return jnp.cumsum(prods, axis=0)


def tile_transition_stats(
    w_tile: jax.Array,
    a_block: jax.Array,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Trace one tile; return (energy_sum[256], count[256], group_hist, act_hist).

    Shapes as in `tile_psum_trace`. Differentiable nowhere; int statistics.

    Single-tile view of the batched oracle: the trace math lives ONCE, in
    `repro.core.profiler.batched_stats_oracle` (the implementation behind the
    pipeline's `profile` stage), and this wrapper is a batch of one. The
    seed's standalone per-tile implementation survives only as the frozen
    baseline of `benchmarks/bench_kernels.py`, where it is *the thing being
    measured against*.
    """
    from repro.core.profiler import batched_stats_oracle

    w = jnp.asarray(w_tile, jnp.int32)[None]
    a = jnp.asarray(a_block, jnp.int32)[None]
    return batched_stats_oracle(w, a, jnp.ones((1,), jnp.float32), coeffs)


def pad_to_tiles(w_mat: jax.Array, x_cols: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Zero-pad W (M, K) and X (K, N) up to multiples of TILE."""
    m, k = w_mat.shape
    k2, n = x_cols.shape
    assert k == k2, f"contraction mismatch: {k} vs {k2}"
    mp = (-m) % TILE
    kp = (-k) % TILE
    np_ = (-n) % TILE
    w_pad = jnp.pad(w_mat, ((0, mp), (0, kp)))
    x_pad = jnp.pad(x_cols, ((0, kp), (0, np_)))
    return w_pad, x_pad


def collect_layer_stats(
    w_mat: jax.Array,
    x_cols: jax.Array,
    *,
    max_tiles: int = 48,
    key: jax.Array | None = None,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    use_kernel: bool = False,
    mesh=None,
) -> LayerStats:
    """Trace a layer's matmul on the 64x64 array and accumulate statistics.

    w_mat: (M, K) int8-valued weights (already quantized to ints).
    x_cols: (K, N) int8-valued streamed activations (im2col for convs).
    max_tiles: number of (m, k, n) tiles to sample (paper also samples).
    use_kernel: route the batched trace through the Pallas kernel.
    mesh: optional 1-D profiling mesh to shard the tile batch over devices.

    All sampled tiles are gathered into one stacked batch and traced by a
    single kernel/oracle invocation (`repro.core.profiler`); the seed's
    per-tile Python dispatch loop is gone.
    """
    from repro.core.profiler import profile_layer

    return profile_layer(w_mat, x_cols, max_tiles=max_tiles, key=key,
                         coeffs=coeffs, use_kernel=use_kernel, mesh=mesh)


def im2col(x: jax.Array, kernel_hw: Tuple[int, int], stride: int = 1,
           padding: str = "SAME") -> jax.Array:
    """im2col for NHWC input -> (kh*kw*Cin, N*Hout*Wout) columns.

    Row ordering is ``k = (kh_i * kw + kw_i) * C_in + c`` so that a kernel
    reshaped as ``w.transpose(3, 0, 1, 2).reshape(C_out, -1)`` satisfies
    ``W_mat @ X_col == conv(x, w)`` exactly (verified in tests). Works on
    integer-valued (quantized) activations — the ordering must match because
    the systolic trace pairs W_mat[m, k] with X_col[k, n].
    """
    x = jnp.asarray(x)
    kh, kw = kernel_hw
    n, h, w, c = x.shape
    if padding == "SAME":
        ho = -(-h // stride)
        wo = -(-w // stride)
        pad_h = max((ho - 1) * stride + kh - h, 0)
        pad_w = max((wo - 1) * stride + kw - w, 0)
        x = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    elif padding == "VALID":
        ho = (h - kh) // stride + 1
        wo = (w - kw) // stride + 1
    else:
        raise ValueError(padding)
    windows = []
    for i in range(kh):
        for j in range(kw):
            windows.append(
                x[:, i:i + (ho - 1) * stride + 1:stride,
                  j:j + (wo - 1) * stride + 1:stride, :]
            )  # (N, Hout, Wout, C)
    patches = jnp.stack(windows, axis=3)  # (N, Hout, Wout, kh*kw, C)
    cols = patches.reshape(n * ho * wo, kh * kw * c).T  # (K, N_cols)
    return cols


def conv_weight_matrix(w: jax.Array) -> jax.Array:
    """HWIO conv kernel -> (C_out, kh*kw*C_in) matrix matching `im2col` rows."""
    return jnp.transpose(w, (3, 0, 1, 2)).reshape(w.shape[3], -1)
