"""Energy-accuracy co-optimized weight-set selection (paper 4.2).

Two stages per layer:

1. **Safe initial candidate set** (4.2.1): rank all int8 weight values by a
   joint score favoring *low energy* and *high usage* in this layer, take the
   top ``k_init`` (default 32). Zero is force-included (pruned weights must
   stay representable).

2. **Greedy backward elimination** (4.2.2): repeatedly score every removable
   value ``w`` by ``S(w) = ΔE(w) / (ΔAcc(w) + ε)`` where ΔE remaps all
   occurrences of ``w`` to the nearest remaining value (O(256) via the
   histogram energy model) and ΔAcc is measured by a cheap calibration pass
   (jitted eval on a scoring batch). The best-scoring removal is accepted iff
   the full validation accuracy stays above ``acc0 - δ``; otherwise the value
   is marked *essential* and skipped thereafter. Terminates at ``k_target``
   or when nothing is removable.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import qat
from repro.core.layer_energy import PASS_ENERGY_SCALE, LayerEnergyModel


@dataclasses.dataclass
class SelectionConfig:
    k_init: int = 32
    k_target: int = 16
    delta_acc: float = 0.03          # δ: allowed global accuracy drop
    epsilon: float = 1e-3            # ε in S(w)
    usage_weight: float = 0.5        # λ: usage vs energy in the initial joint score
    score_batches: int = 1           # cheap calibration pass for ΔAcc scoring
    accept_batches: int = 4          # fuller eval for the accept check
    max_score_candidates: int = 32   # score at most this many removal candidates


@dataclasses.dataclass
class SelectionReport:
    layer: str
    initial: List[int]
    final: List[int]
    removed: List[int]
    essential: List[int]
    energy_before: float
    energy_after: float
    acc_checks: int = 0


def initial_candidate_set(
    counts: jnp.ndarray, lut: jnp.ndarray, cfg: SelectionConfig
) -> List[int]:
    """Joint low-energy / high-usage ranking (paper 4.2.1)."""
    counts = np.asarray(counts, np.float64)
    lut = np.asarray(lut, np.float64)
    e_min, e_max = lut.min(), lut.max()
    norm_e = (lut - e_min) / max(e_max - e_min, 1e-12)
    norm_u = counts / max(counts.max(), 1.0)
    score = cfg.usage_weight * norm_u - (1.0 - cfg.usage_weight) * norm_e
    order = np.argsort(-score)
    chosen = [int(i) - 128 for i in order[: cfg.k_init]]
    if 0 not in chosen:
        chosen[-1] = 0
    return sorted(chosen)


def nearest_other(values: Sequence[int], w: int) -> int:
    others = [v for v in values if v != w]
    return min(others, key=lambda v: (abs(v - w), v))




def _elimination_requests(
    model: LayerEnergyModel,
    candidate: List[int],
    cfg: SelectionConfig,
    acc0: float,
):
    """Generator core of greedy backward elimination (paper 4.2.2).

    Yields ``(value_sets, n_batches)`` accuracy requests — a *list* of trial
    codebooks to measure — and expects ``send()`` to answer with the matching
    list of accuracies. Returns ``(final_values, SelectionReport)`` through
    ``StopIteration.value``. Keeping the decision logic in one generator is
    what lets the serial driver, the batched-scoring driver and the lockstep
    multi-candidate driver all make *identical* decisions: they differ only
    in how many requests they fuse into one eval dispatch.
    """
    values = sorted(candidate)
    # host-side numpy mirrors of the O(256) energy model: the ΔE ranking
    # runs hundreds of times per layer and must not cost a device round-trip
    # per candidate value (`delta_energy_remove` is the jnp equivalent)
    counts = np.asarray(model.counts, np.float64).copy()
    lut = np.asarray(model.lut, np.float64)
    dims = model.dims
    scale = float(PASS_ENERGY_SCALE) * dims.n_tiles
    e_before = float(np.sum(counts * lut) * scale)
    essential: set[int] = set()
    removed: List[int] = []
    acc_checks = 0

    (acc_ref,) = yield ([values], cfg.score_batches)
    acc_checks += 1

    while len(values) > cfg.k_target:
        removable = [w for w in values if w not in essential and w != 0]
        if not removable:
            break

        # cheap ΔE for every candidate; rank, then score ΔAcc for the top few
        d_es = {}
        for w in removable:
            nb = nearest_other(values, w)
            d_es[w] = float(counts[w + 128] * (lut[w + 128] - lut[nb + 128])
                            * scale)
        by_de = sorted(removable, key=lambda w: -d_es[w])
        to_score = by_de[: cfg.max_score_candidates]

        trials = [[v for v in values if v != w] for w in to_score]
        accs = yield (trials, cfg.score_batches)
        acc_checks += len(trials)
        scores = {}
        for w, acc_w in zip(to_score, accs):
            d_acc = max(acc_ref - float(acc_w), 0.0)
            scores[w] = d_es[w] / (d_acc + cfg.epsilon)

        w_star = max(scores, key=scores.get)
        trial = [v for v in values if v != w_star]
        (acc_new,) = yield ([trial], cfg.accept_batches)
        acc_checks += 1
        if acc_new >= acc0 - cfg.delta_acc:
            nb = nearest_other(values, w_star)
            counts[nb + 128] += counts[w_star + 128]
            counts[w_star + 128] = 0.0
            values = trial
            removed.append(w_star)
            (acc_ref,) = yield ([values], cfg.score_batches)
            acc_checks += 1
        else:
            essential.add(w_star)

    e_after = float(np.sum(counts * lut) * scale)
    report = SelectionReport(
        layer=model.name,
        initial=sorted(candidate),
        final=sorted(values),
        removed=removed,
        essential=sorted(essential),
        energy_before=e_before,
        energy_after=e_after,
        acc_checks=acc_checks,
    )
    return sorted(values), report


def greedy_backward_elimination(
    model: LayerEnergyModel,
    candidate: List[int],
    cfg: SelectionConfig,
    acc0: float,
    *,
    eval_with_codebook,   # (codebook_values: List[int], n_batches: int) -> float
) -> Tuple[List[int], SelectionReport]:
    """Paper 4.2.2, serial driver. ``eval_with_codebook`` measures global val
    accuracy with this layer restricted to the given values (other layers
    unchanged). The batched sweep drives the same generator through
    `lockstep_backward_elimination` instead."""
    gen = _elimination_requests(model, candidate, cfg, acc0)
    answer = None
    try:
        while True:
            value_sets, n_batches = gen.send(answer) if answer is not None \
                else next(gen)
            answer = [eval_with_codebook(v, n_batches) for v in value_sets]
    except StopIteration as stop:
        return stop.value


def lockstep_backward_elimination(
    models: Sequence[LayerEnergyModel],
    candidates: Sequence[List[int]],
    cfgs: Sequence[SelectionConfig],
    acc0: float,
    *,
    eval_requests,  # ([(cand_idx, values)], n_batches) -> per-request accs
) -> List[Tuple[List[int], SelectionReport]]:
    """Advance N independent greedy eliminations in lockstep.

    This is the batched candidate sweep's selection stage: each elimination
    is the same `_elimination_requests` generator the serial path drives, so
    per-candidate decisions are identical — but every sync point fuses all
    outstanding requests with the same ``n_batches`` (a whole round's trial
    codebooks across *all* candidates, then all accept checks, then all
    acc_ref refreshes) into one ``eval_requests`` call, which the runner
    serves as a single vmapped dispatch (`CnnRunner.accuracy_gather`).
    """
    gens = [_elimination_requests(m, c, cfg, acc0)
            for m, c, cfg in zip(models, candidates, cfgs)]
    results: List[Optional[Tuple[List[int], SelectionReport]]] = [None] * len(gens)
    pending = {}
    for i, g in enumerate(gens):
        try:
            pending[i] = next(g)
        except StopIteration as stop:   # pragma: no cover - first yield always
            results[i] = stop.value
    while pending:
        by_nb: Dict[int, List[int]] = {}
        for i, (_, n_batches) in pending.items():
            by_nb.setdefault(n_batches, []).append(i)
        next_pending = {}
        for n_batches, idxs in sorted(by_nb.items()):
            reqs = [(i, vals) for i in idxs for vals in pending[i][0]]
            accs = eval_requests(reqs, n_batches)
            pos = 0
            for i in idxs:
                take = len(pending[i][0])
                mine = [float(a) for a in accs[pos:pos + take]]
                pos += take
                try:
                    next_pending[i] = gens[i].send(mine)
                except StopIteration as stop:
                    results[i] = stop.value
        pending = next_pending
    return results


def naive_lowest_energy_set(lut: jnp.ndarray, k: int) -> List[int]:
    """Baseline (paper 5.3.3): the k lowest-energy weight values, ignoring
    representational importance."""
    order = np.argsort(np.asarray(lut))
    vals = sorted(int(i) - 128 for i in order[:k])
    return vals


def codebook_comp(
    comp: Dict[str, qat.CompState], layer: str, values: Sequence[int]
) -> Dict[str, qat.CompState]:
    """Functional update: new comp dict with ``layer`` restricted to values."""
    cb, k = qat.make_codebook(values)
    new_layer = dict(comp[layer])
    new_layer["codebook"], new_layer["codebook_k"] = cb, k
    out = dict(comp)
    out[layer] = new_layer
    return out


def msr_comp(
    comp: Dict[str, qat.CompState], layer: str, bits: int
) -> Dict[str, qat.CompState]:
    """Functional update: set ``layer``'s MSR truncation depth (0 = off).

    The schedule's candidate axis (`ScheduleConfig.msr_bits`) writes the
    same key in place on its trial copies; this is the composable form for
    callers that treat comp dicts as immutable."""
    new_layer = dict(comp[layer])
    new_layer["msr_bits"] = jnp.asarray(int(bits), jnp.int32)
    out = dict(comp)
    out[layer] = new_layer
    return out
