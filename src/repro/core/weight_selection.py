"""Energy-accuracy co-optimized weight-set selection (paper 4.2).

Two stages per layer:

1. **Safe initial candidate set** (4.2.1): rank all int8 weight values by a
   joint score favoring *low energy* and *high usage* in this layer, take the
   top ``k_init`` (default 32). Zero is force-included (pruned weights must
   stay representable).

2. **Greedy backward elimination** (4.2.2): repeatedly score every removable
   value ``w`` by ``S(w) = ΔE(w) / (ΔAcc(w) + ε)`` where ΔE remaps all
   occurrences of ``w`` to the nearest remaining value (O(256) via the
   histogram energy model) and ΔAcc is measured by a cheap calibration pass
   (jitted eval on a scoring batch). The best-scoring removal is accepted iff
   the full validation accuracy stays above ``acc0 - δ``; otherwise the value
   is marked *essential* and skipped thereafter. Terminates at ``k_target``
   or when nothing is removable.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import qat
from repro.core.layer_energy import (
    LayerEnergyModel,
    delta_energy_remove,
    layer_energy_from_counts,
)


@dataclasses.dataclass
class SelectionConfig:
    k_init: int = 32
    k_target: int = 16
    delta_acc: float = 0.03          # δ: allowed global accuracy drop
    epsilon: float = 1e-3            # ε in S(w)
    usage_weight: float = 0.5        # λ: usage vs energy in the initial joint score
    score_batches: int = 1           # cheap calibration pass for ΔAcc scoring
    accept_batches: int = 4          # fuller eval for the accept check
    max_score_candidates: int = 32   # score at most this many removal candidates


@dataclasses.dataclass
class SelectionReport:
    layer: str
    initial: List[int]
    final: List[int]
    removed: List[int]
    essential: List[int]
    energy_before: float
    energy_after: float
    acc_checks: int = 0


def initial_candidate_set(
    counts: jnp.ndarray, lut: jnp.ndarray, cfg: SelectionConfig
) -> List[int]:
    """Joint low-energy / high-usage ranking (paper 4.2.1)."""
    counts = np.asarray(counts, np.float64)
    lut = np.asarray(lut, np.float64)
    e_min, e_max = lut.min(), lut.max()
    norm_e = (lut - e_min) / max(e_max - e_min, 1e-12)
    norm_u = counts / max(counts.max(), 1.0)
    score = cfg.usage_weight * norm_u - (1.0 - cfg.usage_weight) * norm_e
    order = np.argsort(-score)
    chosen = [int(i) - 128 for i in order[: cfg.k_init]]
    if 0 not in chosen:
        chosen[-1] = 0
    return sorted(chosen)


def nearest_other(values: Sequence[int], w: int) -> int:
    others = [v for v in values if v != w]
    return min(others, key=lambda v: (abs(v - w), v))


def _counts_after_remove(counts: jnp.ndarray, w: int, nearest: int) -> jnp.ndarray:
    wi, ni = w + 128, nearest + 128
    moved = counts[wi]
    return counts.at[ni].add(moved).at[wi].set(0.0)


def greedy_backward_elimination(
    model: LayerEnergyModel,
    candidate: List[int],
    cfg: SelectionConfig,
    acc0: float,
    *,
    eval_with_codebook,   # (codebook_values: List[int], n_batches: int) -> float
) -> Tuple[List[int], SelectionReport]:
    """Paper 4.2.2. ``eval_with_codebook`` measures global val accuracy with
    this layer restricted to the given values (other layers unchanged)."""
    values = sorted(candidate)
    counts = model.counts
    lut = model.lut
    dims = model.dims
    e_before = float(layer_energy_from_counts(counts, lut, dims))
    essential: set[int] = set()
    removed: List[int] = []
    acc_checks = 0

    acc_ref = eval_with_codebook(values, cfg.score_batches)
    acc_checks += 1

    while len(values) > cfg.k_target:
        removable = [w for w in values if w not in essential and w != 0]
        if not removable:
            break

        # cheap ΔE for every candidate; rank, then score ΔAcc for the top few
        d_es = {}
        for w in removable:
            nb = nearest_other(values, w)
            d_es[w] = float(delta_energy_remove(counts, lut, dims, w, nb))
        by_de = sorted(removable, key=lambda w: -d_es[w])
        to_score = by_de[: cfg.max_score_candidates]

        scores = {}
        for w in to_score:
            trial = [v for v in values if v != w]
            acc_w = eval_with_codebook(trial, cfg.score_batches)
            acc_checks += 1
            d_acc = max(acc_ref - acc_w, 0.0)
            scores[w] = d_es[w] / (d_acc + cfg.epsilon)

        w_star = max(scores, key=scores.get)
        trial = [v for v in values if v != w_star]
        acc_new = eval_with_codebook(trial, cfg.accept_batches)
        acc_checks += 1
        if acc_new >= acc0 - cfg.delta_acc:
            nb = nearest_other(values, w_star)
            counts = _counts_after_remove(counts, w_star, nb)
            values = trial
            removed.append(w_star)
            acc_ref = eval_with_codebook(values, cfg.score_batches)
            acc_checks += 1
        else:
            essential.add(w_star)

    e_after = float(layer_energy_from_counts(counts, lut, dims))
    report = SelectionReport(
        layer=model.name,
        initial=sorted(candidate),
        final=sorted(values),
        removed=removed,
        essential=sorted(essential),
        energy_before=e_before,
        energy_after=e_after,
        acc_checks=acc_checks,
    )
    return sorted(values), report


def naive_lowest_energy_set(lut: jnp.ndarray, k: int) -> List[int]:
    """Baseline (paper 5.3.3): the k lowest-energy weight values, ignoring
    representational importance."""
    order = np.argsort(np.asarray(lut))
    vals = sorted(int(i) - 128 for i in order[:k])
    return vals


def codebook_comp(
    comp: Dict[str, qat.CompState], layer: str, values: Sequence[int]
) -> Dict[str, qat.CompState]:
    """Functional update: new comp dict with ``layer`` restricted to values."""
    cb, k = qat.make_codebook(values)
    new_layer = dict(comp[layer])
    new_layer["codebook"], new_layer["codebook_k"] = cb, k
    out = dict(comp)
    out[layer] = new_layer
    return out
