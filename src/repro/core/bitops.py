"""Bit-level utilities for the MAC switching-activity model.

All helpers operate on int32 arrays holding *bit patterns*:

- 8-bit operands (weights / activations) are stored as their two's-complement
  bit pattern in the low 8 bits (``x & 0xFF``).
- 16-bit products use the low 16 bits.
- 22-bit partial sums (the accumulator width of the paper's 64x64
  weight-stationary array) use the low 22 bits.

``jax.lax.population_count`` / ``jax.lax.clz`` give exact, vectorized bit
counts, so everything here is jit/vmap/Pallas-compatible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Accumulator width of the systolic array in the paper (Section 3.1):
# 8b x 8b products accumulated over a 64-row column need 16 + log2(64) = 22 bits.
PSUM_BITS = 22
MASK22 = (1 << PSUM_BITS) - 1  # 0x3FFFFF
MASK16 = (1 << 16) - 1
MASK8 = (1 << 8) - 1


def to_bits8(x: jax.Array) -> jax.Array:
    """Two's-complement 8-bit pattern of an int array, as int32 in [0, 255]."""
    return jnp.asarray(x, jnp.int32) & MASK8


def to_bits16(x: jax.Array) -> jax.Array:
    """Two's-complement 16-bit pattern (products of 8b x 8b)."""
    return jnp.asarray(x, jnp.int32) & MASK16


def to_bits22(x: jax.Array) -> jax.Array:
    """Two's-complement 22-bit pattern (partial sums)."""
    return jnp.asarray(x, jnp.int32) & MASK22


def popcount(x: jax.Array) -> jax.Array:
    """Number of set bits (int32 in, int32 out)."""
    return jax.lax.population_count(jnp.asarray(x, jnp.int32))


def hamming_distance(x: jax.Array, y: jax.Array) -> jax.Array:
    """Hamming distance between two equally-masked bit patterns."""
    return popcount(jnp.bitwise_xor(jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32)))


def hamming_weight22(p: jax.Array) -> jax.Array:
    """Hamming weight of the 22-bit pattern of a partial sum."""
    return popcount(to_bits22(p))


def msb22(p: jax.Array) -> jax.Array:
    """Index of the most significant set bit of the 22-bit pattern.

    Returns -1 for zero (no bit set), else a value in [0, 21].
    """
    masked = to_bits22(p)
    # clz on int32: for masked != 0, msb = 31 - clz.
    msb = 31 - jax.lax.clz(masked)
    return jnp.where(masked == 0, jnp.int32(-1), msb.astype(jnp.int32))


def carry_chain_length(p_prev: jax.Array, p_cur: jax.Array) -> jax.Array:
    """Length of the accumulator region disturbed by a transition.

    Approximated as (1 + msb of the toggled-bit pattern): a ripple through the
    adder propagates up to the highest toggled bit. Zero-toggle transitions
    disturb nothing.
    """
    diff = to_bits22(jnp.bitwise_xor(jnp.asarray(p_prev, jnp.int32), jnp.asarray(p_cur, jnp.int32)))
    return (msb22(diff) + 1).astype(jnp.int32)
