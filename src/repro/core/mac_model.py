"""Bit-level MAC switching-energy model (gate-level-simulation stand-in).

The paper measures per-weight MAC power with ModelSim gate-level simulation of
a NanGate-15nm 8bx8b MAC inside a 64x64 weight-stationary systolic array.
That toolchain is unavailable here, so we replace the *measurement* with a
deterministic bit-level switching proxy while keeping the paper's *modeling
framework* (layer statistics -> MSB/HD grouping -> per-weight LUT) intact.

Energy of one MAC cycle transition, for a stationary weight ``w`` observing
activation transition ``a -> a'`` and partial-sum transition ``p -> p'``::

    E = c_prod  * HD(w*a, w*a')            # product register toggles (16b)
      + c_pp    * HD8(a, a') * HW8(w)      # partial-product array activity:
                                           #   each toggled activation bit
                                           #   flips one partial-product row
                                           #   per set weight bit
      + c_acc   * HD22(p, p')              # accumulator register toggles
      + c_carry * carry_chain(p, p')       # adder carry propagation up to the
                                           #   highest toggled bit (MSB effect)

For w == 0 the array is assumed zero-gated (pruning support): the multiplier
terms vanish and the accumulator is bypassed with a cheap latch, modeled as
``c_zero * HD22(p, p')``.

The coefficients below are calibration constants standing in for NanGate 15nm
cell energies; every quantity the paper reports (energy shares, % savings) is
a ratio, so the absolute scale cancels. The model reproduces the *structure*
the paper exploits:

- Fig 1: strong weight-value dependence (bit density + magnitude of w),
- Fig 2a: power approximately monotone in HD of the partial-sum transition,
- Fig 2b: transitions between similar-MSB partial sums are cheaper.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.bitops import (
    carry_chain_length,
    hamming_distance,
    popcount,
    to_bits8,
    to_bits16,
    to_bits22,
)


@dataclass(frozen=True)
class MacEnergyCoeffs:
    """Per-event switching energies, in arbitrary 'energy units' (eu)."""

    c_prod: float = 1.00   # per toggled product-register bit
    c_pp: float = 0.18     # per (activation-bit toggle x weight set bit)
    c_acc: float = 0.80    # per toggled accumulator bit
    c_carry: float = 0.55  # per carry-chain stage reached
    c_zero: float = 0.12   # bypass-latch toggle for zero (pruned) weights
    c_base: float = 0.02   # clock-tree / sequencing floor per cycle


DEFAULT_COEFFS = MacEnergyCoeffs()


def mac_transition_energy(
    w: jax.Array,
    a_prev: jax.Array,
    a_cur: jax.Array,
    p_prev: jax.Array,
    p_cur: jax.Array,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
) -> jax.Array:
    """Energy (eu) of one MAC transition. All inputs are integer arrays.

    ``w``, ``a_prev``, ``a_cur`` are int8-valued (any int dtype), ``p_prev``,
    ``p_cur`` are 22-bit partial sums (int32). Shapes broadcast together.
    """
    w = jnp.asarray(w, jnp.int32)
    a_prev = jnp.asarray(a_prev, jnp.int32)
    a_cur = jnp.asarray(a_cur, jnp.int32)
    p_prev = jnp.asarray(p_prev, jnp.int32)
    p_cur = jnp.asarray(p_cur, jnp.int32)

    prod_prev = to_bits16(w * a_prev)
    prod_cur = to_bits16(w * a_cur)
    t_prod = hamming_distance(prod_prev, prod_cur).astype(jnp.float32)

    t_pp = (
        hamming_distance(to_bits8(a_prev), to_bits8(a_cur))
        * popcount(to_bits8(w))
    ).astype(jnp.float32)

    t_acc = hamming_distance(to_bits22(p_prev), to_bits22(p_cur)).astype(jnp.float32)
    t_carry = carry_chain_length(p_prev, p_cur).astype(jnp.float32)

    active = (
        coeffs.c_prod * t_prod
        + coeffs.c_pp * t_pp
        + coeffs.c_acc * t_acc
        + coeffs.c_carry * t_carry
    )
    gated = coeffs.c_zero * t_acc
    return jnp.where(w == 0, gated, active) + jnp.float32(coeffs.c_base)


def weight_static_energy_profile(
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    n_samples: int = 4096,
    seed: int = 0,
) -> jax.Array:
    """Reference per-weight average MAC energy under *uniform random* traffic.

    This reproduces the paper's Figure 1 setting (random transitions, fixed
    weight) and is used in tests/benchmarks to show the weight-value spread.
    Returns an array of shape (256,) indexed by ``w + 128``.
    """
    key = jax.random.PRNGKey(seed)
    k_a, k_p = jax.random.split(key)
    a_seq = jax.random.randint(k_a, (n_samples + 1,), -128, 128, dtype=jnp.int32)
    p_seq = jax.random.randint(k_p, (n_samples + 1,), 0, 1 << 22, dtype=jnp.int32)
    w_values = jnp.arange(-128, 128, dtype=jnp.int32)

    def per_weight(w):
        e = mac_transition_energy(
            w, a_seq[:-1], a_seq[1:], p_seq[:-1], p_seq[1:], coeffs
        )
        return jnp.mean(e)

    return jax.vmap(per_weight)(w_values)
