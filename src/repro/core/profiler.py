"""Batched whole-layer systolic profiling (paper 3.1.2, fused).

The seed implementation of `collect_layer_stats` dispatched the per-tile
trace one (64, 64) tile at a time from a Python loop — profiling a model was
serialized on kernel-launch overhead exactly where the paper's flow is
serialized on gate-level simulation. This module replaces the loop:

  1. ``gather_layer_tiles`` — all sampled (mi, ki, ni) tiles of a layer are
     gathered into stacked (n_tiles, 64, 64) weight / (n_tiles, 64, T)
     activation batches with ONE take per operand (a reshape/transpose view
     of the padded matrices plus a leading-axis gather).
  2. ``batched_layer_stats`` — the whole batch runs as one device program:
     either the batched Pallas kernel (grid (n_tiles, T-1), tile index as
     the leading block dimension) or a vmapped `tile_transition_stats`
     oracle reduced over the batch (the CPU / interpret fallback).
  3. ``profile_layer`` — sampling + gather + trace + `LayerStats` assembly;
     with more than one device (or an explicit mesh) the tile batch is
     sharded over the 1-D profiling mesh of `repro.distributed.sharding`
     via `shard_map`, each device tracing its slice and psum-reducing the
     four fixed-size statistics outputs.

Padding semantics are inherited from `pad_to_tiles`: partial tiles are
zero-padded and the padded MACs *do* count (w = 0 still clocks, matching
`weight_value_counts`). Batch padding up to the device count, by contrast,
is masked out and contributes nothing.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core.grouping import N_GROUPS
from repro.core.mac_model import DEFAULT_COEFFS, MacEnergyCoeffs
from repro.core.stats import (
    N_WVALS,
    TILE,
    LayerStats,
    pad_to_tiles,
)
from repro.distributed.sharding import TILE_AXIS, tile_mesh

StatsTuple = Tuple[jax.Array, jax.Array, jax.Array, jax.Array]


def _default_interpret() -> bool:
    # the Pallas kernel only compiles on TPU; everywhere else run the
    # interpreter (tests/benchmarks) — callers can still force either way.
    return jax.default_backend() != "tpu"


def gather_layer_tiles(
    w_pad: jax.Array,
    x_pad: jax.Array,
    tile_idx: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Stack sampled tiles: (n, 64, 64) stationary (K x M) + (n, 64, T) blocks.

    ``tile_idx`` holds flat (mi, ki, ni) indices in mi-major order, i.e.
    ``idx = (mi * kt + ki) * nt + ni`` — the same enumeration the seed loop
    used. One gather per operand; no per-tile host round-trips.
    """
    mp, kp = w_pad.shape
    kp2, np_ = x_pad.shape
    assert kp == kp2, (kp, kp2)
    mt, kt, nt = mp // TILE, kp // TILE, np_ // TILE

    idx = jnp.asarray(tile_idx, jnp.int32)
    mi = idx // (kt * nt)
    rest = idx % (kt * nt)
    ki = rest // nt
    ni = rest % nt

    # (mt*kt, K_t, M_t): w_pad[mi*T:(mi+1)T, ki*T:(ki+1)T].T for every (mi, ki)
    w_all = w_pad.reshape(mt, TILE, kt, TILE).transpose(0, 2, 3, 1)
    w_all = w_all.reshape(mt * kt, TILE, TILE)
    # (kt*nt, K_t, T): x_pad[ki*T:(ki+1)T, ni*T:(ni+1)T] for every (ki, ni)
    a_all = x_pad.reshape(kt, TILE, nt, TILE).transpose(0, 2, 1, 3)
    a_all = a_all.reshape(kt * nt, TILE, TILE)

    w_tiles = jnp.take(w_all, mi * kt + ki, axis=0)
    a_blocks = jnp.take(a_all, ki * nt + ni, axis=0)
    return w_tiles, a_blocks


def _pair_hist(bins: jax.Array, host_hist: bool) -> jax.Array:
    """Unweighted histogram of (g_prev*50 + g_cur) codes, shape (2500,).

    XLA's CPU scatter runs ~80 ns/update single-threaded, which would leave
    the group histogram as the profiler's dominant cost; `np.bincount` via
    `pure_callback` counts the same bins ~5x faster and is exact (integer
    counts). Non-CPU backends keep the native scatter (fast there, and the
    Pallas kernel path is the production route anyway). ``host_hist=False``
    forces the scatter — required inside `shard_map`, where concurrent
    callbacks from per-device executors deadlock on CPU. The callback is
    also skipped on single-core hosts: with a 1-thread intra-op pool the
    executor thread that must service the callback is the one blocked on
    the surrounding computation, and the dispatch deadlocks."""
    if host_hist and jax.default_backend() == "cpu" \
            and (os.cpu_count() or 1) > 1:
        def cb(b):
            import numpy as np

            return np.bincount(
                np.asarray(b).ravel(), minlength=N_GROUPS * N_GROUPS
            ).astype(np.float32)

        return jax.pure_callback(
            cb, jax.ShapeDtypeStruct((N_GROUPS * N_GROUPS,), jnp.float32),
            bins)
    return jax.ops.segment_sum(
        jnp.ones((bins.size,), jnp.float32), bins.reshape(-1),
        num_segments=N_GROUPS * N_GROUPS)


@functools.partial(jax.jit, static_argnames=("coeffs", "host_hist"))
def batched_stats_oracle(
    w_tiles: jax.Array,
    a_blocks: jax.Array,
    mask: jax.Array,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    *,
    host_hist: bool = True,
) -> StatsTuple:
    """Pure-jnp trace of the whole tile batch, reduced to layer sums.

    Bin-for-bin identical to summing `tile_transition_stats` per tile (the
    histogram bins are exact integer counts; only fp32 summation order
    differs). Three things make this >5x the seed per-tile loop on CPU:

      * an `optimization_barrier` between the trace producers and the
        histogram scatters — without it XLA CPU fuses the bit-level energy
        computation *into* each scatter and re-evaluates it per update,
        which is what made the seed's per-tile path ~25x slower than the
        sum of its parts;
      * the weight bin of a MAC is constant along the streaming axis, so
        energy_sum / count pre-reduce over T and scatter n*K*M elements
        instead of n*K*M*(T-1) (62x fewer updates);
      * the group histogram (whose bins DO vary per transition) goes
        through `_pair_hist` instead of a scatter.

    ``mask`` zeroes the contribution of batch-padding tiles. Masked tiles'
    inputs are zeroed before tracing, which makes their trace analytic —
    every transition is (w=0, 0 -> 0), group (0, 0), energy c_base — so
    their share of the unweighted group histogram is subtracted in closed
    form rather than weighting all E elements. This holds for ANY caller
    mask, not just the internal all-zero padding.
    """
    from repro.core.grouping import group_id
    from repro.core.mac_model import mac_transition_energy

    mask_i = jnp.asarray(mask != 0, jnp.int32)
    w_tiles = jnp.asarray(w_tiles, jnp.int32) * mask_i[:, None, None]
    a_blocks = jnp.asarray(a_blocks, jnp.int32) * mask_i[:, None, None]
    n, k_t, m_t = w_tiles.shape
    t_len = a_blocks.shape[2]
    trans_per_mac = t_len - 1

    w = w_tiles[:, :, :, None]                                # (n, K, M, 1)
    prods = w * a_blocks[:, :, None, :]                       # (n, K, M, T)
    psums = jnp.cumsum(prods, axis=1)
    p_prev, p_cur = psums[..., :-1], psums[..., 1:]
    a_prev = a_blocks[:, :, None, :-1]
    a_cur = a_blocks[:, :, None, 1:]

    energy = mac_transition_energy(w, a_prev, a_cur, p_prev, p_cur, coeffs)
    e_red = jnp.sum(energy, axis=-1)                          # (n, K, M)
    groups = group_id(psums)                                  # (n, K, M, T)
    g_bins = groups[..., :-1] * N_GROUPS + groups[..., 1:]
    e_red, g_bins = jax.lax.optimization_barrier((e_red, g_bins))

    m_tile = mask[:, None, None]                              # (n, 1, 1)
    w_bins = (w_tiles + 128).reshape(-1)                      # (n*K*M,)
    energy_sum = jax.ops.segment_sum(
        (e_red * m_tile).reshape(-1), w_bins, num_segments=N_WVALS)
    count = jax.ops.segment_sum(
        jnp.broadcast_to(m_tile * trans_per_mac, e_red.shape).reshape(-1),
        w_bins, num_segments=N_WVALS)

    # unweighted pair histogram, minus the analytic all-zero-tile padding
    n_pad = jnp.float32(n) - jnp.sum(mask)
    group_hist = _pair_hist(g_bins, host_hist).reshape(N_GROUPS, N_GROUPS)
    group_hist = group_hist.at[0, 0].add(
        -n_pad * (k_t * m_t * trans_per_mac))

    ap = (a_blocks[:, :, :-1] + 128).reshape(-1)              # (n*K*(T-1),)
    ac = (a_blocks[:, :, 1:] + 128).reshape(-1)
    m_act = jnp.broadcast_to(
        mask[:, None, None], a_blocks[:, :, 1:].shape).reshape(-1)
    act_hist = jax.ops.segment_sum(
        m_act, ap * N_WVALS + ac, num_segments=N_WVALS * N_WVALS
    ).reshape(N_WVALS, N_WVALS)

    return energy_sum, count, group_hist, act_hist


def batched_layer_stats(
    w_tiles: jax.Array,
    a_blocks: jax.Array,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    *,
    mask: Optional[jax.Array] = None,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    host_hist: bool = True,
) -> StatsTuple:
    """One batched trace invocation: Pallas kernel or vectorized oracle."""
    if mask is None:
        mask = jnp.ones((w_tiles.shape[0],), jnp.float32)
    if use_kernel:
        from repro.kernels.transition_energy import ops as te_ops

        interpret = _default_interpret() if interpret is None else interpret
        return te_ops.batched_transition_stats(
            w_tiles, a_blocks, coeffs, mask=mask, interpret=interpret)
    return batched_stats_oracle(w_tiles, a_blocks, mask, coeffs,
                                host_hist=host_hist)


def sharded_layer_stats(
    w_tiles: jax.Array,
    a_blocks: jax.Array,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    *,
    mask: Optional[jax.Array] = None,
    mesh: Optional[Mesh] = None,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> StatsTuple:
    """Shard the tile batch over a 1-D device mesh and psum the statistics.

    The batch is zero-padded (masked) up to a multiple of the mesh size, each
    device traces its local slice with `batched_layer_stats`, and the four
    outputs — (256,), (256,), (50, 50), (256, 256), a few hundred KiB total —
    are psum-reduced, so multi-chip profiling costs one small all-reduce.
    """
    mesh = tile_mesh() if mesh is None else mesh
    n_dev = mesh.shape[TILE_AXIS]
    if use_kernel and (interpret or (interpret is None and
                                     _default_interpret())):
        # Pallas interpret mode inside shard_map deadlocks on host devices;
        # interpret is a CPU-only correctness tool anyway, so the sharded
        # path falls back to the vectorized oracle (identical statistics).
        use_kernel = False
    n = w_tiles.shape[0]
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    pad = (-n) % n_dev
    if pad:
        w_tiles = jnp.pad(w_tiles, ((0, pad), (0, 0), (0, 0)))
        a_blocks = jnp.pad(a_blocks, ((0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, (0, pad))

    def local(w, a, m):
        out = batched_layer_stats(w, a, coeffs, mask=m,
                                  use_kernel=use_kernel, interpret=interpret,
                                  host_hist=False)
        return jax.tree.map(lambda x: jax.lax.psum(x, TILE_AXIS), out)

    spec = PartitionSpec(TILE_AXIS)
    return shard_map(local, mesh, in_specs=(spec, spec, spec),
                     out_specs=PartitionSpec())(w_tiles, a_blocks, mask)


def profile_layer(
    w_mat: jax.Array,
    x_cols: jax.Array,
    *,
    max_tiles: int = 48,
    key: jax.Array | None = None,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    mesh: Optional[Mesh] = None,
) -> LayerStats:
    """Trace a layer's matmul on the 64x64 array — batched, loop-free.

    Drop-in replacement for the seed `collect_layer_stats` body: identical
    sampling (same key -> same tiles) and identical accumulated statistics
    up to fp32 summation order. ``mesh`` (or >1 visible device) routes the
    batch through `sharded_layer_stats`.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    w_pad, x_pad = pad_to_tiles(jnp.asarray(w_mat, jnp.int32),
                                jnp.asarray(x_cols, jnp.int32))
    mt = w_pad.shape[0] // TILE
    kt = w_pad.shape[1] // TILE
    nt = x_pad.shape[1] // TILE
    total_tiles = mt * kt * nt

    n_sample = min(max_tiles, total_tiles)
    choice = jax.random.choice(key, total_tiles, (n_sample,), replace=False)
    w_tiles, a_blocks = gather_layer_tiles(w_pad, x_pad, choice)

    if mesh is not None or jax.device_count() > 1:
        es, cnt, gh, ah = sharded_layer_stats(
            w_tiles, a_blocks, coeffs, mesh=mesh, use_kernel=use_kernel,
            interpret=interpret)
    else:
        es, cnt, gh, ah = batched_layer_stats(
            w_tiles, a_blocks, coeffs, use_kernel=use_kernel,
            interpret=interpret)

    t_len = a_blocks.shape[2]
    return LayerStats(
        act_hist=ah, group_hist=gh, energy_sum=es, count=cnt,
        n_transitions=n_sample * TILE * TILE * (t_len - 1),
    )
