"""repro: energy-aware layer-wise weight selection framework (JAX).

Reproduction + production framework for "Layer-wise Weight Selection for
Power-Efficient Neural Network Acceleration" (Fang, Zhang, Huang; CS.AR 2025).
"""

__version__ = "0.4.0"
