"""Pallas TPU kernels for the compute hot-spots of the paper's pipeline.

  lut_matmul         4-bit codebook-index GEMM (deploys the restricted
                     weight sets of Section 4 on the MXU)
  transition_energy  systolic partial-sum transition statistics (replaces
                     the paper's gate-level MAC profiling loop)
  fake_quant         fused mask+quantize+codebook-project (QAT hot path)

Each kernel ships `<name>.py` (pl.pallas_call + BlockSpec), `ops.py` (jit'd
wrapper + custom VJP where applicable) and `ref.py` (pure-jnp oracle).
Kernels target TPU VMEM/MXU tiling and are validated with interpret=True on
CPU (per-kernel allclose tests sweep shapes and dtypes).
"""
