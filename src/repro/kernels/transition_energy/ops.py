"""jit'd wrapper matching the `repro.core.stats.tile_transition_stats` API."""

from __future__ import annotations

import functools

import jax

from repro.core.mac_model import DEFAULT_COEFFS, MacEnergyCoeffs
from repro.kernels.transition_energy.transition_energy import (
    transition_stats_pallas,
)


@functools.partial(jax.jit, static_argnames=("coeffs", "interpret"))
def tile_transition_stats(
    w_tile: jax.Array,
    a_block: jax.Array,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    *,
    interpret: bool = True,
):
    """Returns (energy_sum[256], count[256], group_hist[50,50],
    act_hist[256,256]) — drop-in for the pure-jnp oracle."""
    return transition_stats_pallas(w_tile, a_block, coeffs,
                                   interpret=interpret)
