"""jit'd wrapper matching the `repro.core.stats.tile_transition_stats` API."""

from __future__ import annotations

import functools

import jax

from repro.core.mac_model import DEFAULT_COEFFS, MacEnergyCoeffs
from repro.kernels.transition_energy.transition_energy import (
    transition_stats_batched_pallas,
    transition_stats_pallas,
)


@functools.partial(jax.jit, static_argnames=("coeffs", "interpret"))
def tile_transition_stats(
    w_tile: jax.Array,
    a_block: jax.Array,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    *,
    interpret: bool = True,
):
    """Returns (energy_sum[256], count[256], group_hist[50,50],
    act_hist[256,256]) — drop-in for the pure-jnp oracle."""
    return transition_stats_pallas(w_tile, a_block, coeffs,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("coeffs", "interpret"))
def batched_transition_stats(
    w_tiles: jax.Array,
    a_blocks: jax.Array,
    coeffs: MacEnergyCoeffs = DEFAULT_COEFFS,
    *,
    mask: jax.Array | None = None,
    interpret: bool = True,
):
    """Whole-tile-batch stats in ONE `pallas_call` (grid (n_tiles, T-1)).

    Same four outputs as `tile_transition_stats`, already summed over the
    batch. `mask` (n_tiles,) zeroes the contribution of padding tiles."""
    return transition_stats_batched_pallas(w_tiles, a_blocks, coeffs,
                                           mask=mask, interpret=interpret)
