from repro.kernels.transition_energy.ops import tile_transition_stats  # noqa: F401
