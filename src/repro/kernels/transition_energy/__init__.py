from repro.kernels.transition_energy.ops import (  # noqa: F401
    batched_transition_stats,
    tile_transition_stats,
)
