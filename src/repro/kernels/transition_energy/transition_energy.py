"""Pallas TPU kernel: systolic-array transition statistics (paper Sec. 3.1).

Profiling a layer means tracing, for every MAC of a 64x64 weight-stationary
tile, the partial-sum transition sequence and accumulating:

  * per-weight-value energy sums / counts        (256 bins)
  * the 50x50 MSB/Hamming group transition hist  (grouping of Sec. 3.1.1)
  * the 256x256 activation transition histogram

This replaces the paper's ModelSim gate-level inner loop and dominates
profiling time, so it gets a kernel. TPU mapping decisions:

  * grid = (T-1,): one program per streaming transition t -> t+1; the psum
    prefix over the K axis is recomputed per step (two 64x64 cumsums, cheap)
    instead of carrying systolic state — grid steps stay independent.
  * histogram scatter is re-expressed as ONE-HOT MATMULS on the MXU
    (onehot(prev)^T @ onehot(cur) / onehot(bins)^T @ energy): no gathers or
    scatters, which TPUs hate; the biggest one-hot tile is (4096, 256) f32 =
    4 MiB, inside VMEM.
  * all outputs revisit the same VMEM blocks across the grid (accumulation
    pattern with pl.when(t == 0) init).

Bit-level ops (population_count / clz) run on the VPU; validated in
interpret mode against the `repro.core.stats` oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.mac_model import MacEnergyCoeffs

TILE = 64
N_WVALS = 256
N_GROUPS = 50
N_MSB_GROUPS = 10
N_HD_SUBGROUPS = 5
MASK22 = (1 << 22) - 1
MASK16 = (1 << 16) - 1


def _popcount(x):
    return jax.lax.population_count(x)


def _msb22(x):
    # Pinned semantics (tests/test_cosim_differential.py, gated against the
    # bit-accurate cosim): the 22-bit mask applies BEFORE the zero test, so
    # any value that is zero modulo 2^22 (including 1 << 22) returns -1 and
    # lands in msb_val = 0; negatives see their two's-complement 22-bit
    # view (e.g. -1 -> MASK22 -> 21).
    masked = x & MASK22
    msb = 31 - jax.lax.clz(masked)
    return jnp.where(masked == 0, jnp.int32(-1), msb)


def _group_id(p):
    # mg = msb_val * 10 // 23 over msb_val 0..22 never exceeds 9, and
    # hg = hw * 5 // 23 over hw 0..22 never exceeds 4 — the minimums are
    # defensive clamps, exercised exhaustively by the boundary tables in
    # tests/test_cosim_differential.py.
    msb_val = _msb22(p) + 1
    mg = jnp.minimum((msb_val * N_MSB_GROUPS) // 23, N_MSB_GROUPS - 1)
    hw = _popcount(p & MASK22)
    hg = jnp.minimum((hw * N_HD_SUBGROUPS) // 23, N_HD_SUBGROUPS - 1)
    return mg * N_HD_SUBGROUPS + hg


def _energy(w, a_prev, a_cur, p_prev, p_cur, c: MacEnergyCoeffs):
    prod_t = _popcount(((w * a_prev) ^ (w * a_cur)) & MASK16).astype(jnp.float32)
    pp_t = (_popcount((a_prev ^ a_cur) & 0xFF)
            * _popcount(w & 0xFF)).astype(jnp.float32)
    dp = (p_prev ^ p_cur) & MASK22
    acc_t = _popcount(dp).astype(jnp.float32)
    carry = (_msb22(dp) + 1).astype(jnp.float32)
    active = c.c_prod * prod_t + c.c_pp * pp_t + c.c_acc * acc_t + c.c_carry * carry
    gated = c.c_zero * acc_t
    return jnp.where(w == 0, gated, active) + jnp.float32(c.c_base)


def _onehot_f32(idx, n):
    return (idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
            ).astype(jnp.float32)


def _accumulate(w, a_prev, a_cur, scale, esum_ref, cnt_ref, ghist_ref,
                ahist_ref, coeffs: MacEnergyCoeffs):
    """Accumulate one streaming transition of one tile into the output refs.

    w: (K, M) int32 stationary weights; a_prev/a_cur: (K,) int32 activation
    columns; scale: f32 weighting (1 for real tiles, 0 for batch padding).
    """
    # systolic column prefix sums at t and t+1
    p_prev = jnp.cumsum(w * a_prev[:, None], axis=0)     # (K, M)
    p_cur = jnp.cumsum(w * a_cur[:, None], axis=0)

    e = _energy(w, a_prev[:, None], a_cur[:, None], p_prev, p_cur, coeffs)

    n = TILE * TILE
    w_bins = (w + 128).reshape(n)
    onehot_w = _onehot_f32(w_bins, N_WVALS)              # (4096, 256)
    e_flat = e.reshape(n, 1)
    esum_ref[...] += scale * jnp.dot(onehot_w.T, e_flat,
                                     preferred_element_type=jnp.float32)[:, 0]
    cnt_ref[...] += scale * jnp.sum(onehot_w, axis=0)

    g_prev = _group_id(p_prev).reshape(n)
    g_cur = _group_id(p_cur).reshape(n)
    oh_gp = _onehot_f32(g_prev, N_GROUPS)
    oh_gc = _onehot_f32(g_cur, N_GROUPS)
    ghist_ref[...] += scale * jnp.dot(oh_gp.T, oh_gc,
                                      preferred_element_type=jnp.float32)

    oh_ap = _onehot_f32(a_prev + 128, N_WVALS)           # (64, 256)
    oh_ac = _onehot_f32(a_cur + 128, N_WVALS)
    ahist_ref[...] += scale * jnp.dot(oh_ap.T, oh_ac,
                                      preferred_element_type=jnp.float32)


def _kernel(w_ref, a_prev_ref, a_cur_ref, esum_ref, cnt_ref, ghist_ref,
            ahist_ref, *, coeffs: MacEnergyCoeffs):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        esum_ref[...] = jnp.zeros_like(esum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        ghist_ref[...] = jnp.zeros_like(ghist_ref)
        ahist_ref[...] = jnp.zeros_like(ahist_ref)

    w = w_ref[...].astype(jnp.int32)                     # (K, M)
    a_prev = a_prev_ref[...].astype(jnp.int32)[:, 0]     # column t
    a_cur = a_cur_ref[...].astype(jnp.int32)[:, 0]       # column t + 1
    _accumulate(w, a_prev, a_cur, jnp.float32(1.0), esum_ref, cnt_ref,
                ghist_ref, ahist_ref, coeffs)


def transition_stats_pallas(
    w_tile: jax.Array,       # (64, 64) int32 (K rows x M cols, stationary)
    a_block: jax.Array,      # (64, T) int32 streamed activations
    coeffs: MacEnergyCoeffs,
    *,
    interpret: bool = False,
):
    k, m = w_tile.shape
    assert (k, m) == (TILE, TILE), (k, m)
    t_len = a_block.shape[1]
    assert t_len >= 2

    kernel = functools.partial(_kernel, coeffs=coeffs)
    out_shapes = (
        jax.ShapeDtypeStruct((N_WVALS,), jnp.float32),
        jax.ShapeDtypeStruct((N_WVALS,), jnp.float32),
        jax.ShapeDtypeStruct((N_GROUPS, N_GROUPS), jnp.float32),
        jax.ShapeDtypeStruct((N_WVALS, N_WVALS), jnp.float32),
    )
    return pl.pallas_call(
        kernel,
        grid=(t_len - 1,),
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda t: (0, 0)),
            pl.BlockSpec((TILE, 1), lambda t: (0, t)),       # column t
            pl.BlockSpec((TILE, 1), lambda t: (0, t + 1)),   # column t + 1
        ],
        out_specs=(
            pl.BlockSpec((N_WVALS,), lambda t: (0,)),
            pl.BlockSpec((N_WVALS,), lambda t: (0,)),
            pl.BlockSpec((N_GROUPS, N_GROUPS), lambda t: (0, 0)),
            pl.BlockSpec((N_WVALS, N_WVALS), lambda t: (0, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(w_tile.astype(jnp.int32), a_block.astype(jnp.int32),
      a_block.astype(jnp.int32))


def _batched_kernel(mask_ref, w_ref, a_prev_ref, a_cur_ref, esum_ref, cnt_ref,
                    ghist_ref, ahist_ref, *, coeffs: MacEnergyCoeffs):
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when((b == 0) & (t == 0))
    def _init():
        esum_ref[...] = jnp.zeros_like(esum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        ghist_ref[...] = jnp.zeros_like(ghist_ref)
        ahist_ref[...] = jnp.zeros_like(ahist_ref)

    w = w_ref[0].astype(jnp.int32)                       # (K, M) of tile b
    a_prev = a_prev_ref[0].astype(jnp.int32)[:, 0]       # column t of tile b
    a_cur = a_cur_ref[0].astype(jnp.int32)[:, 0]         # column t + 1
    scale = mask_ref[0, 0]                               # 0 for pad tiles
    _accumulate(w, a_prev, a_cur, scale, esum_ref, cnt_ref, ghist_ref,
                ahist_ref, coeffs)


def transition_stats_batched_pallas(
    w_tiles: jax.Array,      # (n_tiles, 64, 64) int32 stationary tiles (K x M)
    a_blocks: jax.Array,     # (n_tiles, 64, T) int32 streamed activations
    coeffs: MacEnergyCoeffs,
    *,
    mask: jax.Array | None = None,   # (n_tiles,) f32; 0 disables a pad tile
    interpret: bool = False,
):
    """One fused device program over a whole stacked tile batch.

    Grid is (n_tiles, T-1): the tile index is the leading block dimension, so
    every sampled tile of a layer streams through one `pallas_call` instead of
    one kernel dispatch per tile. All four outputs live in the same VMEM
    blocks across the entire grid (accumulation pattern, initialised at
    (b, t) == (0, 0)); `mask` lets callers pad `n_tiles` up to a convenient
    multiple (e.g. the device count) with zero-weight tiles that contribute
    nothing.
    """
    n_tiles, k, m = w_tiles.shape
    assert (k, m) == (TILE, TILE), (k, m)
    assert a_blocks.shape[:2] == (n_tiles, TILE), a_blocks.shape
    t_len = a_blocks.shape[2]
    assert t_len >= 2
    if mask is None:
        mask = jnp.ones((n_tiles,), jnp.float32)
    mask2d = jnp.asarray(mask, jnp.float32).reshape(n_tiles, 1)

    kernel = functools.partial(_batched_kernel, coeffs=coeffs)
    out_shapes = (
        jax.ShapeDtypeStruct((N_WVALS,), jnp.float32),
        jax.ShapeDtypeStruct((N_WVALS,), jnp.float32),
        jax.ShapeDtypeStruct((N_GROUPS, N_GROUPS), jnp.float32),
        jax.ShapeDtypeStruct((N_WVALS, N_WVALS), jnp.float32),
    )
    return pl.pallas_call(
        kernel,
        grid=(n_tiles, t_len - 1),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, t: (b, 0)),
            pl.BlockSpec((1, TILE, TILE), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, TILE, 1), lambda b, t: (b, 0, t)),
            pl.BlockSpec((1, TILE, 1), lambda b, t: (b, 0, t + 1)),
        ],
        out_specs=(
            pl.BlockSpec((N_WVALS,), lambda b, t: (0,)),
            pl.BlockSpec((N_WVALS,), lambda b, t: (0,)),
            pl.BlockSpec((N_GROUPS, N_GROUPS), lambda b, t: (0, 0)),
            pl.BlockSpec((N_WVALS, N_WVALS), lambda b, t: (0, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(mask2d, w_tiles.astype(jnp.int32), a_blocks.astype(jnp.int32),
      a_blocks.astype(jnp.int32))
