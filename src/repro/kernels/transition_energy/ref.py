"""Oracle for the transition-statistics kernel = the core stats module."""

from repro.core.stats import tile_transition_stats as tile_transition_stats_ref  # noqa: F401
