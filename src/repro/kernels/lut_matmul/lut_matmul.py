"""Pallas TPU kernel: 4-bit codebook-index GEMM.

The compressed layer of Section 4 stores, per weight, only a 4-bit index into
the layer's restricted set C_l (|C_l| <= 16 int8 values) plus a per-output-
channel dequant scale. This kernel streams the packed indices HBM->VMEM,
dequantizes in-register via a 16-way select (no gather — MXU-adjacent VPU
work), and feeds the MXU with bf16/f32 tiles:

    Y[m, n] = sum_k X[m, k] * (codebook[idx[k, n]] * scale[n])

Packing layout (TPU-friendly: unpack is a concat along K, no interleave):
row pair (k, k + K/2) shares byte k of the packed array —
    packed[k, n] = (idx[k, n] & 0xF) | (idx[k + K/2, n] << 4),  k < K/2.
Block shapes keep the unpack aligned: block_k is even and the K grid walks
the *packed* rows, so each (block_k//2, block_n) byte tile expands to a
(block_k, block_n) index tile entirely inside VMEM.

Grid: (M/bm, N/bn, K/bk) with K-innermost accumulation into the output tile
(pl.when(k == 0) zero-init; the output block index ignores k, so the same
VMEM tile is revisited across the K loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_CODES = 16


def _kernel(x_ref, packed_ref, cb_ref, scale_ref, o_ref, *, block_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                      # (bm, bk)
    packed = packed_ref[...]            # (bk//2, bn) int8 bit patterns
    packed_u = packed.astype(jnp.int32) & 0xFF
    low = packed_u & 0xF                # rows [0, bk/2)
    high = (packed_u >> 4) & 0xF        # rows [bk/2, bk)
    idx = jnp.concatenate([low, high], axis=0)  # (bk, bn)

    # 16-way select instead of gather: w = sum_c (idx == c) * cb[c]
    w = jnp.zeros(idx.shape, jnp.float32)
    for c in range(N_CODES):
        w = w + jnp.where(idx == c, cb_ref[c].astype(jnp.float32), 0.0)
    w = w * scale_ref[...].astype(jnp.float32)[None, :]  # per-out-channel

    acc = jnp.dot(x.astype(jnp.float32), w,
                  preferred_element_type=jnp.float32)
    # accumulate in f32 across the K grid; the wrapper casts to out_dtype
    # once after the last K step (accumulating in a narrow out_dtype would
    # re-round the running sum at every K step)
    o_ref[...] += acc


def lut_matmul_pallas(
    x: jax.Array,            # (M, K) float
    packed: jax.Array,       # (K//2, N) int8 packed 4-bit indices
    codebook: jax.Array,     # (16,) int8/int32 codebook values
    scale: jax.Array,        # (N,) float per-channel dequant scale
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = packed.shape
    assert k == 2 * k2, (x.shape, packed.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    assert block_k % 2 == 0
    out_dtype = x.dtype if x.dtype != jnp.bfloat16 else jnp.float32

    grid = (m // block_m, n // block_n, k // block_k)
    kernel = functools.partial(_kernel, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // 2, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((N_CODES,), lambda i, j, kk: (0,)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, packed, codebook, scale)
    return out.astype(out_dtype)
