"""Pallas TPU kernel: 4-bit codebook-index GEMM with fused epilogues.

The compressed layer of Section 4 stores, per weight, only a 4-bit index into
the layer's restricted set C_l (|C_l| <= 16 int8 values) plus a per-output-
channel dequant scale. This kernel streams the packed indices HBM->VMEM,
dequantizes in-register via a 16-way select (no gather — MXU-adjacent VPU
work), and feeds the MXU with bf16/f32 tiles:

    Y[m, n] = act(sum_k X[m, k] * (codebook[idx[k, n]] * scale[n]) + bias[n])
              + residual[m, n]

The epilogue (bias add, activation, residual add) runs inside the kernel on
the last K grid step, while the output tile is still in VMEM — one kernel per
matmul instead of gather -> GEMM -> bias -> activation -> residual as
separate dispatches.

Packing layout (TPU-friendly: unpack is a concat along K, no interleave):
packing is block-local over K blocks of ``pack_block`` rows — within each
block, byte row j packs index rows j (low nibble) and j + pack_block/2
(high nibble):
    packed[j, n] = (idx[j, n] & 0xF) | (idx[j + pack_block/2, n] << 4).
The kernel ``block_k`` may be any multiple of ``pack_block`` (the autotuner
sweeps it); each (block_k//2, block_n) byte tile then expands sub-block by
sub-block entirely inside VMEM.

Grid: (M/bm, N/bn, K/bk) with K-innermost accumulation into the output tile
(pl.when(k == 0) zero-init; the output block index ignores k, so the same
VMEM tile is revisited across the K loop and the epilogue fires exactly once,
at k == K/bk - 1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_CODES = 16

# epilogue activations the kernel can fuse; keys are the public contract
# (serve_dense/serve_conv/apply_dense take the same names)
ACTIVATIONS = {
    "none": lambda v: v,
    "relu": jax.nn.relu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "silu": jax.nn.silu,
}


def _unpack_tile(packed, pack_block: int):
    """(bk//2, bn) packed bytes -> (bk, bn) int32 indices, per pack block."""
    k2, bn = packed.shape
    p = packed.astype(jnp.int32) & 0xFF
    p = p.reshape(2 * k2 // pack_block, pack_block // 2, bn)
    low = p & 0xF                        # sub-block rows [0, pack_block/2)
    high = (p >> 4) & 0xF                # sub-block rows [pack_block/2, ...)
    return jnp.concatenate([low, high], axis=1).reshape(2 * k2, bn)


def _dequant(packed, cb_ref, scale_ref, pack_block: int):
    idx = _unpack_tile(packed, pack_block)
    # 16-way select instead of gather: w = sum_c (idx == c) * cb[c]
    w = jnp.zeros(idx.shape, jnp.float32)
    for c in range(N_CODES):
        w = w + jnp.where(idx == c, cb_ref[c].astype(jnp.float32), 0.0)
    return w * scale_ref[...].astype(jnp.float32)[None, :]  # per-out-channel


def _kernel(x_ref, packed_ref, cb_ref, scale_ref, *rest,
            pack_block: int, grid_k: int, activation: str,
            has_bias: bool, has_residual: bool):
    o_ref = rest[-1]
    bias_ref = rest[0] if has_bias else None
    res_ref = rest[1 if has_bias else 0] if has_residual else None
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                      # (bm, bk)
    w = _dequant(packed_ref[...], cb_ref, scale_ref, pack_block)
    acc = jnp.dot(x.astype(jnp.float32), w,
                  preferred_element_type=jnp.float32)

    # accumulate in f32 across the K grid; the wrapper casts to out_dtype
    # once after the last K step (accumulating in a narrow out_dtype would
    # re-round the running sum at every K step)
    @pl.when(k_idx < grid_k - 1)
    def _accumulate():
        o_ref[...] += acc

    @pl.when(k_idx == grid_k - 1)
    def _finalize():
        y = o_ref[...] + acc
        if has_bias:
            y = y + bias_ref[...].astype(jnp.float32)[None, :]
        y = ACTIVATIONS[activation](y)
        if has_residual:
            y = y + res_ref[...].astype(jnp.float32)
        o_ref[...] = y


def _check_blocks(m, k, n, k2, block_m, block_n, block_k, pack_block):
    if k != 2 * k2:
        raise ValueError(
            f"packed shape {(k2, n)} does not pair with x shape {(m, k)}: "
            f"need K == 2 * packed rows, got K={k} vs {2 * k2}")
    if pack_block % 2 != 0 or pack_block < 2:
        raise ValueError(f"pack_block must be a positive even int, "
                         f"got {pack_block}")
    if block_k % pack_block != 0:
        raise ValueError(
            f"block_k={block_k} must be a multiple of pack_block="
            f"{pack_block} (nibble pairing is block-local to pack_block)")
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"shape (M={m}, K={k}, N={n}) not divisible by blocks "
            f"(block_m={block_m}, block_n={block_n}, block_k={block_k}); "
            "pad via repro.kernels.lut_matmul.ops.lut_matmul")


def lut_matmul_pallas(
    x: jax.Array,            # (M, K) float
    packed: jax.Array,       # (K//2, N) int8 packed 4-bit indices
    codebook: jax.Array,     # (16,) int8/int32 codebook values
    scale: jax.Array,        # (N,) float per-channel dequant scale
    *,
    bias: jax.Array | None = None,       # (N,) fused bias add
    residual: jax.Array | None = None,   # (M, N) fused residual add
    activation: str = "none",            # fused: none|relu|gelu|silu
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    pack_block: int | None = None,       # export-time pack block (default: block_k)
    interpret: bool = False,
) -> jax.Array:
    """Fused LUT GEMM: Y = act(X @ dequant(packed) + bias) + residual."""
    m, k = x.shape
    k2, n = packed.shape
    pack_block = block_k if pack_block is None else pack_block
    _check_blocks(m, k, n, k2, block_m, block_n, block_k, pack_block)
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}; "
                         f"expected one of {sorted(ACTIVATIONS)}")
    out_dtype = x.dtype if x.dtype != jnp.bfloat16 else jnp.float32

    grid = (m // block_m, n // block_n, k // block_k)
    has_bias = bias is not None
    has_residual = residual is not None
    kernel = functools.partial(
        _kernel, pack_block=pack_block, grid_k=grid[2], activation=activation,
        has_bias=has_bias, has_residual=has_residual)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k // 2, block_n), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((N_CODES,), lambda i, j, kk: (0,)),
        pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
    ]
    args = [x, packed, codebook, scale]
    if has_bias:
        if bias.shape != (n,):
            raise ValueError(f"bias shape {bias.shape} != ({n},)")
        in_specs.append(pl.BlockSpec((block_n,), lambda i, j, kk: (j,)))
        args.append(bias)
    if has_residual:
        if residual.shape != (m, n):
            raise ValueError(f"residual shape {residual.shape} != {(m, n)}")
        in_specs.append(
            pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)))
        args.append(residual)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*args)
    return out.astype(out_dtype)
