from repro.kernels.lut_matmul.ops import (  # noqa: F401
    encode_weights,
    lut_matmul,
    pack_indices,
)
