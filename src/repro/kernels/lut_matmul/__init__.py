from repro.kernels.lut_matmul.ops import (  # noqa: F401
    encode_weights,
    lut_matmul,
    lut_matmul_fused,
    pack_indices,
)
