"""Pure-jnp oracle for the 4-bit codebook-index GEMM (+ fused epilogue)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lut_matmul.lut_matmul import ACTIVATIONS

N_CODES = 16


def unpack_indices(packed: jax.Array, block_k: int) -> jax.Array:
    """Invert `ops.pack_indices`: (K//2, N) int8 -> (K, N) int32 indices.

    Packing is block-local over K blocks of ``block_k``: within each block,
    byte row j holds index rows j (low nibble) and j + block_k/2 (high).
    """
    k2, n = packed.shape
    k = 2 * k2
    if k % block_k != 0:
        raise ValueError(f"K={k} is not a multiple of block_k={block_k}")
    p = packed.astype(jnp.int32) & 0xFF
    p = p.reshape(k // block_k, block_k // 2, n)
    low = p & 0xF
    high = (p >> 4) & 0xF
    blocks = jnp.concatenate([low, high], axis=1)  # (nblk, block_k, n)
    return blocks.reshape(k, n)


def lut_matmul_ref(
    x: jax.Array,
    packed: jax.Array,
    codebook: jax.Array,
    scale: jax.Array,
    *,
    block_k: int = 128,
) -> jax.Array:
    """Y = X @ (codebook[idx] * scale) with fp32 accumulation."""
    idx = unpack_indices(packed, block_k)
    w = codebook.astype(jnp.float32)[idx] * scale.astype(jnp.float32)[None, :]
    out = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    out_dtype = x.dtype if x.dtype != jnp.bfloat16 else jnp.float32
    return out.astype(out_dtype)


def lut_matmul_fused_ref(
    x: jax.Array,
    packed: jax.Array,
    codebook: jax.Array,
    scale: jax.Array,
    *,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    activation: str = "none",
    block_k: int = 128,
) -> jax.Array:
    """Y = act(X @ dequant(packed) + bias) + residual, fp32 accumulation.

    Same epilogue order as the Pallas kernel: bias before activation,
    residual after.
    """
    idx = unpack_indices(packed, block_k)
    w = codebook.astype(jnp.float32)[idx] * scale.astype(jnp.float32)[None, :]
    y = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, :]
    y = ACTIVATIONS[activation](y)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    out_dtype = x.dtype if x.dtype != jnp.bfloat16 else jnp.float32
    return y.astype(out_dtype)
