"""jit'd wrappers + weight encode/pack utilities for the LUT GEMM."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lut_matmul.lut_matmul import N_CODES, lut_matmul_pallas
from repro.kernels.lut_matmul.ref import lut_matmul_ref


def encode_weights(w_int: jax.Array, codebook: jax.Array):
    """Map int8-valued weights to nearest-codebook indices.

    w_int: (K, N) int weights already restricted (or to be snapped) to the
    codebook; codebook: (16,) sorted int values. Returns (K, N) int32 indices.
    """
    dist = jnp.abs(w_int[..., None].astype(jnp.int32)
                   - codebook[None, None, :].astype(jnp.int32))
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def pack_indices(idx: jax.Array, block_k: int = 128) -> jax.Array:
    """(K, N) 4-bit indices -> (K//2, N) int8, block-local pairing.

    Within each K block of ``block_k`` rows, byte row j packs index rows j
    (low nibble) and j + block_k/2 (high nibble) so the kernel's unpack is a
    VMEM-internal concat (no cross-block shuffling).
    """
    k, n = idx.shape
    assert k % block_k == 0 and block_k % 2 == 0
    blocks = idx.reshape(k // block_k, block_k, n).astype(jnp.int32)
    low = blocks[:, : block_k // 2]
    high = blocks[:, block_k // 2:]
    packed = (low & 0xF) | ((high & 0xF) << 4)
    return packed.reshape(k // 2, n).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "use_ref"))
def lut_matmul(
    x: jax.Array,
    packed: jax.Array,
    codebook: jax.Array,
    scale: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    """Y = X @ dequant(packed) — pads M/N/K to block multiples as needed."""
    if use_ref:
        return lut_matmul_ref(x, packed, codebook, scale, block_k=block_k)
    m, k = x.shape
    _, n = packed.shape
    pm, pn, pk = (-m) % block_m, (-n) % block_n, (-k) % block_k
    assert pk == 0, "K must already be a multiple of block_k (packing is block-local)"
    xp = jnp.pad(x, ((0, pm), (0, 0))) if pm else x
    pp = jnp.pad(packed, ((0, 0), (0, pn))) if pn else packed
    sp = jnp.pad(scale, (0, pn)) if pn else scale
    out = lut_matmul_pallas(xp, pp, codebook, sp, block_m=block_m,
                            block_n=block_n, block_k=block_k,
                            interpret=interpret)
    return out[:m, :n]


def compress_layer_weights(w: jax.Array, codebook_values, *, block_k: int = 128):
    """End-to-end encode of a float (K, N) weight matrix for serving.

    Returns (packed, codebook_arr, scale): per-output-channel symmetric scale,
    int8 snap to the restricted set, 4-bit pack.
    """
    from repro.core import qat

    scale = qat.weight_scale(w)[0]                      # (N,)
    q = jnp.clip(jnp.round(w / scale[None, :]), -qat.QMAX, qat.QMAX)
    cb = jnp.asarray(sorted(int(v) for v in codebook_values), jnp.int32)
    assert cb.shape[0] <= N_CODES
    cb = jnp.pad(cb, (0, N_CODES - cb.shape[0]), constant_values=cb[-1])
    idx = encode_weights(q.astype(jnp.int32), cb)
    packed = pack_indices(idx, block_k)
    return packed, cb.astype(jnp.int8), scale
