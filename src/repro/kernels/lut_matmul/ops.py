"""jit'd wrappers + weight encode/pack utilities for the LUT GEMM."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.lut_matmul.lut_matmul import N_CODES, lut_matmul_pallas
from repro.kernels.lut_matmul.ref import lut_matmul_fused_ref


def default_interpret() -> bool:
    """Backend-aware Pallas mode: compiled on TPU, interpreted elsewhere.

    The LUT GEMM is a TPU kernel; on CPU/GPU hosts (tests, reduced serving
    configs) interpret mode runs the same program through the Pallas
    interpreter so the packed serving path stays executable everywhere.
    """
    return jax.default_backend() != "tpu"


def encode_weights(w_int: jax.Array, codebook: jax.Array):
    """Map int8-valued weights to nearest-codebook indices.

    w_int: (K, N) int weights already restricted (or to be snapped) to the
    codebook; codebook: (16,) sorted int values. Returns (K, N) int32 indices.
    Ties (including duplicate/padded codebook entries) resolve to the lowest
    index, so padded codebooks encode stably: every chosen index decodes to
    the same value the projection picked.
    """
    dist = jnp.abs(w_int[..., None].astype(jnp.int32)
                   - codebook[None, None, :].astype(jnp.int32))
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def pack_indices(idx: jax.Array, block_k: int = 128) -> jax.Array:
    """(K, N) 4-bit indices -> (K//2, N) int8, block-local pairing.

    Within each K block of ``block_k`` rows, byte row j packs index rows j
    (low nibble) and j + block_k/2 (high nibble) so the kernel's unpack is a
    VMEM-internal concat (no cross-block shuffling).
    """
    k, n = idx.shape
    if block_k % 2 != 0:
        raise ValueError(f"block_k must be even, got {block_k}")
    if k % block_k != 0:
        raise ValueError(
            f"K={k} is not a multiple of block_k={block_k}; pad the index "
            "rows first (packing is block-local, see repro.core.export)")
    blocks = idx.reshape(k // block_k, block_k, n).astype(jnp.int32)
    low = blocks[:, : block_k // 2]
    high = blocks[:, block_k // 2:]
    packed = (low & 0xF) | ((high & 0xF) << 4)
    return packed.reshape(k // 2, n).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("activation", "block_m",
                                             "block_n", "block_k",
                                             "pack_block", "interpret",
                                             "use_ref"))
def _fused_jit(x, packed, codebook, scale, bias, residual, *, activation,
               block_m, block_n, block_k, pack_block, interpret, use_ref):
    """One jitted dispatch: pad M/N, run the fused kernel, slice back."""
    if use_ref:
        return lut_matmul_fused_ref(
            x, packed, codebook, scale, bias=bias, residual=residual,
            activation=activation, block_k=pack_block)
    m, k = x.shape
    _, n = packed.shape
    pm, pn = (-m) % block_m, (-n) % block_n
    xp = jnp.pad(x, ((0, pm), (0, 0))) if pm else x
    pp = jnp.pad(packed, ((0, 0), (0, pn))) if pn else packed
    sp = jnp.pad(scale, (0, pn)) if pn else scale
    bp = None if bias is None else (
        jnp.pad(bias, (0, pn)) if pn else bias)
    rp = None if residual is None else (
        jnp.pad(residual, ((0, pm), (0, pn))) if pm or pn else residual)
    out = lut_matmul_pallas(xp, pp, codebook, sp, bias=bp, residual=rp,
                            activation=activation, block_m=block_m,
                            block_n=block_n, block_k=block_k,
                            pack_block=pack_block, interpret=interpret)
    return out[:m, :n]


def lut_matmul_fused(
    x: jax.Array,            # (M, K)
    packed: jax.Array,       # (K//2, N) int8 packed 4-bit indices
    codebook: jax.Array,     # (16,) int8/int32 codebook values
    scale: jax.Array,        # (N,) per-channel dequant scale
    *,
    bias: Optional[jax.Array] = None,       # (N,)
    residual: Optional[jax.Array] = None,   # (M, N)
    activation: str = "none",               # none|relu|gelu|silu
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    pack_block: int = 128,
    interpret: Optional[bool] = None,
    use_ref: bool = False,
) -> jax.Array:
    """Fused serve matmul: Y = act(X @ dequant(packed) + bias) + residual.

    Pads M/N to block multiples as needed (K must already be a ``pack_block``
    multiple — packing is block-local). Block shapes left as ``None`` resolve
    through the roofline autotuner (`repro.kernels.lut_matmul.autotune`),
    cached per (M, K, N, pack_block, backend) fingerprint. ``interpret=None``
    resolves per backend (`default_interpret`): compiled Pallas on TPU,
    interpreter elsewhere.
    """
    m, k = x.shape
    _, n = packed.shape
    if k % pack_block:
        raise ValueError(
            f"K={k} must already be a multiple of pack_block={pack_block} "
            "(packing is block-local; pad K at export)")
    if interpret is None:
        interpret = default_interpret()
    if use_ref:
        # the ref oracle ignores block shapes — don't touch the autotuner
        block_m = block_n = block_k = pack_block
    if block_m is None or block_n is None or block_k is None:
        from repro.kernels.lut_matmul.autotune import get_default_autotuner

        tm, tn, tk = get_default_autotuner().best(m, k, n,
                                                  pack_block=pack_block)
        block_m = tm if block_m is None else block_m
        block_n = tn if block_n is None else block_n
        block_k = tk if block_k is None else block_k
    if k % block_k:
        raise ValueError(
            f"K={k} must be a multiple of block_k={block_k} "
            "(packing is block-local)")
    return _fused_jit(x, packed, codebook, scale, bias, residual,
                      activation=activation, block_m=block_m, block_n=block_n,
                      block_k=block_k, pack_block=pack_block,
                      interpret=interpret, use_ref=use_ref)


def lut_matmul(
    x: jax.Array,
    packed: jax.Array,
    codebook: jax.Array,
    scale: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
    use_ref: bool = False,
) -> jax.Array:
    """Epilogue-free LUT GEMM (compatibility entry point).

    Equivalent to `lut_matmul_fused` with no bias/activation/residual and
    ``pack_block == block_k`` (the historical contract: kernel block == pack
    block).
    """
    return lut_matmul_fused(x, packed, codebook, scale, block_m=block_m,
                            block_n=block_n, block_k=block_k,
                            pack_block=block_k, interpret=interpret,
                            use_ref=use_ref)


def compress_layer_weights(w: jax.Array, codebook_values, *,
                           mask: Optional[jax.Array] = None,
                           scale: Optional[jax.Array] = None,
                           msr_bits: int = 0,
                           block_k: int = 128,
                           pad_k: bool = False):
    """End-to-end encode of a float (K, N) weight matrix for serving.

    Returns (packed, codebook_arr, scale): per-output-channel symmetric scale,
    int8 snap to the restricted set, 4-bit pack. Mirrors the QAT fake-quant
    semantics: mask -> per-channel scale of the *masked* weight -> round/clip
    -> nearest-codebook projection. ``scale`` overrides the per-column scale
    (used by `repro.core.export` when the training scale reduces over a
    different layout than the matrix columns); ``pad_k=True`` pads K up to a
    ``block_k`` multiple (padded rows encode the 0-nearest entry and pair
    with zero-padded activation rows at serve time).

    A pruning ``mask`` (zeros = pruned) is honored exactly: 0 is
    force-included in the serving codebook when the mask prunes anything, and
    pruned positions encode to the index of 0 — pruned MACs stay zero-gated
    on the array even when the training codebook C_l itself lacks 0.
    """
    from repro.core import qat

    vals = sorted({int(v) for v in codebook_values})
    if not vals:
        raise ValueError("empty codebook")
    prunes = mask is not None and bool(jnp.any(mask == 0))
    serve_vals = sorted(set(vals) | {0}) if prunes else vals
    if len(serve_vals) > N_CODES:
        raise ValueError(
            f"codebook needs {len(serve_vals)} entries (> {N_CODES}); "
            "pruned layers must leave room for the forced 0 entry")

    wm = w * mask.astype(w.dtype) if mask is not None else w
    if scale is None:
        scale = qat.weight_scale(wm)[0]                 # (N,)
    q = jnp.clip(jnp.round(wm / scale[None, :]), -qat.QMAX, qat.QMAX)
    # MSR-truncate then project onto the *training* set (identical order to
    # fake_quant_weight), then force pruned positions to the serving 0 entry
    qi = q.astype(jnp.int32)
    if msr_bits:
        qi = qat.msr_truncate_int(qi, msr_bits)
    cb_train, k_train = qat.make_codebook(vals)
    qp = qat.project_to_codebook(qi, cb_train, k_train)
    if mask is not None:
        qp = jnp.where(mask == 0, 0, qp)

    cb = jnp.asarray(serve_vals, jnp.int32)
    cb = jnp.pad(cb, (0, N_CODES - cb.shape[0]), constant_values=cb[-1])
    idx = encode_weights(qp, cb)
    if pad_k:
        pad = (-idx.shape[0]) % block_k
        if pad:
            zero_idx = int(jnp.argmin(jnp.abs(cb)))
            idx = jnp.pad(idx, ((0, pad), (0, 0)), constant_values=zero_idx)
    packed = pack_indices(idx, block_k)
    return packed, cb.astype(jnp.int8), scale
