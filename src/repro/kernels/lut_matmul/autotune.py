"""Roofline-driven block-shape autotuner for the fused LUT GEMM.

Hand-picked ``(block_m, block_n, block_k) = (128, 128, 128)`` is a fine
default for square compute-bound shapes, but serving runs the kernel on
skinny decode shapes (M = batch of 8) and fat FFN shapes (N = 4d) where the
best tiling differs. This module scores every legal block shape for a given
``(M, K, N)`` against the machine-balance model that `benchmarks/roofline.py`
uses for whole-model analysis (MXU peak vs HBM bandwidth, plus the VPU cost
of the 16-way select dequant and a per-grid-step dispatch overhead), and
caches the winner keyed by a content fingerprint of the problem shape — the
same blake2b-hash discipline as the serve compile cache
(`repro.serving.fleet.comp_fingerprint`).

The cache persists to JSON (``save``/``load``; ``REPRO_LUT_AUTOTUNE_CACHE``
names a default path for the process-wide tuner), so serving warmup and CI
re-runs resolve block shapes with zero retune events. An optional
``measure`` callback refines the model's top-k candidates with wall-clock
timing on the live backend.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import threading
from pathlib import Path
from typing import Callable, Dict, Iterator, Optional, Tuple

from repro.kernels.lut_matmul.lut_matmul import N_CODES

ENV_CACHE_PATH = "REPRO_LUT_AUTOTUNE_CACHE"

BlockShape = Tuple[int, int, int]   # (block_m, block_n, block_k)


@dataclasses.dataclass(frozen=True)
class MachineBalance:
    """Per-chip machine balance (TPU v5e numbers; single source of truth —
    `benchmarks/roofline.py` imports its constants from here)."""

    peak_flops: float = 197e12     # bf16 MXU peak / chip
    hbm_bw: float = 819e9          # HBM bytes/s / chip
    link_bw: float = 50e9          # bytes/s / ICI link (whole-model roofline)
    vpu_flops: float = 24.6e12     # elementwise throughput (~peak/8): dequant
    grid_overhead_s: float = 2e-7  # fixed cost per grid step (issue/sync)
    vmem_bytes: int = 8 * 2**20    # usable VMEM budget per core for one tile


# module-level constants re-exported for benchmarks/roofline.py
_BALANCE = MachineBalance()
PEAK_FLOPS = _BALANCE.peak_flops
HBM_BW = _BALANCE.hbm_bw
LINK_BW = _BALANCE.link_bw

_BM_CANDIDATES = (8, 16, 32, 64, 128, 256, 512)
_BN_CANDIDATES = (128, 256, 512)
_BK_MULTIPLES = (1, 2, 4, 8)


def _ceil_to(v: int, q: int) -> int:
    return ((v + q - 1) // q) * q


def tile_vmem_bytes(bm: int, bn: int, bk: int) -> int:
    """VMEM footprint of one grid step: x tile (f32) + packed bytes +
    dequantized weight tile (f32) + f32 accumulator tile."""
    return 4 * bm * bk + (bk // 2) * bn + 4 * bk * bn + 4 * bm * bn


def candidate_blocks(m: int, k: int, n: int, *, pack_block: int = 128,
                     balance: MachineBalance = _BALANCE,
                     ) -> Iterator[BlockShape]:
    """Legal sweep space: block_m up to the padded M (sublane-aligned),
    block_n a lane-width multiple up to padded N, block_k a multiple of the
    export pack block that divides K (packing is block-local), all within
    the VMEM budget."""
    if k % pack_block:
        raise ValueError(f"K={k} is not a multiple of pack_block={pack_block}")
    m_cap = max(_ceil_to(m, 8), 8)
    n_cap = max(_ceil_to(n, 128), 128)
    bms = [b for b in _BM_CANDIDATES if b <= m_cap] or [8]
    bns = [b for b in _BN_CANDIDATES if b <= n_cap] or [128]
    bks = [j * pack_block for j in _BK_MULTIPLES
           if k % (j * pack_block) == 0] or [pack_block]
    for bm in bms:
        for bn in bns:
            for bk in bks:
                if tile_vmem_bytes(bm, bn, bk) <= balance.vmem_bytes:
                    yield (bm, bn, bk)


def roofline_time(m: int, k: int, n: int, blocks: BlockShape, *,
                  balance: MachineBalance = _BALANCE) -> float:
    """Estimated kernel time for one block shape under the roofline model.

    Grid revisits drive the traffic terms: the x tile streams from HBM once
    per N block and the packed weights once per M block, so skinny shapes
    punish oversized tiles. Compute is MXU MACs (on padded work) plus the
    VPU select-dequant, and every grid step pays a fixed issue overhead —
    which is what rules out degenerate tiny tiles.
    """
    bm, bn, bk = blocks
    gm = math.ceil(m / bm)
    gn = math.ceil(n / bn)
    gk = math.ceil(k / bk)
    mp, np_, kp = gm * bm, gn * bn, gk * bk

    mac_flops = 2.0 * mp * np_ * kp
    dequant_ops = float(N_CODES) * kp * np_ * gm   # selects per packed visit
    compute_s = mac_flops / balance.peak_flops + dequant_ops / balance.vpu_flops

    x_bytes = 4.0 * mp * kp * gn          # x re-read per N block
    w_bytes = (kp / 2.0) * np_ * gm       # packed re-read per M block
    out_bytes = 4.0 * mp * np_            # written once (VMEM-resident revisits)
    memory_s = (x_bytes + w_bytes + out_bytes) / balance.hbm_bw

    return max(compute_s, memory_s) + gm * gn * gk * balance.grid_overhead_s


def shape_fingerprint(m: int, k: int, n: int, *, pack_block: int,
                      backend: str, n_codes: int = N_CODES) -> str:
    """Content fingerprint of one tuning problem (same discipline as
    `repro.serving.fleet.comp_fingerprint`: blake2b over the content)."""
    payload = repr(("lut_matmul", int(m), int(k), int(n), int(pack_block),
                    int(n_codes), str(backend)))
    return hashlib.blake2b(payload.encode(), digest_size=8).hexdigest()


class BlockAutotuner:
    """Fingerprint-keyed cache of winning block shapes.

    ``best()`` resolves a shape to its cached winner (a *hit*, zero cost) or
    runs one tuning sweep (a *miss* -> ``retune_events`` increments): rank
    all legal candidates by `roofline_time`, optionally wall-clock the top-k
    through ``measure(blocks) -> seconds``, record the winner. ``save`` /
    ``load`` round-trip the cache as JSON so a warm process never retunes.
    """

    def __init__(self, balance: MachineBalance = _BALANCE, *,
                 path: Optional[str] = None):
        self.balance = balance
        self.path = Path(path) if path else None
        self._cache: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.retune_events = 0
        if self.path is not None and self.path.exists():
            self.load(self.path)

    # ----------------------------------------------------------- resolution

    def best(self, m: int, k: int, n: int, *, pack_block: int = 128,
             backend: Optional[str] = None,
             measure: Optional[Callable[[BlockShape], float]] = None,
             top_k: int = 3) -> BlockShape:
        if backend is None:
            import jax

            backend = jax.default_backend()
        fp = shape_fingerprint(m, k, n, pack_block=pack_block, backend=backend)
        with self._lock:
            entry = self._cache.get(fp)
            if entry is not None:
                self.hits += 1
                return tuple(entry["blocks"])
            self.misses += 1
            self.retune_events += 1
            entry = self._tune(m, k, n, pack_block=pack_block,
                               backend=backend, measure=measure, top_k=top_k)
            self._cache[fp] = entry
            return tuple(entry["blocks"])

    def _tune(self, m, k, n, *, pack_block, backend, measure, top_k) -> dict:
        cands = list(candidate_blocks(m, k, n, pack_block=pack_block,
                                      balance=self.balance))
        ranked = sorted(
            cands, key=lambda b: roofline_time(m, k, n, b,
                                               balance=self.balance))
        winner, source = ranked[0], "model"
        if measure is not None and len(ranked) > 1:
            timed = [(measure(b), b) for b in ranked[:max(1, top_k)]]
            winner, source = min(timed, key=lambda t: t[0])[1], "measured"
        return {
            "shape": [int(m), int(k), int(n), int(pack_block)],
            "backend": str(backend),
            "blocks": [int(b) for b in winner],
            "source": source,
            "model_s": roofline_time(m, k, n, winner, balance=self.balance),
        }

    # ---------------------------------------------------------- persistence

    def save(self, path: Optional[str] = None) -> Path:
        p = Path(path) if path else self.path
        if p is None:
            raise ValueError("no cache path: pass one to save() or __init__")
        p.parent.mkdir(parents=True, exist_ok=True)
        with self._lock:
            payload = {"version": 1, "entries": self._cache}
        p.write_text(json.dumps(payload, indent=2, sort_keys=True))
        return p

    def load(self, path: Optional[str] = None) -> int:
        """Merge entries from a saved cache; returns how many were loaded."""
        p = Path(path) if path else self.path
        if p is None:
            raise ValueError("no cache path: pass one to load() or __init__")
        payload = json.loads(p.read_text())
        if payload.get("version") != 1:
            raise ValueError(f"unknown autotune cache version in {p}: "
                             f"{payload.get('version')!r}")
        entries = payload["entries"]
        with self._lock:
            self._cache.update(entries)
        return len(entries)

    # -------------------------------------------------------------- reports

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._cache),
                "hits": self.hits,
                "misses": self.misses,
                "retune_events": self.retune_events,
                "path": str(self.path) if self.path else None,
            }

    def clear(self) -> None:
        with self._lock:
            self._cache.clear()
            self.hits = self.misses = self.retune_events = 0


# process-wide default tuner (serve_dense/serve_conv resolve through this
# when no explicit blocks are passed); honors REPRO_LUT_AUTOTUNE_CACHE
_default: Optional[BlockAutotuner] = None
_default_lock = threading.Lock()


def get_default_autotuner() -> BlockAutotuner:
    global _default
    with _default_lock:
        if _default is None:
            _default = BlockAutotuner(path=os.environ.get(ENV_CACHE_PATH))
        return _default


def reset_default_autotuner() -> None:
    """Drop the process-wide tuner (tests; env-path changes)."""
    global _default
    with _default_lock:
        _default = None
