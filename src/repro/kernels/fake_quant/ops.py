"""jit'd wrapper + straight-through-estimator custom VJP."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fake_quant.fake_quant import fake_quant_pallas


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def fake_quant_project(w, mask, scale, codebook, k, *, block_m: int = 256,
                       block_n: int = 256, interpret: bool = True):
    """Forward fused mask+quant+project; pads to block multiples."""
    m, n = w.shape
    pm, pn = (-m) % block_m, (-n) % block_n
    wp = jnp.pad(w, ((0, pm), (0, pn)))
    mp = jnp.pad(mask, ((0, pm), (0, pn)))
    sp = jnp.pad(scale, (0, pn), constant_values=1.0)
    out = fake_quant_pallas(wp, mp, sp, codebook, k, block_m=block_m,
                            block_n=block_n, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ste_fake_quant(w, mask, scale, codebook, k, interpret=True):
    return fake_quant_project(w, mask, scale, codebook, k, interpret=interpret)


def _fwd(w, mask, scale, codebook, k, interpret):
    out = fake_quant_project(w, mask, scale, codebook, k, interpret=interpret)
    return out, mask


def _bwd(interpret, mask, g):
    # straight-through: grad flows to w where unmasked; nothing else trains
    return (g * mask.astype(g.dtype), None, None, None, None)


ste_fake_quant.defvjp(_fwd, _bwd)
