"""Pure-jnp oracle for the fused fake-quant kernel = repro.core.qat path."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import qat


def fake_quant_ref(w, mask, scale, codebook, k):
    """Same math as qat.fake_quant_weight but with an externally supplied
    per-column scale (matching the kernel's contract)."""
    wm = w.astype(jnp.float32) * mask.astype(jnp.float32)
    q = jnp.clip(jnp.round(wm / scale[None, :]), -qat.QMAX, qat.QMAX)
    qi = qat.project_to_codebook(q.astype(jnp.int32), codebook, k)
    return (qi.astype(jnp.float32) * scale[None, :]).astype(w.dtype)
