from repro.kernels.fake_quant.ops import fake_quant_project, ste_fake_quant  # noqa: F401
