"""Pallas TPU kernel: fused mask + int8 quantize + codebook projection.

The QAT forward hot path (paper 4.2) applies, per weight tile:

    q  = clip(round(w * mask / scale), -127, 127)
    q' = nearest value among the first k codebook entries (k = 0 => identity)
    w' = q' * scale

Fusing keeps the tile in VMEM for the whole chain (5 elementwise passes plus
a 32-way nearest-value select) instead of 5 HBM round trips. The per-output-
channel scale is computed outside (a cheap column max) and streamed per
N block. Grid (M/bm, N/bn); the backward (straight-through) is the mask, so
the custom VJP in ops.py never re-runs the kernel.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_MAX = 32
QMAX = 127.0


def _kernel(w_ref, mask_ref, scale_ref, cb_ref, k_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    mask = mask_ref[...].astype(jnp.float32)
    scale = scale_ref[...].astype(jnp.float32)[None, :]
    k = k_ref[0]

    wm = w * mask
    q = jnp.clip(jnp.round(wm / scale), -QMAX, QMAX)

    # nearest among the first k codebook values (unrolled 32-way select)
    best_d = jnp.full(q.shape, 1e9, jnp.float32)
    best_v = q
    for c in range(K_MAX):
        cv = cb_ref[c].astype(jnp.float32)
        d = jnp.abs(q - cv)
        valid = c < k
        take = jnp.logical_and(d < best_d, valid)
        best_d = jnp.where(take, d, best_d)
        best_v = jnp.where(take, cv, best_v)
    q_proj = jnp.where(k > 0, best_v, q)

    o_ref[...] = (q_proj * scale).astype(o_ref.dtype)


def fake_quant_pallas(
    w: jax.Array,            # (M, N) float
    mask: jax.Array,         # (M, N) int8/float
    scale: jax.Array,        # (N,) float per-out-channel
    codebook: jax.Array,     # (K_MAX,) int32
    k: jax.Array,            # () int32 valid entries
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    m, n = w.shape
    assert m % block_m == 0 and n % block_n == 0
    return pl.pallas_call(
        _kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((K_MAX,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=interpret,
    )(w, mask, scale, codebook, k.reshape(1))
