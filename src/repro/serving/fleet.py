"""Multi-plan fleet serving: SLO-aware routing across compression levels.

The paper's energy/accuracy trade-off is a *curve*, but a single
`ServingEngine` freezes one point of it at construction time. This module
lifts the choice to serve time:

* `PlanHandle` — one serving variant: a comp tree (codebook restriction +
  optional MSR truncation) plus the identity the serving stack keys on. The
  identity is a **content fingerprint** hashing the codebook values, masks,
  ``msr_bits`` and the schedule's decision set — not the bare ``compress_k``
  integer, which silently collides for two plans with equal k but different
  codebooks or MSR settings (`comp_fingerprint`).
* `PlanRegistry` — N resident handles per architecture, deduplicated by
  fingerprint; `PlanRegistry.from_dir` loads every saved `CompressionPlan`
  (``<base>.json`` + ``<base>.npz``) in a directory.
* `FleetRouter` — an admission layer over one `ServingEngine` per handle.
  Each submitted `ServeRequest` is routed to a *fidelity level* (handles
  sorted by measured per-token energy, highest first) from

    - **queue pressure**: pending requests across the fleet over the slot
      capacity (``max_batch * max_waves``). Above ``high_watermark`` the
      router steps one level toward aggressive compression; below
      ``low_watermark`` it steps back toward high fidelity. A level change
      needs ``hysteresis`` *consecutive* same-direction observations, so a
      noisy queue cannot flap the fleet between plans step to step.
    - **per-request budget**: ``ServeRequest.budget.energy_eu_per_token``
      caps the variant's measured energy; the router picks the first level
      at or below the cap (never a *less* compressed level than pressure
      already selected). An unsatisfiable budget routes to the most
      aggressive plan anyway — requests are never rejected — and records
      the SLO miss.

  Accounting is per tenant (requests, tokens, energy-units, SLO hit-rate)
  and per plan, both summing exactly to the fleet totals; `route_log` keeps
  every admission decision so degrade/recover transitions are auditable
  (gated in ``benchmarks/bench_fleet.py``).

Engines are drained with interleaved scheduler steps (`ServingEngine.step`),
so one busy variant does not head-of-line block another's first token.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "PlanHandle",
    "PlanRegistry",
    "RouterConfig",
    "FleetRouter",
    "comp_fingerprint",
]


# ------------------------------------------------------------- fingerprints


def _hash_node(h, node) -> None:
    """Feed one comp-tree node into the hash, order-independent of dict
    insertion (keys are sorted) and exact on array contents + dtype."""
    if node is None:
        h.update(b"\x00none")
    elif isinstance(node, (bool, int, float, str)):
        h.update(repr(node).encode())
    elif isinstance(node, dict):
        for k in sorted(node, key=str):
            if k == "serve":
                # packed ServeArtifacts (lm_compress.attach_serve_artifacts)
                # are *derived* from the other leaves — hashing them would
                # make a plan's identity depend on whether artifacts were
                # attached yet
                continue
            h.update(str(k).encode())
            _hash_node(h, node[k])
    elif isinstance(node, (list, tuple)):
        h.update(f"\x00seq{len(node)}".encode())
        for v in node:
            _hash_node(h, v)
    else:
        a = np.asarray(node)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())


def comp_fingerprint(comp, extra: Optional[str] = None) -> str:
    """Content hash of a comp tree (masks, codebook values, ``codebook_k``,
    ``msr_bits`` — every leaf) plus an optional ``extra`` string (e.g. the
    schedule's serialized decision set). Two plans that serve different
    weights can never share a fingerprint; ``comp=None`` hashes to a
    distinguished uncompressed identity."""
    h = hashlib.blake2b(digest_size=8)
    if comp is None:
        h.update(b"uncompressed")
    else:
        _hash_node(h, comp)
    if extra:
        h.update(b"\x00extra")
        h.update(extra.encode())
    return h.hexdigest()


# ------------------------------------------------------------- plan handles


@dataclasses.dataclass
class PlanHandle:
    """One serving variant: comp tree + content identity + measured scores.

    ``energy_per_token`` (eu, `repro.serving.metrics.per_token_energy`) and
    ``accuracy_score`` come from plan metrics when loaded from a
    `CompressionPlan`; the router fills a missing energy from the live
    engine's measurement at construction. ``compress_k`` is kept for
    reporting only — the serving stack keys on ``fingerprint``.
    """

    plan_id: str
    comp: Any = None
    compress_k: int = 0
    msr_bits: int = 0
    fingerprint: str = ""
    energy_per_token: Optional[float] = None
    accuracy_score: Optional[float] = None
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.fingerprint:
            self.fingerprint = comp_fingerprint(self.comp)

    @property
    def compressed(self) -> bool:
        return self.comp is not None

    # -------------------------------------------------------- constructors

    @classmethod
    def uncompressed(cls, plan_id: str = "base") -> "PlanHandle":
        """The full-fidelity variant: no codebook restriction."""
        return cls(plan_id=plan_id, comp=None, compress_k=0)

    @classmethod
    def from_comp(cls, comp, *, compress_k: int = 0, plan_id: str = "custom",
                  **kw) -> "PlanHandle":
        """Wrap a pre-built comp tree (e.g. a schedule's mixed decisions)."""
        return cls(plan_id=plan_id, comp=comp, compress_k=int(compress_k),
                   **kw)

    @classmethod
    def from_compress_k(cls, model, k: int, *, msr_bits: int = 0,
                        plan_id: Optional[str] = None) -> "PlanHandle":
        """Uniform k-value codebook restriction over every eligible matmul,
        optionally with MSR truncation to ``msr_bits`` magnitude bits."""
        from repro.core import lm_compress

        k = int(k)
        if not k:
            return cls.uncompressed(plan_id or "base")
        comp = lm_compress.init_lm_comp(model)
        comp = lm_compress.restrict_all_codebooks(
            model, comp, lm_compress.symmetric_codebook_values(k))
        if msr_bits:
            comp = _with_msr_bits(comp, int(msr_bits))
        if plan_id is None:
            plan_id = f"k{k}" + (f"m{msr_bits}" if msr_bits else "")
        return cls(plan_id=plan_id, comp=comp, compress_k=k,
                   msr_bits=int(msr_bits))

    @classmethod
    def from_compression_plan(cls, plan,
                              plan_id: Optional[str] = None) -> "PlanHandle":
        """Adopt a `repro.pipeline.CompressionPlan`: its comp tree, its
        fingerprint (codebooks + decisions), and its measured metrics."""
        m = plan.metrics
        if plan_id is None:
            arch = plan.target.get("name", plan.target.get("arch", "plan"))
            k = int(m.get("compress_k", 0) or 0)
            plan_id = f"{arch}-k{k}" if k else f"{arch}-base"
        acc = m.get("acc_final", m.get("serve_accuracy"))
        return cls(
            plan_id=plan_id,
            comp=plan.comp,
            compress_k=int(m.get("compress_k", 0) or 0),
            fingerprint=plan.fingerprint(),
            energy_per_token=(float(m["energy_after"])
                              if "energy_after" in m else None),
            accuracy_score=None if acc is None else float(acc),
            metrics={k_: v for k_, v in m.items()
                     if isinstance(v, (int, float, bool, str))},
        )


def _with_msr_bits(comp, msr_bits: int):
    """Return a comp tree whose per-unit entries carry ``msr_bits`` (read by
    `repro.core.qat.quantize_weight_int` / `fake_quant_weight`)."""

    def walk(node):
        if isinstance(node, dict):
            if "codebook" in node:
                out = dict(node)
                out["msr_bits"] = int(msr_bits)
                return out
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(comp)


# ----------------------------------------------------------------- registry


class PlanRegistry:
    """Resident serving variants for one architecture, deduped by content.

    Registering a handle whose fingerprint is already resident returns the
    existing handle (same weights -> same executables; there is nothing new
    to serve). Registering a *different* plan under a taken ``plan_id``
    raises — ids are the human names routing reports use.
    """

    def __init__(self, handles: Sequence[PlanHandle] = ()):
        self._by_id: Dict[str, PlanHandle] = {}
        self._by_fp: Dict[str, PlanHandle] = {}
        for h in handles:
            self.register(h)

    def register(self, handle: PlanHandle) -> PlanHandle:
        existing = self._by_fp.get(handle.fingerprint)
        if existing is not None:
            return existing
        if handle.plan_id in self._by_id:
            raise ValueError(
                f"plan_id {handle.plan_id!r} already registered with a "
                f"different fingerprint "
                f"({self._by_id[handle.plan_id].fingerprint} != "
                f"{handle.fingerprint})")
        self._by_id[handle.plan_id] = handle
        self._by_fp[handle.fingerprint] = handle
        return handle

    def get(self, plan_id: str) -> PlanHandle:
        if plan_id not in self._by_id:
            raise KeyError(f"unknown plan_id {plan_id!r}; resident: "
                           f"{sorted(self._by_id)}")
        return self._by_id[plan_id]

    def handles(self) -> List[PlanHandle]:
        return list(self._by_id.values())

    def __len__(self) -> int:
        return len(self._by_id)

    def __iter__(self):
        return iter(self._by_id.values())

    def __contains__(self, plan_id: str) -> bool:
        return plan_id in self._by_id

    @classmethod
    def from_dir(cls, path, *, include_uncompressed: bool = False
                 ) -> "PlanRegistry":
        """Load every saved `CompressionPlan` (``<base>.json`` +
        ``<base>.npz``) under ``path`` into a registry. Plan ids are the
        file stems; ``include_uncompressed`` adds a k=0 handle so the fleet
        always holds a full-fidelity fallback."""
        from pathlib import Path

        from repro.pipeline.plan import CompressionPlan

        reg = cls()
        base_dir = Path(path)
        if not base_dir.is_dir():
            raise FileNotFoundError(f"plan registry dir {base_dir} not found")
        for json_path in sorted(base_dir.glob("*.json")):
            if not json_path.with_suffix(".npz").exists():
                continue
            plan = CompressionPlan.load(json_path)
            reg.register(PlanHandle.from_compression_plan(
                plan, plan_id=json_path.stem))
        if include_uncompressed:
            reg.register(PlanHandle.uncompressed())
        if not len(reg):
            raise ValueError(f"no CompressionPlan artifacts under {base_dir}")
        return reg


# ------------------------------------------------------------------- router


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Admission policy knobs (see module docstring for the mechanics)."""

    high_watermark: float = 0.75   # pressure above -> step toward aggressive
    low_watermark: float = 0.25    # pressure below -> step toward fidelity
    hysteresis: int = 2            # consecutive observations per level change

    def __post_init__(self):
        if not 0.0 <= self.low_watermark <= self.high_watermark:
            raise ValueError(
                f"need 0 <= low_watermark <= high_watermark, got "
                f"{self.low_watermark} / {self.high_watermark}")
        if self.hysteresis < 1:
            raise ValueError(f"hysteresis must be >= 1, got {self.hysteresis}")


class FleetRouter:
    """One `ServingEngine` per resident plan + an SLO-aware admission layer.

    Levels are the handles sorted by measured per-token energy, *highest
    first* — level 0 is the high-fidelity default served when idle, the last
    level the most aggressive compression served under pressure.
    """

    def __init__(self, model, params,
                 plans: Union[PlanRegistry, Sequence[PlanHandle]], *,
                 mode: str = "engine", config=None,
                 router: RouterConfig = RouterConfig(),
                 arch: Optional[str] = None, mesh=None):
        from repro.serving.bucketing import EngineConfig
        from repro.serving.engine import ServingEngine

        if config is None:
            config = EngineConfig()
        self.registry = (plans if isinstance(plans, PlanRegistry)
                         else PlanRegistry(plans))
        if not len(self.registry):
            raise ValueError("fleet needs at least one resident plan")
        self.config = config
        self.router = router
        self.engines: Dict[str, Any] = {}
        for h in self.registry:
            self.engines[h.plan_id] = ServingEngine(
                model, params, mode=mode, config=config, plan=h, arch=arch,
                mesh=mesh)
        # measure any handle the plan metrics didn't already price — the
        # engine's lazy per-token energy is the same model the charge uses
        for h in self.registry:
            if h.energy_per_token is None:
                h.energy_per_token = self.engines[h.plan_id].per_token_energy_eu
        self.levels: List[PlanHandle] = sorted(
            self.registry.handles(),
            key=lambda h: (-float(h.energy_per_token), h.plan_id))
        self._level = 0
        self._high_streak = 0
        self._low_streak = 0
        self._warm_compiles: Optional[int] = None
        self.route_log: List[Dict[str, Any]] = []
        self._routes: Dict[int, Tuple[str, int]] = {}   # rid -> (plan, erid)
        self._slo_energy_miss: Dict[int, bool] = {}
        self._requests: Dict[int, Any] = {}             # rid -> ServeRequest
        self._next_rid = 0
        self.wall_s = 0.0

    # ------------------------------------------------------------- capacity

    @property
    def slot_capacity(self) -> int:
        return self.config.slot_capacity

    @property
    def pending(self) -> int:
        """Requests submitted but not yet finished, across the fleet."""
        return sum(e.pending for e in self.engines.values())

    @property
    def pressure(self) -> float:
        return self.pending / max(self.slot_capacity, 1)

    # -------------------------------------------------------------- warmup

    def warmup(self, shapes: Sequence[tuple]) -> dict:
        """Warm every resident engine's executable set; zero recompiles
        after this is the fleet gate (``bench_fleet.py``)."""
        stats = {pid: e.warmup(shapes) for pid, e in self.engines.items()}
        self._warm_compiles = self._compile_count()
        return stats

    def _compile_count(self) -> int:
        return sum(e.cache.compile_count for e in self.engines.values())

    @property
    def recompiles_after_warmup(self) -> int:
        if self._warm_compiles is None:
            return 0
        return self._compile_count() - self._warm_compiles

    # ------------------------------------------------------------ admission

    def _observe_pressure(self, pressure: float) -> None:
        """Hysteresis: a level moves only after ``hysteresis`` consecutive
        same-direction observations; anything else decays both streaks."""
        r = self.router
        if pressure > r.high_watermark and self._level < len(self.levels) - 1:
            self._high_streak += 1
            self._low_streak = 0
            if self._high_streak >= r.hysteresis:
                self._level += 1
                self._high_streak = 0
        elif pressure < r.low_watermark and self._level > 0:
            self._low_streak += 1
            self._high_streak = 0
            if self._low_streak >= r.hysteresis:
                self._level -= 1
                self._low_streak = 0
        else:
            self._high_streak = 0
            self._low_streak = 0

    def _budget_level(self, budget, base_level: int) -> Tuple[int, bool]:
        """First level at or past ``base_level`` whose measured energy fits
        the request's cap; (most aggressive, miss=True) when none does."""
        cap = getattr(budget, "energy_eu_per_token", None)
        if cap is None:
            return base_level, False
        for lvl in range(base_level, len(self.levels)):
            if float(self.levels[lvl].energy_per_token) <= float(cap):
                return lvl, False
        return len(self.levels) - 1, True

    def submit(self, request) -> int:
        """Route one `ServeRequest` to a resident plan; returns the fleet
        request id. Requests are never rejected: an unsatisfiable energy
        budget lands on the most aggressive plan with the SLO miss
        recorded."""
        pressure = self.pressure
        self._observe_pressure(pressure)
        level = self._level
        miss = False
        if request.budget is not None:
            level, miss = self._budget_level(request.budget, level)
        handle = self.levels[level]
        engine = self.engines[handle.plan_id]
        erid = engine.submit_request(request)
        rid = self._next_rid
        self._next_rid += 1
        self._routes[rid] = (handle.plan_id, erid)
        self._requests[rid] = request
        self._slo_energy_miss[rid] = miss
        self.route_log.append({
            "rid": rid,
            "plan_id": handle.plan_id,
            "level": level,
            "pressure": pressure,
            "tenant": request.tenant,
            "budget_miss": miss,
        })
        return rid

    # ----------------------------------------------------------------- run

    def run(self) -> Dict[int, Any]:
        """Drain every engine with interleaved scheduler steps; returns
        {fleet rid: ServeResult} for every request routed so far."""
        t0 = time.perf_counter()
        progressed = True
        while progressed:
            progressed = False
            for engine in self.engines.values():
                progressed = engine.step() or progressed
        self.wall_s += time.perf_counter() - t0
        out = {}
        for rid, (plan_id, erid) in self._routes.items():
            res = self.engines[plan_id].result(erid)
            if res is not None:
                out[rid] = res
        return out

    def serve(self, requests: Sequence[Any]) -> List[Any]:
        """Submit a batch of `ServeRequest`s and drain; results in order."""
        rids = [self.submit(r) for r in requests]
        out = self.run()
        return [out[rid] for rid in rids]

    # -------------------------------------------------------------- reports

    def _slo_hit(self, rid: int, stats) -> Optional[bool]:
        """SLO verdict for a budgeted request (None when no budget): the
        routed variant fit the energy cap and the measured latency fit
        ``latency_s`` when set."""
        req = self._requests[rid]
        if req.budget is None:
            return None
        if self._slo_energy_miss.get(rid):
            return False
        lat_cap = getattr(req.budget, "latency_s", None)
        if lat_cap is not None and stats.latency_s > float(lat_cap):
            return False
        return True

    def report(self) -> dict:
        """Fleet totals + per-plan and per-tenant breakdowns (both sum to
        the totals) + the observed level transitions."""
        from repro.serving.metrics import summarize

        finished: List[Tuple[int, Any]] = []
        for rid, (plan_id, erid) in self._routes.items():
            res = self.engines[plan_id].result(erid)
            if res is not None:
                finished.append((rid, res))
        stats = [r.stats for _, r in finished]
        out = summarize(stats, self.wall_s)
        out["plans_resident"] = len(self.levels)
        out["recompiles_after_warmup"] = self.recompiles_after_warmup

        plans: Dict[str, dict] = {}
        for h in self.levels:
            eng = self.engines[h.plan_id]
            plans[h.plan_id] = {
                "level": self.levels.index(h),
                "compress_k": h.compress_k,
                "fingerprint": h.fingerprint,
                "energy_eu_per_token_plan": float(h.energy_per_token),
                "requests": 0, "new_tokens": 0, "energy_eu": 0.0,
                "compile_count": eng.cache.compile_count,
            }
        tenants: Dict[str, dict] = {}
        for rid, res in finished:
            s = res.stats
            p = plans[s.plan_id]
            p["requests"] += 1
            p["new_tokens"] += s.new_tokens
            p["energy_eu"] += s.energy_eu
            t = tenants.setdefault(s.tenant, {
                "requests": 0, "new_tokens": 0, "energy_eu": 0.0,
                "slo_total": 0, "slo_hits": 0})
            t["requests"] += 1
            t["new_tokens"] += s.new_tokens
            t["energy_eu"] += s.energy_eu
            hit = self._slo_hit(rid, s)
            if hit is not None:
                t["slo_total"] += 1
                t["slo_hits"] += int(hit)
        for t in tenants.values():
            t["slo_hit_rate"] = (t["slo_hits"] / t["slo_total"]
                                 if t["slo_total"] else 1.0)
        out["plans"] = plans
        out["tenants"] = tenants
        out["slo_total"] = sum(t["slo_total"] for t in tenants.values())
        out["slo_hits"] = sum(t["slo_hits"] for t in tenants.values())

        levels = [e["level"] for e in self.route_log]
        out["level_degrades"] = sum(
            1 for a, b in zip(levels, levels[1:]) if b > a)
        out["level_recovers"] = sum(
            1 for a, b in zip(levels, levels[1:]) if b < a)
        return out
