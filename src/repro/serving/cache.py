"""Artifact/compile cache for the serving engine.

Two maps, both keyed on the engine identity ``(arch, fingerprint)`` — the
architecture name and the serving plan's *content fingerprint*
(`repro.serving.fleet.comp_fingerprint`, hashing codebook values, masks and
``msr_bits``). The fingerprint replaced the old bare ``compress_k`` integer:
two plans with equal k but different codebooks or MSR settings used to
collide and silently share executables and exported artifacts built from the
*first* plan's weights.

* ``(arch, fingerprint, shape-key)`` -> compiled executables. Wave/oneshot
  modes key on a `BucketSpec` and get a `CompiledStep` (prefill + lockstep
  decode); the slot-level engine keys on ``("group", batch, total_len)`` for
  its active-masked group decode (`GroupStep`) and on
  ``("chunk", rows, chunk, batch, total_len)`` for each chunked-prefill
  executable (`ChunkStep`) — a small *fixed* set determined by the config's
  chunk buckets, never by request shapes. Compilation happens exactly once
  per key, through `jax.jit(...).lower(...).compile()`; the resulting
  executables *reject* any differently-shaped call with a ``TypeError``
  instead of silently recompiling, so "compiles once per shape, never per
  request" is enforced structurally, not just measured.
* ``(arch, fingerprint)`` -> exported `ServeArtifact` tree + summary for the
  packed 4-bit deployment form (`repro.core.lm_compress.export_lm_matmuls`),
  used for footprint reporting and parity checks.

``compile_count`` increments on every executable build; the serving benchmark
gates on it staying flat after warmup.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import QuantConfig
from repro.serving.bucketing import BucketSpec, EngineConfig


@dataclasses.dataclass(frozen=True)
class CompiledStep:
    """AOT executables for one bucket: ``prefill(params, prompts)`` ->
    (logits, cache); ``decode(params, cache, tok)`` -> (logits, cache)."""

    bucket: BucketSpec
    prefill: Callable
    decode: Callable


@dataclasses.dataclass(frozen=True)
class GroupStep:
    """AOT decode for one slot group: ``decode(params, cache, tok, active)``
    -> (logits, cache). Rows where ``active`` is False keep their cache and
    position; their logits are garbage. ``make_cache()`` returns a fresh
    zeroed group cache (every slot's positions start invalid)."""

    batch: int
    total_len: int
    decode: Callable
    make_cache: Callable


@dataclasses.dataclass(frozen=True)
class ChunkStep:
    """AOT chunked-prefill step:
    ``fn(params, cache, tokens, rows, start, active)`` -> (logits, cache).

    Gathers ``rows`` (int32 (rows,)) out of the group cache, runs one
    prefill chunk per gathered row starting at ``start`` (int32 (rows,)),
    and scatters the updated rows back (``active`` masks padding rows).
    Logits are (rows, V) — each row's *last* chunk position only, which is
    all decode needs: a row's final chunk seeds its first sampled token.
    Compiled per (row-width, chunk) pair from the config's fixed
    ``chunk_row_buckets`` x chunk-size grid, so refilling one freed slot
    dispatches a 1-row chunk instead of a full-width one."""

    rows: int
    chunk: int
    fn: Callable


class ServeCompileCache:
    """Per-(arch, plan-fingerprint) compile + artifact cache. Engine and
    oneshot serving apply the same discipline; the oneshot fallback warms
    batch-1 buckets (its wave width), so the two modes' bucket keys are
    disjoint."""

    def __init__(self, model, *, arch: str, fingerprint: str = "",
                 compress_k: int = 0, qcfg: Optional[QuantConfig] = None,
                 comp=None, config: EngineConfig = EngineConfig(),
                 place_prompts: Optional[Callable] = None,
                 place_replicated: Optional[Callable] = None):
        self.model = model
        self.arch = arch
        self.compress_k = int(compress_k)
        if not fingerprint:
            # direct construction without an explicit plan identity: derive
            # it from the comp content so distinct comps never share keys
            from repro.serving.fleet import comp_fingerprint

            fingerprint = comp_fingerprint(comp)
        self.fingerprint = fingerprint
        self.qcfg = qcfg if qcfg is not None else QuantConfig.off()
        self.comp = comp
        self.config = config
        self._place = place_prompts if place_prompts is not None else (lambda x: x)
        # slot-group state is placed replicated under an optional mesh (the
        # 'requests' sharding speedup applies to the wave/oneshot paths)
        self._rep = place_replicated if place_replicated is not None \
            else (lambda x: x)
        self._steps: Dict[Tuple, object] = {}
        self._artifacts: Dict[Tuple, Tuple[dict, dict]] = {}
        self.compile_count = 0

    # ------------------------------------------------------------ step fns

    def _key(self, bucket: BucketSpec) -> Tuple:
        return (self.arch, self.fingerprint, bucket.key())

    def fns(self, bucket: BucketSpec, params) -> CompiledStep:
        """Compiled (prefill, decode) for the bucket; compiles on first use."""
        key = self._key(bucket)
        if key in self._steps:
            return self._steps[key]

        model, cfg = self.model, self.config
        qcfg, comp = self.qcfg, self.comp
        cache_dtype = jnp.dtype(cfg.cache_dtype)

        def prefill_fn(p, prompts):
            return model.prefill(p, prompts, max_len=bucket.total_len,
                                 qcfg=qcfg, comp=comp, cache_dtype=cache_dtype,
                                 q_block=cfg.q_block, kv_block=cfg.kv_block)

        def decode_fn(p, cache, tok):
            return model.decode_step(p, cache, tok, qcfg=qcfg, comp=comp)

        prompts0 = self._place(
            jnp.zeros((bucket.batch, bucket.prompt_len), jnp.int32))
        prefill_c = jax.jit(prefill_fn).lower(params, prompts0).compile()
        self.compile_count += 1
        # lower decode from a *concrete* prefill output so avals (and, under
        # an optional serving mesh, shardings) match the runtime cache exactly
        _, cache0 = prefill_c(params, prompts0)
        tok0 = self._place(jnp.zeros((bucket.batch, 1), jnp.int32))
        decode_c = jax.jit(decode_fn, donate_argnums=(1,)).lower(params, cache0, tok0).compile()
        self.compile_count += 1

        step = CompiledStep(bucket=bucket, prefill=prefill_c, decode=decode_c)
        self._steps[key] = step
        return step

    # --------------------------------------------------- slot-group step fns

    def _group_shape(self) -> Tuple[int, int]:
        cfg = self.config
        return cfg.max_batch, cfg.group_total_len

    def _group_cache_zero(self):
        batch, total_len = self._group_shape()
        spec = self.model.cache_spec(batch, total_len,
                                     jnp.dtype(self.config.cache_dtype))
        zero = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        # fresh slots must look *unwritten*: per-row positions of 0 with an
        # all-zero cache are harmless (chunk prefill overwrites from pos 0
        # before any decode touches the row), so zeros are the right init
        return jax.tree.map(self._rep, zero)

    def group_fns(self, params) -> GroupStep:
        """Compiled active-masked decode for the slot group shape."""
        batch, total_len = self._group_shape()
        key = (self.arch, self.fingerprint, ("group", batch, total_len))
        if key in self._steps:
            return self._steps[key]

        model, qcfg, comp = self.model, self.qcfg, self.comp

        def decode_fn(p, cache, tok, active):
            return model.decode_step(p, cache, tok, qcfg=qcfg, comp=comp,
                                     active=active)

        cache0 = self._group_cache_zero()
        tok0 = self._rep(jnp.zeros((batch, 1), jnp.int32))
        act0 = self._rep(jnp.zeros((batch,), bool))
        decode_c = jax.jit(decode_fn, donate_argnums=(1,)).lower(params, cache0, tok0,
                                            act0).compile()
        self.compile_count += 1
        step = GroupStep(batch=batch, total_len=total_len, decode=decode_c,
                         make_cache=self._group_cache_zero)
        self._steps[key] = step
        return step

    def chunk_fns(self, chunk: int, rows: int, params) -> ChunkStep:
        """Compiled chunked-prefill step for one (chunk size, row width)
        pair, operating on gathered group rows."""
        cfg = self.config
        batch, total_len = self._group_shape()
        rows = int(rows)
        key = (self.arch, self.fingerprint,
               ("chunk", rows, int(chunk), batch, total_len))
        if key in self._steps:
            return self._steps[key]

        model, qcfg, comp = self.model, self.qcfg, self.comp

        def chunk_fn(p, cache, tokens, row_ids, start, active):
            row_cache = model.gather_cache_rows(cache, row_ids)
            logits, new_rows = model.prefill_chunk(
                p, row_cache, tokens, start=start, qcfg=qcfg, comp=comp,
                q_block=cfg.q_block, kv_block=cfg.kv_block)
            new_cache = model.scatter_cache_rows(cache, row_ids, new_rows,
                                                 active)
            return logits[:, -1, :], new_cache

        cache0 = self._group_cache_zero()
        tokens0 = self._rep(jnp.zeros((rows, int(chunk)), jnp.int32))
        rows0 = self._rep(jnp.zeros((rows,), jnp.int32))
        start0 = self._rep(jnp.zeros((rows,), jnp.int32))
        act0 = self._rep(jnp.zeros((rows,), bool))
        fn_c = jax.jit(chunk_fn, donate_argnums=(1,)).lower(params, cache0, tokens0, rows0,
                                       start0, act0).compile()
        self.compile_count += 1
        step = ChunkStep(rows=rows, chunk=int(chunk), fn=fn_c)
        self._steps[key] = step
        return step

    # ----------------------------------------------------------- artifacts

    def artifacts(self, params) -> Tuple[dict, dict]:
        """Packed `ServeArtifact` tree + footprint summary for
        (arch, fingerprint).

        Empty when the engine is uncompressed — there is nothing to pack
        without a codebook restriction.
        """
        key = (self.arch, self.fingerprint)
        if key in self._artifacts:
            return self._artifacts[key]
        if self.comp is None:
            arts: dict = {}
            summary = {"layers": 0, "weight_bytes_packed": 0}
        else:
            from repro.core.export import export_summary
            from repro.core.lm_compress import export_lm_matmuls

            arts, _skips = export_lm_matmuls(self.model, params, self.comp)
            summary = export_summary(arts)
        self._artifacts[key] = (arts, summary)
        return self._artifacts[key]

    # ------------------------------------------------------------- reports

    def stats(self) -> dict:
        return {
            "arch": self.arch,
            "compress_k": self.compress_k,
            "fingerprint": self.fingerprint,
            "buckets_compiled": len(self._steps),
            "compile_count": self.compile_count,
        }
