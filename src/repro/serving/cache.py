"""Artifact/compile cache for the serving engine.

Two maps, both keyed on the engine identity ``(arch, k)`` (architecture name
and codebook size, 0 = uncompressed):

* ``(arch, k, bucket)`` -> `CompiledStep`: ahead-of-time compiled prefill and
  decode executables for one `BucketSpec`. Compilation happens exactly once
  per bucket, through `jax.jit(...).lower(...).compile()`; the resulting
  executables *reject* any differently-shaped call with a ``TypeError``
  instead of silently recompiling, so "compiles once per bucket, never per
  request" is enforced structurally, not just measured.
* ``(arch, k)`` -> exported `ServeArtifact` tree + summary for the packed
  4-bit deployment form (`repro.core.lm_compress.export_lm_matmuls`), used
  for footprint reporting and parity checks.

``compile_count`` increments on every executable build; the serving benchmark
gates on it staying flat after bucket warmup.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import QuantConfig
from repro.serving.bucketing import BucketSpec, EngineConfig


@dataclasses.dataclass(frozen=True)
class CompiledStep:
    """AOT executables for one bucket: ``prefill(params, prompts)`` ->
    (logits, cache); ``decode(params, cache, tok)`` -> (logits, cache)."""

    bucket: BucketSpec
    prefill: Callable
    decode: Callable


class ServeCompileCache:
    """Per-(arch, k) compile + artifact cache. Engine and oneshot serving
    apply the same discipline; the oneshot fallback warms batch-1 buckets
    (its wave width), so the two modes' bucket keys are disjoint."""

    def __init__(self, model, *, arch: str, compress_k: int = 0,
                 qcfg: Optional[QuantConfig] = None, comp=None,
                 config: EngineConfig = EngineConfig(),
                 place_prompts: Optional[Callable] = None):
        self.model = model
        self.arch = arch
        self.compress_k = int(compress_k)
        self.qcfg = qcfg if qcfg is not None else QuantConfig.off()
        self.comp = comp
        self.config = config
        self._place = place_prompts if place_prompts is not None else (lambda x: x)
        self._steps: Dict[Tuple, CompiledStep] = {}
        self._artifacts: Dict[Tuple, Tuple[dict, dict]] = {}
        self.compile_count = 0

    # ------------------------------------------------------------ step fns

    def _key(self, bucket: BucketSpec) -> Tuple:
        return (self.arch, self.compress_k, bucket.key())

    def fns(self, bucket: BucketSpec, params) -> CompiledStep:
        """Compiled (prefill, decode) for the bucket; compiles on first use."""
        key = self._key(bucket)
        if key in self._steps:
            return self._steps[key]

        model, cfg = self.model, self.config
        qcfg, comp = self.qcfg, self.comp
        cache_dtype = jnp.dtype(cfg.cache_dtype)

        def prefill_fn(p, prompts):
            return model.prefill(p, prompts, max_len=bucket.total_len,
                                 qcfg=qcfg, comp=comp, cache_dtype=cache_dtype,
                                 q_block=cfg.q_block, kv_block=cfg.kv_block)

        def decode_fn(p, cache, tok):
            return model.decode_step(p, cache, tok, qcfg=qcfg, comp=comp)

        prompts0 = self._place(
            jnp.zeros((bucket.batch, bucket.prompt_len), jnp.int32))
        prefill_c = jax.jit(prefill_fn).lower(params, prompts0).compile()
        self.compile_count += 1
        # lower decode from a *concrete* prefill output so avals (and, under
        # an optional serving mesh, shardings) match the runtime cache exactly
        _, cache0 = prefill_c(params, prompts0)
        tok0 = self._place(jnp.zeros((bucket.batch, 1), jnp.int32))
        decode_c = jax.jit(decode_fn).lower(params, cache0, tok0).compile()
        self.compile_count += 1

        step = CompiledStep(bucket=bucket, prefill=prefill_c, decode=decode_c)
        self._steps[key] = step
        return step

    # ----------------------------------------------------------- artifacts

    def artifacts(self, params) -> Tuple[dict, dict]:
        """Packed `ServeArtifact` tree + footprint summary for (arch, k).

        Empty when the engine is uncompressed (k == 0) — there is nothing to
        pack without a codebook restriction.
        """
        key = (self.arch, self.compress_k)
        if key in self._artifacts:
            return self._artifacts[key]
        if not self.compress_k or self.comp is None:
            arts: dict = {}
            summary = {"layers": 0, "weight_bytes_packed": 0}
        else:
            from repro.core.export import export_summary
            from repro.core.lm_compress import export_lm_matmuls

            arts = export_lm_matmuls(self.model, params, self.comp)
            summary = export_summary(arts)
        self._artifacts[key] = (arts, summary)
        return self._artifacts[key]

    # ------------------------------------------------------------- reports

    def stats(self) -> dict:
        return {
            "arch": self.arch,
            "compress_k": self.compress_k,
            "buckets_compiled": len(self._steps),
            "compile_count": self.compile_count,
        }
