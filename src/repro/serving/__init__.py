"""Continuous-batching compressed serving engine (see docs/serving.md)."""

from repro.serving.bucketing import (  # noqa: F401
    BucketSpec,
    EngineConfig,
    bucket_for,
    bucket_up,
    pad_prompts,
)
from repro.serving.cache import CompiledStep, ServeCompileCache  # noqa: F401
from repro.serving.engine import (  # noqa: F401
    Request,
    RequestResult,
    ServingEngine,
)
from repro.serving.metrics import (  # noqa: F401
    RequestStats,
    per_token_energy,
    percentile,
    summarize,
)
