"""Continuous-batching compressed serving engine (see docs/serving.md)."""

from repro.serving.bucketing import (  # noqa: F401
    BucketSpec,
    EngineConfig,
    bucket_for,
    bucket_up,
    chunk_plan,
    pad_prompts,
)
from repro.serving.cache import (  # noqa: F401
    ChunkStep,
    CompiledStep,
    GroupStep,
    ServeCompileCache,
)
from repro.serving.engine import (  # noqa: F401
    Request,
    RequestBudget,
    RequestResult,
    ServeRequest,
    ServeResult,
    ServingEngine,
)
from repro.serving.fleet import (  # noqa: F401
    FleetRouter,
    PlanHandle,
    PlanRegistry,
    RouterConfig,
    comp_fingerprint,
)
from repro.serving.metrics import (  # noqa: F401
    RequestStats,
    per_token_energy,
    percentile,
    summarize,
)
