"""Continuous-batching compressed serving engine (see docs/serving.md)."""

from repro.serving.bucketing import (  # noqa: F401
    BucketSpec,
    EngineConfig,
    bucket_for,
    bucket_up,
    chunk_plan,
    pad_prompts,
)
from repro.serving.cache import (  # noqa: F401
    ChunkStep,
    CompiledStep,
    GroupStep,
    ServeCompileCache,
)
from repro.serving.engine import (  # noqa: F401
    Request,
    RequestResult,
    ServingEngine,
)
from repro.serving.metrics import (  # noqa: F401
    RequestStats,
    per_token_energy,
    percentile,
    summarize,
)
