"""Continuous-batching serving engine over the compressed LM serving path.

Requests enter a FIFO queue and are packed into *waves*: fixed-shape
micro-batches padded to a `BucketSpec` (see `repro.serving.bucketing`), so
jit compiles once per bucket and never per request. The scheduling loop
interleaves admission (prefill of a new wave from the queue) with decode
steps across all in-flight waves; a wave retires as soon as every request in
it has its tokens, freeing capacity for the next admission. Requests with
different ``new_tokens`` can share a wave — finished slots idle (their
sampled tokens are discarded) until the longest request completes.

``mode="oneshot"`` is the single-shot fallback: the same code path restricted
to batch-1 waves, one request at a time, sharing the bucket padding contract
and the compile cache — so engine-vs-oneshot output parity is exact (greedy
*and* seeded-temperature sampling happen host-side per request in both
modes), and the benchmarked speedup isolates the batching/scheduling win.

Position bookkeeping: the decode cache keeps one scalar position for the
whole wave, so all requests in a wave advance in lockstep from the padded
prompt length. Slot-level refill of a retired request inside a live wave
would need per-sequence positions in `repro.models.lm` — wave-level
admission is the contract until then (see docs/serving.md).

With ``compress_k > 0`` every eligible matmul is restricted to a symmetric
k-value codebook (`repro.core.lm_compress.restrict_all_codebooks`) and both
prefill and decode run the compressed fake-quant forward; the packed 4-bit
`ServeArtifact` tree is exported into the cache for footprint/parity
reporting, and per-request energy is charged via the tile-level model
(`repro.serving.metrics.per_token_energy`).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.nn.layers import QuantConfig
from repro.serving.bucketing import (
    BucketSpec,
    EngineConfig,
    bucket_for,
    pad_prompts,
)
from repro.serving.cache import ServeCompileCache
from repro.serving.metrics import RequestStats, per_token_energy, summarize


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    new_tokens: int
    temperature: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]             # exactly new_tokens entries
    stats: RequestStats


class _Slot:
    """One request's in-wave state."""

    def __init__(self, req: Request, stats: RequestStats):
        self.req = req
        self.stats = stats
        self.tokens: List[int] = []
        # the sampling stream is a pure function of the request's own seed
        # (not of engine-local ids), so engine and oneshot draws agree;
        # submit distinct seeds for independent streams across requests
        self.rng = np.random.default_rng(req.seed)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.req.new_tokens


class _Wave:
    """A fixed-shape micro-batch mid-decode."""

    def __init__(self, bucket: BucketSpec, slots: List[_Slot], fns, cache,
                 tok):
        self.bucket = bucket
        self.slots = slots
        self.fns = fns
        self.cache = cache
        self.tok = tok            # (batch, 1) int32 device array

    @property
    def done(self) -> bool:
        return all(s.done for s in self.slots)


class ServingEngine:
    """Queue + micro-batcher + compile cache over one LM and its params."""

    def __init__(self, model, params, *, mode: str = "engine",
                 config: EngineConfig = EngineConfig(), compress_k: int = 0,
                 comp=None, arch: Optional[str] = None, mesh=None):
        if mode not in ("engine", "oneshot"):
            raise ValueError(f"mode must be 'engine' or 'oneshot', got {mode!r}")
        self.model = model
        self.config = config
        self.mode = mode
        self.compress_k = int(compress_k)
        self.arch = arch if arch is not None else model.cfg.name

        if comp is not None:
            # pre-built comp tree (e.g. a CompressionPlan's codebooks);
            # compress_k stays the cache key for the restriction level
            self.comp = comp
            self.qcfg = QuantConfig.on()
        elif self.compress_k:
            from repro.core import lm_compress

            comp = lm_compress.init_lm_comp(model)
            values = lm_compress.symmetric_codebook_values(self.compress_k)
            self.comp = lm_compress.restrict_all_codebooks(model, comp, values)
            self.qcfg = QuantConfig.on()
        else:
            self.comp = None
            self.qcfg = QuantConfig.off()

        self.mesh = mesh
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            self._replicated = NamedSharding(mesh, PartitionSpec())
            params = jax.device_put(params, self._replicated)
        self.params = params

        self.cache = ServeCompileCache(
            model, arch=self.arch, compress_k=self.compress_k, qcfg=self.qcfg,
            comp=self.comp, config=config, place_prompts=self._place)

        self._queue: collections.deque[Request] = collections.deque()
        self._waves: List[_Wave] = []
        self._next_rid = 0
        self._stats_pending: Dict[int, RequestStats] = {}
        self._completed: Dict[int, RequestResult] = {}
        self._e_per_token: Optional[float] = None
        self.last_wall_s = 0.0
        self.total_wall_s = 0.0

    # ------------------------------------------------------------ placement

    def _place(self, x):
        """Put a batch-major array on device (sharded over 'requests' when an
        optional serving mesh is attached and the batch divides it)."""
        x = jnp.asarray(x)
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec

        n = self.mesh.devices.size
        if x.ndim >= 1 and x.shape[0] % n == 0:
            spec = PartitionSpec("requests", *([None] * (x.ndim - 1)))
            return jax.device_put(x, NamedSharding(self.mesh, spec))
        return jax.device_put(x, self._replicated)

    # ------------------------------------------------------------ admission

    @property
    def wave_width(self) -> int:
        return 1 if self.mode == "oneshot" else self.config.max_batch

    @property
    def max_inflight(self) -> int:
        """Oneshot means one request at a time — no wave overlap either."""
        return 1 if self.mode == "oneshot" else self.config.max_waves

    def submit(self, prompt: Sequence[int], new_tokens: int, *,
               temperature: float = 0.0, seed: int = 0) -> int:
        """Enqueue one request; returns its request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, new_tokens=int(new_tokens),
                      temperature=float(temperature), seed=int(seed))
        # validates the shape fits a bucket at submit time, not mid-run
        bucket_for(prompt.shape[0], req.new_tokens, self.config,
                   self.wave_width)
        self._queue.append(req)
        self._stats_pending[rid] = RequestStats(
            rid=rid, prompt_len=int(prompt.shape[0]),
            new_tokens=req.new_tokens, bucket=(),
            t_submit=time.perf_counter())
        return rid

    def warmup(self, shapes: Sequence[tuple]) -> dict:
        """Precompile the buckets for (prompt_len, new_tokens) shapes and the
        per-token energy model; returns cache stats. After warmup, serving
        those shapes adds zero compiles and no lazy one-time costs."""
        for plen, ntok in shapes:
            bucket = bucket_for(plen, ntok, self.config, self.wave_width)
            self.cache.fns(bucket, self.params)
        _ = self.per_token_energy_eu
        return self.cache.stats()

    def _sample_row(self, row: np.ndarray, slot: Optional[_Slot]) -> int:
        """Host-side sampling — shared by both modes, so parity is exact."""
        if slot is None or slot.req.temperature <= 0.0:
            return int(np.argmax(row))
        z = row / slot.req.temperature
        z = z - np.max(z)
        p = np.exp(z)
        p /= np.sum(p)
        return int(slot.rng.choice(row.shape[0], p=p))

    def _admit(self) -> bool:
        """Form one wave from the queue head's bucket; False if queue empty."""
        if not self._queue:
            return False
        width = self.wave_width
        head = self._queue[0]
        bucket = bucket_for(head.prompt.shape[0], head.new_tokens,
                            self.config, width)
        taken: List[Request] = []
        kept: collections.deque = collections.deque()
        while self._queue:
            r = self._queue.popleft()
            same = bucket_for(r.prompt.shape[0], r.new_tokens, self.config,
                              width) == bucket
            if same and len(taken) < width:
                taken.append(r)
            else:
                kept.append(r)
        self._queue = kept

        fns = self.cache.fns(bucket, self.params)
        prompts = pad_prompts([r.prompt for r in taken], bucket,
                              self.config.pad_token)
        t_admit = time.perf_counter()
        logits, kv = fns.prefill(self.params, self._place(prompts))
        vocab = self.model.cfg.vocab
        last = np.asarray(logits[:, -1, :vocab])

        slots: List[_Slot] = []
        tok = np.zeros((bucket.batch, 1), np.int32)
        t_first = time.perf_counter()
        for i in range(bucket.batch):
            slot = None
            if i < len(taken):
                stats = self._stats_pending.pop(taken[i].rid)
                stats.bucket = bucket.key()
                stats.t_admitted = t_admit
                slot = _Slot(taken[i], stats)
                slots.append(slot)
            tok[i, 0] = self._sample_row(last[i], slot)
            if slot is not None:
                slot.tokens.append(int(tok[i, 0]))
                slot.stats.t_first_token = t_first
        wave = _Wave(bucket, slots, fns, kv, self._place(tok))
        self._finish_done(wave)
        if not wave.done:
            self._waves.append(wave)
        return True

    # --------------------------------------------------------------- decode

    def _step(self, wave: _Wave) -> None:
        logits, wave.cache = wave.fns.decode(self.params, wave.cache, wave.tok)
        vocab = self.model.cfg.vocab
        rows = np.asarray(logits[:, 0, :vocab])
        tok = np.zeros((wave.bucket.batch, 1), np.int32)
        t = time.perf_counter()
        for i in range(wave.bucket.batch):
            slot = wave.slots[i] if i < len(wave.slots) else None
            active = slot is not None and not slot.done
            tok[i, 0] = self._sample_row(rows[i], slot if active else None)
            if active:
                slot.tokens.append(int(tok[i, 0]))
                if slot.done:
                    slot.stats.t_finish = t
        wave.tok = self._place(tok)
        self._finish_done(wave)

    def _finish_done(self, wave: _Wave) -> None:
        t = time.perf_counter()
        for slot in wave.slots:
            if slot.done and slot.req.rid not in self._completed:
                if slot.stats.t_finish == 0.0:
                    slot.stats.t_finish = t
                slot.stats.energy_eu = (
                    self.per_token_energy_eu
                    * (slot.stats.prompt_len + slot.stats.new_tokens))
                self._completed[slot.req.rid] = RequestResult(
                    rid=slot.req.rid, tokens=slot.tokens, stats=slot.stats)
        if wave.done and wave in self._waves:
            self._waves.remove(wave)

    # ----------------------------------------------------------------- run

    def run(self) -> Dict[int, RequestResult]:
        """Drain the queue: admit + decode until every request completes."""
        t0 = time.perf_counter()
        while self._queue or self._waves:
            while self._queue and len(self._waves) < self.max_inflight:
                if not self._admit():
                    break
            for wave in list(self._waves):
                self._step(wave)
        self.last_wall_s = time.perf_counter() - t0
        self.total_wall_s += self.last_wall_s
        return dict(self._completed)

    def serve(self, prompts: Sequence[Sequence[int]],
              new_tokens) -> Dict[int, RequestResult]:
        """Convenience: submit a trace (per-request or shared new_tokens) and
        run it to completion."""
        if isinstance(new_tokens, int):
            new_tokens = [new_tokens] * len(prompts)
        rids = [self.submit(p, n) for p, n in zip(prompts, new_tokens)]
        out = self.run()
        return {rid: out[rid] for rid in rids}

    # -------------------------------------------------------------- reports

    @property
    def per_token_energy_eu(self) -> float:
        if self._e_per_token is None:
            self._e_per_token = per_token_energy(self.model, self.params,
                                                 self.comp)
        return self._e_per_token

    def artifacts(self):
        """Packed `ServeArtifact` tree + footprint summary (compressed only)."""
        return self.cache.artifacts(self.params)

    def report(self) -> dict:
        """Aggregate over every request completed so far (throughput uses the
        cumulative wall time of all `run()` calls)."""
        stats = [r.stats for r in self._completed.values()]
        return summarize(stats, self.total_wall_s, self.cache.stats())
