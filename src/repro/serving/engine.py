"""Continuous-batching serving engine over the compressed LM serving path.

``mode="engine"`` is slot-level continuous batching: requests enter a FIFO
queue and are admitted one *slot* at a time into persistent fixed-shape slot
groups (``max_batch`` rows x ``group_total_len`` cache positions, up to
``max_waves`` groups). The moment a slot's request finishes mid-decode it is
refilled from the queue head — no lockstep wave drain — and prompts are
prefilled in fixed-size *chunks* (``EngineConfig.chunk_buckets``) that
interleave with ongoing decode steps, so a long prompt never stalls the
group. Per-sequence positions in the decode cache (`repro.models.lm`) let
every row sit at its own depth; an ``active`` mask keeps empty/prefilling
rows' state untouched during decode. Admission is strictly FIFO over free
slots, so a deep-queue request can never starve the queue head.

The AOT zero-recompile contract survives: the slot engine compiles one
active-masked group decode plus one chunked-prefill executable per chunk
size — a small set fixed by the config, independent of request shapes — and
every executable rejects differently-shaped calls with a ``TypeError``
(`repro.serving.cache`).

``mode="wave"`` is the previous wave-lockstep scheduler, kept as the
measured baseline: fixed-shape waves padded to a `BucketSpec` that prefill
once and decode in lockstep, early-finishing slots idling until the wave
drains. ``mode="oneshot"`` is the single-shot fallback: the wave path
restricted to batch-1, one request at a time. All three modes share the
bucket padding contract and host-side sampling (greedy *and*
seeded-temperature draws are a pure function of the request's seed), so
cross-mode output parity holds token for token.

Accounting prices the compute actually performed, not the compute requested:
``executed_positions`` counts every padded/idle position pushed through the
array (prefill rows x padded length, chunk rows x chunk, decode batch per
step); `metrics.summarize` reports the gap to the per-request charge as
``energy_eu_overhead`` and a ``slot_utilization`` ratio. Slot-level refill
is the mechanism that drives that overhead toward zero.

The engine serves exactly one compression variant, identified by a
`repro.serving.fleet.PlanHandle` (``plan=``): the handle's comp tree drives
the compressed fake-quant forward, and its *content fingerprint* — not a
bare ``compress_k`` integer — keys the compile/artifact cache, so two plans
with equal k but different codebooks or ``msr_bits`` never share
executables. The packed 4-bit `ServeArtifact` tree is exported into the
cache for footprint/parity reporting, and per-request energy is charged via
the tile-level model (`repro.serving.metrics.per_token_energy`).
``ServingEngine(compress_k=...)`` survives as a deprecated shim that builds
the uniform-restriction handle internally. Multi-variant serving — routing
each request across several resident plans by load and budget — lives in
`repro.serving.fleet.FleetRouter`.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import time
import warnings
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.nn.layers import QuantConfig
from repro.serving.bucketing import (
    BucketSpec,
    EngineConfig,
    bucket_for,
    bucket_up,
    chunk_plan,
    pad_prompts,
)
from repro.serving.cache import ServeCompileCache
from repro.serving.metrics import RequestStats, per_token_energy, summarize


@dataclasses.dataclass(frozen=True)
class RequestBudget:
    """Per-request SLO caps. ``energy_eu_per_token`` bounds the serving
    variant's measured per-token MAC energy (a routing input for the fleet,
    see `repro.serving.fleet.FleetRouter`); ``latency_s`` bounds end-to-end
    request latency (evaluated post-hoc for the SLO hit-rate)."""

    energy_eu_per_token: Optional[float] = None
    latency_s: Optional[float] = None


@dataclasses.dataclass
class ServeRequest:
    """One serving request, the unit `ServingEngine.serve` and the fleet
    router accept. ``tokens`` is the prompt; ``tenant`` and ``budget`` feed
    the fleet's accounting and routing and are inert for a pinned engine."""

    tokens: Sequence[int]
    max_new_tokens: int
    tenant: str = "default"
    budget: Optional[RequestBudget] = None
    temperature: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    tenant: str = "default"
    budget: Optional[RequestBudget] = None


@dataclasses.dataclass
class ServeResult:
    rid: int
    tokens: List[int]             # exactly new_tokens entries
    stats: RequestStats


# the pre-fleet name; old call sites keep working unchanged
RequestResult = ServeResult


class _Slot:
    """One request's in-flight state (wave slot or slot-group row)."""

    def __init__(self, req: Request, stats: RequestStats):
        self.req = req
        self.stats = stats
        self.tokens: List[int] = []
        # the sampling stream is a pure function of the request's own seed
        # (not of engine-local ids), so all modes' draws agree; submit
        # distinct seeds for independent streams across requests
        self.rng = np.random.default_rng(req.seed)
        # chunked-prefill state (slot mode only)
        self.chunks: List[np.ndarray] = []
        self.next_chunk = 0
        self.start = 0                # padded positions already prefilled

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.req.new_tokens

    @property
    def prefilling(self) -> bool:
        return self.next_chunk < len(self.chunks)


class _Wave:
    """A fixed-shape micro-batch mid-decode (wave/oneshot modes)."""

    def __init__(self, bucket: BucketSpec, slots: List[_Slot], fns, cache,
                 tok):
        self.bucket = bucket
        self.slots = slots
        self.fns = fns
        self.cache = cache
        self.tok = tok            # (batch, 1) int32 device array

    @property
    def done(self) -> bool:
        return all(s.done for s in self.slots)


class _SlotGroup:
    """A persistent fixed-shape row group for slot-level batching."""

    def __init__(self, step, cache):
        self.step = step          # cache.GroupStep
        self.cache = cache
        self.slots: List[Optional[_Slot]] = [None] * step.batch
        self.tok = np.zeros((step.batch, 1), np.int32)

    @property
    def busy(self) -> bool:
        return any(s is not None for s in self.slots)


class ServingEngine:
    """Queue + micro-batcher + compile cache over one LM and its params."""

    def __init__(self, model, params, *, mode: str = "engine",
                 config: EngineConfig = EngineConfig(), plan=None,
                 compress_k: Optional[int] = None, comp=None,
                 arch: Optional[str] = None, mesh=None):
        if mode not in ("engine", "wave", "oneshot"):
            raise ValueError(
                f"mode must be 'engine', 'wave' or 'oneshot', got {mode!r}")
        self.model = model
        self.config = config
        self.mode = mode
        self.arch = arch if arch is not None else model.cfg.name

        from repro.serving.fleet import PlanHandle

        if plan is not None:
            if compress_k is not None or comp is not None:
                raise ValueError(
                    "pass either plan= or the deprecated compress_k=/comp=, "
                    "not both")
        elif compress_k is not None or comp is not None:
            warnings.warn(
                "ServingEngine(compress_k=..., comp=...) is deprecated; "
                "construct a repro.serving.fleet.PlanHandle and pass "
                "plan=handle (see docs/serving.md)",
                DeprecationWarning, stacklevel=2)
            k = int(compress_k or 0)
            if comp is not None:
                # pre-built comp tree (e.g. a CompressionPlan's codebooks)
                plan = PlanHandle.from_comp(
                    comp, compress_k=k, plan_id=f"k{k}" if k else "custom")
            else:
                plan = PlanHandle.from_compress_k(model, k)
        else:
            plan = PlanHandle.uncompressed()

        self.plan = plan
        self.comp = plan.comp
        self.compress_k = int(plan.compress_k)
        self.serve_units = 0
        if plan.comp is None:
            self.qcfg = QuantConfig.off()
        elif config.lut_serve:
            # Packed-LUT serving: attach real 4-bit serve artifacts to the
            # plan's comp tree and dispatch eligible matmuls to the fused
            # LUT GEMM. The plan fingerprint is already fixed (artifacts
            # are derived content and excluded from comp hashing).
            from repro.core.lm_compress import attach_serve_artifacts
            from repro.kernels.lut_matmul.ops import default_interpret

            use_ref = config.lut_use_ref
            if use_ref is None:
                use_ref = default_interpret()   # jnp oracle off-TPU
            if config.autotune_cache:
                from repro.kernels.lut_matmul.autotune import \
                    get_default_autotuner
                if os.path.exists(config.autotune_cache):
                    get_default_autotuner().load(config.autotune_cache)
            self.comp, self.serve_units = attach_serve_artifacts(
                model, params, plan.comp)
            if self.serve_units == 0:
                raise ValueError(
                    "lut_serve=True but no eligible unit in the plan's comp "
                    "tree is 4-bit servable (every codebook needs "
                    "0 < k <= 16)")
            self.qcfg = QuantConfig.serve(use_ref_kernel=use_ref)
        else:
            self.qcfg = QuantConfig.on()

        self.mesh = mesh
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            self._replicated = NamedSharding(mesh, PartitionSpec())
            params = jax.device_put(params, self._replicated)
        self.params = params

        if mode == "engine":
            self._check_chunkable()

        self.cache = ServeCompileCache(
            model, arch=self.arch, fingerprint=plan.fingerprint,
            compress_k=self.compress_k, qcfg=self.qcfg, comp=self.comp,
            config=config, place_prompts=self._place,
            place_replicated=self._place_rep)

        self._queue: collections.deque[Request] = collections.deque()
        self._waves: List[_Wave] = []
        self._groups: List[_SlotGroup] = []
        self._next_rid = 0
        self._stats_pending: Dict[int, RequestStats] = {}
        self._completed: Dict[int, RequestResult] = {}
        self._e_per_token: Optional[float] = None
        self.executed_positions = 0
        self.last_wall_s = 0.0
        self.total_wall_s = 0.0

    # --------------------------------------------------------- chunk gating

    def _check_chunkable(self) -> None:
        """Slot mode needs the chunk path; reject models it cannot serve."""
        cfg, ecfg = self.model.cfg, self.config
        if cfg.encoder_decoder:
            raise ValueError("slot-level batching has no chunk path for "
                             "encoder-decoder models; use mode='wave' or "
                             "'oneshot'")
        for bt in set(cfg.pattern):
            if bt in ("attn", "local"):
                window = cfg.attn_dims(bt == "local").window
                if 0 < window < ecfg.group_total_len:
                    raise ValueError(
                        f"slot-level batching needs the attention window "
                        f"({window}) to cover the group cache "
                        f"({ecfg.group_total_len}): chunked prefill cannot "
                        f"write through a ring buffer; use mode='wave'")
        recurrent = any(bt in ("rglru", "ssm") for bt in cfg.pattern)
        if recurrent and ecfg.chunk_buckets is not None:
            for p in ecfg.prompt_buckets:
                if chunk_plan(p, ecfg.chunk_buckets) != (p,):
                    raise ValueError(
                        "recurrent mixers (rglru/ssm) have no mid-sequence "
                        "state injection: chunk buckets must give every "
                        "prompt bucket a single-chunk plan")
        self._single_chunk_only = recurrent and self.config.chunk_buckets is None

    def _chunk_plan(self, padded_prompt: int) -> tuple:
        if getattr(self, "_single_chunk_only", False):
            return (padded_prompt,)
        return chunk_plan(padded_prompt, self.config.resolved_chunk_buckets)

    def _chunk_sizes(self) -> set:
        """The fixed executable set: every chunk size any prompt bucket
        plan uses."""
        sizes = set()
        for p in self.config.prompt_buckets:
            sizes.update(self._chunk_plan(p))
        return sizes

    # ------------------------------------------------------------ placement

    def _place(self, x):
        """Put a batch-major array on device (sharded over 'requests' when an
        optional serving mesh is attached and the batch divides it)."""
        x = jnp.asarray(x)
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec

        n = self.mesh.devices.size
        if x.ndim >= 1 and x.shape[0] % n == 0:
            spec = PartitionSpec("requests", *([None] * (x.ndim - 1)))
            return jax.device_put(x, NamedSharding(self.mesh, spec))
        return jax.device_put(x, self._replicated)

    def _place_rep(self, x):
        """Replicated placement for slot-group state: gather/scatter row
        shuffles make 'requests'-sharding the group cache unprofitable, so
        under a mesh the slot path runs replicated (wave/oneshot keep the
        sharded speedup)."""
        x = jnp.asarray(x)
        if self.mesh is None:
            return x
        return jax.device_put(x, self._replicated)

    # ------------------------------------------------------------ admission

    @property
    def wave_width(self) -> int:
        return 1 if self.mode == "oneshot" else self.config.max_batch

    @property
    def max_inflight(self) -> int:
        """Oneshot means one request at a time — no wave overlap either."""
        return 1 if self.mode == "oneshot" else self.config.max_waves

    def submit(self, prompt: Sequence[int], new_tokens: int, *,
               temperature: float = 0.0, seed: int = 0,
               tenant: str = "default",
               budget: Optional[RequestBudget] = None) -> int:
        """Enqueue one request; returns its request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, new_tokens=int(new_tokens),
                      temperature=float(temperature), seed=int(seed),
                      tenant=str(tenant), budget=budget)
        # validates the shape fits a bucket at submit time, not mid-run
        bucket_for(prompt.shape[0], req.new_tokens, self.config,
                   self.wave_width)
        self._queue.append(req)
        self._stats_pending[rid] = RequestStats(
            rid=rid, prompt_len=int(prompt.shape[0]),
            new_tokens=req.new_tokens, bucket=(),
            t_submit=time.perf_counter(), tenant=req.tenant,
            plan_id=self.plan.plan_id)
        return rid

    def submit_request(self, request: ServeRequest) -> int:
        """Enqueue one `ServeRequest`; returns its request id."""
        return self.submit(request.tokens, request.max_new_tokens,
                           temperature=request.temperature,
                           seed=request.seed, tenant=request.tenant,
                           budget=request.budget)

    @property
    def pending(self) -> int:
        """Requests submitted but not yet finished (queued + in flight) —
        the fleet router's queue-depth signal."""
        n = len(self._queue)
        if self.mode == "engine":
            n += sum(1 for g in self._groups for s in g.slots
                     if s is not None)
        else:
            n += sum(1 for w in self._waves for s in w.slots if not s.done)
        return n

    def result(self, rid: int) -> Optional[ServeResult]:
        """The finished result for ``rid``, or None while it is in flight."""
        return self._completed.get(rid)

    def warmup(self, shapes: Sequence[tuple]) -> dict:
        """Precompile every executable serving the (prompt_len, new_tokens)
        shapes needs, plus the per-token energy model; returns cache stats.
        After warmup, serving those shapes adds zero compiles and no lazy
        one-time costs. In slot mode the executable set (group decode + one
        step per chunk size) is fixed by the config, so warmup compiles it
        all regardless of the particular shapes."""
        for plen, ntok in shapes:
            bucket = bucket_for(plen, ntok, self.config, self.wave_width)
            if self.mode != "engine":
                self.cache.fns(bucket, self.params)
        if self.mode == "engine":
            self.cache.group_fns(self.params)
            for size in sorted(self._chunk_sizes()):
                for rows in self.config.chunk_row_buckets:
                    self.cache.chunk_fns(size, rows, self.params)
        _ = self.per_token_energy_eu
        if self.config.lut_serve and self.config.autotune_cache:
            # persist block winners discovered while compiling, so a warm
            # restart (or the CI cache) serves these shapes with zero retunes
            from repro.kernels.lut_matmul.autotune import get_default_autotuner
            get_default_autotuner().save(self.config.autotune_cache)
        return self.cache.stats()

    def _sample_row(self, row: np.ndarray, slot: Optional[_Slot]) -> int:
        """Host-side sampling — shared by all modes, so parity is exact."""
        if slot is None or slot.req.temperature <= 0.0:
            return int(np.argmax(row))
        z = row / slot.req.temperature
        z = z - np.max(z)
        p = np.exp(z)
        p /= np.sum(p)
        return int(slot.rng.choice(row.shape[0], p=p))

    def _admit(self) -> bool:
        """Form one wave from the queue head's bucket; False if queue empty.

        Wave/oneshot only: scans the whole queue for bucket-mates of the
        head request (the head itself is always admitted, so the scan cannot
        starve it)."""
        if not self._queue:
            return False
        width = self.wave_width
        head = self._queue[0]
        bucket = bucket_for(head.prompt.shape[0], head.new_tokens,
                            self.config, width)
        taken: List[Request] = []
        kept: collections.deque = collections.deque()
        while self._queue:
            r = self._queue.popleft()
            same = bucket_for(r.prompt.shape[0], r.new_tokens, self.config,
                              width) == bucket
            if same and len(taken) < width:
                taken.append(r)
            else:
                kept.append(r)
        self._queue = kept

        fns = self.cache.fns(bucket, self.params)
        prompts = pad_prompts([r.prompt for r in taken], bucket,
                              self.config.pad_token)
        t_admit = time.perf_counter()
        logits, kv = fns.prefill(self.params, self._place(prompts))
        self.executed_positions += bucket.batch * bucket.prompt_len
        vocab = self.model.cfg.vocab
        last = np.asarray(logits[:, -1, :vocab])

        slots: List[_Slot] = []
        tok = np.zeros((bucket.batch, 1), np.int32)
        t_first = time.perf_counter()
        for i in range(bucket.batch):
            slot = None
            if i < len(taken):
                stats = self._stats_pending.pop(taken[i].rid)
                stats.bucket = bucket.key()
                stats.t_admitted = t_admit
                slot = _Slot(taken[i], stats)
                slots.append(slot)
            tok[i, 0] = self._sample_row(last[i], slot)
            if slot is not None:
                slot.tokens.append(int(tok[i, 0]))
                slot.stats.t_first_token = t_first
        wave = _Wave(bucket, slots, fns, kv, self._place(tok))
        self._finish_done(wave)
        if not wave.done:
            self._waves.append(wave)
        return True

    # ------------------------------------------------- decode (wave modes)

    def _step(self, wave: _Wave) -> None:
        logits, wave.cache = wave.fns.decode(self.params, wave.cache, wave.tok)
        self.executed_positions += wave.bucket.batch
        vocab = self.model.cfg.vocab
        rows = np.asarray(logits[:, 0, :vocab])
        tok = np.zeros((wave.bucket.batch, 1), np.int32)
        t = time.perf_counter()
        for i in range(wave.bucket.batch):
            slot = wave.slots[i] if i < len(wave.slots) else None
            active = slot is not None and not slot.done
            tok[i, 0] = self._sample_row(rows[i], slot if active else None)
            if active:
                slot.tokens.append(int(tok[i, 0]))
                if slot.done:
                    slot.stats.t_finish = t
        wave.tok = self._place(tok)
        self._finish_done(wave)

    def _finish_done(self, wave: _Wave) -> None:
        t = time.perf_counter()
        for slot in wave.slots:
            if slot.done and slot.req.rid not in self._completed:
                if slot.stats.t_finish is None:
                    slot.stats.t_finish = t
                self._complete(slot)
        if wave.done and wave in self._waves:
            self._waves.remove(wave)

    def _complete(self, slot: _Slot) -> None:
        slot.stats.energy_eu = (
            self.per_token_energy_eu
            * (slot.stats.prompt_len + slot.stats.new_tokens))
        self._completed[slot.req.rid] = RequestResult(
            rid=slot.req.rid, tokens=slot.tokens, stats=slot.stats)

    # ------------------------------------------------- scheduler (slot mode)

    def _make_slot(self, req: Request) -> _Slot:
        stats = self._stats_pending.pop(req.rid)
        cfg = self.config
        p = bucket_up(req.prompt.shape[0], cfg.prompt_buckets)
        n = bucket_up(req.new_tokens, cfg.new_token_buckets)
        stats.bucket = (1, p, p + n)    # slot-level: one row, own depths
        stats.t_admitted = time.perf_counter()
        slot = _Slot(req, stats)
        padded = np.full((p,), cfg.pad_token, np.int32)
        padded[:req.prompt.shape[0]] = req.prompt
        off = 0
        for size in self._chunk_plan(p):
            slot.chunks.append(padded[off:off + size])
            off += size
        return slot

    def _refill_slots(self) -> None:
        """Strict-FIFO admission into free slots; grows the group list up to
        ``max_waves`` groups when the queue still has depth."""
        for g in self._groups:
            for i in range(g.step.batch):
                if not self._queue:
                    return
                if g.slots[i] is None:
                    g.slots[i] = self._make_slot(self._queue.popleft())
        while self._queue and len(self._groups) < self.max_inflight:
            step = self.cache.group_fns(self.params)
            g = _SlotGroup(step, step.make_cache())
            self._groups.append(g)
            for i in range(g.step.batch):
                if not self._queue:
                    break
                g.slots[i] = self._make_slot(self._queue.popleft())

    def _chunk_steps(self, g: _SlotGroup) -> bool:
        """Advance every prefilling slot of the group by one chunk."""
        pending = [i for i, s in enumerate(g.slots)
                   if s is not None and s.prefilling]
        if not pending:
            return False
        by_size: Dict[int, List[int]] = {}
        for i in pending:
            s = g.slots[i]
            by_size.setdefault(len(s.chunks[s.next_chunk]), []).append(i)
        cap = self.config.resolved_chunk_rows
        for size, rows in sorted(by_size.items()):
            for j0 in range(0, len(rows), cap):
                batch = rows[j0:j0 + cap]
                # narrowest compiled row width that fits this refill batch,
                # so a single freed slot costs a 1-row chunk dispatch
                width = bucket_up(len(batch), self.config.chunk_row_buckets)
                self._chunk_call(g, self.cache.chunk_fns(size, width,
                                                         self.params), batch)
        return True

    def _chunk_call(self, g: _SlotGroup, step, rows: List[int]) -> None:
        size, n_rows = step.chunk, step.rows
        toks = np.full((n_rows, size), self.config.pad_token, np.int32)
        row_ids = np.zeros((n_rows,), np.int32)
        start = np.zeros((n_rows,), np.int32)
        active = np.zeros((n_rows,), bool)
        for j, r in enumerate(rows):
            s = g.slots[r]
            toks[j] = s.chunks[s.next_chunk]
            row_ids[j], start[j], active[j] = r, s.start, True
        logits, g.cache = step.fn(
            self.params, g.cache, self._place_rep(toks),
            self._place_rep(row_ids), self._place_rep(start),
            self._place_rep(active))
        self.executed_positions += n_rows * size
        finishing = [j for j, r in enumerate(rows)
                     if g.slots[r].next_chunk + 1 == len(g.slots[r].chunks)]
        last = None
        if finishing:
            vocab = self.model.cfg.vocab
            last = np.asarray(logits[:, :vocab])
        t = time.perf_counter()
        for j, r in enumerate(rows):
            s = g.slots[r]
            s.next_chunk += 1
            s.start += size
            if not s.prefilling:
                tok = self._sample_row(last[j], s)
                s.tokens.append(tok)
                s.stats.t_first_token = t
                g.tok[r, 0] = tok
                if s.done:
                    s.stats.t_finish = t
                    self._complete(s)
                    g.slots[r] = None

    def _decode_group(self, g: _SlotGroup) -> bool:
        """One decode step over the group's rows that hold decoding slots."""
        rows = [i for i, s in enumerate(g.slots)
                if s is not None and not s.prefilling]
        if not rows:
            return False
        act = np.zeros((g.step.batch,), bool)
        act[rows] = True
        logits, g.cache = g.step.decode(
            self.params, g.cache, self._place_rep(g.tok),
            self._place_rep(act))
        self.executed_positions += g.step.batch
        vocab = self.model.cfg.vocab
        out = np.asarray(logits[:, 0, :vocab])
        t = time.perf_counter()
        for r in rows:
            s = g.slots[r]
            tok = self._sample_row(out[r], s)
            s.tokens.append(tok)
            g.tok[r, 0] = tok
            if s.done:
                s.stats.t_finish = t
                self._complete(s)
                g.slots[r] = None
        return True

    # ----------------------------------------------------------------- run

    def step(self) -> bool:
        """Advance the scheduler by one iteration; False when idle.

        One iteration is one refill + chunk + decode pass (slot mode) or one
        admit + lockstep-decode pass (wave/oneshot). The fleet router drains
        several engines by interleaving their steps so no variant
        head-of-line blocks another."""
        if self.mode == "engine":
            if not (self._queue or any(g.busy for g in self._groups)):
                return False
            self._refill_slots()
            for g in self._groups:
                self._chunk_steps(g)
            for g in self._groups:
                self._decode_group(g)
            return True
        if not (self._queue or self._waves):
            return False
        while self._queue and len(self._waves) < self.max_inflight:
            if not self._admit():
                break
        for wave in list(self._waves):
            self._step(wave)
        return True

    def run(self) -> Dict[int, ServeResult]:
        """Drain the queue: admit + decode until every request completes."""
        t0 = time.perf_counter()
        while self.step():
            pass
        self.last_wall_s = time.perf_counter() - t0
        self.total_wall_s += self.last_wall_s
        return dict(self._completed)

    def serve(self, requests: Union[Sequence[ServeRequest],
                                    Sequence[Sequence[int]]],
              new_tokens=None):
        """Submit a batch and run it to completion.

        The current form takes a sequence of `ServeRequest` and returns the
        `ServeResult`s **in submission order** (a list). The pre-fleet form
        ``serve(prompts, new_tokens)`` still works — it constructs requests
        internally and returns the old ``{rid: ServeResult}`` dict — but
        emits a DeprecationWarning.
        """
        requests = list(requests)
        if new_tokens is None and all(isinstance(r, ServeRequest)
                                      for r in requests):
            rids = [self.submit_request(r) for r in requests]
            out = self.run()
            return [out[rid] for rid in rids]
        warnings.warn(
            "ServingEngine.serve(prompts, new_tokens) is deprecated; pass a "
            "sequence of ServeRequest (see docs/serving.md)",
            DeprecationWarning, stacklevel=2)
        if new_tokens is None:
            raise ValueError(
                "serve() needs ServeRequest entries or (prompts, new_tokens)")
        if isinstance(new_tokens, int):
            new_tokens = [new_tokens] * len(requests)
        if len(new_tokens) != len(requests):
            raise ValueError(
                f"got {len(requests)} prompts but {len(new_tokens)} "
                f"new_tokens entries; zip would silently drop requests")
        rids = [self.submit(p, n) for p, n in zip(requests, new_tokens)]
        out = self.run()
        return {rid: out[rid] for rid in rids}

    # -------------------------------------------------------------- reports

    @property
    def per_token_energy_eu(self) -> float:
        if self._e_per_token is None:
            self._e_per_token = per_token_energy(self.model, self.params,
                                                 self.comp)
        return self._e_per_token

    def artifacts(self):
        """Packed `ServeArtifact` tree + footprint summary (compressed only)."""
        return self.cache.artifacts(self.params)

    def report(self) -> dict:
        """Aggregate over every request completed so far (throughput uses the
        cumulative wall time of all `run()` calls)."""
        stats = [r.stats for r in self._completed.values()]
        return summarize(stats, self.total_wall_s, self.cache.stats(),
                         executed_positions=self.executed_positions,
                         per_token_energy_eu=self.per_token_energy_eu)
