"""Shape buckets for the continuous-batching serving engine.

The engine never compiles per request: every request is mapped to a
`BucketSpec` — a fixed ``(batch, prompt_len, total_len)`` triple — and the
compile cache holds exactly one (prefill, decode) executable pair per bucket.
Prompts are right-padded with ``pad_token`` up to the bucket prompt length
and generation starts at position ``prompt_len`` (the padded length) for
every request in the bucket; batches are padded with inert dummy rows. This
"pad-to-bucket" contract is part of the serving semantics (the fixed-shape
engine has no per-token attention masking), and it is shared bit-for-bit by
``mode="engine"`` and the ``mode="oneshot"`` fallback, so the two modes stay
output-identical. A request whose prompt exactly fills its bucket reproduces
the unpadded `repro.launch.serve.generate` path exactly (tested).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """One fixed compile shape: batch rows, padded prompt, total cache len."""

    batch: int
    prompt_len: int     # padded prompt length (generation starts here)
    total_len: int      # prompt_len + padded new-token budget

    @property
    def new_tokens(self) -> int:
        return self.total_len - self.prompt_len

    def key(self) -> Tuple[int, int, int]:
        return (self.batch, self.prompt_len, self.total_len)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine knobs (hashable; part of no compile key — buckets are).

    Validated in ``__post_init__``: bucket tuples must be non-empty tuples of
    distinct positive ints and the scalar knobs must be >= 1, so a bad config
    fails at construction instead of as a confusing `bucket_up`/compile error
    mid-serve.
    """

    max_batch: int = 8                 # slot-group width (wave width in wave mode)
    prompt_buckets: Tuple[int, ...] = (16, 32, 64)
    new_token_buckets: Tuple[int, ...] = (16, 32)
    max_waves: int = 2                 # in-flight slot groups / decode waves
    pad_token: int = 0
    q_block: int = 8                   # prefill attention tiling (CPU-sized)
    kv_block: int = 8
    cache_dtype: str = "float32"
    # chunked prefill: sizes a padded prompt bucket is split into (None ->
    # one size, the gcd of the prompt buckets) and how many rows one chunk
    # executable carries (0 -> max(1, max_batch // 2))
    chunk_buckets: Optional[Tuple[int, ...]] = None
    chunk_rows: int = 0
    # packed-LUT serving: dispatch compressed plans to the fused 4-bit
    # LUT GEMM (attention QKV/out, FFN, decode hot loop) instead of the
    # fake-quant dense path. ``lut_use_ref=None`` resolves per backend
    # (jnp oracle off-TPU, compiled Pallas on TPU); ``autotune_cache``
    # names a JSON file of block-shape winners loaded at construction and
    # saved after warmup so a warm restart never retunes.
    lut_serve: bool = False
    lut_use_ref: Optional[bool] = None
    autotune_cache: Optional[str] = None

    def __post_init__(self):
        if not isinstance(self.lut_serve, bool):
            raise ValueError(f"EngineConfig.lut_serve must be a bool, "
                             f"got {self.lut_serve!r}")
        if self.lut_use_ref is not None \
                and not isinstance(self.lut_use_ref, bool):
            raise ValueError(f"EngineConfig.lut_use_ref must be None or a "
                             f"bool, got {self.lut_use_ref!r}")
        if self.autotune_cache is not None \
                and not isinstance(self.autotune_cache, str):
            raise ValueError(f"EngineConfig.autotune_cache must be None or a "
                             f"path string, got {self.autotune_cache!r}")
        for name in ("max_batch", "max_waves", "q_block", "kv_block"):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(f"EngineConfig.{name} must be an int >= 1, "
                                 f"got {v!r}")
        if not isinstance(self.chunk_rows, int) \
                or isinstance(self.chunk_rows, bool) or self.chunk_rows < 0:
            raise ValueError(f"EngineConfig.chunk_rows must be an int >= 0 "
                             f"(0 = auto), got {self.chunk_rows!r}")
        _check_bucket_tuple("prompt_buckets", self.prompt_buckets)
        _check_bucket_tuple("new_token_buckets", self.new_token_buckets)
        if self.chunk_buckets is not None:
            _check_bucket_tuple("chunk_buckets", self.chunk_buckets)
            for p in self.prompt_buckets:
                chunk_plan(p, self.chunk_buckets)   # raises if no exact cover

    @property
    def resolved_chunk_buckets(self) -> Tuple[int, ...]:
        if self.chunk_buckets is not None:
            return tuple(sorted(self.chunk_buckets))
        return (functools.reduce(math.gcd, self.prompt_buckets),)

    @property
    def resolved_chunk_rows(self) -> int:
        rows = self.chunk_rows or max(1, self.max_batch // 2)
        return min(rows, self.max_batch)

    @property
    def chunk_row_buckets(self) -> Tuple[int, ...]:
        """Row widths the chunk executables are compiled at: powers of two
        up to ``resolved_chunk_rows`` (plus the cap itself). Refilling a
        single freed slot then costs a 1-row chunk, not a full-width one."""
        cap = self.resolved_chunk_rows
        out = []
        r = 1
        while r < cap:
            out.append(r)
            r *= 2
        out.append(cap)
        return tuple(out)

    @property
    def group_total_len(self) -> int:
        """Cache length of one slot group: any admissible request fits."""
        return max(self.prompt_buckets) + max(self.new_token_buckets)

    @property
    def slot_capacity(self) -> int:
        """Concurrent requests one engine can hold in flight (all groups
        full) — the fleet router's queue-pressure denominator."""
        return self.max_batch * self.max_waves


def _check_bucket_tuple(name: str, t) -> None:
    if not isinstance(t, tuple) or not t:
        raise ValueError(f"EngineConfig.{name} must be a non-empty tuple, "
                         f"got {t!r}")
    for b in t:
        if not isinstance(b, int) or isinstance(b, bool) or b < 1:
            raise ValueError(f"EngineConfig.{name} entries must be ints >= 1, "
                             f"got {t!r}")
    if len(set(t)) != len(t):
        raise ValueError(f"EngineConfig.{name} has duplicate buckets: {t!r}")


def chunk_plan(prompt_len: int, chunks: Sequence[int]) -> Tuple[int, ...]:
    """Greedy largest-first exact decomposition of a padded prompt bucket
    into chunk sizes; raises when the sizes cannot cover it exactly."""
    out = []
    rem = int(prompt_len)
    for c in sorted(chunks, reverse=True):
        while rem >= c:
            out.append(int(c))
            rem -= c
    if rem:
        raise ValueError(f"chunk buckets {tuple(sorted(chunks))} cannot "
                         f"exactly cover prompt bucket {prompt_len} "
                         f"(greedy remainder {rem})")
    return tuple(out)


def bucket_up(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n; raises if the request doesn't fit any bucket."""
    for b in sorted(buckets):
        if n <= b:
            return int(b)
    raise ValueError(f"no bucket >= {n} in {tuple(sorted(buckets))}")


def bucket_for(prompt_len: int, new_tokens: int, cfg: EngineConfig,
               batch: int) -> BucketSpec:
    """Map a request shape to its compile bucket at the given wave width."""
    if prompt_len < 1 or new_tokens < 1:
        raise ValueError(f"need prompt_len>=1, new_tokens>=1, got "
                         f"({prompt_len}, {new_tokens})")
    p = bucket_up(prompt_len, cfg.prompt_buckets)
    n = bucket_up(new_tokens, cfg.new_token_buckets)
    return BucketSpec(batch=batch, prompt_len=p, total_len=p + n)


def pad_prompts(prompts: Sequence[Sequence[int]], bucket: BucketSpec,
                pad_token: int) -> np.ndarray:
    """Right-pad prompts to the bucket prompt length and the batch with
    all-pad dummy rows; returns (bucket.batch, bucket.prompt_len) int32."""
    if len(prompts) > bucket.batch:
        raise ValueError(f"{len(prompts)} prompts > bucket batch {bucket.batch}")
    out = np.full((bucket.batch, bucket.prompt_len), pad_token, np.int32)
    for i, p in enumerate(prompts):
        p = np.asarray(p, np.int32)
        if p.ndim != 1 or p.shape[0] > bucket.prompt_len:
            raise ValueError(f"prompt {i} shape {p.shape} does not fit "
                             f"bucket prompt_len {bucket.prompt_len}")
        out[i, :p.shape[0]] = p
    return out
