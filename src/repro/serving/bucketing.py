"""Shape buckets for the continuous-batching serving engine.

The engine never compiles per request: every request is mapped to a
`BucketSpec` — a fixed ``(batch, prompt_len, total_len)`` triple — and the
compile cache holds exactly one (prefill, decode) executable pair per bucket.
Prompts are right-padded with ``pad_token`` up to the bucket prompt length
and generation starts at position ``prompt_len`` (the padded length) for
every request in the bucket; batches are padded with inert dummy rows. This
"pad-to-bucket" contract is part of the serving semantics (the fixed-shape
engine has no per-token attention masking), and it is shared bit-for-bit by
``mode="engine"`` and the ``mode="oneshot"`` fallback, so the two modes stay
output-identical. A request whose prompt exactly fills its bucket reproduces
the unpadded `repro.launch.serve.generate` path exactly (tested).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """One fixed compile shape: batch rows, padded prompt, total cache len."""

    batch: int
    prompt_len: int     # padded prompt length (generation starts here)
    total_len: int      # prompt_len + padded new-token budget

    @property
    def new_tokens(self) -> int:
        return self.total_len - self.prompt_len

    def key(self) -> Tuple[int, int, int]:
        return (self.batch, self.prompt_len, self.total_len)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine knobs (hashable; part of no compile key — buckets are)."""

    max_batch: int = 8                 # wave width in engine mode
    prompt_buckets: Tuple[int, ...] = (16, 32, 64)
    new_token_buckets: Tuple[int, ...] = (16, 32)
    max_waves: int = 2                 # in-flight decode waves
    pad_token: int = 0
    q_block: int = 8                   # prefill attention tiling (CPU-sized)
    kv_block: int = 8
    cache_dtype: str = "float32"


def bucket_up(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n; raises if the request doesn't fit any bucket."""
    for b in sorted(buckets):
        if n <= b:
            return int(b)
    raise ValueError(f"no bucket >= {n} in {tuple(sorted(buckets))}")


def bucket_for(prompt_len: int, new_tokens: int, cfg: EngineConfig,
               batch: int) -> BucketSpec:
    """Map a request shape to its compile bucket at the given wave width."""
    if prompt_len < 1 or new_tokens < 1:
        raise ValueError(f"need prompt_len>=1, new_tokens>=1, got "
                         f"({prompt_len}, {new_tokens})")
    p = bucket_up(prompt_len, cfg.prompt_buckets)
    n = bucket_up(new_tokens, cfg.new_token_buckets)
    return BucketSpec(batch=batch, prompt_len=p, total_len=p + n)


def pad_prompts(prompts: Sequence[Sequence[int]], bucket: BucketSpec,
                pad_token: int) -> np.ndarray:
    """Right-pad prompts to the bucket prompt length and the batch with
    all-pad dummy rows; returns (bucket.batch, bucket.prompt_len) int32."""
    if len(prompts) > bucket.batch:
        raise ValueError(f"{len(prompts)} prompts > bucket batch {bucket.batch}")
    out = np.full((bucket.batch, bucket.prompt_len), pad_token, np.int32)
    for i, p in enumerate(prompts):
        p = np.asarray(p, np.int32)
        if p.ndim != 1 or p.shape[0] > bucket.prompt_len:
            raise ValueError(f"prompt {i} shape {p.shape} does not fit "
                             f"bucket prompt_len {bucket.prompt_len}")
        out[i, :p.shape[0]] = p
    return out
