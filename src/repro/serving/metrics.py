"""Per-request accounting for the serving engine.

Latency, time-to-first-token, throughput, and an estimated MAC energy per
request. The energy estimate extends the paper's tile-level layer model
(`repro.core.layer_energy`) to serving traffic: every eligible LM matmul
contributes

    E_unit(1 token) = sum_w counts_padded(w) * LUT(w) * 2T * ceil(1/64 tiles)

with ``counts_padded`` the int8-projected weight histogram (codebook
restriction applied when the engine serves compressed) and LUT the
traffic-agnostic `repro.core.energy_lut.uniform_trace_lut` (no profiled
activation statistics exist at serve time). A request is charged
``per_token_energy * (prompt_len + new_tokens)`` — the token positions it
actually pushed through the array. Energies are tile-granular (n is rounded
up to one 64-column tile), consistent with the training-side model.

The per-request charge deliberately excludes padded/idle work. The engine
tracks the positions it *actually executed* (padding rows, idle lockstep
slots, chunk padding) separately; `summarize` exposes the gap as
``energy_eu_overhead`` — the energy spent on positions no request was
charged for — plus a ``slot_utilization`` ratio (charged / executed
positions). Slot-level continuous batching exists to push that ratio
toward 1.0.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp


@dataclasses.dataclass
class RequestStats:
    """Timing/energy record for one served request (times are wall-clock
    seconds from a shared origin)."""

    rid: int
    prompt_len: int
    new_tokens: int
    bucket: tuple            # BucketSpec.key()
    # lifecycle timestamps stay None until the event happens — 0.0 is a
    # valid perf_counter reading, not a usable "unset" sentinel
    t_submit: Optional[float] = None
    t_admitted: Optional[float] = None   # prefill of this request started
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    energy_eu: float = 0.0
    # fleet accounting: who asked, and which resident plan served it
    tenant: str = "default"
    plan_id: str = ""

    @property
    def latency_s(self) -> float:
        if self.t_finish is None or self.t_submit is None:
            raise ValueError(f"request {self.rid} has not finished; "
                             f"latency_s is undefined")
        return self.t_finish - self.t_submit

    @property
    def ttft_s(self) -> float:
        if self.t_first_token is None or self.t_submit is None:
            raise ValueError(f"request {self.rid} has no first token yet; "
                             f"ttft_s is undefined")
        return self.t_first_token - self.t_submit


def percentile(values: List[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]); 0.0 on empty input."""
    if not values:
        return 0.0
    xs = sorted(values)
    if len(xs) == 1:
        return float(xs[0])
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


def summarize(stats: List[RequestStats], wall_s: float,
              cache_stats: Optional[dict] = None, *,
              executed_positions: Optional[int] = None,
              per_token_energy_eu: Optional[float] = None) -> Dict:
    """Aggregate report over a set of completed requests.

    ``executed_positions`` (with ``per_token_energy_eu``) adds the
    padded-work accounting: ``slot_utilization`` = charged / executed
    positions and ``energy_eu_overhead`` = energy of the executed positions
    no request was charged for.
    """
    lat = [s.latency_s for s in stats]
    ttft = [s.ttft_s for s in stats]
    new_tokens = sum(s.new_tokens for s in stats)
    all_tokens = sum(s.prompt_len + s.new_tokens for s in stats)
    out = {
        "requests": len(stats),
        "wall_s": wall_s,
        "new_tokens": new_tokens,
        "total_tokens": all_tokens,
        "tokens_per_s": new_tokens / wall_s if wall_s > 0 else 0.0,
        "latency_p50_s": percentile(lat, 50),
        "latency_p90_s": percentile(lat, 90),
        "latency_p99_s": percentile(lat, 99),
        "ttft_p50_s": percentile(ttft, 50),
        "ttft_p90_s": percentile(ttft, 90),
        "ttft_p99_s": percentile(ttft, 99),
        "energy_eu_total": sum(s.energy_eu for s in stats),
        "energy_eu_per_token": (sum(s.energy_eu for s in stats)
                                / max(all_tokens, 1)),
    }
    if executed_positions is not None:
        executed = int(executed_positions)
        out["executed_positions"] = executed
        out["slot_utilization"] = (all_tokens / executed) if executed else 0.0
        if per_token_energy_eu is not None:
            idle = max(executed - all_tokens, 0)
            out["energy_eu_overhead"] = float(per_token_energy_eu) * idle
    if cache_stats:
        out.update({f"cache_{k}": v for k, v in cache_stats.items()})
    return out


# ------------------------------------------------------------------ energy


def per_token_energy(model, params, comp=None) -> float:
    """Estimated MAC energy (eu) of pushing one token position through every
    eligible LM matmul, on the paper's 64x64 weight-stationary array."""
    from repro.core import qat
    from repro.core.energy_lut import uniform_trace_lut
    from repro.core.layer_energy import (
        dense_matmul_dims,
        layer_energy_from_counts,
        weight_value_counts,
    )
    from repro.core.lm_compress import iter_eligible_units

    lut = uniform_trace_lut()
    total = jnp.zeros((), jnp.float32)
    for _name, w, c, layout in iter_eligible_units(model, params, comp):
        w_int = qat.quantize_weight_int(w, c)
        if layout == "in_first":
            mat = w_int.reshape(w_int.shape[0], -1)
        else:
            mat = w_int.reshape(-1, w_int.shape[-1])
        dims = dense_matmul_dims(fan_in=mat.shape[0], fan_out=mat.shape[1],
                                 n_tokens=1)
        counts = weight_value_counts(mat.T, dims)  # (M, K) layout for padding
        total = total + layer_energy_from_counts(counts, lut, dims)
    return float(total)
