"""Differential tests: transition-energy kernel vs. the bit-accurate cosim.

The cosim (`repro.cosim`) recomputes the 22-bit partial-sum transition
histogram from a cycle-accurate PE-array model with independent bit
primitives (no clz / population_count, integer scatter histograms). These
tests assert the Pallas kernel (interpret mode) and the vectorized jnp
oracle reproduce it EXACTLY — bin for bin — across random tiles, sign
patterns, and adversarial corner cases, and pin the `_msb22`/`_group_id`
edge-case semantics of the kernel with exact-value checks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stats import TILE, tile_psum_trace
from repro.cosim import (
    MASK22,
    bits22,
    pe_array_trace,
    ref_group_id,
    ref_msb_val22,
    ref_popcount22,
    tile_cosim_stats,
    verify_tiles,
)
from repro.kernels.transition_energy.transition_energy import (
    N_HD_SUBGROUPS,
    N_MSB_GROUPS,
    _group_id,
    _msb22,
)


# ------------------------------------------------- cycle-accurate PE model


@pytest.mark.parametrize("k,m,t", [(64, 64, 33), (64, 64, 8), (16, 8, 5)])
def test_cycle_trace_equals_prefix_sum_trace(k, m, t):
    """The skewed cycle-by-cycle register trace must visit exactly the
    unskewed prefix sums S[r, c, t], in t-order, per PE."""
    key = jax.random.PRNGKey(k + m + t)
    w = jax.random.randint(key, (k, m), -128, 128, dtype=jnp.int32)
    a = jax.random.randint(jax.random.fold_in(key, 1), (k, t), -128, 128,
                           dtype=jnp.int32)
    got = pe_array_trace(w, a)
    want = tile_psum_trace(w, a)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cycle_trace_hand_computed():
    """2x2 array, 2-element stream, checked by hand."""
    w = jnp.asarray([[1, -2], [3, 4]], jnp.int32)
    a = jnp.asarray([[5, -6], [7, 8]], jnp.int32)
    got = np.asarray(pe_array_trace(w, a))
    # S[0, c, t] = w[0, c] * a[0, t]; S[1, c, t] = S[0, c, t] + w[1, c]*a[1, t]
    want = np.asarray([[[5, -6], [-10, 12]],
                       [[5 + 21, -6 + 24], [-10 + 28, 12 + 32]]])
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------- independent bit primitives


def test_ref_popcount22_exact():
    vals = np.asarray([0, 1, 3, MASK22, 1 << 21, (1 << 22) | 1, -1],
                      np.int32)
    # -1 masks to MASK22 (22 ones); bit 22 is cleared before counting
    want = [0, 1, 2, 22, 1, 1, 22]
    np.testing.assert_array_equal(
        np.asarray(ref_popcount22(jnp.asarray(vals))), want)


def test_ref_msb_val22_exact():
    vals = np.asarray([0, 1, 2, 3, 1 << 21, MASK22, 1 << 22, -1], np.int32)
    want = [0, 1, 2, 2, 22, 22, 0, 22]   # 1<<22 masks to 0
    np.testing.assert_array_equal(
        np.asarray(ref_msb_val22(jnp.asarray(vals))), want)


def test_ref_primitives_match_kernel_on_all_boundaries():
    """ref (threshold sums) vs kernel (clz / popcount intrinsics) on every
    power of two, every all-ones run, and random values."""
    probe = [0] + [1 << b for b in range(23)] + \
        [(1 << b) - 1 for b in range(1, 23)] + [-1, -2, MASK22, 1 << 22]
    probe += list(np.random.RandomState(0).randint(-(2 ** 31), 2 ** 31 - 1,
                                                   512).astype(np.int64))
    x = jnp.asarray(np.asarray(probe, np.int64).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(ref_msb_val22(x)),
                                  np.asarray(_msb22(x) + 1))
    np.testing.assert_array_equal(np.asarray(ref_group_id(x)),
                                  np.asarray(_group_id(x)))


# -------------------------------------- pinned kernel edge-case semantics


def test_msb22_pinned_values():
    """_msb22 semantics the energy model depends on, as exact values:
    clz on the masked-zero value returns -1 (so msb_val = 0), the mask
    clears bit 22 and above, and negatives see their 22-bit view."""
    cases = {0: -1, 1: 0, 2: 1, 3: 1, MASK22: 21, 1 << 21: 21,
             1 << 22: -1, (1 << 22) | 5: 2, -1: 21}
    for v, want in cases.items():
        assert int(_msb22(jnp.asarray(v, jnp.int32))) == want, v


def test_msb_group_boundary_table():
    """mg = min(msb_val * N_MSB_GROUPS // 23, 9) pinned over every possible
    msb_val 0..22 — including the group-9 ceiling at msb_val 21 and 22."""
    want_mg = [min(mv * N_MSB_GROUPS // 23, N_MSB_GROUPS - 1)
               for mv in range(23)]
    assert want_mg == [0, 0, 0, 1, 1, 2, 2, 3, 3, 3, 4, 4, 5, 5, 6, 6, 6,
                      7, 7, 8, 8, 9, 9]
    # psum with msb_val = mv (0 -> value 0); hw of these probes is 1 (or 0)
    for mv in range(23):
        p = jnp.asarray(0 if mv == 0 else 1 << (mv - 1), jnp.int32)
        gid = int(_group_id(p))
        assert gid // N_HD_SUBGROUPS == want_mg[mv], mv
        assert gid == int(ref_group_id(p)), mv


def test_hd_subgroup_boundary_table():
    """hg = min(hw * N_HD_SUBGROUPS // 23, 4) pinned over every possible
    Hamming weight 0..22 via all-ones runs (hw = run length)."""
    for hw in range(23):
        p = jnp.asarray((1 << hw) - 1, jnp.int32)   # hw ones
        want_hg = min(hw * N_HD_SUBGROUPS // 23, N_HD_SUBGROUPS - 1)
        gid = int(_group_id(p))
        assert gid % N_HD_SUBGROUPS == want_hg, hw
        assert gid == int(ref_group_id(p)), hw


# --------------------------------------------- randomized differential sweep


def _rand_tiles(key, n, t_len, lo, hi, dtype=jnp.int32):
    kw, ka = jax.random.split(key)
    w = jax.random.randint(kw, (n, TILE, TILE), lo, hi, dtype=jnp.int32)
    a = jax.random.randint(ka, (n, TILE, t_len), lo, hi, dtype=jnp.int32)
    return w.astype(dtype), a.astype(dtype)


@pytest.mark.parametrize("t_len,lo,hi,dtype", [
    (33, -128, 128, jnp.int32),     # full signed int8 range
    (8, 0, 128, jnp.int32),         # non-negative: no sign wraps
    (16, -128, 1, jnp.int32),       # non-positive: every psum wraps
    (8, -4, 5, jnp.int8),           # narrow dtype in, small magnitudes
])
def test_kernel_and_oracle_match_cosim(t_len, lo, hi, dtype):
    key = jax.random.PRNGKey(t_len * 31 + hi)
    w, a = _rand_tiles(key, 3, t_len, lo, hi, dtype)
    for use_kernel in (False, True):
        res = verify_tiles(w, a, use_kernel=use_kernel, interpret=True)
        assert res["exactness_ok"]
        assert res["match"], (use_kernel, res)
        assert res["kernel_total"] == res["cosim_total"] \
            == 3 * TILE * TILE * (t_len - 1)


def test_masked_padding_tiles_contribute_nothing():
    key = jax.random.PRNGKey(7)
    w, a = _rand_tiles(key, 4, 9, -128, 128)
    mask = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    full = verify_tiles(w[:2], a[:2], use_kernel=False)
    masked = verify_tiles(w, a, mask=mask, use_kernel=False)
    assert masked["match"] and full["match"]
    assert masked["n_tiles"] == 2
    assert masked["cosim_total"] == full["cosim_total"]
    assert masked["toggles"] == full["toggles"]


# ------------------------------------------------------- adversarial cases


def test_all_zero_partial_sums():
    """w = 0 everywhere: every transition is (0 -> 0), group (0, 0)."""
    w = jnp.zeros((1, TILE, TILE), jnp.int32)
    a = jax.random.randint(jax.random.PRNGKey(1), (1, TILE, 12), -128, 128,
                           dtype=jnp.int32)
    hist, toggles = tile_cosim_stats(w[0], a[0])
    assert int(hist[0, 0]) == TILE * TILE * 11
    assert int(hist.sum()) == TILE * TILE * 11
    assert int(toggles) == 0
    for use_kernel in (False, True):
        res = verify_tiles(w, a, use_kernel=use_kernel, interpret=True)
        assert res["match"], res


def test_sign_flip_transitions():
    """Alternating +v / -v activations: every transition flips the sign of
    every partial sum, crossing the two's-complement wrap each time (the
    negative view has msb_val 22 -> MSB group 9)."""
    w = jnp.ones((1, TILE, TILE), jnp.int32)
    a = jnp.tile(jnp.asarray([3, -3], jnp.int32), (8,))[None, None, :]
    a = jnp.broadcast_to(a, (1, TILE, 16))
    hist, toggles = tile_cosim_stats(w[0], a[0])
    # every psum alternates between +3r and -3r (r = row+1 > 0): each of the
    # 15 transitions connects a positive-view group and a wrap-view group
    assert int(hist.sum()) == TILE * TILE * 15
    assert int(hist[0, 0]) == 0
    for use_kernel in (False, True):
        res = verify_tiles(w, a, use_kernel=use_kernel, interpret=True)
        assert res["match"], res


def test_boundary_magnitude_psums():
    """Drive partial sums through the 22-bit corner values: 0, +-1, the
    2^21 MSB-group-9 floor, and the MASK22 ceiling."""
    # row of 127s with 127 activations climbs to 64*127*127 = 1032256 > 2^19;
    # alternating extremes slam between large positive and wrapped negative
    w = jnp.full((1, TILE, TILE), 127, jnp.int32)
    a_cases = [
        jnp.full((1, TILE, 10), 127, jnp.int32),
        jnp.full((1, TILE, 10), -128, jnp.int32),
        jnp.tile(jnp.asarray([127, -128], jnp.int32), (5,))[None, None, :]
        * jnp.ones((1, TILE, 1), jnp.int32),
    ]
    for a in a_cases:
        for use_kernel in (False, True):
            res = verify_tiles(w, a, use_kernel=use_kernel, interpret=True)
            assert res["match"], res


def test_cosim_group_histogram_totals_and_dtype():
    key = jax.random.PRNGKey(5)
    w, a = _rand_tiles(key, 2, 17, -128, 128)
    hist, toggles = tile_cosim_stats(w[0], a[0])
    assert hist.dtype == jnp.int32
    assert int(hist.sum()) == TILE * TILE * 16
    # toggles bounded by 22 bits per transition
    assert 0 <= int(toggles) <= TILE * TILE * 16 * 22
    # bits22 view is what the toggle count runs on
    assert int(bits22(jnp.asarray(-1)).max()) == MASK22
