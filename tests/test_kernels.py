"""Per-kernel allclose tests (interpret=True) sweeping shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qat
from repro.core.mac_model import DEFAULT_COEFFS, MacEnergyCoeffs
from repro.core.stats import TILE, tile_transition_stats as stats_oracle
from repro.kernels.fake_quant.ops import fake_quant_project, ste_fake_quant
from repro.kernels.fake_quant.ref import fake_quant_ref
from repro.kernels.lut_matmul.ops import (
    compress_layer_weights,
    encode_weights,
    lut_matmul,
    pack_indices,
)
from repro.kernels.lut_matmul.ref import lut_matmul_ref, unpack_indices
from repro.kernels.transition_energy.ops import tile_transition_stats


# ---------------------------------------------------------------- lut_matmul


def _random_lut_case(key, m, k, n, dtype, block_k):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (m, k), jnp.float32).astype(dtype)
    cb = jnp.sort(jax.random.choice(k2, jnp.arange(-127, 128), (16,),
                                    replace=False)).astype(jnp.int8)
    idx = jax.random.randint(k3, (k, n), 0, 16, dtype=jnp.int32)
    packed = pack_indices(idx, block_k)
    scale = jax.random.uniform(k4, (n,), jnp.float32, 0.005, 0.02)
    return x, packed, cb, scale


def test_pack_unpack_roundtrip():
    key = jax.random.PRNGKey(0)
    idx = jax.random.randint(key, (256, 64), 0, 16, dtype=jnp.int32)
    for block_k in (64, 128, 256):
        packed = pack_indices(idx, block_k)
        assert packed.shape == (128, 64)
        back = unpack_indices(packed, block_k)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(idx))


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (64, 256, 96),
                                   (200, 384, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_matmul_matches_ref(m, k, n, dtype):
    key = jax.random.PRNGKey(m * 7 + n)
    block = dict(block_m=64, block_n=64, block_k=128)
    x, packed, cb, scale = _random_lut_case(key, m, k, n, dtype, 128)
    got = lut_matmul(x, packed, cb, scale, interpret=True, **block)
    want = lut_matmul_ref(x, packed, cb, scale, block_k=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-3)


def test_lut_matmul_matches_dense_qat_layer():
    """End-to-end: a codebook-restricted float layer served via the LUT kernel
    must match the QAT fake-quant forward."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (128, 64)) * 0.05
    values = [-96, -64, -32, -16, -8, 0, 8, 16, 32, 64, 96, 127]
    packed, cb, scale = compress_layer_weights(w, values, block_k=128)

    comp = qat.identity_comp(w.shape)
    comp["codebook"], comp["codebook_k"] = qat.make_codebook(values)
    w_fake = qat.fake_quant_weight(w, comp)

    x = jax.random.normal(jax.random.fold_in(key, 1), (32, 128))
    y_kernel = lut_matmul(x, packed, cb, scale, block_m=64, block_n=64,
                          block_k=128, interpret=True)
    y_fake = x @ w_fake
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_fake),
                               rtol=1e-4, atol=1e-4)


def test_lut_matmul_k_sweep_accumulates_in_f32():
    """Accumulation across many K steps must happen in f32, with a single
    cast to the narrow out_dtype at the end.

    Construction: K block 0 contributes a partial sum of 8192 per column
    (f16 ulp there is 8); each of the remaining 32 blocks nets +2. An
    out_dtype (f16) accumulator rounds every +2 away (8192 + 2 -> 8192) and
    lands on 8192; f32 accumulation gives the exact 8256.
    """
    block_k, nblk, m, n = 128, 33, 8, 8
    k = block_k * nblk
    cb = jnp.asarray([-1, 1, 64] + [64] * 13, jnp.int8)
    idx = np.zeros((k, n), np.int32)
    idx[:block_k] = 2                       # value 64
    for b in range(1, nblk):
        blk = np.zeros((block_k, n), np.int32)
        blk[:65] = 1                        # 65 x (+1)
        idx[b * block_k:(b + 1) * block_k] = blk  # 63 x (-1)
    packed = pack_indices(jnp.asarray(idx), block_k)
    scale = jnp.ones((n,), jnp.float32)
    x = jnp.ones((m, k), jnp.float16)
    got = lut_matmul(x, packed, cb, scale, interpret=True)
    want = lut_matmul_ref(x, packed, cb, scale, block_k=block_k)
    assert got.dtype == jnp.float16
    np.testing.assert_array_equal(np.asarray(got),
                                  np.full((m, n), 8256.0, np.float16))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_encode_weights_snaps_to_nearest():
    cb = jnp.asarray([-50, 0, 50], jnp.int32)
    cb16 = jnp.pad(cb, (0, 13), constant_values=50)
    w = jnp.asarray([[-60, -20, 10, 60]], jnp.int32)
    idx = encode_weights(w, cb16)
    np.testing.assert_array_equal(np.asarray(cb16[idx]),
                                  [[-50, 0, 0, 50]])


# ---------------------------------------------------------- transition_energy


@pytest.mark.parametrize("t_len", [8, 33, 64])
def test_transition_stats_kernel_matches_oracle(t_len):
    key = jax.random.PRNGKey(t_len)
    w = jax.random.randint(key, (TILE, TILE), -128, 128, dtype=jnp.int32)
    a = jax.random.randint(jax.random.fold_in(key, 1), (TILE, t_len), -128,
                           128, dtype=jnp.int32)
    got = tile_transition_stats(w, a, DEFAULT_COEFFS, interpret=True)
    want = stats_oracle(w, a, DEFAULT_COEFFS)
    names = ("energy_sum", "count", "group_hist", "act_hist")
    for g, w_, name in zip(got, want, names):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_),
                                   rtol=1e-5, atol=1e-3, err_msg=name)


def test_transition_stats_kernel_custom_coeffs():
    coeffs = MacEnergyCoeffs(c_prod=0.5, c_pp=0.3, c_acc=1.2, c_carry=0.1,
                             c_zero=0.4, c_base=0.0)
    key = jax.random.PRNGKey(9)
    w = jax.random.randint(key, (TILE, TILE), -16, 17, dtype=jnp.int32)
    a = jax.random.randint(jax.random.fold_in(key, 1), (TILE, 16), -16, 17,
                           dtype=jnp.int32)
    got = tile_transition_stats(w, a, coeffs, interpret=True)
    want = stats_oracle(w, a, coeffs)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-3)


def test_transition_stats_kernel_in_pipeline():
    """collect_layer_stats(use_kernel=True) must agree with the oracle path."""
    from repro.core.stats import collect_layer_stats

    key = jax.random.PRNGKey(4)
    w = jax.random.randint(key, (96, 70), -100, 100, dtype=jnp.int32)
    x = jax.random.randint(jax.random.fold_in(key, 1), (70, 150), -100, 100,
                           dtype=jnp.int32)
    s_ref = collect_layer_stats(w, x, max_tiles=4, key=key, use_kernel=False)
    s_ker = collect_layer_stats(w, x, max_tiles=4, key=key, use_kernel=True)
    # one-hot-matmul vs segment-sum accumulation order: fp32 noise only
    np.testing.assert_allclose(np.asarray(s_ker.energy_sum),
                               np.asarray(s_ref.energy_sum), rtol=1e-3,
                               atol=1e-2)
    np.testing.assert_allclose(np.asarray(s_ker.group_hist),
                               np.asarray(s_ref.group_hist), atol=0.5)


# ----------------------------------------------------------------- fake_quant


@pytest.mark.parametrize("m,n", [(256, 256), (100, 300), (64, 80)])
@pytest.mark.parametrize("k_valid", [0, 5, 16])
def test_fake_quant_kernel_matches_ref(m, n, k_valid):
    key = jax.random.PRNGKey(m + n + k_valid)
    w = jax.random.normal(key, (m, n)) * 0.1
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (m, n)) > 0.3
            ).astype(jnp.float32)
    scale = qat.weight_scale(w)[0]
    values = sorted(np.random.RandomState(k_valid).choice(
        np.arange(-127, 128), size=max(k_valid, 1), replace=False).tolist())
    cb, _ = qat.make_codebook(values)
    k = jnp.asarray(k_valid, jnp.int32)
    got = fake_quant_project(w, mask, scale, cb, k, block_m=64, block_n=64,
                             interpret=True)
    want = fake_quant_ref(w, mask, scale, cb, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_ste_fake_quant_gradient_is_masked_passthrough():
    key = jax.random.PRNGKey(7)
    w = jax.random.normal(key, (64, 64)) * 0.1
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (64, 64)) > 0.5
            ).astype(jnp.float32)
    scale = qat.weight_scale(w)[0]
    cb, k = qat.make_codebook([-64, -16, 0, 16, 64])

    def f(w):
        return jnp.sum(ste_fake_quant(w, mask, scale, cb, k) * 2.0)

    g = jax.grad(f)(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(2.0 * mask),
                               rtol=1e-6)
