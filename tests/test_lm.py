"""LM substrate tests: forward/prefill/decode agreement across families."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.lm import build_lm
from repro.nn.layers import QuantConfig
from repro.nn.spec import init_params


def _mk(name="t", **kw):
    base = dict(
        name=name, family="dense", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=300, head_dim=16,
        compute_dtype="float32",
    )
    base.update(kw)
    return ArchConfig(**base)


def _roundtrip(cfg, s_prompt=16, s_total=22, batch=2, **fwd):
    """prefill + decode must reproduce the full forward logits."""
    m = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), m.spec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, s_total), 0, cfg.vocab)
    kwargs = dict(q_block=8, kv_block=8)
    full, _ = m.forward(params, toks, **kwargs, **fwd)
    lg, cache = m.prefill(params, toks[:, :s_prompt], max_len=s_total + 8,
                          cache_dtype=jnp.float32, **kwargs, **fwd)
    pf_err = float(jnp.max(jnp.abs(lg[:, :s_prompt, :cfg.vocab]
                                   - full[:, :s_prompt, :cfg.vocab])))
    errs = []
    for t in range(s_prompt, s_total):
        lg_d, cache = m.decode_step(params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(
            lg_d[:, 0, :cfg.vocab] - full[:, t, :cfg.vocab]))))
    return pf_err, max(errs), full


def test_dense_gqa_roundtrip():
    pf, dec, full = _roundtrip(_mk())
    assert bool(jnp.all(jnp.isfinite(full[..., :300])))
    assert pf < 1e-4 and dec < 1e-4


def test_local_global_ring_buffer_roundtrip():
    cfg = _mk(n_layers=5, pattern=("local", "local", "attn"), window=12)
    pf, dec, _ = _roundtrip(cfg)
    assert pf < 1e-4 and dec < 1e-4


def test_qkv_bias_and_untied():
    cfg = _mk(qkv_bias=True, tie_embeddings=False)
    pf, dec, _ = _roundtrip(cfg)
    assert pf < 1e-4 and dec < 1e-4


def test_nonparam_ln():
    cfg = _mk(norm="nonparam_ln", ffn="swiglu")
    m = build_lm(cfg)
    # non-parametric LN has zero norm params
    assert "scale" not in m.spec["final_norm"]
    pf, dec, _ = _roundtrip(cfg)
    assert pf < 1e-4 and dec < 1e-4


def test_ssm_mamba2_roundtrip():
    cfg = _mk(family="ssm", pattern=("ssm",), n_layers=4, n_heads=1,
              n_kv_heads=1, d_ff=0, ssm_d_state=32, ssm_head_dim=32,
              ssm_chunk=8)
    pf, dec, _ = _roundtrip(cfg)
    # recurrent states round through fp32; tolerance slightly looser
    assert pf < 1e-3 and dec < 1e-3


def test_rglru_hybrid_roundtrip():
    cfg = _mk(family="hybrid", pattern=("rglru", "rglru", "local"), window=12,
              n_layers=5, rnn_width=64, ffn="geglu", embed_scale=True)
    pf, dec, _ = _roundtrip(cfg)
    assert pf < 1e-3 and dec < 1e-3


def test_moe_roundtrip_and_aux():
    cfg = _mk(family="moe", n_experts=4, moe_top_k=2, moe_d_ff=64,
              capacity_factor=2.0)
    m = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), m.spec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 300)
    logits, aux = m.forward(params, toks, q_block=8, kv_block=8)
    assert float(aux["lb_loss"]) > 0.0
    # lb loss for uniform routing ~= n_layers (E * sum(me*ce)/k ~ 1 per layer)
    assert float(aux["lb_loss"]) < 3 * cfg.n_layers
    pf, dec, _ = _roundtrip(cfg, s_prompt=12, s_total=18)
    # token dropping differs between batched prefill and single decode only
    # if capacity binds; with cf=2 it should not
    assert pf < 1e-3 and dec < 1e-3


def test_moe_shared_experts():
    cfg = _mk(family="moe", n_experts=4, moe_top_k=2, moe_d_ff=64,
              n_shared_experts=1, capacity_factor=2.0)
    pf, dec, _ = _roundtrip(cfg, s_prompt=12, s_total=16)
    assert pf < 1e-3 and dec < 1e-3


def test_encdec_whisper_roundtrip():
    cfg = _mk(family="audio", encoder_decoder=True, n_enc_layers=2,
              n_layers=2, ffn="gelu", norm="layernorm", rope_theta=0.0)
    m = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), m.spec)
    b, s_enc, s_dec = 2, 24, 14
    frames = jax.random.normal(jax.random.PRNGKey(2), (b, s_enc, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s_dec), 0, 300)
    full, _ = m.forward(params, toks, enc_embeds=frames, q_block=8, kv_block=8)
    assert bool(jnp.all(jnp.isfinite(full[..., :300])))
    lg, cache = m.prefill(params, toks[:, :8], max_len=s_dec + 4,
                          enc_embeds=frames, cache_dtype=jnp.float32,
                          q_block=8, kv_block=8)
    pf_err = float(jnp.max(jnp.abs(lg[:, :8, :300] - full[:, :8, :300])))
    errs = []
    for t in range(8, s_dec):
        lg_d, cache = m.decode_step(params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(lg_d[:, 0, :300] - full[:, t, :300]))))
    assert pf_err < 1e-3 and max(errs) < 1e-3


def test_vlm_prefix_forward_and_loss():
    cfg = _mk(family="vlm", prefix_len=8)
    m = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), m.spec)
    b, p, s = 2, 8, 12
    prefix = jax.random.normal(jax.random.PRNGKey(2), (b, p, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, 300)
    logits, _ = m.forward(params, toks, prefix_embeds=prefix, q_block=8,
                          kv_block=8)
    assert logits.shape == (b, p + s, cfg.padded_vocab)
    loss, metrics = m.loss(
        params, {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                 "prefix_embeds": prefix}, q_block=8, kv_block=8)
    assert bool(jnp.isfinite(loss))


def test_qat_forward_close_to_float():
    cfg = _mk()
    m = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), m.spec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 300)
    lf, _ = m.forward(params, toks, q_block=8, kv_block=8)
    lq, _ = m.forward(params, toks, qcfg=QuantConfig.on(), q_block=8, kv_block=8)
    lf = lf[..., :300]
    lq = lq[..., :300]
    rel = float(jnp.linalg.norm(lq - lf) / jnp.maximum(jnp.linalg.norm(lf), 1e-9))
    assert rel < 0.2


def test_remat_matches_no_remat():
    cfg = _mk()
    m = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), m.spec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 300)

    def loss_fn(p, remat):
        return m.loss(p, {"tokens": toks[:, :-1], "labels": toks[:, 1:]},
                      remat=remat, q_block=8, kv_block=8)[0]

    l0, g0 = jax.value_and_grad(lambda p: loss_fn(p, False))(params)
    l1, g1 = jax.value_and_grad(lambda p: loss_fn(p, True))(params)
    assert float(jnp.abs(l0 - l1)) < 1e-5
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g0, g1)
    assert max(jax.tree.leaves(diffs)) < 1e-4


def test_window_equals_full_when_window_large():
    """Local attention with window >= seq must equal full attention."""
    cfg_full = _mk(pattern=("attn",))
    cfg_loc = _mk(pattern=("local",), window=4096)
    m_f, m_l = build_lm(cfg_full), build_lm(cfg_loc)
    params = init_params(jax.random.PRNGKey(0), m_f.spec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 300)
    lf, _ = m_f.forward(params, toks, q_block=8, kv_block=8)
    ll, _ = m_l.forward(params, toks, q_block=8, kv_block=8)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(ll), atol=1e-5)
