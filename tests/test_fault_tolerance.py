"""Checkpointing, resilient loop, elastic restore, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.fault import Heartbeat, StragglerMonitor, run_resilient_loop
from repro.optim.compression import compressed, int8_compressor, topk_compressor
from repro.optim.optimizers import apply_updates, sgdm


def _toy_state(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "b": jnp.zeros((8,))},
        "opt": {"step": jnp.zeros((), jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2, async_save=False)
    state = _toy_state()
    ckpt.save(10, state)
    step, restored = ckpt.restore()
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert restored["opt"]["step"].shape == ()


def test_checkpoint_keep_and_latest(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, _toy_state(s))
    assert ckpt.all_steps() == [3, 4]
    assert ckpt.latest_step() == 4


def test_checkpoint_async_and_mutation_safety(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=3, async_save=True)
    state = _toy_state()
    ckpt.save(1, state)
    # mutate immediately after scheduling the save — snapshot must be stable
    state["params"]["w"] = state["params"]["w"] * 0.0
    ckpt.wait()
    _, restored = ckpt.restore(1)
    assert float(jnp.sum(jnp.abs(restored["params"]["w"]))) > 0


def test_checkpoint_atomic_no_partial(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)
    ckpt.save(5, _toy_state())
    # a stale tmp dir from a "crashed" save must not break restore
    (tmp_path / "step_00000009.tmp").mkdir()
    assert ckpt.latest_step() == 5
    step, _ = ckpt.restore()
    assert step == 5


def test_resilient_loop_recovers_from_faults(tmp_path):
    """Inject 3 faults; the loop must restore and still converge the count."""
    opt = sgdm(0.1)

    def step_fn(state, batch):
        def loss_fn(p):
            return jnp.sum((p["w"] @ batch - 1.0) ** 2)

        loss, g = jax.value_and_grad(loss_fn)(state["params"])
        updates, o = opt.update(g, state["opt"], state["params"])
        return ({"params": apply_updates(state["params"], updates), "opt": o},
                {"loss": loss})

    def data_fn(step):
        return jax.random.normal(jax.random.PRNGKey(step), (8,)) * 0.1

    faults = {7, 23, 24}
    fired = set()

    def fault_hook(step):
        if step in faults and step not in fired:
            fired.add(step)
            raise RuntimeError(f"injected device failure at step {step}")

    state = {"params": {"w": jnp.ones((8,))}, "opt": sgdm(0.1).init({"w": jnp.ones((8,))})}
    ckpt = CheckpointManager(tmp_path, keep=3, async_save=False)
    final, report = run_resilient_loop(
        step_fn=step_fn, data_fn=data_fn, state=state, ckpt=ckpt,
        n_steps=40, checkpoint_every=10, fault_hook=fault_hook)
    assert report.failures == 3
    assert report.restores == 3
    assert report.final_step == 40
    # loss must still have improved despite replays; each step draws a fresh
    # random batch so single-step losses are noisy — compare windowed means
    assert np.mean(report.losses[-10:]) < np.mean(report.losses[:10])


def test_resilient_loop_deterministic_replay(tmp_path):
    """A run with faults must end bit-identical to a run without faults."""
    opt = sgdm(0.05)

    def step_fn(state, batch):
        g = jax.grad(lambda p: jnp.sum((p["w"] - batch) ** 2))(state["params"])
        updates, o = opt.update(g, state["opt"], state["params"])
        return ({"params": apply_updates(state["params"], updates), "opt": o},
                {"loss": jnp.zeros(())})

    def data_fn(step):
        return jax.random.normal(jax.random.PRNGKey(step), (4,))

    def run(faults, path):
        fired = set()

        def hook(step):
            if step in faults and step not in fired:
                fired.add(step)
                raise RuntimeError("boom")

        params = {"w": jnp.zeros((4,))}
        state = {"params": params, "opt": opt.init(params)}
        ckpt = CheckpointManager(path, async_save=False)
        final, _ = run_resilient_loop(
            step_fn=step_fn, data_fn=data_fn, state=state, ckpt=ckpt,
            n_steps=25, checkpoint_every=5, fault_hook=hook)
        return np.asarray(final["params"]["w"])

    clean = run(set(), tmp_path / "a")
    faulty = run({3, 13, 22}, tmp_path / "b")
    np.testing.assert_array_equal(clean, faulty)


def test_straggler_monitor():
    mon = StragglerMonitor(window=16, threshold=2.0)
    for i in range(20):
        mon.record(i, 0.1)
    assert mon.record(20, 0.5) is True
    assert 20 in mon.flagged
    assert mon.record(21, 0.11) is False


def test_heartbeat_dead_worker_detection():
    hb = Heartbeat(timeout=10.0)
    hb.beat(0, now=100.0)
    hb.beat(1, now=100.0)
    hb.beat(0, now=120.0)
    assert hb.dead_workers(now=125.0) == [1]


@pytest.mark.parametrize("make_comp", [int8_compressor,
                                       lambda: topk_compressor(0.05)])
def test_gradient_compression_error_feedback_converges(make_comp):
    """Compressed SGD on a quadratic must still reach the optimum thanks to
    error feedback."""
    target = jnp.asarray([1.0, -2.0, 3.0, 0.5])
    params = {"w": jnp.zeros((4,))}
    opt = compressed(sgdm(0.2, momentum=0.0), make_comp())
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        updates, state = opt.update(g, state, params)
        params = apply_updates(params, updates)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_int8_compressor_wire_bytes():
    comp = int8_compressor()
    grads = {"w": jnp.ones((1000,))}
    ef = comp.init(grads)
    _, _, stats = comp.compress(grads, ef)
    assert stats["wire_bytes"] < 0.3 * stats["raw_bytes"]


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Save under one mesh shape, restore under another (8 fake devices,
    subprocess to control device count)."""
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        import sys
        sys.path.insert(0, "src")
        from repro.checkpoint.manager import CheckpointManager

        def mesh(shape):
            return Mesh(np.asarray(jax.devices()).reshape(shape), ("data", "model"))

        m1 = mesh((4, 2))
        sh1 = {{"params": {{"w": NamedSharding(m1, P("data", "model"))}}}}
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        state = {{"params": {{"w": jax.device_put(w, sh1["params"]["w"])}}}}
        ckpt = CheckpointManager(r"{tmp_path}", async_save=False)
        ckpt.save(1, state)

        # elastic: restore onto a DIFFERENT mesh shape
        m2 = mesh((2, 4))
        sh2 = {{"params": {{"w": NamedSharding(m2, P("data", "model"))}}}}
        step, restored = ckpt.restore(shardings=sh2)
        assert step == 1
        got = np.asarray(jax.device_get(restored["params"]["w"]))
        np.testing.assert_array_equal(got, np.arange(64).reshape(8, 8))
        assert restored["params"]["w"].sharding.mesh.shape["model"] == 4
        print("ELASTIC_OK")
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, cwd=os.getcwd(), timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
