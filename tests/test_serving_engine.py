"""Continuous-batching serving engine: bucketing, engine-vs-oneshot parity,
zero-recompile enforcement, accounting, trajectory gates, and the launcher
CLI's compress_report path."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params
from repro.serving import (
    EngineConfig,
    ServingEngine,
    bucket_for,
    bucket_up,
    pad_prompts,
    percentile,
)

CFG = EngineConfig(max_batch=4, prompt_buckets=(8, 16),
                   new_token_buckets=(8,), max_waves=2)

# (prompt_len, new_tokens) mixed-length trace over both prompt buckets,
# with early-finishing requests inside a wave
TRACE = [(6, 8), (8, 5), (14, 8), (5, 8), (8, 8), (16, 6), (12, 8)]


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("olmo-1b").scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), model.spec)
    return model, params


@pytest.fixture(scope="module")
def prompts(lm):
    model, _ = lm
    rng = np.random.default_rng(3)
    return [rng.integers(0, model.cfg.vocab, size=plen).astype(np.int32)
            for plen, _ in TRACE]


@pytest.fixture(scope="module")
def engines(lm):
    model, params = lm
    eng = ServingEngine(model, params, mode="engine", config=CFG)
    one = ServingEngine(model, params, mode="oneshot", config=CFG)
    for e in (eng, one):
        e.warmup(TRACE)
    return eng, one


# ------------------------------------------------------------- pure helpers


def test_bucket_up_and_bucket_for():
    assert bucket_up(5, (8, 16)) == 8
    assert bucket_up(8, (8, 16)) == 8
    assert bucket_up(9, (8, 16)) == 16
    with pytest.raises(ValueError):
        bucket_up(17, (8, 16))
    b = bucket_for(5, 6, CFG, batch=4)
    assert (b.batch, b.prompt_len, b.total_len) == (4, 8, 16)
    assert b.new_tokens == 8
    with pytest.raises(ValueError):
        bucket_for(0, 6, CFG, batch=4)


def test_pad_prompts():
    b = bucket_for(5, 6, CFG, batch=4)
    out = pad_prompts([[1, 2, 3], [4, 5, 6, 7, 8]], b, pad_token=0)
    assert out.shape == (4, 8) and out.dtype == np.int32
    assert list(out[0]) == [1, 2, 3, 0, 0, 0, 0, 0]
    assert list(out[1]) == [4, 5, 6, 7, 8, 0, 0, 0]
    assert not out[2:].any()          # dummy rows are all-pad
    with pytest.raises(ValueError):
        pad_prompts([[1]] * 5, b, pad_token=0)      # too many rows
    with pytest.raises(ValueError):
        pad_prompts([list(range(9))], b, pad_token=0)  # prompt too long


def test_percentile():
    assert percentile([], 50) == 0.0
    assert percentile([3.0], 99) == 3.0
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == pytest.approx(2.5)


# ------------------------------------------------------------------- engine


def test_engine_vs_oneshot_parity_mixed_lengths(engines, prompts):
    eng, one = engines
    news = [n for _, n in TRACE]
    r_eng = eng.serve(prompts, news)
    r_one = one.serve(prompts, news)
    assert sorted(r_eng) == sorted(r_one)
    for (rid_e, rid_o) in zip(sorted(r_eng), sorted(r_one)):
        assert len(r_eng[rid_e].tokens) == news[sorted(r_eng).index(rid_e)]
        assert r_eng[rid_e].tokens == r_one[rid_o].tokens


def test_zero_recompiles_after_warmup(engines, prompts):
    eng, one = engines
    news = [n for _, n in TRACE]
    for e in (eng, one):
        before = e.cache.compile_count
        e.serve(prompts, news)
        e.serve(prompts[::-1], news[::-1])
        assert e.cache.compile_count == before, \
            "serving warmed shapes must not build new executables"


def test_compiled_steps_reject_other_shapes(engines, lm):
    """The AOT cache *enforces* one-compile-per-bucket: a shape miss raises
    instead of silently recompiling."""
    eng, _ = engines
    model, params = lm
    fns = eng.cache.fns(bucket_for(6, 8, CFG, batch=4), params)
    import jax.numpy as jnp

    with pytest.raises(TypeError):
        fns.prefill(params, jnp.zeros((2, 8), jnp.int32))   # wrong batch
    with pytest.raises(TypeError):
        fns.prefill(params, jnp.zeros((4, 12), jnp.int32))  # wrong length


def test_wave_packing_partial_and_multi_wave(lm, prompts):
    """5 same-bucket requests at width 4 -> one full + one partial wave
    (legacy lockstep baseline, kept as mode="wave")."""
    model, params = lm
    eng = ServingEngine(model, params, mode="wave", config=CFG)
    eng.warmup([(8, 8)])
    same = [p[:7] for p in prompts[:5]]
    res = eng.serve(same, 8)
    assert len(res) == 5
    assert all(len(r.tokens) == 8 for r in res.values())
    rep = eng.report()
    assert rep["requests"] == 5
    assert rep["cache_buckets_compiled"] == 1


def test_engine_vs_wave_parity(lm, engines, prompts):
    """The slot scheduler changes *when* work runs, never *what* each
    request computes: token streams match the lockstep baseline exactly."""
    model, params = lm
    eng, _ = engines
    wav = ServingEngine(model, params, mode="wave", config=CFG)
    wav.warmup(TRACE)
    news = [n for _, n in TRACE]
    r_eng = eng.serve(prompts, news)
    r_wav = wav.serve(prompts, news)
    assert ([r_eng[r].tokens for r in sorted(r_eng)]
            == [r_wav[r].tokens for r in sorted(r_wav)])


def test_slot_admission_is_fifo(lm, prompts):
    """Slot admission never lets a bucket-mate jump the queue head: with 2
    slots and 6 alternating-bucket requests, t_admitted follows submit
    order (the wave scheduler's whole-queue bucket scan could starve the
    short-prompt requests here)."""
    model, params = lm
    cfg = EngineConfig(max_batch=2, prompt_buckets=(8, 16),
                       new_token_buckets=(8,), max_waves=1)
    eng = ServingEngine(model, params, mode="engine", config=cfg)
    eng.warmup([(16, 8), (8, 8)])
    rids = []
    for i in range(6):
        p = prompts[2] if i % 2 == 0 else prompts[1][:8]   # 14 / 8 tokens
        rids.append(eng.submit(p, 8))
    res = eng.run()
    admitted = [res[r].stats.t_admitted for r in rids]
    assert all(a is not None for a in admitted)
    assert admitted == sorted(admitted), \
        "slot refill must admit strictly in submit order"


def test_chunked_prefill_matches_full_prefill(lm, prompts):
    """Prefilling 16 tokens as two 8-token chunks against a live cache
    yields the same logits/cache as one full prefill (float roundoff)."""
    import jax
    import jax.numpy as jnp

    model, params = lm
    toks = jnp.asarray(np.stack([np.resize(prompts[2], 16),
                                 np.resize(prompts[6], 16)]))
    full_logits, full_cache = model.prefill(params, toks, max_len=24)

    spec = model.cache_spec(2, 24, jnp.float32)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    z = jnp.zeros((2,), jnp.int32)
    l1, cache = model.prefill_chunk(params, cache, toks[:, :8], start=z)
    l2, cache = model.prefill_chunk(params, cache, toks[:, 8:], start=z + 8)
    assert np.asarray(cache["pos"]).tolist() == [16, 16]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(full_logits[:, :8]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(full_logits[:, 8:]),
                               atol=1e-4, rtol=1e-4)
    # token-level decision parity on the position that seeds generation
    assert (np.argmax(np.asarray(l2[:, -1]), -1).tolist()
            == np.argmax(np.asarray(full_logits[:, -1]), -1).tolist())


def test_exact_fit_matches_reference_generate(lm, engines, prompts):
    """A prompt that fills its bucket reproduces the pre-engine
    `repro.launch.serve.generate` path token for token."""
    from repro.launch.serve import generate

    model, params = lm
    _, one = engines
    prompt = prompts[1][:8]                     # exact bucket fit (8 -> 8)
    res = one.serve([prompt], 8)
    want = generate(model, params, np.asarray(prompt)[None, :], new_tokens=8)
    assert list(res[min(res)].tokens) == [int(t) for t in np.asarray(want)[0]]


def test_temperature_sampling_parity(engines, prompts):
    """Seeded host-side sampling is mode-independent."""
    eng, one = engines
    outs = {}
    for e in (eng, one):
        rids = [e.submit(prompts[i], 6, temperature=0.7, seed=11)
                for i in (0, 1, 3)]
        res = e.run()
        outs[e.mode] = [res[r].tokens for r in rids]
    assert outs["engine"] == outs["oneshot"]
    # and genuinely stochastic vs greedy
    eng2, _ = engines
    rid = eng2.submit(prompts[0], 6, temperature=0.0)
    greedy = eng2.run()[rid].tokens
    assert len(greedy) == 6


def test_submit_rejects_unbucketable(engines):
    eng, _ = engines
    with pytest.raises(ValueError):
        eng.submit(np.zeros(17, np.int32), 8)   # prompt > largest bucket
    with pytest.raises(ValueError):
        eng.submit(np.zeros(8, np.int32), 9)    # new_tokens > largest bucket


def test_engine_rejects_unknown_mode(lm):
    model, params = lm
    with pytest.raises(ValueError, match="mode"):
        ServingEngine(model, params, mode="waves", config=CFG)


def test_serve_raises_on_length_mismatch(engines, prompts):
    """Regression: serve() used to zip-truncate silently when the new_tokens
    list was shorter/longer than the prompt list, dropping requests."""
    eng, one = engines
    for e in (eng, one):
        with pytest.raises(ValueError, match="new_tokens"):
            e.serve(prompts[:3], [8, 8])
        with pytest.raises(ValueError, match="new_tokens"):
            e.serve(prompts[:2], [8, 8, 8])


def test_request_stats_guard_unset_timestamps():
    """Regression: unset timestamps defaulted to 0.0, so latency_s/ttft_s on
    an in-flight request returned negative garbage instead of raising."""
    from repro.serving import RequestStats

    s = RequestStats(rid=0, prompt_len=4, new_tokens=4, bucket=(),
                     t_submit=123.0)
    assert s.t_finish is None and s.t_first_token is None
    with pytest.raises(ValueError, match="latency"):
        s.latency_s
    with pytest.raises(ValueError, match="first token"):
        s.ttft_s
    s.t_first_token = 124.0
    s.t_finish = 125.0
    assert s.ttft_s == pytest.approx(1.0)
    assert s.latency_s == pytest.approx(2.0)


def test_engine_config_validation():
    """Regression: EngineConfig accepted empty/duplicate/non-positive
    buckets and zero max_batch/max_waves, failing later as confusing
    bucket_up/compile errors."""
    from repro.serving import chunk_plan

    for bad in (dict(max_batch=0), dict(max_waves=0), dict(q_block=0),
                dict(kv_block=-1), dict(chunk_rows=-1),
                dict(prompt_buckets=()), dict(prompt_buckets=(8, 8)),
                dict(prompt_buckets=(8, 0)), dict(prompt_buckets=[8, 16]),
                dict(new_token_buckets=(True,)),
                dict(prompt_buckets=(8,), chunk_buckets=(5,))):
        with pytest.raises(ValueError):
            EngineConfig(**bad)
    cfg = EngineConfig(max_batch=4, prompt_buckets=(8, 16),
                       new_token_buckets=(8,))
    assert cfg.resolved_chunk_buckets == (8,)        # gcd of prompt buckets
    assert cfg.chunk_row_buckets == (1, 2)
    assert cfg.group_total_len == 24
    assert chunk_plan(32, (16,)) == (16, 16)
    assert chunk_plan(24, (16, 8)) == (16, 8)
    with pytest.raises(ValueError):
        chunk_plan(12, (16, 8))                      # greedy remainder 4


# -------------------------------------------------------------- accounting


def test_energy_accounting(engines, prompts):
    eng, _ = engines
    e_tok = eng.per_token_energy_eu
    assert e_tok > 0.0
    res = eng.serve([prompts[0]], 8)
    stats = res[min(res)].stats
    assert stats.energy_eu == pytest.approx(e_tok * (len(prompts[0]) + 8))
    assert stats.latency_s >= stats.ttft_s >= 0.0


def test_report_shape(engines, prompts):
    eng, _ = engines
    eng.serve([prompts[0]], 8)
    rep = eng.report()
    for key in ("requests", "tokens_per_s", "latency_p50_s", "latency_p99_s",
                "ttft_p50_s", "ttft_p99_s", "energy_eu_total",
                "executed_positions", "slot_utilization",
                "energy_eu_overhead", "cache_compile_count",
                "cache_buckets_compiled"):
        assert key in rep, key
    assert rep["tokens_per_s"] > 0


def test_padded_work_accounting(lm, prompts):
    """Regression: per-request energy ignored padded/idle array work. A
    6-token prompt in an 8-bucket at batch 1 executes 8 prefill + 7 decode
    positions but is charged 6 + 8 tokens; the report must expose the gap."""
    model, params = lm
    one = ServingEngine(model, params, mode="oneshot", config=CFG)
    one.warmup([(6, 8)])
    one.serve([prompts[0][:6]], 8)
    rep = one.report()
    assert rep["executed_positions"] == 8 + 7
    assert rep["slot_utilization"] == pytest.approx(14 / 15)
    assert rep["energy_eu_overhead"] == pytest.approx(
        one.per_token_energy_eu * 1)
    assert rep["energy_eu_total"] == pytest.approx(
        one.per_token_energy_eu * 14)


# -------------------------------------------------------------- compressed


def test_compressed_engine_parity_and_artifacts(lm, prompts):
    model, params = lm
    cfg_small = EngineConfig(max_batch=2, prompt_buckets=(8,),
                             new_token_buckets=(6,), max_waves=1)
    shapes = [(8, 6), (8, 6)]
    pair = {}
    for mode in ("engine", "oneshot"):
        e = ServingEngine(model, params, mode=mode, config=cfg_small,
                          compress_k=4)
        e.warmup(shapes)
        res = e.serve([prompts[1][:8], prompts[4][:8]], 6)
        pair[mode] = ([res[r].tokens for r in sorted(res)], e)
    assert pair["engine"][0] == pair["oneshot"][0]
    arts, summary = pair["engine"][1].artifacts()
    assert summary["layers"] > 0 and len(arts) == summary["layers"]
    assert summary["weight_bytes_packed"] > 0


# -------------------------------------------------------- trajectory gating


def test_trajectory_gate_detects_regression(tmp_path, monkeypatch, capsys):
    import tools.check_gates as cg

    hist = {
        "trajectory_keys": ["engine_tokens_per_s"],
        "history": [
            {"pr": 1, "engine_tokens_per_s": 100.0, "other_speedup": 3.0},
            {"pr": 2, "engine_tokens_per_s": 80.5, "other_speedup": 1.0},
        ],
    }
    (tmp_path / "BENCH_x.json").write_text(json.dumps(hist))
    monkeypatch.setattr(cg, "ROOT", tmp_path)
    monkeypatch.setattr(cg, "OUT_DIR", tmp_path / "out")
    # 100 -> 80.5 is within the 20% tolerance; declared keys only, so the
    # 3.0 -> 1.0 collapse of the undeclared key is ignored
    assert cg.check_trajectory() == 0

    hist["history"][1]["engine_tokens_per_s"] = 79.0   # > 20% regression
    (tmp_path / "BENCH_x.json").write_text(json.dumps(hist))
    assert cg.check_trajectory() == 1
    capsys.readouterr()

    # default key detection (no declared trajectory_keys): *_per_s + *speedup*
    del hist["trajectory_keys"]
    hist["history"][1]["engine_tokens_per_s"] = 99.0
    (tmp_path / "BENCH_x.json").write_text(json.dumps(hist))
    assert cg.check_trajectory() == 1   # other_speedup 3.0 -> 1.0 now gates


def test_trajectory_gate_latency_keys_lower_is_better(tmp_path, monkeypatch,
                                                      capsys):
    """``*_s`` keys (but not ``*_per_s`` throughputs) regress by going UP:
    the trajectory gate must bound them from above."""
    import tools.check_gates as cg

    hist = {
        "trajectory_keys": ["ttft_p99_s"],
        "history": [
            {"pr": 1, "ttft_p99_s": 0.10},
            {"pr": 2, "ttft_p99_s": 0.11},   # +10%: within 20% tolerance
        ],
    }
    (tmp_path / "BENCH_x.json").write_text(json.dumps(hist))
    monkeypatch.setattr(cg, "ROOT", tmp_path)
    monkeypatch.setattr(cg, "OUT_DIR", tmp_path / "out")
    assert cg.check_trajectory() == 0

    hist["history"][1]["ttft_p99_s"] = 0.13   # +30%: a latency regression
    (tmp_path / "BENCH_x.json").write_text(json.dumps(hist))
    assert cg.check_trajectory() == 1

    hist["history"][1]["ttft_p99_s"] = 0.02   # big improvement passes
    (tmp_path / "BENCH_x.json").write_text(json.dumps(hist))
    assert cg.check_trajectory() == 0
    capsys.readouterr()


# ------------------------------------------------------------ CLI coverage


def _run_sub(args_or_code, *, code=False, extra_env=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    if extra_env:
        env.update(extra_env)
    cmd = [sys.executable] + (["-c", args_or_code] if code else args_or_code)
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)


def test_launch_serve_compress_report_cli_smoke():
    """`python -m repro.launch.serve --reduced --compress-k` end to end:
    export + LUT parity report + engine serve through the restricted comp."""
    out = _run_sub(["-m", "repro.launch.serve", "--arch", "olmo-1b",
                    "--reduced", "--compress-k", "4", "--batch", "2",
                    "--prompt-len", "12", "--new-tokens", "6", "--mixed",
                    "--mode", "oneshot"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "compressed export" in out.stdout
    assert "LUT parity max rel err" in out.stdout
    assert "oneshot: 2 requests" in out.stdout


def test_sharded_decode_subprocess():
    """Optional sharded decode: 2 forced host devices, wave batch sharded
    over the 'requests' mesh axis, outputs identical to unsharded."""
    code = textwrap.dedent("""
        import jax, numpy as np
        assert jax.device_count() == 2, jax.device_count()
        from repro.configs import get_config
        from repro.models.lm import build_lm
        from repro.nn.spec import init_params
        from repro.distributed.sharding import request_mesh
        from repro.serving import EngineConfig, ServingEngine

        cfg = get_config("olmo-1b").scaled_down(compute_dtype="float32")
        model = build_lm(cfg)
        params = init_params(jax.random.PRNGKey(0), model.spec)
        ecfg = EngineConfig(max_batch=2, prompt_buckets=(8,),
                            new_token_buckets=(6,), max_waves=1)
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, cfg.vocab, size=7).astype(np.int32)
                   for _ in range(2)]
        plain = ServingEngine(model, params, mode="engine", config=ecfg)
        shard = ServingEngine(model, params, mode="engine", config=ecfg,
                              mesh=request_mesh())
        toks = {}
        for name, e in (("plain", plain), ("shard", shard)):
            e.warmup([(7, 6)])
            res = e.serve(prompts, 6)
            toks[name] = [res[r].tokens for r in sorted(res)]
        assert toks["plain"] == toks["shard"], toks
        print("OK")
    """)
    out = _run_sub(code, code=True, extra_env={
        "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "") +
                      " --xla_force_host_platform_device_count=2").strip()})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
