"""Tests for weight selection, layer-wise scheduling, and the full pipeline."""


import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qat
from repro.core.compression import CompressionPipeline, PipelineConfig
from repro.core.layer_energy import LayerEnergyModel, MatmulDims
from repro.core.runner import CnnRunner
from repro.core.schedule import ScheduleConfig
from repro.core.weight_selection import (
    SelectionConfig,
    greedy_backward_elimination,
    initial_candidate_set,
    naive_lowest_energy_set,
    nearest_other,
)
from repro.data.synthetic import SyntheticImages
from repro.nn import cnn


def test_initial_candidate_set_properties():
    counts = jnp.zeros((256,)).at[128 + 5].set(100.0).at[128 - 3].set(80.0)
    lut = jnp.linspace(1.0, 3.0, 256)  # energy grows with value index
    cfg = SelectionConfig(k_init=8)
    values = initial_candidate_set(counts, lut, cfg)
    assert len(values) == 8
    assert 0 in values
    assert 5 in values  # heavily used value must make the cut
    assert -3 in values


def test_nearest_other():
    assert nearest_other([-4, 0, 3, 9], 3) == 0
    assert nearest_other([-4, 0, 3, 9], 9) == 3
    assert nearest_other([1, 2], 1) == 2


def test_naive_lowest_energy_set():
    lut = jnp.arange(256.0)[::-1]  # w=-128 most expensive ... w=127 cheapest
    vals = naive_lowest_energy_set(lut, 4)
    assert vals == [124, 125, 126, 127]


def test_greedy_elimination_respects_essential_values():
    """A value whose removal tanks accuracy must be kept; cheap-but-useless
    values must go."""
    counts = jnp.zeros((256,))
    lut = jnp.ones((256,))
    candidate = [-64, -32, -8, 0, 8, 32, 64, 96]
    for v in candidate:
        counts = counts.at[v + 128].set(50.0)
    # make high-magnitude values expensive
    for v in candidate:
        lut = lut.at[v + 128].set(1.0 + abs(v) / 32.0)
    model = LayerEnergyModel("t", MatmulDims(64, 64, 64), lut, counts)

    def eval_with_codebook(values, n_batches):
        del n_batches
        # accuracy collapses without +-32; otherwise mild degradation per value
        if 32 not in values or -32 not in values:
            return 0.2
        return 0.9 - 0.005 * (len(candidate) - len(values))

    cfg = SelectionConfig(k_target=5, delta_acc=0.05, epsilon=1e-3,
                          score_batches=1, accept_batches=1)
    final, report = greedy_backward_elimination(
        model, candidate, cfg, acc0=0.9, eval_with_codebook=eval_with_codebook)
    assert len(final) == 5
    assert 32 in final and -32 in final
    assert 0 in final
    # the most expensive removable values (96, 64, -64) should be gone
    assert 96 not in final
    assert report.energy_after < report.energy_before


def _tiny_runner(seed=0):
    return CnnRunner(cnn.lenet5(), SyntheticImages(seed=3), batch_size=64,
                     lr=2e-3, seed=seed)


@pytest.fixture(scope="module")
def trained_lenet():
    runner = _tiny_runner()
    params, state, opt_state, comp = runner.init()
    params, state, opt_state, _ = runner.train(params, state, opt_state, comp, 200)
    stats = runner.profile(params, state, comp, n_batches=1, max_tiles=6)
    return runner, params, state, opt_state, comp, stats


def test_energy_models_and_shares(trained_lenet):
    runner, params, state, opt_state, comp, stats = trained_lenet
    models = runner.energy_models(params, comp, stats)
    assert set(models) == {cl.name for cl in runner.model.comp_layers}
    energies = {n: m.energy for n, m in models.items()}
    assert all(e > 0 for e in energies.values())
    # conv2 dominates LeNet-5 conv energy (16x6x25 weights over 10x10 map)
    assert energies["conv2"] > energies["fc3"]


def test_schedule_end_to_end(trained_lenet):
    runner, params, state, opt_state, comp, stats = trained_lenet
    cfg = ScheduleConfig(
        prune_ratios=(0.5,), k_targets=(16,), delta_acc=0.06,
        finetune_steps=25, trial_finetune_steps=15, eval_batches=2,
        max_layers=2, min_energy_share=0.0)
    sel = SelectionConfig(k_init=24, k_target=16, delta_acc=0.06,
                          score_batches=1, accept_batches=2,
                          max_score_candidates=6)
    from repro.core.schedule import energy_prioritized_compression

    p2, s2, o2, c2, result = energy_prioritized_compression(
        runner, params, state, opt_state, comp, stats, cfg, sel)
    assert result.acc_final >= result.acc0 - cfg.delta_acc - 1e-6
    accepted = [d for d in result.decisions if d.accepted]
    assert accepted, "at least one layer should accept the aggressive config"
    # energy must go down on accepted layers
    for d in accepted:
        assert d.energy_after < d.energy_before
        # restriction actually holds: <= k distinct quantized values
        w = runner.model.get_weight(p2, d.layer)
        w_int = qat.quantize_weight_int(w, c2[d.layer])
        assert len(np.unique(np.asarray(w_int))) <= d.k
    assert result.energy_after < result.energy_before


def test_pipeline_smoke():
    """Full pipeline (QAT -> profile -> schedule -> finetune) on a tiny budget."""
    runner = _tiny_runner(seed=1)
    cfg = PipelineConfig(
        qat_steps=150,
        profile_batches=1,
        profile_max_tiles=4,
        final_finetune_steps=20,
        eval_batches=2,
        schedule=ScheduleConfig(prune_ratios=(0.5,), k_targets=(16,),
                                delta_acc=0.08, finetune_steps=15,
                                trial_finetune_steps=10, eval_batches=2,
                                max_layers=1),
        selection=SelectionConfig(k_init=20, k_target=16, delta_acc=0.08,
                                  score_batches=1, accept_batches=1,
                                  max_score_candidates=4),
    )
    result = CompressionPipeline(runner, cfg).run()
    assert result.acc_base > 0.4  # learned something
    assert result.energy_saving > 0.0
    assert result.accuracy_drop < 0.1
    summary = result.summary()
    assert summary["layers"]
