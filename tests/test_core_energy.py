"""Unit + property tests for the core MAC energy model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops, grouping
from repro.core.energy_lut import grouped_model_lut, model_fidelity, trace_lut
from repro.core.layer_energy import (
    MatmulDims,
    conv_matmul_dims,
    delta_energy_remove,
    layer_energy,
    layer_energy_from_counts,
    weight_value_counts,
)
from repro.core.mac_model import DEFAULT_COEFFS, mac_transition_energy, weight_static_energy_profile
from repro.core.stats import TILE, collect_layer_stats, im2col, tile_psum_trace, tile_transition_stats

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------- bitops


def test_popcount_matches_numpy():
    xs = jnp.arange(-512, 512, dtype=jnp.int32)
    got = np.asarray(bitops.popcount(xs & 0xFF))
    want = np.asarray([bin(int(x) & 0xFF).count("1") for x in xs])
    np.testing.assert_array_equal(got, want)


def test_msb22():
    assert int(bitops.msb22(jnp.int32(0))) == -1
    assert int(bitops.msb22(jnp.int32(1))) == 0
    assert int(bitops.msb22(jnp.int32(0x3FFFFF))) == 21
    # negative values use their 22-bit two's-complement pattern -> high bit set
    assert int(bitops.msb22(jnp.int32(-1))) == 21


def test_hamming_distance_symmetric_zero_diag():
    key = jax.random.PRNGKey(0)
    xs = jax.random.randint(key, (64,), 0, 1 << 22, dtype=jnp.int32)
    assert int(jnp.sum(bitops.hamming_distance(xs, xs))) == 0
    ys = jnp.roll(xs, 1)
    np.testing.assert_array_equal(
        np.asarray(bitops.hamming_distance(xs, ys)),
        np.asarray(bitops.hamming_distance(ys, xs)),
    )


# ---------------------------------------------------------------- mac model


def test_zero_transition_energy_is_floor():
    e = mac_transition_energy(5, 3, 3, 100, 100)
    assert float(e) == pytest.approx(DEFAULT_COEFFS.c_base, abs=1e-6)


def test_pruned_weight_much_cheaper():
    key = jax.random.PRNGKey(1)
    a = jax.random.randint(key, (2, 1024), -128, 128, dtype=jnp.int32)
    p = jax.random.randint(key, (2, 1024), 0, 1 << 22, dtype=jnp.int32)
    e_zero = jnp.mean(mac_transition_energy(0, a[0], a[1], p[0], p[1]))
    e_big = jnp.mean(mac_transition_energy(-127, a[0], a[1], p[0], p[1]))
    assert float(e_zero) < 0.25 * float(e_big)


def test_energy_monotone_in_psum_hamming_distance():
    """Paper Fig 2a: power increases ~monotonically with HD of the transition."""
    base = jnp.int32(0)
    es = []
    for hd in range(0, 22, 3):
        p_cur = jnp.int32((1 << hd) - 1)  # exactly `hd` toggled bits
        e = mac_transition_energy(7, 10, 10, base, p_cur)
        es.append(float(e))
    assert all(b > a for a, b in zip(es, es[1:]))


def test_energy_higher_for_high_msb_transitions():
    """Paper Fig 2b: transitions involving higher MSBs cost more."""
    e_low = mac_transition_energy(7, 10, 10, 0b0001, 0b0010)
    e_high = mac_transition_energy(7, 10, 10, 1 << 20, 1 << 21)
    assert float(e_high) > float(e_low)


def test_weight_profile_has_spread():
    """Paper Fig 1: per-weight average power varies substantially."""
    prof = weight_static_energy_profile(n_samples=512)
    assert prof.shape == (256,)
    lo, hi = float(jnp.min(prof)), float(jnp.max(prof))
    assert hi > 1.5 * lo
    # zero weight is the cheapest (zero-gated)
    assert int(jnp.argmin(prof)) == 128


# ---------------------------------------------------------------- grouping


def test_group_ids_in_range():
    key = jax.random.PRNGKey(2)
    ps = jax.random.randint(key, (4096,), -(1 << 21), 1 << 21, dtype=jnp.int32)
    gids = grouping.group_id(ps)
    assert int(jnp.min(gids)) >= 0
    assert int(jnp.max(gids)) < grouping.N_GROUPS


def _magnitude_spread_psums(key, n):
    """Realistic partial sums: magnitudes spread across bit-widths (prefix
    sums grow along the systolic column, so small and large values coexist)."""
    k1, k2 = jax.random.split(key)
    width = jax.random.randint(k1, (n,), 1, 23, dtype=jnp.int32)
    raw = jax.random.randint(k2, (n,), 0, 1 << 22, dtype=jnp.int32)
    return raw & ((1 << width) - 1)


def test_grouping_stability_ratio_beats_random_grouping():
    """The MSB x HD grouping should explain energy variance far better than a
    random assignment of transitions to the same number of groups."""
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    n = 65536
    p_prev = _magnitude_spread_psums(k1, n)
    p_cur = _magnitude_spread_psums(k2, n)
    e = mac_transition_energy(11, 5, 5, p_prev, p_cur)

    g = grouping.group_id(p_prev) * grouping.N_GROUPS + grouping.group_id(p_cur)
    sr_model = float(grouping.stability_ratio(e, g, grouping.N_GROUPS**2))
    g_rand = jax.random.randint(k3, (n,), 0, grouping.N_GROUPS**2, dtype=jnp.int32)
    sr_rand = float(grouping.stability_ratio(e, g_rand, grouping.N_GROUPS**2))
    assert sr_model > 5 * sr_rand
    assert sr_model > 1.0


def test_group_representatives_land_in_their_group():
    reps = grouping.group_representatives(jax.random.PRNGKey(0), samples_per_group=4)
    assert reps.shape == (grouping.N_GROUPS, 4)
    gid = grouping.group_id(reps)
    expected = jnp.broadcast_to(
        jnp.arange(grouping.N_GROUPS)[:, None], gid.shape
    )
    # msb groups always match; hw may clamp for infeasible cells -> allow
    # mismatch only within the same msb group
    msb_ok = (gid // grouping.N_HD_SUBGROUPS) == (expected // grouping.N_HD_SUBGROUPS)
    assert bool(jnp.all(msb_ok))
    # low-MSB cells cannot host high Hamming weights (hw > msb+1 infeasible),
    # so exact matches are only expected for the feasible majority of cells.
    exact = float(jnp.mean((gid == expected).astype(jnp.float32)))
    assert exact > 0.5


# ---------------------------------------------------------------- trace stats


def test_tile_psum_trace_matches_matmul():
    key = jax.random.PRNGKey(4)
    w = jax.random.randint(key, (TILE, TILE), -128, 128, dtype=jnp.int32)
    a = jax.random.randint(key, (TILE, 16), -128, 128, dtype=jnp.int32)
    psums = tile_psum_trace(w, a)
    # final row of the cumsum is the full dot product column
    np.testing.assert_array_equal(
        np.asarray(psums[-1]), np.asarray(w.T @ a)
    )


def test_tile_stats_shapes_and_counts():
    key = jax.random.PRNGKey(5)
    w = jax.random.randint(key, (TILE, TILE), -128, 128, dtype=jnp.int32)
    a = jax.random.randint(key, (TILE, TILE), -128, 128, dtype=jnp.int32)
    es, cnt, gh, ah = tile_transition_stats(w, a)
    assert es.shape == (256,)
    assert gh.shape == (50, 50)
    assert ah.shape == (256, 256)
    # every MAC sees TILE-1 transitions
    assert float(jnp.sum(cnt)) == TILE * TILE * (TILE - 1)
    # activation transitions counted once per row per step
    assert float(jnp.sum(ah)) == TILE * (TILE - 1)


def test_collect_layer_stats_runs_and_luts_sane():
    key = jax.random.PRNGKey(6)
    w = jax.random.randint(key, (96, 80), -100, 100, dtype=jnp.int32)
    x = jax.random.randint(key, (80, 200), -100, 100, dtype=jnp.int32)
    stats = collect_layer_stats(w, x, max_tiles=6, key=key)
    lut = trace_lut(stats)
    assert lut.shape == (256,)
    assert bool(jnp.all(lut > 0))
    glut = grouped_model_lut(stats, n_mc=512)
    assert glut.shape == (256,)
    assert bool(jnp.all(jnp.isfinite(glut)))


def test_grouped_model_correlates_with_trace():
    """The paper's grouped model must preserve per-weight energy ordering."""
    key = jax.random.PRNGKey(7)
    w = jax.random.randint(key, (128, 128), -128, 128, dtype=jnp.int32)
    x = jax.random.randint(key, (128, 256), -128, 128, dtype=jnp.int32)
    stats = collect_layer_stats(w, x, max_tiles=8, key=key)
    fid = model_fidelity(stats, n_mc=2048)
    assert fid["pearson"] > 0.9
    assert fid["spearman"] > 0.85


def test_im2col_shape():
    x = jnp.ones((2, 8, 8, 3), jnp.int32)
    cols = im2col(x, (3, 3), stride=1, padding="SAME")
    assert cols.shape == (3 * 9, 2 * 8 * 8)


# ---------------------------------------------------------------- layer energy


def test_weight_value_counts_includes_padding():
    dims = MatmulDims(m=65, k=65, n=10)
    w = jnp.ones((65, 65), jnp.int32)
    counts = weight_value_counts(w, dims)
    assert float(counts[128 + 1]) == 65 * 65
    # padded up to 2x2 tiles of 64x64
    assert float(counts[128]) == 128 * 128 - 65 * 65
    assert float(jnp.sum(counts)) == 128 * 128


def test_layer_energy_scales_with_n():
    key = jax.random.PRNGKey(8)
    w = jax.random.randint(key, (64, 64), -128, 128, dtype=jnp.int32)
    lut = jnp.ones((256,), jnp.float32)
    e1 = layer_energy(w, lut, MatmulDims(64, 64, 64))
    e2 = layer_energy(w, lut, MatmulDims(64, 64, 128))
    assert float(e2) == pytest.approx(2 * float(e1))


def test_delta_energy_remove_matches_recompute():
    key = jax.random.PRNGKey(9)
    dims = MatmulDims(m=64, k=64, n=64)
    w = jax.random.randint(key, (64, 64), -4, 5, dtype=jnp.int32)
    lut = jax.random.uniform(key, (256,), minval=0.5, maxval=2.0)
    counts = weight_value_counts(w, dims)
    e_before = layer_energy_from_counts(counts, lut, dims)
    # remove value 3 -> remap to 2
    delta = delta_energy_remove(counts, lut, dims, 3, 2)
    w_after = jnp.where(w == 3, 2, w)
    e_after = layer_energy(w_after, lut, dims)
    assert float(e_before - e_after) == pytest.approx(float(delta), rel=1e-5)


def test_conv_matmul_dims():
    dims = conv_matmul_dims(c_in=16, c_out=32, kernel_hw=(3, 3), out_hw=(8, 8), batch=2)
    assert (dims.m, dims.k, dims.n) == (32, 144, 128)
    assert dims.total_tiles == 1 * 3 * 2


# ---------------------------------------------------------------- properties

if HAVE_HYPOTHESIS:

    @given(
        w=st.integers(min_value=-128, max_value=127),
        a0=st.integers(min_value=-128, max_value=127),
        a1=st.integers(min_value=-128, max_value=127),
        p0=st.integers(min_value=0, max_value=(1 << 22) - 1),
        p1=st.integers(min_value=0, max_value=(1 << 22) - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_energy_nonnegative_and_finite(w, a0, a1, p0, p1):
        e = float(mac_transition_energy(w, a0, a1, p0, p1))
        assert e >= 0.0
        assert np.isfinite(e)

    @given(
        p0=st.integers(min_value=0, max_value=(1 << 22) - 1),
        p1=st.integers(min_value=0, max_value=(1 << 22) - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_energy_symmetric_in_psum_swap_for_fixed_act(p0, p1):
        # HD and carry terms are symmetric; with a_prev == a_cur the whole
        # energy is symmetric under psum swap.
        e01 = float(mac_transition_energy(9, 4, 4, p0, p1))
        e10 = float(mac_transition_energy(9, 4, 4, p1, p0))
        assert e01 == pytest.approx(e10, rel=1e-6)

    @given(st.integers(min_value=-(1 << 21), max_value=(1 << 21) - 1))
    @settings(max_examples=100, deadline=None)
    def test_group_id_in_range_property(p):
        gid = int(grouping.group_id(jnp.int32(p)))
        assert 0 <= gid < grouping.N_GROUPS
