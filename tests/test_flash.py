"""Flash-attention custom VJP vs jax.autodiff of the blocked path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import AttnDims, blocked_attention


def _case(key, b, s, hkv, g, hd):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, s, hkv * g, hd))
    k = jax.random.normal(k2, (b, s, hkv, hd))
    v = jax.random.normal(k3, (b, s, hkv, hd))
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 12), (False, 0)])
@pytest.mark.parametrize("hkv,g", [(2, 1), (1, 4), (2, 2)])
def test_flash_forward_matches_blocked(causal, window, hkv, g):
    key = jax.random.PRNGKey(hkv * 10 + g + window)
    b, s, hd = 2, 32, 16
    q, k, v = _case(key, b, s, hkv, g, hd)
    dims = AttnDims(d_model=hkv * g * hd, n_heads=hkv * g, n_kv_heads=hkv,
                    head_dim=hd, causal=causal, window=window)
    out_ref = blocked_attention(q, k, v, dims, q_block=8, kv_block=8)
    out_flash = blocked_attention(q, k, v, dims, q_block=8, kv_block=8,
                                  use_flash=True)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 12), (False, 0)])
def test_flash_gradients_match_autodiff(causal, window):
    key = jax.random.PRNGKey(window + 1)
    b, s, hkv, g, hd = 2, 32, 2, 2, 16
    q, k, v = _case(key, b, s, hkv, g, hd)
    dims = AttnDims(d_model=hkv * g * hd, n_heads=hkv * g, n_kv_heads=hkv,
                    head_dim=hd, causal=causal, window=window)
    tangent = jax.random.normal(jax.random.fold_in(key, 7),
                                (b, s, hkv * g, hd))

    def loss_ref(q, k, v):
        out = blocked_attention(q, k, v, dims, q_block=8, kv_block=8)
        return jnp.sum(out * tangent)

    def loss_flash(q, k, v):
        out = blocked_attention(q, k, v, dims, q_block=8, kv_block=8,
                                use_flash=True)
        return jnp.sum(out * tangent)

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b_, name in zip(g_flash, g_ref, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-3,
                                   atol=2e-3, err_msg=f"d{name}")


def test_flash_model_level_grads():
    """Whole-model gradients with flash on vs off must agree."""
    from repro.models.config import ArchConfig
    from repro.models.lm import build_lm
    from repro.nn.spec import init_params

    cfg = ArchConfig(name="t", family="dense", n_layers=3, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=300,
                     head_dim=16, pattern=("local", "attn"), window=16,
                     compute_dtype="float32")
    m = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), m.spec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 300)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def loss(p, flash):
        return m.loss(p, batch, q_block=8, kv_block=8, use_flash=flash,
                      remat=True)[0]

    l0, g0 = jax.value_and_grad(lambda p: loss(p, False))(params)
    l1, g1 = jax.value_and_grad(lambda p: loss(p, True))(params)
    assert float(jnp.abs(l0 - l1)) < 1e-5
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g0, g1)
    assert max(jax.tree.leaves(diffs)) < 1e-3
