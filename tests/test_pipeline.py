"""Unified pipeline API: plan round-trip, resume equivalence, refactor parity.

The load-bearing guarantees under test:

* `CompressionPlan.save/load` round-trips bit-exactly (codebooks, masks,
  decisions, packed artifacts);
* ``run_until(stage)`` + save + `Pipeline.from_plan` + ``run()`` produces
  exactly what a single uninterrupted ``run()`` produces;
* `Pipeline` reproduces the pre-refactor hand-wired flow (QAT train ->
  profile -> energy_prioritized_compression -> final finetune -> export)
  decision for decision, codebook for codebook — the api_redesign moved the
  wiring, not the math;
* the `repro` CLI parses with no jax import, and `repro compress --reduced`
  produces a plan that passes ``tools/check_gates.py --plan``.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.schedule import ScheduleConfig
from repro.core.weight_selection import SelectionConfig
from repro.pipeline import (
    CompressionPlan,
    Pipeline,
    PipelineConfig,
    ProfileStageConfig,
    TargetConfig,
    TrainStageConfig,
)

ROOT = os.path.join(os.path.dirname(__file__), "..")


def micro_config() -> PipelineConfig:
    """Smallest CNN pipeline that still accepts a restriction on one layer."""
    return PipelineConfig(
        target=TargetConfig(kind="cnn", arch="lenet5", seed=0, data_seed=3,
                            batch_size=64, lr=2e-3),
        train=TrainStageConfig(qat_steps=40, final_finetune_steps=10,
                               eval_batches=1),
        profile=ProfileStageConfig(batches=1, max_tiles=2),
        schedule=ScheduleConfig(prune_ratios=(0.5,), k_targets=(16,),
                                delta_acc=0.1, finetune_steps=6,
                                trial_finetune_steps=5, eval_batches=1,
                                max_layers=1),
        selection=SelectionConfig(k_init=18, k_target=16, delta_acc=0.1,
                                  score_batches=1, accept_batches=1,
                                  max_score_candidates=2),
    )


@pytest.fixture(scope="module")
def staged_run(tmp_path_factory):
    """One micro pipeline run, interrupted after `profile` (plan saved to
    disk at that point) and then driven to completion — the reference for
    both the resume-equivalence and the refactor-parity tests."""
    base = tmp_path_factory.mktemp("plans") / "profile_ckpt"
    pipe = Pipeline(micro_config())
    pipe.run_until("profile")
    pipe.plan.save(base)
    full_plan = pipe.run()
    return base, full_plan


def _codebook_state(plan):
    return {layer: (np.asarray(c["codebook"]), int(c["codebook_k"]),
                    np.asarray(c["mask"]))
            for layer, c in plan.comp.items()}


def _assert_same_compression(plan_a, plan_b):
    assert plan_a.decisions == plan_b.decisions
    cb_a, cb_b = _codebook_state(plan_a), _codebook_state(plan_b)
    assert cb_a.keys() == cb_b.keys()
    for layer in cb_a:
        np.testing.assert_array_equal(cb_a[layer][0], cb_b[layer][0])
        assert cb_a[layer][1] == cb_b[layer][1]
        np.testing.assert_array_equal(cb_a[layer][2], cb_b[layer][2])
    arts_a = plan_a.artifacts or {}
    arts_b = plan_b.artifacts or {}
    assert arts_a.keys() == arts_b.keys()
    for name in arts_a:
        np.testing.assert_array_equal(np.asarray(arts_a[name].packed),
                                      np.asarray(arts_b[name].packed))
        np.testing.assert_array_equal(np.asarray(arts_a[name].codebook),
                                      np.asarray(arts_b[name].codebook))


# ------------------------------------------------------------------- config


def test_config_roundtrip_and_validation():
    cfg = micro_config()
    d = cfg.to_dict()
    cfg2 = PipelineConfig.from_dict(d)
    assert cfg2 == cfg                       # dataclass eq, tuples restored
    assert isinstance(cfg2.schedule.prune_ratios, tuple)
    cfg3 = PipelineConfig.from_json(cfg.to_json())
    assert cfg3 == cfg

    with pytest.raises(ValueError, match="unknown field"):
        bad = cfg.to_dict()
        bad["schedule"]["not_a_knob"] = 1
        PipelineConfig.from_dict(bad)
    with pytest.raises(ValueError, match="search_mode"):
        bad = cfg.to_dict()
        bad["schedule"]["search_mode"] = "quantum"
        PipelineConfig.from_dict(bad)
    with pytest.raises(ValueError, match="kind"):
        bad = cfg.to_dict()
        bad["target"]["kind"] = "rnn"
        PipelineConfig.from_dict(bad)

    over = cfg.with_overrides({"schedule": {"max_layers": 2}})
    assert over.schedule.max_layers == 2 and cfg.schedule.max_layers == 1
    with pytest.raises(ValueError, match="unknown config section"):
        cfg.with_overrides({"sched": {"max_layers": 2}})


# ------------------------------------------------------------ plan roundtrip


def test_plan_json_npz_roundtrip_bit_exact(staged_run, tmp_path):
    _, full_plan = staged_run
    base = tmp_path / "full"
    json_path, npz_path = full_plan.save(base)
    assert json_path.exists() and npz_path.exists()

    loaded = CompressionPlan.load(base)
    assert loaded.completed == full_plan.completed
    assert loaded.decisions == full_plan.decisions
    assert loaded.metrics == full_plan.metrics
    assert loaded.shares == full_plan.shares
    assert loaded.config == full_plan.config
    _assert_same_compression(full_plan, loaded)
    # params and trace statistics round-trip bit-exactly too
    for (a, b) in zip(jax.tree.leaves(full_plan.params),
                      jax.tree.leaves(loaded.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for layer, s in full_plan.stats.items():
        np.testing.assert_array_equal(np.asarray(s.act_hist),
                                      np.asarray(loaded.stats[layer].act_hist))
        assert s.n_transitions == loaded.stats[layer].n_transitions
    loaded.validate()


def test_plan_is_a_pytree(staged_run):
    _, full_plan = staged_run
    leaves = jax.tree.leaves(full_plan)
    assert leaves, "plan should flatten to its array sections"
    mapped = jax.tree.map(lambda x: x, full_plan)
    assert mapped.decisions == full_plan.decisions
    assert mapped.completed == full_plan.completed


def test_plan_load_rejects_wrong_schema(staged_run, tmp_path):
    _, full_plan = staged_run
    base = tmp_path / "tampered"
    json_path, _ = full_plan.save(base)
    doc = json.loads(json_path.read_text())
    doc["schema_version"] = 99
    json_path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="schema"):
        CompressionPlan.load(base)


# --------------------------------------------------------- resume == run()


def test_run_until_resume_equals_full_run(staged_run):
    """Save after `profile`, reload into a FRESH target, run to completion:
    every decision, codebook, artifact and metric must match the
    uninterrupted run."""
    base, full_plan = staged_run
    resumed = Pipeline.from_plan(CompressionPlan.load(base)).run()
    assert resumed.completed == full_plan.completed
    _assert_same_compression(full_plan, resumed)
    for key in ("acc0", "acc_final", "energy_before", "energy_after",
                "max_codebook", "serve_accuracy", "serve_logit_rel_err"):
        assert resumed.metrics[key] == full_plan.metrics[key], key


# ------------------------------------------------- pre-refactor parity gate


def test_pipeline_matches_prerefactor_wiring(staged_run):
    """The acceptance gate: `Pipeline.run()` produces the same schedule
    decisions and exported artifacts as the pre-refactor hand wiring
    (QAT train -> profile -> energy_prioritized_compression -> final
    finetune -> export_model), given the same seeds and budgets."""
    from repro.core.export import export_model
    from repro.core.runner import CnnRunner
    from repro.core.schedule import energy_prioritized_compression
    from repro.data.synthetic import SyntheticImages
    from repro.nn import cnn

    _, full_plan = staged_run
    cfg = micro_config()
    runner = CnnRunner(cnn.lenet5(), SyntheticImages(seed=cfg.target.data_seed),
                       batch_size=cfg.target.batch_size, lr=cfg.target.lr,
                       seed=cfg.target.seed)
    params, state, opt_state, comp = runner.init()
    params, state, opt_state, _ = runner.train(
        params, state, opt_state, comp, cfg.train.qat_steps)
    stats = runner.profile(params, state, comp,
                           n_batches=cfg.profile.batches,
                           max_tiles=cfg.profile.max_tiles)
    params, state, opt_state, comp, sched = energy_prioritized_compression(
        runner, params, state, opt_state, comp, stats, cfg.schedule,
        cfg.selection)
    if cfg.train.final_finetune_steps:
        params, state, opt_state, _ = runner.train(
            params, state, opt_state, comp, cfg.train.final_finetune_steps)
    arts = export_model(runner.model, params, comp)

    # identical accepted (prune, k) per layer, in the same sweep order
    got = [(d["layer"], d["prune_ratio"], d["k"], d["accepted"])
           for d in full_plan.decisions]
    want = [(d.layer, d.prune_ratio, d.k, d.accepted)
            for d in sched.decisions]
    assert got == want
    # identical codebooks + masks
    for layer in comp:
        np.testing.assert_array_equal(
            np.asarray(comp[layer]["codebook"]),
            np.asarray(full_plan.comp[layer]["codebook"]))
        assert int(comp[layer]["codebook_k"]) == int(
            full_plan.comp[layer]["codebook_k"])
        np.testing.assert_array_equal(
            np.asarray(comp[layer]["mask"]),
            np.asarray(full_plan.comp[layer]["mask"]))
    # identical exported artifacts
    assert arts.keys() == (full_plan.artifacts or {}).keys()
    for name in arts:
        np.testing.assert_array_equal(
            np.asarray(arts[name].packed),
            np.asarray(full_plan.artifacts[name].packed))
        np.testing.assert_array_equal(
            np.asarray(arts[name].scale),
            np.asarray(full_plan.artifacts[name].scale))


# ------------------------------------------------------------------ the CLI


def _run_sub(args, *, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    return subprocess.run([sys.executable] + args, env=env,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=ROOT)


def test_cli_help_exits_zero_without_jax():
    out = _run_sub(["-m", "repro", "--help"], timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "profile" in out.stdout and "serve" in out.stdout
    probe = ("import sys; import repro.pipeline.cli as cli; "
             "cli.build_parser(); import repro.pipeline; "
             "assert 'jax' not in sys.modules, 'jax was imported'; "
             "print('NOJAX-OK')")
    out = _run_sub(["-c", probe], timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "NOJAX-OK" in out.stdout


def test_cli_compress_reduced_smoke_and_plan_gate(tmp_path):
    """`repro compress --reduced` end to end in a subprocess, then the saved
    plan passes the CI schema gate (`check_gates.py --plan`)."""
    base = tmp_path / "cli_plan"
    out = _run_sub(["-m", "repro", "compress", "--reduced", "--quiet",
                    "--plan-out", str(base)])
    assert out.returncode == 0, out.stderr[-2000:]
    assert (tmp_path / "cli_plan.json").exists()
    assert (tmp_path / "cli_plan.npz").exists()
    summary = json.loads(out.stdout[out.stdout.index("{"):
                                    out.stdout.rindex("}") + 1])
    assert summary["completed"] == ["profile", "energy_model", "schedule"]

    gate = _run_sub(["tools/check_gates.py", "--plan", str(base)],
                    timeout=120)
    assert gate.returncode == 0, gate.stdout + gate.stderr[-1000:]


def test_cli_lm_plan_compress_then_serve(tmp_path):
    """LM flow across two CLI invocations: compress saves a plan, serve
    resumes it — exercising export + the engine with zero post-warmup
    recompiles and engine==oneshot parity on an exact-fit trace (the
    bench_serving contract)."""
    base = tmp_path / "lm_plan"
    out = _run_sub(["-m", "repro", "compress", "--target", "lm",
                    "--arch", "olmo-1b", "--reduced", "--compress-k", "4",
                    "--quiet", "--plan-out", str(base)])
    assert out.returncode == 0, out.stderr[-2000:]

    out = _run_sub(["-m", "repro", "serve", "--plan-in", str(base),
                    "--mode", "engine", "--requests", "2",
                    "--prompt-len", "8", "--new-tokens", "6", "--no-mixed",
                    "--max-batch", "2", "--verify-oneshot", "--quiet"])
    assert out.returncode == 0, out.stderr[-2000:]
    summary = json.loads(out.stdout[out.stdout.index("{"):
                                    out.stdout.rindex("}") + 1])
    m = summary["metrics"]
    assert summary["completed"] == ["profile", "energy_model", "schedule",
                                    "export", "serve"]
    assert m["serve_recompiles_after_warmup"] == 0
    assert m["serve_parity_engine_vs_oneshot"] is True
    assert m["export_layers"] > 0


# ------------------------------------------------------------- schema gate


def test_check_gates_plan_mode_rejects_bad_docs(tmp_path):
    from repro.pipeline.schema import validate_plan_doc

    good = {
        "format": "repro.pipeline.plan", "schema_version": 1,
        "completed": ["profile", "energy_model"],
        "shares": {"a": 0.6, "b": 0.4}, "decisions": [], "metrics": {},
        "arrays": {"a00000": {"shape": [2], "dtype": "float32"}},
    }
    assert all(g["pass"] for g in validate_plan_doc(good))

    bad_order = dict(good, completed=["schedule", "profile"])
    assert any(not g["pass"] for g in validate_plan_doc(bad_order))
    bad_shares = dict(good, shares={"a": 0.2})
    assert any(not g["pass"] for g in validate_plan_doc(bad_shares))
    bad_decision = dict(
        good, completed=["profile", "energy_model", "schedule"],
        decisions=[{"layer": "x", "accepted": True, "k": 200,
                    "energy_before": 1.0, "energy_after": 0.5}])
    assert any(not g["pass"] for g in validate_plan_doc(bad_decision))

    # missing file / tampered version through the tool entry point
    import tools.check_gates as cg

    assert cg.check_plan(str(tmp_path / "nope")) == 1
    (tmp_path / "t.json").write_text(json.dumps(dict(good, schema_version=9)))
    (tmp_path / "t.npz").write_bytes(b"")
    assert cg.check_plan(str(tmp_path / "t")) == 1


# --------------------------------------------------- legacy shim delegation


def test_legacy_compression_pipeline_delegates():
    """The deprecated `CompressionPipeline` must warn and expose the plan."""
    from repro.core.compression import CompressionPipeline
    from repro.core.compression import PipelineConfig as LegacyConfig
    from repro.core.runner import CnnRunner
    from repro.data.synthetic import SyntheticImages
    from repro.nn import cnn

    runner = CnnRunner(cnn.lenet5(), SyntheticImages(seed=3), batch_size=32,
                       lr=2e-3)
    cfg = LegacyConfig(
        qat_steps=5, profile_batches=1, profile_max_tiles=2,
        final_finetune_steps=0, eval_batches=1,
        schedule=ScheduleConfig(prune_ratios=(0.5,), k_targets=(16,),
                                delta_acc=0.5, finetune_steps=2,
                                trial_finetune_steps=2, eval_batches=1,
                                max_layers=1),
        selection=SelectionConfig(k_init=18, k_target=16, delta_acc=0.5,
                                  score_batches=1, accept_batches=1,
                                  max_score_candidates=2),
    )
    pipe = CompressionPipeline(runner, cfg)
    with pytest.warns(DeprecationWarning):
        result = pipe.run()
    assert pipe.plan.is_done("schedule") and not pipe.plan.is_done("export")
    assert result.summary()["layers"]
    assert pipe.comp is pipe.plan.comp
