"""Multi-plan fleet serving: content fingerprints, the plan registry, the
SLO-aware router (degrade/recover with hysteresis, per-tenant accounting,
budget routing), routed-vs-pinned parity, and the plan-centric serving API's
deprecation shims."""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models.lm import build_lm
from repro.nn.spec import init_params
from repro.pipeline.config import parse_plan_spec
from repro.serving import (
    EngineConfig,
    FleetRouter,
    PlanHandle,
    PlanRegistry,
    RequestBudget,
    RouterConfig,
    ServeRequest,
    ServeResult,
    ServingEngine,
    comp_fingerprint,
)
from repro.serving.cache import ServeCompileCache

CFG = EngineConfig(max_batch=2, prompt_buckets=(8,), new_token_buckets=(4,),
                   max_waves=2)
# capacity 4 slots: small bursts cross the watermark, so the routing tests
# stay cheap. hysteresis=2 and the 0.5 watermark mirror bench_fleet.py.
ROUTER = RouterConfig(high_watermark=0.5, low_watermark=0.25, hysteresis=2)
SHAPES = [(6, 4), (8, 4)]


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("olmo-1b").scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), model.spec)
    return model, params


@pytest.fixture(scope="module")
def handles(lm):
    model, _ = lm
    return [PlanHandle.uncompressed(),
            PlanHandle.from_compress_k(model, 8),
            PlanHandle.from_compress_k(model, 4)]


def _request(model, plen=6, ntok=4, *, tenant="default", budget=None, seed=3):
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, model.cfg.vocab, size=plen).astype(np.int32)
    return ServeRequest(tokens=prompt, max_new_tokens=ntok, tenant=tenant,
                        budget=budget)


# --------------------------------------------------------------- fingerprints


def test_comp_fingerprint_distinguishes_content(lm):
    model, _ = lm
    base = PlanHandle.uncompressed()
    k4 = PlanHandle.from_compress_k(model, 4)
    k4m2 = PlanHandle.from_compress_k(model, 4, msr_bits=2)
    k8 = PlanHandle.from_compress_k(model, 8)
    fps = [h.fingerprint for h in (base, k4, k4m2, k8)]
    assert len(set(fps)) == 4, f"fingerprints collide: {fps}"
    # same content -> same fingerprint (rebuild from scratch)
    assert PlanHandle.from_compress_k(model, 4).fingerprint == k4.fingerprint
    # the decision-set extra separates equal comps scheduled differently
    assert comp_fingerprint(None) != comp_fingerprint(None, extra="layer:0")


def test_registry_dedupes_by_content_and_guards_ids(lm):
    model, _ = lm
    k4 = PlanHandle.from_compress_k(model, 4)
    reg = PlanRegistry([PlanHandle.uncompressed(), k4])
    # identical content registers as the existing handle, whatever its id
    again = reg.register(PlanHandle.from_compress_k(model, 4,
                                                    plan_id="k4-copy"))
    assert again is k4
    assert len(reg) == 2 and "k4-copy" not in reg
    # an id collision with *different* content is an error, not a silent swap
    with pytest.raises(ValueError, match="already registered"):
        reg.register(PlanHandle.from_compress_k(model, 8, plan_id="k4"))
    with pytest.raises(KeyError):
        reg.get("missing")


def test_registry_from_dir_errors(tmp_path):
    with pytest.raises(FileNotFoundError):
        PlanRegistry.from_dir(tmp_path / "nope")
    with pytest.raises(ValueError, match="no CompressionPlan"):
        PlanRegistry.from_dir(tmp_path)


def test_cache_keys_on_fingerprint_not_compress_k(lm):
    """Regression: two plans with equal k but different msr_bits used to
    share (arch, k, bucket) executable keys and the (arch, k) artifact map —
    serving the second plan with the first plan's compiled weights."""
    model, _ = lm
    k4 = PlanHandle.from_compress_k(model, 4)
    k4m2 = PlanHandle.from_compress_k(model, 4, msr_bits=2)
    assert k4.compress_k == k4m2.compress_k == 4
    caches = [ServeCompileCache(model, arch="olmo-1b", comp=h.comp,
                                compress_k=h.compress_k, config=CFG,
                                fingerprint=h.fingerprint)
              for h in (k4, k4m2)]
    from repro.serving.bucketing import bucket_for

    bucket = bucket_for(6, 4, CFG, batch=CFG.max_batch)
    assert caches[0]._key(bucket) != caches[1]._key(bucket)
    assert (caches[0].arch, caches[0].fingerprint) != \
        (caches[1].arch, caches[1].fingerprint)
    # equal content still shares the key (no spurious cache splits)
    twin = ServeCompileCache(model, arch="olmo-1b", comp=k4.comp,
                             compress_k=4, config=CFG)
    assert twin._key(bucket) == caches[0]._key(bucket)


def test_router_config_validation():
    with pytest.raises(ValueError):
        RouterConfig(high_watermark=0.2, low_watermark=0.5)
    with pytest.raises(ValueError):
        RouterConfig(hysteresis=0)
    with pytest.raises(ValueError):
        RouterConfig(low_watermark=-0.1)


def test_parse_plan_spec():
    assert parse_plan_spec("base") == (0, 0)
    assert parse_plan_spec("k8") == (8, 0)
    assert parse_plan_spec("k4m2") == (4, 2)
    assert parse_plan_spec("plans/olmo-k4") == (None, 0)


# -------------------------------------------------------------------- routing


@pytest.fixture(scope="module")
def fleet(lm, handles):
    model, params = lm
    fr = FleetRouter(model, params, handles, config=CFG, router=ROUTER)
    fr.warmup(SHAPES)
    return fr


def test_fleet_levels_sorted_by_energy(fleet):
    energies = [float(h.energy_per_token) for h in fleet.levels]
    assert energies == sorted(energies, reverse=True)
    assert fleet.levels[0].plan_id == "base"       # high fidelity first
    assert fleet.levels[-1].plan_id == "k4"        # most aggressive last


def test_burst_degrades_trickle_recovers_with_hysteresis(lm, fleet):
    """One shared drive through the module fleet: burst past the watermark
    (degrade), then drain-per-submit (recover), asserting the route log at
    every phase. Shared because engines compile once per module."""
    model, _ = lm
    log0 = len(fleet.route_log)
    burst = [_request(model, tenant=f"tenant{i % 2}", seed=i)
             for i in range(10)]
    rids = [fleet.submit(r) for r in burst]
    results = fleet.run()
    assert all(rid in results for rid in rids)
    assert all(len(results[rid].tokens) == 4 for rid in rids)

    levels = [e["level"] for e in fleet.route_log[log0:]]
    # pressure only rises during the burst: the level may only step toward
    # aggressive, never flap back mid-burst
    assert levels == sorted(levels), f"level flapped during burst: {levels}"
    assert levels[0] == 0 and levels[-1] == len(fleet.levels) - 1
    degrades = sum(1 for a, b in zip(levels, levels[1:]) if b > a)
    assert degrades == len(fleet.levels) - 1
    # hysteresis: consecutive level changes are >= hysteresis submissions
    # apart (a single pressure spike cannot move the level)
    change_at = [i for i in range(1, len(levels))
                 if levels[i] != levels[i - 1]]
    assert all(b - a >= ROUTER.hysteresis
               for a, b in zip(change_at, change_at[1:]))

    # trickle: queue is empty at every submit, so the router walks back to
    # high fidelity, again gated by hysteresis
    log1 = len(fleet.route_log)
    for i in range(5):
        rid = fleet.submit(_request(model, tenant="tenant0", seed=20 + i))
        out = fleet.run()
        assert len(out[rid].tokens) == 4
    trickle_levels = [e["level"] for e in fleet.route_log[log1:]]
    assert trickle_levels == sorted(trickle_levels, reverse=True)
    assert trickle_levels[-1] == 0
    rep = fleet.report()
    assert rep["level_degrades"] >= 2 and rep["level_recovers"] >= 2


def test_budget_routed_not_rejected(lm, fleet):
    model, _ = lm
    lo = float(fleet.levels[-1].energy_per_token)
    hi = float(fleet.levels[-2].energy_per_token)
    # satisfiable cap between the two most aggressive plans: routed to the
    # most aggressive even though the idle router sits at high fidelity
    cap = (lo + hi) / 2
    rid = fleet.submit(_request(
        model, budget=RequestBudget(energy_eu_per_token=cap)))
    assert fleet.route_log[-1]["plan_id"] == fleet.levels[-1].plan_id
    assert fleet.route_log[-1]["budget_miss"] is False
    # unsatisfiable cap: still served (most aggressive), miss recorded
    rid2 = fleet.submit(_request(
        model, budget=RequestBudget(energy_eu_per_token=lo * 0.5)))
    assert fleet.route_log[-1]["plan_id"] == fleet.levels[-1].plan_id
    assert fleet.route_log[-1]["budget_miss"] is True
    out = fleet.run()
    assert len(out[rid].tokens) == 4 and len(out[rid2].tokens) == 4
    rep = fleet.report()
    assert rep["slo_total"] >= 2
    assert rep["slo_hits"] <= rep["slo_total"] - 1


def test_tenant_and_plan_accounting_sum_to_totals(fleet):
    rep = fleet.report()
    assert sum(t["requests"] for t in rep["tenants"].values()) \
        == rep["requests"]
    assert sum(t["new_tokens"] for t in rep["tenants"].values()) \
        == rep["new_tokens"]
    assert sum(t["energy_eu"] for t in rep["tenants"].values()) \
        == pytest.approx(rep["energy_eu_total"], rel=1e-6)
    assert sum(p["requests"] for p in rep["plans"].values()) \
        == rep["requests"]
    assert sum(p["energy_eu"] for p in rep["plans"].values()) \
        == pytest.approx(rep["energy_eu_total"], rel=1e-6)
    for t in rep["tenants"].values():
        assert 0.0 <= t["slo_hit_rate"] <= 1.0
    assert rep["recompiles_after_warmup"] == 0


def test_routed_matches_pinned_per_plan(lm):
    """Routing picks *which* plan serves a request, never what that plan
    outputs: a pinned engine of the routed plan reproduces the tokens
    exactly. Oneshot mode serves batch-1, so the pinned engine's output is
    independent of what else was in the fleet's queue."""
    model, params = lm
    handles = [PlanHandle.uncompressed(), PlanHandle.from_compress_k(model, 4)]
    fr = FleetRouter(model, params, handles, mode="oneshot", config=CFG,
                     router=RouterConfig(high_watermark=0.3,
                                         low_watermark=0.1, hysteresis=1))
    fr.warmup(SHAPES)
    reqs = [_request(model, plen=5 + (i % 3), seed=30 + i) for i in range(6)]
    routed = fr.serve(reqs)
    plans = [e["plan_id"] for e in fr.route_log]
    assert len(set(plans)) == 2, f"trace routed to one plan only: {plans}"
    for h in handles:
        eng = ServingEngine(model, params, mode="oneshot", config=CFG, plan=h)
        eng.warmup(SHAPES)
        pinned = eng.serve(list(reqs))
        for i, pid in enumerate(plans):
            if pid == h.plan_id:
                assert list(routed[i].tokens) == list(pinned[i].tokens)


# ------------------------------------------------------- plan-centric API


def test_serve_request_api_returns_ordered_results(lm, fleet):
    model, _ = lm
    reqs = [_request(model, plen=6, seed=40 + i, tenant="api") for i in range(3)]
    results = fleet.serve(reqs)
    assert [type(r) for r in results] == [ServeResult] * 3
    assert all(r.stats.tenant == "api" for r in results)
    assert all(r.stats.plan_id in fleet.engines for r in results)


def test_engine_serve_legacy_signature_warns(lm):
    model, params = lm
    eng = ServingEngine(model, params, mode="oneshot", config=CFG)
    eng.warmup(SHAPES)
    req = _request(model, plen=6, seed=50)
    new = eng.serve([req])
    with pytest.warns(DeprecationWarning, match="ServeRequest"):
        old = eng.serve([req.tokens], 4)
    assert isinstance(old, dict) and len(old) == 1
    assert list(next(iter(old.values())).tokens) == list(new[0].tokens)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            eng.serve([req.tokens], [4, 4])  # length mismatch still raises


def test_engine_compress_k_constructor_warns(lm):
    model, params = lm
    with pytest.warns(DeprecationWarning, match="PlanHandle"):
        eng = ServingEngine(model, params, mode="oneshot", config=CFG,
                            compress_k=4)
    assert eng.plan.compress_k == 4
    assert eng.plan.fingerprint \
        == PlanHandle.from_compress_k(model, 4).fingerprint
    with pytest.raises(ValueError, match="not both"):
        ServingEngine(model, params, config=CFG,
                      plan=PlanHandle.uncompressed(), compress_k=4)
