"""Batched candidate sweep: decision parity with the serial reference,
rollback correctness, lockstep elimination, and the sharded sweep path."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import qat
from repro.core.layer_energy import LayerEnergyModel, MatmulDims
from repro.core.runner import CnnRunner
from repro.core.schedule import ScheduleConfig, energy_prioritized_compression
from repro.core.weight_selection import (
    SelectionConfig,
    greedy_backward_elimination,
    lockstep_backward_elimination,
)
from repro.data.synthetic import SyntheticImages
from repro.nn import cnn


# ------------------------------------------------------ stacked-comp helpers


def test_stack_broadcast_index_roundtrip():
    comps = [qat.identity_comp((4, 3)) for _ in range(3)]
    comps[1]["codebook"], comps[1]["codebook_k"] = qat.make_codebook([-8, 0, 8])
    stacked = qat.stack_pytrees(comps)
    assert stacked["mask"].shape == (3, 4, 3)
    back = qat.index_pytree(stacked, 1)
    assert int(back["codebook_k"]) == 3
    bc = qat.broadcast_pytree(comps[0], 5)
    assert bc["codebook"].shape == (5, qat.K_MAX)
    padded = qat.pad_leading(stacked, 4)
    assert padded["mask"].shape == (4, 4, 3)
    np.testing.assert_array_equal(np.asarray(padded["mask"][3]),
                                  np.asarray(stacked["mask"][2]))


def test_make_codebooks_matches_make_codebook():
    sets = [[-16, 0, 16], [0], list(range(-8, 8))]
    cbs, ks = qat.make_codebooks(sets)
    for e, values in enumerate(sets):
        cb, k = qat.make_codebook(values)
        np.testing.assert_array_equal(np.asarray(cbs[e]), np.asarray(cb))
        assert int(ks[e]) == int(k)


# -------------------------------------------------------- lockstep selection


def _toy_model(name="t"):
    counts = np.zeros((256,))
    lut = np.ones((256,))
    candidate = [-64, -32, -8, 0, 8, 32, 64, 96]
    for v in candidate:
        counts[v + 128] = 50.0
        lut[v + 128] = 1.0 + abs(v) / 32.0
    return LayerEnergyModel(name, MatmulDims(64, 64, 64),
                            np.asarray(lut), np.asarray(counts)), candidate


def _toy_eval(values, n_batches, sensitivity=(32, -32)):
    del n_batches
    if any(s not in values for s in sensitivity):
        return 0.2
    return 0.9 - 0.005 * (8 - len(values))


def test_lockstep_matches_serial_elimination():
    """N independent eliminations advanced in lockstep must emit exactly the
    per-candidate decisions of N serial `greedy_backward_elimination` runs."""
    cfgs = [SelectionConfig(k_target=k, delta_acc=0.05, score_batches=1,
                            accept_batches=2, max_score_candidates=3)
            for k in (4, 5, 6)]
    models, candidates = [], []
    for name in ("a", "b", "c"):
        m, cand = _toy_model(name)
        models.append(m)
        candidates.append(cand)

    serial = [greedy_backward_elimination(
        m, c, cfg, acc0=0.9, eval_with_codebook=_toy_eval)
        for m, c, cfg in zip(models, candidates, cfgs)]

    calls = []

    def eval_requests(reqs, n_batches):
        calls.append(len(reqs))
        return [_toy_eval(v, n_batches) for _, v in reqs]

    lock = lockstep_backward_elimination(models, candidates, cfgs, 0.9,
                                         eval_requests=eval_requests)
    for (sv, sr), (lv, lr) in zip(serial, lock):
        assert sv == lv
        assert sr.removed == lr.removed
        assert sr.essential == lr.essential
        assert sr.acc_checks == lr.acc_checks
        assert sr.energy_after == lr.energy_after
    # the whole point: rounds fuse across candidates into multi-request calls
    assert max(calls) > 1


# ------------------------------------------------------------- seeded parity


def _runner():
    # noisier images than the default so the aggressive candidates actually
    # cost accuracy and the accept decision has something to decide
    return CnnRunner(cnn.lenet5(), SyntheticImages(seed=3, noise=1.4),
                     batch_size=64, lr=2e-3, seed=0)


@pytest.fixture(scope="module")
def trained_lenet():
    runner = _runner()
    params, state, opt_state, comp = runner.init()
    params, state, opt_state, _ = runner.train(params, state, opt_state,
                                               comp, 180)
    stats = runner.profile(params, state, comp, n_batches=1, max_tiles=6)
    return runner, params, state, opt_state, comp, stats


def _schedule_cfg(mode):
    return ScheduleConfig(
        search_mode=mode,
        prune_ratios=(0.95, 0.5), k_targets=(8,), delta_acc=0.04,
        finetune_steps=8, trial_finetune_steps=6, eval_batches=2,
        max_layers=2, min_energy_share=0.0)


_SEL = SelectionConfig(k_init=12, k_target=8, delta_acc=0.04,
                       score_batches=1, accept_batches=2,
                       max_score_candidates=4)


def test_batched_reproduces_serial_decisions(trained_lenet):
    """The headline parity gate: on a seeded LeNet run, the batched sweep
    must accept exactly the serial walk's (prune, k) per layer and land on
    the same energy saving (decisions identical; trajectories only differ by
    vmapped-vs-single fp summation order)."""
    runner, params, state, opt_state, comp, stats = trained_lenet
    results = {}
    for mode in ("serial", "batched"):
        _, _, _, _, res = energy_prioritized_compression(
            runner, params, state, opt_state, comp, stats,
            _schedule_cfg(mode), _SEL)
        results[mode] = res

    ser, bat = results["serial"], results["batched"]
    assert [(d.layer, d.prune_ratio, d.k, d.accepted) for d in ser.decisions] \
        == [(d.layer, d.prune_ratio, d.k, d.accepted) for d in bat.decisions]
    assert ser.acc0 == bat.acc0
    # identical decisions -> identical codebook sizes; energies agree to the
    # fp drift of the diverging fine-tune trajectories
    np.testing.assert_allclose(bat.energy_saving, ser.energy_saving,
                               atol=5e-3)
    for ds, db in zip(ser.decisions, bat.decisions):
        if ds.accepted:
            np.testing.assert_allclose(db.saving, ds.saving, atol=5e-3)
    # selection reports pair up accept-for-accept
    assert [r.layer for r in ser.selection_reports] \
        == [r.layer for r in bat.selection_reports]


def test_rejected_candidates_leave_state_untouched(trained_lenet):
    """Rollback correctness: when no candidate passes the floor, the sweep
    must hand back the caller's params/opt_state/comp objects unchanged."""
    runner, params, state, opt_state, comp, stats = trained_lenet
    cfg = _schedule_cfg("batched")
    cfg.delta_acc = -1.0   # floor acc0 + 1: unreachable, every candidate fails
    cfg.max_layers = 1
    p2, s2, o2, c2, res = energy_prioritized_compression(
        runner, params, state, opt_state, comp, stats, cfg, _SEL)
    assert all(not d.accepted for d in res.decisions)
    assert res.energy_saving == 0.0
    for got, want in ((p2, params), (o2, opt_state)):
        leaves_got = jax.tree.leaves(got)
        leaves_want = jax.tree.leaves(want)
        assert len(leaves_got) == len(leaves_want)
        for a, b in zip(leaves_got, leaves_want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in comp:
        for leaf in ("mask", "codebook", "codebook_k"):
            np.testing.assert_array_equal(np.asarray(c2[name][leaf]),
                                          np.asarray(comp[name][leaf]))


def test_accuracy_batched_matches_singles(trained_lenet):
    """The vmapped eval vector must agree with per-candidate evals."""
    runner, params, state, opt_state, comp, stats = trained_lenet
    comps = []
    for prune in (0.0, 0.5, 0.9):
        c = {nm: dict(cc) for nm, cc in comp.items()}
        w = runner.model.get_weight(params, "conv2")
        c["conv2"]["mask"] = qat.magnitude_prune_mask(w, prune)
        comps.append(c)
    stacked = qat.stack_pytrees(comps)
    params_s = qat.broadcast_pytree(params, 3)
    state_s = qat.broadcast_pytree(state, 3)
    accs = runner.accuracy_batched(params_s, state_s, stacked, n_batches=2)
    singles = [runner.accuracy(params, state, c, n_batches=2) for c in comps]
    # integer correct-counts: vmapped and single evals may flip an argmax on
    # a knife-edge sample, nothing more
    bound = 2.0 / (2 * runner.batch_size)
    np.testing.assert_allclose(accs, singles, atol=bound)
    comp_accs = runner.accuracy_comps(params, state, stacked, n_batches=2)
    np.testing.assert_allclose(comp_accs, singles, atol=bound)
    idx = np.asarray([2, 0, 1], np.int32)
    gathered = runner.accuracy_gather(
        params_s, state_s, jax.tree.map(lambda x: x[idx], stacked), idx,
        n_batches=2)
    np.testing.assert_allclose(gathered, [singles[2], singles[0], singles[1]],
                               atol=bound)


# --------------------------------------------------------------- sharded path


def test_multi_device_sharded_sweep_subprocess():
    """Force 4 host devices and check the shard_map candidate sweep (3
    candidates padded to 4) matches the single-device vmapped path."""
    code = textwrap.dedent("""
        import jax, numpy as np
        assert jax.device_count() == 4, jax.device_count()
        from repro.core import qat
        from repro.core.runner import CnnRunner
        from repro.data.synthetic import SyntheticImages
        from repro.distributed.sharding import sweep_mesh
        from repro.nn import cnn

        def build(mesh):
            return CnnRunner(cnn.lenet5(), SyntheticImages(seed=3),
                             batch_size=32, lr=2e-3, seed=0, sweep_mesh=mesh)

        runner = build(None)
        params, state, opt_state, comp = runner.init()
        comps = []
        for prune in (0.0, 0.5, 0.9):
            c = {nm: dict(cc) for nm, cc in comp.items()}
            w = runner.model.get_weight(params, "conv1")
            c["conv1"]["mask"] = qat.magnitude_prune_mask(w, prune)
            comps.append(c)
        stacked = qat.stack_pytrees(comps)
        ps, ss, os_ = (qat.broadcast_pytree(t, 3)
                       for t in (params, state, opt_state))

        p1, s1, o1, l1 = runner.train_batched(ps, ss, os_, stacked, 3)
        a1 = runner.accuracy_batched(p1, s1, stacked, n_batches=2)

        sharded = build(sweep_mesh())
        p2, s2, o2, l2 = sharded.train_batched(ps, ss, os_, stacked, 3)
        a2 = sharded.accuracy_batched(p2, s2, stacked, n_batches=2)

        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4)
        np.testing.assert_allclose(a1, a2, atol=2.0 / 64)
        for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5)
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
